"""Benchmark harness — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Multi-device benchmarks run in
subprocesses with placeholder host devices (the main process keeps 1 device).

  Table 2  -> bench_boxing_cost           (subprocess, 8 devices)
  Fig 6    -> bench_pipeline_registers    (in-process, simulator)
  §4.3     -> bench_actor_pipeline        (subprocess, 8 devices; also
              writes BENCH_actor_pipeline.json: serialized vs 1F1B makespan)
  §4.3/§6.5-> bench_1f1b_train            (subprocess, 8 devices; also
              writes BENCH_1f1b_train.json: serialized vs 1F1B *training*
              makespan + peak in-flight activations)
  §3.3+§4.3-> bench_1f1b_adamw            (subprocess, 8 devices; also
              writes BENCH_1f1b_adamw.json: stateful AdamW + cross-stage
              grad-clipping pipeline, serialized vs 1F1B)
  §6.4+Fig14-> bench_zero_adamw           (subprocess, 8 devices; also
              writes BENCH_zero_adamw.json: mixed-precision ZeRO stream at
              DP=2 vs dense bf16 AdamW — bitwise-gated, per-device
              optimizer-state bytes >= 1.8x down, step time within 1.15x)
  §4.3 serve-> bench_serve_pipeline       (subprocess; also writes
              BENCH_serve_pipeline.json: serialized single-request decode
              vs pipelined continuous batching, tok/s)
  §5 Fig 7/8-> bench_process_pipeline     (subprocess; also writes
              BENCH_process_pipeline.json: threaded vs process-backed
              runtime on the same train/serve pipelines, bitwise-gated)
  snapshots -> bench_snapshot_overhead    (subprocess; also writes
              BENCH_snapshot_overhead.json: async snap{s} actors on vs
              off, overhead gated at 1.1x, bitwise + roundtrip gated)
  paged    -> bench_paged_serve           (subprocess; also writes
              BENCH_paged_serve.json: dense per-slot cache vs paged pool
              on short-request serving — bitwise-gated, cache bytes
              >= 2x down, tok/s within 1.15x)
  §4 static-> bench_static_analysis       (subprocess; also writes
              BENCH_static_analysis.json: static verifier wall time on
              the deepseek-v3-671b proxy plan, gated < 5s, plus the
              per-compile re-check of a real 4-stage train session)

``--smoke`` runs only the BENCH_*.json-writing benchmarks, one repetition
each (BENCH_SMOKE=1), so CI keeps the recording code paths honest without
paying for full timing runs.
  Fig 9    -> bench_data_pipeline         (in-process, threads)
  Fig 10   -> bench_parallelisms dp8      (subprocess, 8 devices)
  Fig 11/12-> bench_model_parallel_softmax(subprocess, 8 devices)
  Fig 13   -> bench_embedding_mp          (subprocess, 8 devices)
  Fig 15   -> bench_parallelisms zero8    (subprocess, 8 devices)
  Fig 16   -> bench_parallelisms hybrid   (subprocess, 8 devices)
"""
import sys
import traceback


BENCH_WRITERS = ("bench_actor_pipeline", "bench_1f1b_train",
                 "bench_1f1b_adamw", "bench_zero_adamw",
                 "bench_serve_pipeline", "bench_process_pipeline",
                 "bench_snapshot_overhead", "bench_paged_serve",
                 "bench_static_analysis")


def main() -> None:
    smoke = "--smoke" in sys.argv[1:]
    print("name,us_per_call,derived")
    from benchmarks import bench_data_pipeline, bench_pipeline_registers
    from benchmarks._util import run_subprocess_bench

    failures = []

    def run(label, fn):
        try:
            fn()
        except Exception as e:
            failures.append((label, repr(e)))
            traceback.print_exc(file=sys.stderr)

    if smoke:
        for mod in BENCH_WRITERS:
            run(mod, lambda m=mod: run_subprocess_bench(
                m, devices=8, extra_env={"BENCH_SMOKE": "1"}))
    else:
        run("pipeline_registers", bench_pipeline_registers.main)
        run("data_pipeline", bench_data_pipeline.main)
        for mod in ("bench_boxing_cost", *BENCH_WRITERS,
                    "bench_model_parallel_softmax",
                    "bench_embedding_mp", "bench_parallelisms"):
            run(mod, lambda m=mod: run_subprocess_bench(m, devices=8))

    if failures:
        print(f"# {len(failures)} benchmark failures: {failures}",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
