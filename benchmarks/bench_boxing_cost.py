"""Table 2 validation: analytic boxing costs vs HLO-parsed wire bytes.

For every same-set SBP transition, build the boxing collective on an 8-way
axis, lower it, parse the emitted collective from the StableHLO, and compare
per-device wire bytes against the Table-2 prediction. derived column:
``predicted=<bytes>;parsed=<bytes>``.
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys


def main():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.compat import shard_map
    from repro.core.boxing import boxing_fn, transition_cost
    from repro.core.sbp import Sbp, ndsbp
    from repro.launch.dryrun import _HloTextParser, wire_bytes
    from benchmarks._util import emit, timeit

    mesh = jax.make_mesh((8,), ("x",))
    shape = (256, 512)
    T = 256 * 512 * 4

    cases = [("S(0)", "S(1)"), ("S(0)", "B"), ("B", "S(0)"),
             ("P", "S(0)"), ("P", "B"), ("S(1)", "S(0)")]
    for src, dst in cases:
        pred = transition_cost(Sbp.parse(src), Sbp.parse(dst), T, 8)
        fn = boxing_fn(ndsbp(src), ndsbp(dst), ("x",), (8,), shape)
        src_clean = "B" if src.startswith("P") else src
        dst_clean = "B" if dst.startswith("P") else dst

        def pspec(sig):
            nd = ndsbp(sig)
            comp = nd[0]
            if comp.is_split:
                return P(*(["x"] if comp.axis == 0 else [None, "x"]))
            return P()

        prog = jax.jit(shard_map(
            fn, mesh=mesh, in_specs=(pspec(src_clean),),
            out_specs=pspec(dst_clean), check=False))
        x = jnp.asarray(np.random.default_rng(0).normal(size=shape),
                        jnp.float32)
        lowered = prog.lower(x)
        parser = _HloTextParser(lowered.as_text())
        parsed = sum(wire_bytes(c) * c["trip"] for c in parser.collectives)
        us = timeit(prog, x, iters=5)
        emit(f"table2/{src}->{dst}", us,
             f"predicted={pred.volume:.0f};parsed={parsed:.0f};"
             f"prim={pred.primitive}")


if __name__ == "__main__":
    main()
