"""Fig 6 / §6.5: pipelining from register quotas (simulated makespan).

Sweeps the out-register quota of a 4-stage pipeline with 16 microbatches;
derived: makespan, bubble fraction, peak in-flight activations. GPipe-style
(quota=M) vs 1F1B (quota=S) shows the paper's memory/throughput trade."""
import sys


def main():
    sys.path.insert(0, "src")
    from benchmarks._util import emit
    from repro.runtime.pipeline import analyze, plan_registers

    S, M = 4, 16
    for quota in (1, 2, 4, 8, 16):
        p = analyze(S, M, regs=[quota] * S)
        emit(f"pipeline/regs={quota}", p.makespan * 1e6,
             f"bubble={p.bubble_fraction:.3f};"
             f"peak_act={max(p.peak_activation_regs.values())}")
    plan = plan_registers(S, M)
    emit("pipeline/auto_plan", plan.makespan * 1e6,
         f"regs={plan.regs[0]};bubble={plan.bubble_fraction:.3f};"
         f"peak_act={max(plan.peak_activation_regs.values())}")


if __name__ == "__main__":
    main()
