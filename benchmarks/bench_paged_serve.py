"""Paged KV/state cache vs the dense per-slot reservation, serving many
short requests.

The dense serve path (PR 5) reserves ``cache_len`` positions for every
decode slot, sized for the worst-case request — short requests strand most
of it. The paged path backs the same stage programs with a shared page
slab: each request maps only the pages its actual length needs, so the
pool can be sized for the *observed* in-flight load instead of the
worst case.

Both paths serve the identical request mix (10x the slot count, lengths
well under the worst case) through the same 2-stage actor pipeline with an
emulated per-stage device latency, and the paged token streams are gated
bitwise against dense. Gates: the dense cache reservation must be >= 2x
the paged pool bytes, and paged tok/s must stay within 1.15x of dense.

Writes ``BENCH_paged_serve.json``.
"""
import dataclasses
import json
import os
import pathlib
import sys
import time

STAGES = 2
DEVICE_LATENCY = 0.010      # emulated per-stage device time (seconds)
NUM_GROUPS = 2
GROUP_SIZE = 2              # 4 decode slots
MAX_PROMPT_LEN = 16
MAX_NEW_TOKENS = 16
CACHE_LEN = 36              # worst case 16 + 16 < 36, parking slot at 35
PAGE_LEN = 4
NUM_PAGES = 16              # 64 positions vs the dense 4 * 36 = 144


def main():
    sys.path.insert(0, "src")
    import numpy as np

    from benchmarks._util import emit
    from repro import api
    from repro.configs.registry import get_config
    from repro.models.model_zoo import build_model
    from repro.train.steps import plan_from_mesh

    import jax

    smoke = bool(os.environ.get("BENCH_SMOKE"))
    n_requests = 12 if smoke else 10 * NUM_GROUPS * GROUP_SIZE

    cfg = get_config("qwen2.5-3b").reduced()
    cfg = dataclasses.replace(cfg, vocab_size=1000)   # padded-vocab head
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = build_model(cfg, plan_from_mesh(mesh)).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # short requests: prompt + generation - 1 <= 16 positions (4 pages), so
    # four concurrent requests always fit the 16-page pool while the dense
    # path still reserves all 36 positions per slot
    requests = []
    for _ in range(n_requests):
        plen = int(rng.integers(4, 13))
        gen = int(rng.integers(2, 6))
        requests.append(
            (rng.integers(0, cfg.vocab_size, (plen,)).astype(np.int32), gen))
    total = sum(g for _, g in requests)

    def with_latency(stage_index, fn):
        def body(payload):
            out = fn(payload)
            time.sleep(DEVICE_LATENCY)
            return out
        return body

    common = dict(mode="serve", params=params, mesh=mesh,
                  num_groups=NUM_GROUPS, group_size=GROUP_SIZE,
                  max_prompt_len=MAX_PROMPT_LEN,
                  max_new_tokens=MAX_NEW_TOKENS, cache_len=CACHE_LEN)
    paged_kw = dict(cache="paged", page_len=PAGE_LEN, num_pages=NUM_PAGES)

    # token-identity reference: dense monolithic greedy
    ref = api.compile(cfg, backend="monolithic", **common).generate(requests)

    def measure(label, **kw):
        sess = api.compile(cfg, backend="actors", stages=STAGES,
                           fn_wrap=with_latency, **common, **kw)
        best, stats = None, None
        reps = 1 if smoke else 2
        for _ in range(reps + 1):     # first rep is the jit warmup
            outs = sess.generate(requests)
            assert all(np.array_equal(a, b) for a, b in zip(outs, ref)), label
            span = sess.last_stats["wall_s"]
            best = span if best is None else min(best, span)
            stats = sess.last_stats
        bytes_ = sess.cache_bytes()
        sess.close()
        return total / best, bytes_, stats

    dense_tok_s, dense_bytes, _ = measure("dense")
    paged_tok_s, paged_bytes, stats = measure("paged", **paged_kw)
    bytes_ratio = dense_bytes / paged_bytes
    slowdown = dense_tok_s / paged_tok_s

    emit("paged_serve/dense", 1e6 * total / dense_tok_s,
         f"tok_s={dense_tok_s:.1f};cache_bytes={dense_bytes}")
    emit("paged_serve/paged", 1e6 * total / paged_tok_s,
         f"tok_s={paged_tok_s:.1f};cache_bytes={paged_bytes};"
         f"bytes_ratio={bytes_ratio:.2f};peak_pages={stats['peak_pages']}")

    out = {
        "stages": STAGES, "requests": n_requests, "total_tokens": total,
        "device_latency_s": DEVICE_LATENCY, "cache_len": CACHE_LEN,
        "page_len": PAGE_LEN, "num_pages": NUM_PAGES,
        "dense_tok_s": dense_tok_s, "paged_tok_s": paged_tok_s,
        "dense_cache_bytes": dense_bytes, "paged_cache_bytes": paged_bytes,
        "cache_bytes_ratio": bytes_ratio,
        "peak_pages": stats["peak_pages"],
        "admitted_mid_flight": stats["admitted_mid_flight"],
    }
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_paged_serve.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    if bytes_ratio < 2.0:
        raise RuntimeError(
            f"paged pool saves only {bytes_ratio:.2f}x cache bytes "
            f"({dense_bytes} dense vs {paged_bytes} paged); gate is 2x")
    if slowdown > 1.15:
        raise RuntimeError(
            f"paged decode {paged_tok_s:.1f} tok/s is {slowdown:.2f}x "
            f"slower than dense {dense_tok_s:.1f} tok/s; gate is 1.15x")
    if stats["admitted_mid_flight"] < 1:
        raise RuntimeError("no request was admitted mid-flight")


if __name__ == "__main__":
    if "--smoke" in sys.argv[1:]:
        os.environ["BENCH_SMOKE"] = "1"
    main()
