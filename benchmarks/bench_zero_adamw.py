"""§6.4 + Fig 14: the mixed-precision ZeRO optimizer stream, DP=2.

Four pipeline stages on disjoint 2-device meshes, bf16 compute over fp32
masters (``precision="bf16"``, static power-of-two loss scale). Two
configurations of the same 1F1B AdamW pipeline are compared:

* **dense** — every device holds the full fp32 masters + Adam moments
  (replicated across the DP=2 group): 12 bytes per parameter element.
* **zero** — the opt actors hold flat ``(2, 1, chunk)`` fp32 master/moment
  shards (§6.4, ZeRO-DP from SBP) and emit gathered bf16 weights with the
  Fig-14 cast placed before the gather: 6 bytes per element per device.

Gates (all hard failures):

* bitwise identity: the zero pipeline's losses, params and merged moments
  equal the dense pipeline's over the gated steps (the flat shard is pure
  layout; AdamW is elementwise);
* memory: per-device optimizer-state bytes reduced by >= 1.8x;
* speed: the zero pipeline's best 1F1B step makespan within 1.15x of the
  dense pipeline's.

Writes ``BENCH_zero_adamw.json`` — see docs/benchmarks.md for the schema.
Set ``BENCH_SMOKE=1`` for a single repetition (CI); the gates still run.
"""
import json
import os
import pathlib
import sys
import time

STAGES = 4
MICROBATCHES = 8
BATCH = 64
WIDTH = 128
DP = 2
FWD_LATENCY = 0.02              # emulated per-stage device time (seconds)
BWD_LATENCY = 0.04
GRAD_CLIP = 1.0
LOSS_SCALE = 2.0 ** 12
BYTES_RATIO_GATE = 1.8
TIME_RATIO_GATE = 1.15


def lr_schedule(step: int) -> float:
    return 1e-3 * (0.9 ** step)


def main():
    sys.path.insert(0, "src")
    import numpy as np

    from benchmarks._util import emit
    from repro import api
    from repro.core.graph import LogicalGraph
    from repro.core.lowering import OptimizerSpec
    from repro.core.placement import Placement

    import jax

    smoke = bool(os.environ.get("BENCH_SMOKE"))
    reps = 1 if smoke else 3

    devs = jax.devices()
    if len(devs) < STAGES * DP:
        raise RuntimeError(f"need {STAGES * DP} devices, have {len(devs)}")

    placement = Placement(("data",), (DP,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (BATCH, WIDTH), sbp="S(0)")
    labels = g.input("labels", (BATCH,), dtype="int32", sbp="S(0)")
    for i in range(STAGES):
        w = g.input(f"w{i}", (WIDTH, WIDTH))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < STAGES - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")

    rng = np.random.default_rng(0)
    params = {f"w{i}": (rng.normal(size=(WIDTH, WIDTH)) * 0.5
                        ).astype(np.float32) for i in range(STAGES)}
    data = {"x": rng.normal(size=(BATCH, WIDTH)).astype(np.float32),
            "labels": rng.integers(0, WIDTH, size=(BATCH,)).astype(np.int32)}
    stage_meshes = [placement.to_mesh(devices=devs[DP * s:DP * s + DP])
                    for s in range(STAGES)]

    def compile_pipeline(zero, fn_wrap=None):
        return api.compile(
            g, mode="train", backend="actors", stages=STAGES,
            params=dict(params),
            optimizer=OptimizerSpec.adamw(lr=lr_schedule,
                                          grad_clip=GRAD_CLIP),
            num_microbatches=MICROBATCHES, stage_meshes=stage_meshes,
            zero=zero, precision="bf16", loss_scale=LOSS_SCALE,
            fn_wrap=fn_wrap)

    # -- correctness gate: zero vs dense, bitwise, plus byte accounting ------
    dense = compile_pipeline(zero=False)
    zero = compile_pipeline(zero=True)
    try:
        api.assert_sessions_match(zero, dense, data, steps=2)
        st = zero.opt_state
        assert int(st.step) == 2
        assert all(float(np.abs(np.asarray(st.mu[n])).sum()) > 0
                   for n in params)
        grad_norm = float(zero.executor.last_grad_norm)
        dense_bytes = sum(dense.executor.opt_state_bytes().values())
        zero_bytes = sum(zero.executor.opt_state_bytes().values())
    finally:
        dense.close()
        zero.close()
    bytes_ratio = dense_bytes / zero_bytes

    def with_latency(kind, stage_index, fn):
        delay = FWD_LATENCY if kind == "fwd" else BWD_LATENCY

        def body(*args):
            out = fn(*args)
            time.sleep(delay)
            return out
        return body

    def measure(zero_flag):
        sess = compile_pipeline(zero=zero_flag, fn_wrap=with_latency)
        try:
            best = None
            for _ in range(reps):
                sess.step(**data)
                span = sess.last_makespan
                best = span if best is None else min(best, span)
        finally:
            sess.close()
        return best

    dense_time = measure(False)
    zero_time = measure(True)
    time_ratio = zero_time / dense_time

    emit("zero_adamw/dense_bf16_1f1b", dense_time * 1e6,
         f"S={STAGES};M={MICROBATCHES};dp={DP};"
         f"opt_bytes_per_dev={dense_bytes}")
    emit("zero_adamw/zero_bf16_1f1b", zero_time * 1e6,
         f"S={STAGES};M={MICROBATCHES};dp={DP};"
         f"opt_bytes_per_dev={zero_bytes};bytes_ratio={bytes_ratio:.2f};"
         f"time_ratio={time_ratio:.3f};grad_norm={grad_norm:.1f}")

    out = {
        "stages": STAGES, "microbatches": MICROBATCHES, "dp": DP,
        "fwd_latency_s": FWD_LATENCY, "bwd_latency_s": BWD_LATENCY,
        "precision": "bf16", "loss_scale": LOSS_SCALE,
        "optimizer": "adamw", "grad_clip": GRAD_CLIP,
        "lr_schedule": "1e-3 * 0.9**step",
        "opt_state_bytes_per_device_dense": dense_bytes,
        "opt_state_bytes_per_device_zero": zero_bytes,
        "bytes_ratio": bytes_ratio,
        "dense_pipelined_s": dense_time,
        "zero_pipelined_s": zero_time,
        "time_ratio": time_ratio,
        "grad_norm_step1": grad_norm,
        "gates": {"bytes_ratio_min": BYTES_RATIO_GATE,
                  "time_ratio_max": TIME_RATIO_GATE,
                  "bitwise_vs_dense": True},
    }
    path = (pathlib.Path(__file__).resolve().parent.parent
            / "BENCH_zero_adamw.json")
    path.write_text(json.dumps(out, indent=2) + "\n")
    if bytes_ratio < BYTES_RATIO_GATE:
        raise RuntimeError(
            f"per-device optimizer-state bytes only {bytes_ratio:.2f}x "
            f"below dense (gate {BYTES_RATIO_GATE}x): "
            f"{dense_bytes} -> {zero_bytes}")
    if time_ratio > TIME_RATIO_GATE:
        raise RuntimeError(
            f"zero pipeline {time_ratio:.3f}x the dense step time "
            f"(gate {TIME_RATIO_GATE}x): {dense_time:.3f}s vs "
            f"{zero_time:.3f}s")


if __name__ == "__main__":
    main()
