"""Figs 10/12/15/16: data / model / ZeRO / hybrid parallel train steps.

Runs a reduced GPT-style model on an 8-device host mesh under four plans:
  dp8   : (8 data x 1 model), plain optimizer        (Fig 10)
  tp8   : (1 data x 8 model), tensor parallel        (Fig 12, InsightFace)
  zero8 : (8 data x 1 model), ZeRO master shards     (Fig 15)
  hyb   : (2 data x 4 model), ZeRO + tensor parallel (Fig 16)
derived: tokens/s and per-device param+optimizer bytes (the Fig 15 memory
comparison).
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys


def main():
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from benchmarks._util import emit, timeit
    from repro.configs.registry import ARCHITECTURES
    from repro.train.steps import make_train_step

    cfg = dataclasses.replace(
        ARCHITECTURES["qwen3-1.7b"].reduced(),
        num_layers=4, d_model=256, num_heads=8, num_kv_heads=8, d_ff=1024,
        vocab_size=2048)
    B, S = 8, 128
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(B, S + 1)), jnp.int32)}

    plans = [
        ("dp8", (8, 1), False), ("tp8", (1, 8), False),
        ("zero8", (8, 1), True), ("hybrid_2x4", (2, 4), True),
    ]
    for name, (d_, m_), zero in plans:
        mesh = jax.make_mesh((d_, m_), ("data", "model"))
        ts = make_train_step(cfg, mesh, zero=zero)
        params = ts.init_params(jax.random.PRNGKey(0))
        params = jax.tree.map(
            lambda p, s: jax.device_put(
                p, jax.sharding.NamedSharding(mesh, s)),
            params, ts.model_param_specs,
            is_leaf=lambda x: isinstance(x, (jnp.ndarray, np.ndarray)))
        if zero:
            params = ts.shard_params_fn(params)
        opt = ts.init_opt(params)

        def step(p, o):
            return ts.step_fn(p, o, batch)

        # run once for state, then time with fresh copies (donation!)
        def timed():
            p2 = jax.tree.map(jnp.copy, params)
            o2 = jax.tree.map(jnp.copy, opt)
            return ts.step_fn(p2, o2, batch)

        us = timeit(timed, iters=5, warmup=2)
        # per-device param + optimizer state bytes
        def bytes_per_dev(tree):
            total = 0
            for leaf in jax.tree.leaves(tree):
                if hasattr(leaf, "sharding"):
                    shard = leaf.sharding.shard_shape(leaf.shape)
                    total += int(np.prod(shard)) * leaf.dtype.itemsize
            return total

        mem = bytes_per_dev(params) + bytes_per_dev(opt)
        toks = B * S
        emit(f"parallelism/{name}", us,
             f"tok_s={toks/(us/1e6):.0f};state_bytes_per_dev={mem}")


if __name__ == "__main__":
    main()
