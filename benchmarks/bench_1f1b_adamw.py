"""§4.3/§6.5 + §3.3 end-to-end: stateful AdamW 1F1B training pipelines.

Same setup as ``bench_1f1b_train`` (S stages on disjoint single-device
meshes, emulated device latency, serialized R=1 vs 1F1B R[s]=S-s), but the
optimizer is the PR-3 subsystem: per-stage AdamW state actors (the second
register stream), a step-indexed lr schedule, and *global*-norm gradient
clipping through the cross-stage ``norm`` actor — the P→B boxing of the
per-stage squared-norm partials expressed on the actor protocol.

Correctness gate before timing: two steps of the pipelined executor against
the monolithic AdamW reference (loss, clipped grads, params, AdamWState and
the global norm), plus optimizer-state persistence (step counter advances,
moments nonzero) across every timed step.

Writes ``BENCH_1f1b_adamw.json`` — see docs/benchmarks.md for the schema.
Set ``BENCH_SMOKE=1`` to run a single repetition per quota (the CI smoke
job); the correctness assertions still run.
"""
import json
import os
import pathlib
import sys
import time

STAGES = 4
MICROBATCHES = 8
BATCH = 64
WIDTH = 128
FWD_LATENCY = 0.02              # emulated per-stage device time (seconds)
BWD_LATENCY = 0.04
GRAD_CLIP = 1.0


def lr_schedule(step: int) -> float:
    return 1e-3 * (0.9 ** step)


def main():
    sys.path.insert(0, "src")
    import numpy as np

    from benchmarks._util import emit
    from repro.core.graph import LogicalGraph, partition_stages
    from repro.core.lowering import OptimizerSpec, lower_train_stages
    from repro.core.placement import Placement
    from repro.core.planner import plan
    from repro.runtime import TrainPipelineExecutor
    from repro.train.steps import make_graph_train_step

    import jax

    smoke = bool(os.environ.get("BENCH_SMOKE"))
    reps = 1 if smoke else 3

    devs = jax.devices()
    if len(devs) < STAGES:
        raise RuntimeError(f"need {STAGES} devices, have {len(devs)}")

    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (BATCH, WIDTH))
    labels = g.input("labels", (BATCH,), dtype="int32")
    for i in range(STAGES):
        w = g.input(f"w{i}", (WIDTH, WIDTH))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < STAGES - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")

    opt = OptimizerSpec.adamw(lr=lr_schedule, grad_clip=GRAD_CLIP)
    p = plan(g)
    part = partition_stages(g, num_stages=STAGES)
    stage_meshes = [placement.to_mesh(devices=[devs[s]])
                    for s in range(STAGES)]
    tstaged = lower_train_stages(g, p, part,
                                 [f"w{i}" for i in range(STAGES)],
                                 stage_meshes=stage_meshes, optimizer=opt)

    rng = np.random.default_rng(0)
    params = {f"w{i}": (rng.normal(size=(WIDTH, WIDTH)) * 0.5
                        ).astype(np.float32) for i in range(STAGES)}
    data = {"x": rng.normal(size=(BATCH, WIDTH)).astype(np.float32),
            "labels": rng.integers(0, WIDTH, size=(BATCH,)).astype(np.int32)}

    # -- correctness gate: lockstep vs the monolithic AdamW reference --------
    mono = make_graph_train_step(g, placement.to_mesh(devices=[devs[0]]),
                                 list(params), ["x", "labels"], MICROBATCHES,
                                 optimizer=opt)
    check = TrainPipelineExecutor(tstaged, dict(params), ["x", "labels"],
                                  MICROBATCHES)
    mono_params = dict(params)
    for step in range(2):
        ml, mg, mono_params = mono.step(mono_params, data)
        pl, pg, pp = check.step(data)
        assert np.allclose(float(pl), float(ml), rtol=1e-4), step
        assert float(check.last_grad_norm) > GRAD_CLIP  # clipping engaged
        assert np.allclose(float(check.last_grad_norm),
                           float(mono.last_grad_norm), rtol=1e-5)
        for n in params:
            assert np.allclose(np.asarray(pg[n]), np.asarray(mg[n]),
                               rtol=1e-3, atol=1e-6), n
            assert np.allclose(np.asarray(pp[n]), np.asarray(mono_params[n]),
                               rtol=1e-3, atol=1e-6), n
    grad_norm = float(check.last_grad_norm)

    def with_latency(kind, stage_index, fn):
        delay = FWD_LATENCY if kind == "fwd" else BWD_LATENCY

        def body(*args):
            out = fn(*args)
            time.sleep(delay)
            return out
        return body

    def measure(regs, label):
        ex = TrainPipelineExecutor(tstaged, dict(params), ["x", "labels"],
                                   MICROBATCHES, regs=regs,
                                   fn_wrap=with_latency)
        best, peak = None, 0
        for it in range(reps):
            ex.step(data)
            # state persistence across the timed steps, not just correctness
            st = ex.opt_state
            assert int(st.step) == it + 1, label
            assert all(float(np.abs(np.asarray(st.mu[n])).sum()) > 0
                       for n in params), label
            span = ex.last_makespan
            best = span if best is None else min(best, span)
            peak = max(peak, ex.peak_inflight_activations)
        return best, peak

    serialized, peak_ser = measure([1] * STAGES, "serialized")
    quota = [max(1, STAGES - s) for s in range(STAGES)]
    pipelined, peak_1f1b = measure(quota, "1f1b")
    speedup = serialized / pipelined

    emit("1f1b_adamw/serialized_r1", serialized * 1e6,
         f"S={STAGES};M={MICROBATCHES};peak_inflight={peak_ser}")
    emit("1f1b_adamw/pipelined_1f1b", pipelined * 1e6,
         f"S={STAGES};M={MICROBATCHES};peak_inflight={peak_1f1b};"
         f"speedup={speedup:.2f};grad_norm={grad_norm:.1f}")

    out = {
        "stages": STAGES, "microbatches": MICROBATCHES,
        "fwd_latency_s": FWD_LATENCY, "bwd_latency_s": BWD_LATENCY,
        "serialized_s": serialized, "pipelined_s": pipelined,
        "speedup": speedup,
        "quota_1f1b": quota,
        "peak_inflight_serialized": peak_ser,
        "peak_inflight_1f1b": peak_1f1b,
        "optimizer": "adamw", "grad_clip": GRAD_CLIP,
        "lr_schedule": "1e-3 * 0.9**step",
        "grad_norm_step1": grad_norm,
    }
    path = (pathlib.Path(__file__).resolve().parent.parent
            / "BENCH_1f1b_adamw.json")
    path.write_text(json.dumps(out, indent=2) + "\n")
    if pipelined >= serialized:
        raise RuntimeError(
            f"pipelined AdamW makespan {pipelined:.3f}s not below "
            f"serialized {serialized:.3f}s")
    if peak_1f1b > max(quota):
        raise RuntimeError(
            f"peak in-flight {peak_1f1b} exceeds 1F1B quota {max(quota)}")


if __name__ == "__main__":
    main()
