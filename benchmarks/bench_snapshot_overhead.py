"""Async snapshot actors: checkpointing must stay off the hot path.

The ``snap{s}`` actors subscribe to the optimizer actors' output registers
and serialize each stage's post-update params + AdamW moments from their
own mailbox thread, so the 1F1B schedule never waits on disk. This bench
runs the same emulated-latency 4-stage AdamW pipeline as
``bench_1f1b_adamw`` with snapshots off vs snapshots every step and gates
the makespan ratio at 1.1x — checkpointing costs at most 10% of a step.

Correctness gate before timing: both executors' losses are bitwise equal,
and the final snapshot on disk round-trips bitwise to the live params and
optimizer moments.

Writes ``BENCH_snapshot_overhead.json``. Set ``BENCH_SMOKE=1`` for one
repetition per variant (the CI smoke job); the gates still run.
"""
import json
import os
import pathlib
import sys
import tempfile
import time

STAGES = 4
MICROBATCHES = 8
BATCH = 64
WIDTH = 128
FWD_LATENCY = 0.02              # emulated per-stage device time (seconds)
BWD_LATENCY = 0.04
GRAD_CLIP = 1.0
MAX_OVERHEAD = 1.10             # snapshotting may cost <= 10% of a step


def lr_schedule(step: int) -> float:
    return 1e-3 * (0.9 ** step)


def main():
    sys.path.insert(0, "src")
    import numpy as np

    from benchmarks._util import emit
    from repro.core.graph import LogicalGraph, partition_stages
    from repro.core.lowering import OptimizerSpec, lower_train_stages
    from repro.core.placement import Placement
    from repro.core.planner import plan
    from repro.runtime import TrainPipelineExecutor
    from repro.runtime.snapshot import latest_snapshot, load_snapshot

    import jax

    smoke = bool(os.environ.get("BENCH_SMOKE"))
    reps = 1 if smoke else 3

    devs = jax.devices()
    if len(devs) < STAGES:
        raise RuntimeError(f"need {STAGES} devices, have {len(devs)}")

    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (BATCH, WIDTH))
    labels = g.input("labels", (BATCH,), dtype="int32")
    for i in range(STAGES):
        w = g.input(f"w{i}", (WIDTH, WIDTH))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < STAGES - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")

    opt = OptimizerSpec.adamw(lr=lr_schedule, grad_clip=GRAD_CLIP)
    p = plan(g)
    part = partition_stages(g, num_stages=STAGES)
    stage_meshes = [placement.to_mesh(devices=[devs[s]])
                    for s in range(STAGES)]
    tstaged = lower_train_stages(g, p, part,
                                 [f"w{i}" for i in range(STAGES)],
                                 stage_meshes=stage_meshes, optimizer=opt)

    rng = np.random.default_rng(0)
    params = {f"w{i}": (rng.normal(size=(WIDTH, WIDTH)) * 0.5
                        ).astype(np.float32) for i in range(STAGES)}
    data = {"x": rng.normal(size=(BATCH, WIDTH)).astype(np.float32),
            "labels": rng.integers(0, WIDTH, size=(BATCH,)).astype(np.int32)}

    def with_latency(kind, stage_index, fn):
        delay = FWD_LATENCY if kind == "fwd" else BWD_LATENCY

        def body(*args):
            out = fn(*args)
            time.sleep(delay)
            return out
        return body

    quota = [max(1, STAGES - s) for s in range(STAGES)]

    def measure(snapshot_dir):
        ex = TrainPipelineExecutor(tstaged, dict(params), ["x", "labels"],
                                   MICROBATCHES, regs=quota,
                                   fn_wrap=with_latency,
                                   snapshot_dir=snapshot_dir)
        best, losses = None, []
        for _ in range(reps):
            loss, _, _ = ex.step(data)
            losses.append(float(loss))
            span = ex.last_makespan
            best = span if best is None else min(best, span)
        return best, losses, ex

    with tempfile.TemporaryDirectory() as d:
        base_best, base_losses, _ = measure(None)
        snap_best, snap_losses, ex = measure(d)

        # -- correctness gates ---------------------------------------------
        if snap_losses != base_losses:
            raise RuntimeError(
                f"snapshotting changed training bits: {snap_losses} vs "
                f"{base_losses}")
        if latest_snapshot(d) != reps:
            raise RuntimeError(
                f"expected {reps} completed snapshots, found "
                f"{latest_snapshot(d)}")
        got_params, got_opt, step, _ = load_snapshot(d)
        assert step == reps
        live_opt = ex.opt_state
        for n, v in ex.params.items():
            if not np.array_equal(np.asarray(got_params[n]), np.asarray(v)):
                raise RuntimeError(f"snapshot param {n} != live param")
            if not np.array_equal(np.asarray(got_opt.mu[n]),
                                  np.asarray(live_opt.mu[n])):
                raise RuntimeError(f"snapshot moment {n} != live moment")

    ratio = snap_best / base_best
    emit("snapshot_overhead/no_snapshot", base_best * 1e6,
         f"S={STAGES};M={MICROBATCHES}")
    emit("snapshot_overhead/snapshot_every_step", snap_best * 1e6,
         f"S={STAGES};M={MICROBATCHES};ratio={ratio:.3f}")

    out = {
        "stages": STAGES, "microbatches": MICROBATCHES,
        "fwd_latency_s": FWD_LATENCY, "bwd_latency_s": BWD_LATENCY,
        "no_snapshot_s": base_best, "snapshot_every_step_s": snap_best,
        "overhead_ratio": ratio, "max_overhead_ratio": MAX_OVERHEAD,
        "quota_1f1b": quota,
        "optimizer": "adamw", "grad_clip": GRAD_CLIP,
    }
    path = (pathlib.Path(__file__).resolve().parent.parent
            / "BENCH_snapshot_overhead.json")
    path.write_text(json.dumps(out, indent=2) + "\n")
    if ratio > MAX_OVERHEAD:
        raise RuntimeError(
            f"snapshot overhead {ratio:.3f}x exceeds the "
            f"{MAX_OVERHEAD}x budget "
            f"({snap_best:.3f}s vs {base_best:.3f}s)")


if __name__ == "__main__":
    main()
