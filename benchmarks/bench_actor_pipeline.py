"""§4.3 end-to-end: actor-driven pipeline execution of lowered stages.

The compiler cuts an MLP into S stages, lowers each onto its *own* device
(disjoint single-device meshes — the paper's one-stage-per-accelerator
placement), and the threaded actor runtime streams M microbatches through the
stage actors. The only knob compared is the out-register quota:

* ``regs = [1] * S``          -> serialized: a stage cannot start microbatch
  k+1 until its consumer finished microbatch k (ack-after-use);
* ``regs = 1F1B (S - s)``     -> pipelined: quotas admit S in-flight
  microbatches and the overlap emerges from the protocol alone.

Host CPU cores cannot stand in for S busy accelerators, so each stage body
adds a fixed ``DEVICE_LATENCY`` sleep emulating the device-side execution the
host thread would block on — the jitted stage computation itself is real and
its results are checked against the monolithic program.

Writes ``BENCH_actor_pipeline.json`` (serialized vs pipelined makespan) so
the perf trajectory is recorded across PRs.
"""
import json
import os
import pathlib
import sys
import time

STAGES = 4
MICROBATCHES = 8
DEVICE_LATENCY = 0.025          # emulated per-stage device time (seconds)


def main():
    sys.path.insert(0, "src")
    import numpy as np

    from benchmarks._util import emit
    from repro.core.graph import LogicalGraph, partition_stages
    from repro.core.lowering import lower_plan, lower_stages
    from repro.core.placement import Placement
    from repro.core.planner import plan
    from repro.runtime import ActorPipelineExecutor

    import jax

    devs = jax.devices()
    if len(devs) < STAGES:
        raise RuntimeError(f"need {STAGES} devices, have {len(devs)}")

    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (64, 128))
    for i in range(STAGES):
        w = g.input(f"w{i}", (128, 128))
        h = g.matmul(h, w, name=f"mm{i}")
        h = g.unary(h, "relu", name=f"relu{i}")
    p = plan(g)
    part = partition_stages(g, num_stages=STAGES)
    stage_meshes = [placement.to_mesh(devices=[devs[s]]) for s in range(STAGES)]
    staged = lower_stages(g, p, part, stage_meshes=stage_meshes)
    mono = lower_plan(g, p, placement.to_mesh(devices=[devs[0]]))

    rng = np.random.default_rng(0)
    inputs = {t.name: rng.normal(size=t.shape).astype(np.float32)
              for t in g.inputs}
    ref = np.asarray(mono(*(inputs[t.name] for t in g.inputs))[0])

    def with_latency(stage_index, fn):
        def body(payload):
            out = fn(payload)
            time.sleep(DEVICE_LATENCY)
            return out
        return body

    def measure(regs, label):
        ex = ActorPipelineExecutor(staged, ["x"], MICROBATCHES, regs=regs,
                                   fn_wrap=with_latency)
        best = None
        reps = 1 if os.environ.get("BENCH_SMOKE") else 3
        for _ in range(reps):        # warmup included: jit compiles on run 1
            got = ex.run(inputs)
            assert np.allclose(got[0], ref, rtol=1e-4, atol=1e-4), label
            span = ex.last_makespan
            best = span if best is None else min(best, span)
        return best

    serialized = measure([1] * STAGES, "serialized")
    pipelined = measure([max(1, STAGES - s) for s in range(STAGES)], "1f1b")
    speedup = serialized / pipelined

    emit(f"actor_pipeline/serialized_r1", serialized * 1e6,
         f"S={STAGES};M={MICROBATCHES}")
    emit(f"actor_pipeline/pipelined_1f1b", pipelined * 1e6,
         f"S={STAGES};M={MICROBATCHES};speedup={speedup:.2f}")

    out = {
        "stages": STAGES, "microbatches": MICROBATCHES,
        "device_latency_s": DEVICE_LATENCY,
        "serialized_s": serialized, "pipelined_s": pipelined,
        "speedup": speedup,
    }
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_actor_pipeline.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    if pipelined >= serialized:
        raise RuntimeError(
            f"pipelined makespan {pipelined:.3f}s not below serialized "
            f"{serialized:.3f}s")


if __name__ == "__main__":
    main()
