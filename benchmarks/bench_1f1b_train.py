"""§4.3/§6.5 end-to-end: 1F1B *training* from register quotas alone.

The compiler cuts an MLP+softmax-xent training graph into S stages, lowers
forward/backward/optimizer programs per stage onto one device each (disjoint
single-device meshes — the paper's MPMD placement), and the threaded actor
runtime streams M microbatches through fwd and bwd stage actors. As in
``bench_actor_pipeline``, the only knob compared is the forward out-register
quota:

* ``regs = [1] * S``      -> serialized: one microbatch in flight;
* ``regs = 1F1B (S - s)`` -> pipelined: up to S-s in-flight activations per
  stage, the 1F1B steady state, from back-pressure alone.

Host CPU cores cannot stand in for S busy accelerators, so each stage body
adds a fixed sleep emulating device time (backward 2x forward, the usual
cost ratio); the jitted fwd/bwd computations are real and the resulting
gradients are checked against the monolithic whole-graph program.

Writes ``BENCH_1f1b_train.json`` (serialized vs 1F1B training makespan plus
peak in-flight activation counts) so the perf trajectory is recorded across
PRs — see docs/benchmarks.md for the schema.
"""
import json
import os
import pathlib
import sys
import time

STAGES = 4
MICROBATCHES = 8
BATCH = 64
WIDTH = 128
FWD_LATENCY = 0.02              # emulated per-stage device time (seconds)
BWD_LATENCY = 0.04


def main():
    sys.path.insert(0, "src")
    import numpy as np

    from benchmarks._util import emit
    from repro.core.graph import LogicalGraph, partition_stages
    from repro.core.lowering import lower_train_stages
    from repro.core.placement import Placement
    from repro.core.planner import plan
    from repro.runtime import TrainPipelineExecutor
    from repro.train.steps import make_graph_train_step

    import jax

    devs = jax.devices()
    if len(devs) < STAGES:
        raise RuntimeError(f"need {STAGES} devices, have {len(devs)}")

    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (BATCH, WIDTH))
    labels = g.input("labels", (BATCH,), dtype="int32")
    for i in range(STAGES):
        w = g.input(f"w{i}", (WIDTH, WIDTH))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < STAGES - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")

    p = plan(g)
    part = partition_stages(g, num_stages=STAGES)
    stage_meshes = [placement.to_mesh(devices=[devs[s]]) for s in range(STAGES)]
    tstaged = lower_train_stages(g, p, part, [f"w{i}" for i in range(STAGES)],
                                 stage_meshes=stage_meshes)

    rng = np.random.default_rng(0)
    params = {f"w{i}": (rng.normal(size=(WIDTH, WIDTH)) * 0.1).astype(np.float32)
              for i in range(STAGES)}
    data = {"x": rng.normal(size=(BATCH, WIDTH)).astype(np.float32),
            "labels": rng.integers(0, WIDTH, size=(BATCH,)).astype(np.int32)}

    mono = make_graph_train_step(g, placement.to_mesh(devices=[devs[0]]),
                                 list(params), ["x", "labels"], MICROBATCHES)
    ref_loss, ref_grads, _ = mono.step(dict(params), data)

    def with_latency(kind, stage_index, fn):
        delay = FWD_LATENCY if kind == "fwd" else BWD_LATENCY

        def body(*args):
            out = fn(*args)
            time.sleep(delay)
            return out
        return body

    def measure(regs, label):
        best, peak = None, 0
        reps = 1 if os.environ.get("BENCH_SMOKE") else 3
        for _ in range(reps):        # warmup included: jit compiles on run 1
            ex = TrainPipelineExecutor(tstaged, dict(params), ["x", "labels"],
                                       MICROBATCHES, regs=regs,
                                       fn_wrap=with_latency)
            loss, grads, _ = ex.step(data)
            assert np.allclose(float(loss), float(ref_loss), rtol=1e-4), label
            for n in params:
                assert np.allclose(np.asarray(grads[n]),
                                   np.asarray(ref_grads[n]),
                                   rtol=1e-3, atol=1e-4), (label, n)
            span = ex.last_makespan
            best = span if best is None else min(best, span)
            peak = max(peak, ex.peak_inflight_activations)
        return best, peak

    serialized, peak_ser = measure([1] * STAGES, "serialized")
    quota = [max(1, STAGES - s) for s in range(STAGES)]
    pipelined, peak_1f1b = measure(quota, "1f1b")
    speedup = serialized / pipelined

    emit("1f1b_train/serialized_r1", serialized * 1e6,
         f"S={STAGES};M={MICROBATCHES};peak_inflight={peak_ser}")
    emit("1f1b_train/pipelined_1f1b", pipelined * 1e6,
         f"S={STAGES};M={MICROBATCHES};peak_inflight={peak_1f1b};"
         f"speedup={speedup:.2f}")

    out = {
        "stages": STAGES, "microbatches": MICROBATCHES,
        "fwd_latency_s": FWD_LATENCY, "bwd_latency_s": BWD_LATENCY,
        "serialized_s": serialized, "pipelined_s": pipelined,
        "speedup": speedup,
        "quota_1f1b": quota,
        "peak_inflight_serialized": peak_ser,
        "peak_inflight_1f1b": peak_1f1b,
    }
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_1f1b_train.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    if pipelined >= serialized:
        raise RuntimeError(
            f"pipelined training makespan {pipelined:.3f}s not below "
            f"serialized {serialized:.3f}s")
    if peak_1f1b > max(quota):
        raise RuntimeError(
            f"peak in-flight {peak_1f1b} exceeds 1F1B quota {max(quota)}")


if __name__ == "__main__":
    main()
