"""Fig 11/12 (InsightFace): hierarchical sharded-vocab softmax-xent vs the
naive all-gather-logits implementation, on an 8-way model axis.

derived: parsed collective wire bytes per device for each plan — the
hierarchical (local-reduce) version moves O(rows) stats instead of the
O(rows x vocab) logits."""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys


def main():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from benchmarks._util import emit, timeit
    from repro.compat import shard_map
    from repro.kernels.softmax_xent.ref import combine_stats, local_stats_ref
    from repro.launch.dryrun import _HloTextParser, wire_bytes

    mesh = jax.make_mesh((8,), ("model",))
    N, V = 2048, 8192
    Vl = V // 8
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(N, V)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, size=(N,)), jnp.int32)

    def hierarchical(lg, lb):
        off = jax.lax.axis_index("model") * Vl
        m, s, z = local_stats_ref(lg, lb, off)
        tok = combine_stats(m, s, z, axis_name="model")
        return jax.lax.pmean(tok.mean(), "model")

    def allgather(lg, lb):
        full = jax.lax.all_gather(lg, "model", axis=1, tiled=True)
        m, s, z = local_stats_ref(full, lb, 0)
        tok = jnp.log(s) + m - z
        return jax.lax.pmean(tok.mean(), "model")

    for name, fn in (("hierarchical", hierarchical), ("allgather", allgather)):
        prog = jax.jit(shard_map(
            fn, mesh=mesh, in_specs=(P(None, "model"), P()),
            out_specs=P(), check=False))
        lowered = prog.lower(logits, labels)
        parsed = sum(wire_bytes(c) * c["trip"]
                     for c in _HloTextParser(lowered.as_text()).collectives)
        us = timeit(prog, logits, labels, iters=5)
        emit(f"mp_softmax/{name}", us, f"wire_bytes_per_dev={parsed:.0f}")


if __name__ == "__main__":
    main()
