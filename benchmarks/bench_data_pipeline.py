"""Fig 9: data-pipeline overlap — actor-runtime prefetch vs synchronous.

A consumer with fixed per-batch compute iterates both pipelines; the actor
version (2 out-registers per stage, paper §4.3) should approach the
synthetic-data bound. derived: tokens/s and the bound."""
import sys
import time


def main():
    import numpy as np

    sys.path.insert(0, "src")
    from benchmarks._util import emit
    from repro.data.pipeline import ActorDataPipeline, SyncDataPipeline

    vocab, batch, seq, n = 1024, 8, 512, 30
    compute_s = 0.01             # simulated train-step time

    def consume(pipe):
        t0 = time.perf_counter()
        for tokens in pipe:
            # "training step": fixed compute + a touch of the data
            assert tokens.shape == (batch, seq + 1)
            time.sleep(compute_s)
            _ = tokens.sum()
        return time.perf_counter() - t0

    def loader(i, _rng=np.random.default_rng(0)):
        # real loading cost: zipf sampling is deliberately expensive
        z = _rng.zipf(1.3, size=(batch, seq + 1))
        return (z % vocab).astype(np.int32)

    sync_t = consume(SyncDataPipeline(loader, n))
    actor_t = consume(ActorDataPipeline(loader, n, buffers=2))
    bound_t = n * compute_s     # synthetic-data case: compute only

    toks = n * batch * seq
    emit("data_pipeline/sync", sync_t / n * 1e6,
         f"tok_s={toks/sync_t:.0f}")
    emit("data_pipeline/actor_prefetch", actor_t / n * 1e6,
         f"tok_s={toks/actor_t:.0f};overlap_eff="
         f"{min(1.0, bound_t/actor_t):.2f}")
    emit("data_pipeline/synthetic_bound", bound_t / n * 1e6,
         f"tok_s={toks/bound_t:.0f}")


if __name__ == "__main__":
    main()
