"""Process-backed actor runtime vs the threaded runtime, same pipelines.

Two workloads, both compiled twice through the public API with only the
``runtime=`` option changed:

* train: a 4-stage 1F1B AdamW pipeline (global-norm clipping) stepped in
  lockstep — ``runtime="processes"`` puts each stage's actors in their own
  OS worker process, with activations/cotangents crossing real process
  boundaries as host arrays;
* serve: 2-stage continuous batching (2 groups x 2 slots, 8 requests of
  unequal length) — prefill/decode rounds drive the same worker pool.

Both are correctness-gated before timing: train sessions must be *bitwise*
equal to a fresh monolithic reference (loss, post-clip grads, params, opt
state — ``api.assert_sessions_match``), serve token streams must be
identical to the monolithic engine token for token.

The interesting number is the transport overhead: the process runtime pays
pickling + pipes + host round-trips for every cross-node edge (per-step
bytes recorded from ``last_edge_bytes``), where the threaded runtime passes
device arrays by reference. Writes ``BENCH_process_pipeline.json`` so the
overhead trajectory is recorded across PRs.
"""
import json
import os
import pathlib
import sys
import time

STAGES = 4
BATCH, WIDTH, MICROBATCHES = 16, 32, 4
SERVE_STAGES = 2
PROMPT_LEN = 8
GENS = [6, 3, 5, 4, 6, 2, 4, 6]


def _train_graph():
    from repro.core.graph import LogicalGraph
    from repro.core.placement import Placement

    g = LogicalGraph(Placement(("d",), (1,), device_kind="cpu"))
    h = g.input("x", (BATCH, WIDTH))
    labels = g.input("labels", (BATCH,), dtype="int32")
    for i in range(STAGES):
        w = g.input(f"w{i}", (WIDTH, WIDTH))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < STAGES - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")
    return g


def main():
    sys.path.insert(0, "src")
    import dataclasses

    import numpy as np

    from benchmarks._util import emit
    from repro import api
    from repro.core.lowering import OptimizerSpec

    smoke = bool(os.environ.get("BENCH_SMOKE"))
    reps = 2 if smoke else 6

    # ---- train: 4-stage 1F1B AdamW, threads vs processes -------------------
    rng = np.random.default_rng(0)
    params = {f"w{i}": (rng.normal(size=(WIDTH, WIDTH)) * 0.5
                        ).astype(np.float32) for i in range(STAGES)}
    data = {"x": rng.normal(size=(BATCH, WIDTH)).astype(np.float32),
            "labels": rng.integers(0, WIDTH, (BATCH,)).astype(np.int32)}
    opt = OptimizerSpec.adamw(lr=1e-2, grad_clip=0.5)
    kw = dict(mode="train", stages=STAGES, num_microbatches=MICROBATCHES,
              optimizer=opt)

    def mono():
        return api.compile(_train_graph(), backend="monolithic",
                           params=dict(params), optimizer=opt, mode="train",
                           num_microbatches=MICROBATCHES)

    results = {}
    edge_bytes = {}
    for runtime in ("threads", "processes"):
        sess = api.compile(_train_graph(), runtime=runtime,
                           params=dict(params), **kw)
        # correctness gate: bitwise vs a fresh monolithic reference
        api.assert_sessions_match(sess, mono(), data, steps=2)
        spans = []
        for _ in range(reps):
            t0 = time.perf_counter()
            sess.step(**data)
            spans.append(time.perf_counter() - t0)
        spans.sort()
        results[runtime] = spans[len(spans) // 2]
        edge_bytes[runtime] = dict(sess.executor.last_edge_bytes)
        sess.close()

    overhead = results["processes"] / results["threads"]
    step_bytes = sum(edge_bytes["processes"].values())
    for runtime in ("threads", "processes"):
        emit(f"process_pipeline/train_{runtime}",
             1e6 * results[runtime],
             f"steps_per_s={1.0 / results[runtime]:.2f}")
    emit("process_pipeline/train_overhead", 1e6 * (
        results["processes"] - results["threads"]),
        f"x{overhead:.2f};edge_bytes_per_step={step_bytes}")

    # ---- serve: 2-stage continuous batching, threads vs processes ----------
    from repro.configs.registry import get_config

    cfg = get_config("qwen2.5-3b").reduced()
    cfg = dataclasses.replace(cfg, vocab_size=1000)
    srng = np.random.default_rng(1)
    requests = [
        (srng.integers(0, cfg.vocab_size, (PROMPT_LEN,)).astype(np.int32), g)
        for g in GENS]
    total = sum(GENS)
    serve_kw = dict(mode="serve", num_groups=2, group_size=2,
                    max_prompt_len=PROMPT_LEN, max_new_tokens=max(GENS))
    ref = api.compile(cfg, backend="monolithic", **serve_kw
                      ).generate(requests)

    tok_s = {}
    for runtime in ("threads", "processes"):
        sess = api.compile(cfg, runtime=runtime, stages=SERVE_STAGES,
                           **serve_kw)
        best = None
        for _ in range(reps + 1):      # first rep is the jit warmup
            outs = sess.generate(requests)
            # correctness gate: token-identical to the monolithic engine
            assert all(np.array_equal(a, b)
                       for a, b in zip(outs, ref)), runtime
            span = sess.last_stats["wall_s"]
            best = span if best is None else min(best, span)
        tok_s[runtime] = total / best
        sess.close()
        emit(f"process_pipeline/serve_{runtime}", 1e6 * total / tok_s[runtime],
             f"tok_s={tok_s[runtime]:.1f}")

    out = {
        "train": {
            "stages": STAGES, "microbatches": MICROBATCHES,
            "threads_step_s": results["threads"],
            "processes_step_s": results["processes"],
            "overhead_x": overhead,
            "edge_bytes_per_step": step_bytes,
            "edges": {f"{a}->{b}": v
                      for (a, b), v in sorted(edge_bytes["processes"].items())},
        },
        "serve": {
            "stages": SERVE_STAGES, "requests": len(GENS),
            "total_tokens": total,
            "threads_tok_s": tok_s["threads"],
            "processes_tok_s": tok_s["processes"],
        },
    }
    path = pathlib.Path(__file__).resolve().parent.parent / \
        "BENCH_process_pipeline.json"
    path.write_text(json.dumps(out, indent=2) + "\n")


if __name__ == "__main__":
    main()
