"""Continuous-batching serve pipeline vs serialized single-request decode.

Eight requests with unequal generation lengths are served two ways through
the SAME 2-stage actor pipeline (repro.api mode="serve"):

* serialized: 1 group x 1 slot — one request decodes at a time, one token
  per round, no admission overlap (the classic request-at-a-time server);
* continuous batching: 2 groups x 2 slots — every round advances 4 requests
  by a token, groups overlap across the stage actors under the forward
  register quotas, and retired slots are refilled from the queue mid-flight.

Host CPU cores cannot stand in for busy accelerators, so each stage body
adds a fixed DEVICE_LATENCY sleep emulating the device-side decode step the
host thread would block on — the jitted stage computation itself is real,
and the continuous-batching token streams are gated against the monolithic
whole-stack engine, token for token.

Writes ``BENCH_serve_pipeline.json`` (tok/s both ways + speedup) so the
serving-throughput trajectory is recorded across PRs.
"""
import dataclasses
import json
import os
import pathlib
import sys
import time

STAGES = 2
PROMPT_LEN = 8
GENS = [6, 3, 5, 4, 6, 2, 4, 6]     # 8 requests, 36 tokens, unequal lengths
DEVICE_LATENCY = 0.010              # emulated per-stage device time (seconds)


def main():
    sys.path.insert(0, "src")
    import numpy as np

    from benchmarks._util import emit
    from repro import api
    from repro.configs.registry import get_config
    from repro.models.model_zoo import build_model
    from repro.train.steps import plan_from_mesh

    import jax

    cfg = get_config("qwen2.5-3b").reduced()
    cfg = dataclasses.replace(cfg, vocab_size=1000)   # padded-vocab head
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = build_model(cfg, plan_from_mesh(mesh)).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    requests = [
        (rng.integers(0, cfg.vocab_size, (PROMPT_LEN,)).astype(np.int32), g)
        for g in GENS]
    total = sum(GENS)

    def with_latency(stage_index, fn):
        def body(payload):
            out = fn(payload)
            time.sleep(DEVICE_LATENCY)
            return out
        return body

    common = dict(mode="serve", params=params, mesh=mesh,
                  max_prompt_len=PROMPT_LEN, max_new_tokens=max(GENS))

    # token-identity reference: the monolithic whole-stack engine
    ref = api.compile(cfg, backend="monolithic", num_groups=2, group_size=2,
                      **common).generate(requests)

    def measure(label, **kw):
        sess = api.compile(cfg, backend="actors", stages=STAGES,
                           fn_wrap=with_latency, **common, **kw)
        best = None
        reps = 1 if os.environ.get("BENCH_SMOKE") else 2
        for _ in range(reps + 1):     # first rep is the jit warmup
            outs = sess.generate(requests)
            assert all(np.array_equal(a, b) for a, b in zip(outs, ref)), label
            span = sess.last_stats["wall_s"]
            best = span if best is None else min(best, span)
        return total / best, sess.last_stats

    serialized_tok_s, _ = measure("serialized", num_groups=1, group_size=1,
                                  regs=[1] * STAGES)
    pipelined_tok_s, stats = measure("continuous", num_groups=2, group_size=2)
    speedup = pipelined_tok_s / serialized_tok_s

    emit("serve_pipeline/serialized_1x1", 1e6 * total / serialized_tok_s,
         f"tok_s={serialized_tok_s:.1f}")
    emit("serve_pipeline/continuous_2x2", 1e6 * total / pipelined_tok_s,
         f"tok_s={pipelined_tok_s:.1f};speedup={speedup:.2f};"
         f"admitted_mid_flight={stats['admitted_mid_flight']}")

    out = {
        "stages": STAGES, "requests": len(GENS), "prompt_len": PROMPT_LEN,
        "total_tokens": total, "device_latency_s": DEVICE_LATENCY,
        "serialized_tok_s": serialized_tok_s,
        "pipelined_tok_s": pipelined_tok_s,
        "speedup": speedup,
        "admitted_mid_flight": stats["admitted_mid_flight"],
    }
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve_pipeline.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    if stats["admitted_mid_flight"] < 1:
        raise RuntimeError("no request was admitted mid-flight")
    if speedup < 1.5:
        raise RuntimeError(
            f"continuous batching {pipelined_tok_s:.1f} tok/s is under "
            f"1.5x the serialized {serialized_tok_s:.1f} tok/s")


if __name__ == "__main__":
    main()
