"""Benchmark helpers: timing + multi-device subprocess execution.

Benchmarks print ``name,us_per_call,derived`` CSV lines. The main benchmark
process keeps the default single CPU device; anything needing N>1 devices
re-executes itself in a subprocess with the placeholder-device flag (same
policy as the tests)."""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys
import time

SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")


def timeit(fn, *args, iters: int = 10, warmup: int = 2):
    """Median wall time per call in microseconds (blocks on jax arrays)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


def run_subprocess_bench(module: str, devices: int = 8,
                         timeout: float = 1200.0,
                         extra_env: dict = None):
    """Run ``python -m benchmarks.<module>`` with N placeholder devices and
    forward its CSV lines. ``extra_env`` adds/overrides environment entries
    (the smoke job sets ``BENCH_SMOKE=1`` this way)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    if extra_env:
        env.update(extra_env)
    proc = subprocess.run(
        [sys.executable, "-m", f"benchmarks.{module}"],
        env=env, capture_output=True, text=True, timeout=timeout,
        cwd=str(pathlib.Path(__file__).resolve().parent.parent))
    if proc.returncode != 0:
        raise RuntimeError(f"bench {module} failed:\n{proc.stderr[-3000:]}")
    for line in proc.stdout.splitlines():
        if line.count(",") >= 2 and not line.startswith("#"):
            print(line, flush=True)
