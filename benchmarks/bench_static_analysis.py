"""Static plan verifier: the checks must stay cheap enough to run on every
compile.

``api.compile(..., check="static")`` runs the deadlock, SBP-legality and
memory-bound passes before any actor fires, so their cost is paid by every
session.  Two measurements keep that cost honest:

* the deepseek-v3-671b proxy stack (61 layers, d_model 7168) cut into 8
  stages — the largest plan in the config zoo, analyzed exactly the way
  ``python -m repro.analysis`` does (plan SBP, partition, skeleton, all
  passes), gated at under 5 seconds for the analyzer portion;
* a real compiled 4-stage train session re-checked with
  ``analysis.run_session_checks`` — the per-compile overhead users see.

Both runs must report PASS — a FAIL here means the analyzer regressed on
plans the executors demonstrably run.

Writes ``BENCH_static_analysis.json``.  ``BENCH_SMOKE=1`` does one
repetition instead of three; the gates still run.
"""
import json
import os
import pathlib
import sys
import time

STAGES_BIG = 8
STAGES_TRAIN = 4
MICROBATCHES = 8
BATCH = 8
WIDTH = 16
MAX_ANALYZER_SECONDS = 5.0      # gate: static checks on the biggest plan


def main():
    sys.path.insert(0, "src")
    import numpy as np

    from benchmarks._util import emit
    from repro import analysis, api
    from repro.analysis import membound
    from repro.analysis.__main__ import build_stack_graph, parse_regs
    from repro.analysis.skeleton import train_spec_skeleton
    from repro.configs.registry import get_config
    from repro.core.graph import LogicalGraph, partition_stages
    from repro.core.placement import Placement
    from repro.core.planner import plan as plan_sbp

    smoke = bool(os.environ.get("BENCH_SMOKE"))
    reps = 1 if smoke else 3

    # --- 1) the biggest zoo plan, analyzed the way the CLI does -----------
    cfg = get_config("deepseek-v3-671b")
    regs = parse_regs("1f1b", STAGES_BIG, MICROBATCHES)
    graph = build_stack_graph(cfg.num_layers, cfg.d_model, STAGES_BIG)
    plan = plan_sbp(graph)
    partition = partition_stages(graph)
    specs = train_spec_skeleton(STAGES_BIG, MICROBATCHES, regs)

    best = None
    for _ in range(reps):
        t0 = time.perf_counter()
        memory = membound.stage_boundary_bound(
            graph, plan, partition, regs, MICROBATCHES)
        report = analysis.run_static_checks(
            specs=specs, graph=graph, plan=plan, partition=partition,
            memory=memory)
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
        if report.verdict != "PASS":
            raise RuntimeError(
                f"analyzer rejected the {cfg.name} plan:\n"
                + report.describe())
    emit("static_analysis/deepseek_v3_671b", best * 1e6,
         f"layers={cfg.num_layers};stages={STAGES_BIG};"
         f"edges={report.checked_edges}")

    # --- 2) re-check of a real compiled 4-stage train session -------------
    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (BATCH, WIDTH))
    labels = g.input("labels", (BATCH,), dtype="int32")
    for i in range(STAGES_TRAIN):
        w = g.input(f"w{i}", (WIDTH, WIDTH))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < STAGES_TRAIN - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")

    rng = np.random.default_rng(0)
    params = {f"w{i}": (rng.normal(size=(WIDTH, WIDTH)) * 0.1)
              .astype(np.float32) for i in range(STAGES_TRAIN)}
    sess = api.compile(g, mode="train", stages=STAGES_TRAIN,
                       params=params, num_microbatches=MICROBATCHES)
    try:
        best_sess = None
        for _ in range(reps):
            t0 = time.perf_counter()
            session_report = analysis.run_session_checks(sess)
            dt = time.perf_counter() - t0
            best_sess = dt if best_sess is None else min(best_sess, dt)
        if session_report.verdict != "PASS":
            raise RuntimeError("analyzer rejected a compiled train session:\n"
                               + session_report.describe())
    finally:
        sess.close()
    emit("static_analysis/train_session_recheck", best_sess * 1e6,
         f"stages={STAGES_TRAIN};edges={session_report.checked_edges}")

    out = {
        "model": cfg.name,
        "layers": cfg.num_layers, "d_model": cfg.d_model,
        "stages_big": STAGES_BIG, "microbatches": MICROBATCHES,
        "analyzer_seconds_deepseek": best,
        "max_analyzer_seconds": MAX_ANALYZER_SECONDS,
        "checked_edges_deepseek": report.checked_edges,
        "train_session_stages": STAGES_TRAIN,
        "analyzer_seconds_train_session": best_sess,
        "verdicts": {"deepseek": report.verdict,
                     "train_session": session_report.verdict},
    }
    path = (pathlib.Path(__file__).resolve().parent.parent
            / "BENCH_static_analysis.json")
    path.write_text(json.dumps(out, indent=2) + "\n")
    if best > MAX_ANALYZER_SECONDS:
        raise RuntimeError(
            f"static analysis took {best:.2f}s on the {cfg.name} plan, "
            f"over the {MAX_ANALYZER_SECONDS}s budget — too slow to run "
            f"on every compile")


if __name__ == "__main__":
    main()
