"""Fig 13 (HugeCTR / Wide&Deep): model-parallel embedding lookup.

Vocab-split (S(0)) embedding with masked-gather + P(sum) combine vs
replicated-table lookup, on an 8-way model axis. derived: per-device table
bytes (the Fig 13 memory story: S(0) scales the vocab, B does not)."""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys


def main():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from benchmarks._util import emit, timeit
    from repro.compat import shard_map

    mesh = jax.make_mesh((8,), ("model",))
    V, D, N = 1 << 18, 64, 4096
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, V, size=(N,)), jnp.int32)

    def sharded(tbl, ix):
        Vl = tbl.shape[0]
        off = jax.lax.axis_index("model") * Vl
        local = ix - off
        ok = (local >= 0) & (local < Vl)
        e = tbl[jnp.clip(local, 0, Vl - 1)]
        e = jnp.where(ok[:, None], e, 0.0)
        return jax.lax.psum(e, "model")       # P(sum) -> B

    def replicated(tbl, ix):
        return tbl[ix]

    p1 = jax.jit(shard_map(sharded, mesh=mesh,
                           in_specs=(P("model"), P()), out_specs=P(),
                           check=False))
    p2 = jax.jit(shard_map(replicated, mesh=mesh,
                           in_specs=(P(), P()), out_specs=P(),
                           check=False))
    us1 = timeit(p1, table, ids, iters=5)
    us2 = timeit(p2, table, ids, iters=5)
    emit("embedding_mp/vocab_split_S0", us1,
         f"table_bytes_per_dev={V*D*4//8}")
    emit("embedding_mp/replicated_B", us2,
         f"table_bytes_per_dev={V*D*4}")


if __name__ == "__main__":
    main()
