"""Actor-driven pipeline execution of a compiled physical program (§4.3).

One `api.compile` call cuts the logical graph into stages, lowers each to
its own jitted program, and wires the actor runtime whose register quotas
alone turn those stage callables into a pipelined, back-pressured executor —
no scheduler in sight. The same call with `backend="monolithic"` produces
the whole-graph reference Session; `regs=` switches the schedule
declaratively ("serial", "1f1b", "gpipe", or an explicit quota list).

Run (either form works from the repo root):

    python examples/actor_pipeline.py
    python -m examples.actor_pipeline
"""
try:
    from examples import _bootstrap  # noqa: F401  (python -m examples.actor_pipeline)
except ImportError:
    import _bootstrap  # noqa: F401  (python examples/actor_pipeline.py)

import numpy as np

from repro import api
from repro.core.graph import LogicalGraph
from repro.core.placement import Placement

STAGES, MICROBATCHES = 4, 8


def build():
    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (64, 128))
    for i in range(STAGES):
        w = g.input(f"w{i}", (128, 128))
        h = g.matmul(h, w, name=f"mm{i}")
        h = g.unary(h, "relu", name=f"relu{i}")
    return g


def main():
    import jax

    g = build()

    # one device per stage: the paper's MPMD placement
    devs = jax.devices()
    if len(devs) < STAGES:
        raise SystemExit(
            f"need {STAGES} devices for one-per-stage placement, have "
            f"{len(devs)}; run with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={STAGES} or more")
    stage_meshes = [g.placement.to_mesh(devices=[devs[s]])
                    for s in range(STAGES)]

    rng = np.random.default_rng(0)
    inputs = {t.name: rng.normal(size=t.shape).astype(np.float32)
              for t in g.inputs}

    mono = api.compile(g, mode="infer", backend="monolithic",
                       num_microbatches=MICROBATCHES, microbatch_inputs=["x"],
                       mesh=g.placement.to_mesh(devices=[devs[0]]))
    ref = mono.run(**inputs)["relu3.out"]

    for label, regs in (("serialized (R=1)", "serial"),
                        ("1F1B quota     ", "1f1b")):
        sess = api.compile(g, mode="infer", backend="actors", stages=STAGES,
                           num_microbatches=MICROBATCHES,
                           microbatch_inputs=["x"], regs=regs,
                           stage_meshes=stage_meshes)
        if regs == "serial":
            print(sess.describe())
            for st in sess.executor.staged.stages:
                print(f"  stage {st.index}: {list(st.input_names)} -> "
                      f"{list(st.output_names)}  on {devs[st.index]}")
        got = sess.run(**inputs)       # first run includes jit compile
        got = sess.run(**inputs)["relu3.out"]
        ok = np.array_equal(got, ref) or np.allclose(got, ref, rtol=1e-4)
        print(f"{label}: makespan {sess.last_makespan * 1e3:7.1f} ms   "
              f"matches monolithic: {ok}")
        spans = sess.executor.last_history
        for s in range(STAGES):
            hist = spans[f"stage{s}"]
            busy = sum(e - b for b, e in hist)
            print(f"    stage{s}: {len(hist)} fires, busy {busy * 1e3:6.1f} ms, "
                  f"first fire at {hist[0][0] * 1e3:6.1f} ms")
    print("(stage compute here is sub-ms host work, so the two schedules can "
          "tie on a small CPU; benchmarks/bench_actor_pipeline.py emulates "
          "per-stage device latency and shows the quota-driven speedup)")


if __name__ == "__main__":
    main()
