"""Actor-driven pipeline execution of a compiled physical program (§4.3).

The missing seam of the reproduction, now wired: the SBP compiler cuts the
logical graph into stages and lowers each to its own jitted program; the
actor runtime's register quotas alone turn those stage callables into a
pipelined, back-pressured executor — no scheduler in sight.

Run:  PYTHONPATH=src python examples/actor_pipeline.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core.graph import LogicalGraph, partition_stages
from repro.core.lowering import lower_plan, lower_stages
from repro.core.placement import Placement
from repro.core.planner import plan
from repro.runtime import ActorPipelineExecutor

STAGES, MICROBATCHES = 4, 8


def build():
    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (64, 128))
    for i in range(STAGES):
        w = g.input(f"w{i}", (128, 128))
        h = g.matmul(h, w, name=f"mm{i}")
        h = g.unary(h, "relu", name=f"relu{i}")
    return g


def main():
    import jax

    g = build()
    p = plan(g)
    part = partition_stages(g, num_stages=STAGES)
    print(part.describe(g))

    # one device per stage: the paper's MPMD placement
    devs = jax.devices()
    if len(devs) < STAGES:
        raise SystemExit(
            f"need {STAGES} devices for one-per-stage placement, have "
            f"{len(devs)}; run with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={STAGES} or more")
    stage_meshes = [g.placement.to_mesh(devices=[devs[s]])
                    for s in range(STAGES)]
    staged = lower_stages(g, p, part, stage_meshes=stage_meshes)
    for st in staged.stages:
        print(f"  stage {st.index}: {list(st.input_names)} -> "
              f"{list(st.output_names)}  on {devs[st.index]}")

    rng = np.random.default_rng(0)
    inputs = {t.name: rng.normal(size=t.shape).astype(np.float32)
              for t in g.inputs}

    mono = lower_plan(g, p, g.placement.to_mesh(devices=[devs[0]]))
    ref = np.asarray(mono(*(inputs[t.name] for t in g.inputs))[0])

    for label, regs in (("serialized (R=1)", [1] * STAGES),
                        ("1F1B quota     ", [STAGES - s for s in range(STAGES)])):
        ex = ActorPipelineExecutor(staged, ["x"], MICROBATCHES, regs=regs)
        got = ex.run(inputs)       # first run includes jit compile
        got = ex.run(inputs)
        ok = np.array_equal(got[0], ref) or np.allclose(got[0], ref, rtol=1e-4)
        print(f"{label}: makespan {ex.last_makespan * 1e3:7.1f} ms   "
              f"matches monolithic: {ok}")
        spans = ex.last_history
        for s in range(STAGES):
            hist = spans[f"stage{s}"]
            busy = sum(e - b for b, e in hist)
            print(f"    stage{s}: {len(hist)} fires, busy {busy * 1e3:6.1f} ms, "
                  f"first fire at {hist[0][0] * 1e3:6.1f} ms")
    print("(stage compute here is sub-ms host work, so the two schedules can "
          "tie on a small CPU; benchmarks/bench_actor_pipeline.py emulates "
          "per-stage device latency and shows the quota-driven speedup)")


if __name__ == "__main__":
    main()
