"""Serving example: continuous-batching pipelined decode via repro.api.

Six requests with unequal generation lengths are served through a 2-stage
actor pipeline with 2 request groups of 2 decode slots each: finished
requests retire their slot mid-flight and queued requests are admitted into
it (prompt prefill flows down the same stage actors). The monolithic
whole-stack backend replays the same schedule inline and must produce the
same tokens.

    python examples/serve_decode.py --arch deepseek-v2-lite-16b
    python -m examples.serve_decode --arch qwen2.5-3b
"""
try:
    from examples import _bootstrap  # noqa: F401  (python -m examples.serve_decode)
except ImportError:
    import _bootstrap  # noqa: F401  (python examples/serve_decode.py)

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args()

    import numpy as np

    from repro import api
    from repro.configs.registry import get_config

    cfg = get_config(args.arch).reduced()
    rng = np.random.default_rng(0)
    requests = [
        (rng.integers(0, cfg.vocab_size, (args.prompt_len,)).astype(np.int32),
         1 + (i * 3) % args.gen)                       # unequal gen lengths
        for i in range(args.requests)]

    t0 = time.time()
    sess = api.compile(cfg, mode="serve", backend="actors",
                       num_groups=2, group_size=2,
                       max_prompt_len=args.prompt_len,
                       max_new_tokens=args.gen)
    print(sess.describe())
    outs = sess.generate(requests)
    stats = sess.last_stats
    print(f"pipelined: {stats['tokens']} tokens over {stats['rounds']} "
          f"rounds in {time.time()-t0:.1f}s "
          f"({stats['admitted_mid_flight']} requests admitted mid-flight)")
    print("request 0 ids:", outs[0])

    # the whole-stack monolithic engine is the token-identity reference
    mono = api.compile(cfg, mode="serve", backend="monolithic",
                       num_groups=2, group_size=2,
                       max_prompt_len=args.prompt_len,
                       max_new_tokens=args.gen)
    ref = mono.generate(requests)
    assert all(np.array_equal(a, b) for a, b in zip(outs, ref)), \
        "pipelined tokens != monolithic tokens"
    assert all((o < cfg.vocab_size).all() for o in outs)
    assert stats["admitted_mid_flight"] >= 1
    print("OK (pipelined == monolithic, "
          f"{len(requests)} requests token-identical)")


if __name__ == "__main__":
    main()
