"""Serving example: batched prefill + greedy decode with a seq-sharded KV
cache (GQA) or latent cache (MLA).

    python examples/serve_decode.py --arch deepseek-v2-lite-16b
    python -m examples.serve_decode --arch jamba-v0.1-52b
"""
try:
    from examples import _bootstrap  # noqa: F401  (python -m examples.serve_decode)
except ImportError:
    import _bootstrap  # noqa: F401  (python examples/serve_decode.py)

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.registry import get_config
    from repro.models.model_zoo import build_model
    from repro.train.steps import make_serve_step, plan_from_mesh

    cfg = get_config(args.arch).reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ss = make_serve_step(cfg, mesh, cache_len=args.prompt_len + args.gen + 8)
    params = build_model(cfg, plan_from_mesh(mesh)).init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    batch = {}
    if cfg.embed_frontend and not cfg.encoder_decoder:
        batch["embeds"] = jnp.asarray(rng.normal(
            size=(args.batch, args.prompt_len, cfg.d_model)).astype(np.float32))
    else:
        batch["tokens"] = jnp.asarray(rng.integers(
            0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
    if cfg.encoder_decoder:
        batch["enc_embeds"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32))

    t0 = time.time()
    h_last, caches = ss.prefill_fn(params, batch)
    jax.block_until_ready(h_last)
    print(f"{args.arch}: prefill {args.batch}x{args.prompt_len} "
          f"in {time.time()-t0:.2f}s")

    tok = jnp.argmax(h_last[:, 0] @ params["unembed"], -1).astype(jnp.int32)
    pos = jnp.full((args.batch,), args.prompt_len, jnp.int32)
    out = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(args.gen):
        logits, caches = ss.decode_fn(params, caches, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(np.asarray(tok))
        pos = pos + 1
    jax.block_until_ready(tok)
    gen = np.stack(out, 1)
    print(f"decoded {args.gen} tokens/seq in {time.time()-t0:.2f}s")
    print("row 0 ids:", gen[0])
    assert np.isfinite(gen).all()
    print("OK")


if __name__ == "__main__":
    main()
