"""Shared example bootstrap — import this FIRST in every example.

Makes a source checkout runnable without installation (puts ``src/`` on
``sys.path``) and defaults to 8 virtual CPU devices so the multi-device
examples work on a laptop (must happen before jax is imported). Import it
with the two-form dance that keeps both invocations working::

    try:
        from examples import _bootstrap  # noqa: F401  (python -m examples.foo)
    except ImportError:
        import _bootstrap  # noqa: F401  (python examples/foo.py)
"""
import os
import pathlib
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

_SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
