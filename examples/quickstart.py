"""Quickstart: the paper's Table-4 program, then `repro.api` in one breath.

Shows the layers of the reproduction:
  1. GlobalTensor + SBP signatures + to_global (the eager user API),
  2. a LogicalGraph compiled with `repro.api.compile` — ONE call that picks
     the SBP plan, cuts pipeline stages, plans register quotas, and returns
     a Session (the framework decides how to lower and run, paper §2/§4),
  3. the same Session surface over the monolithic whole-graph program,
     bit-identical to the actor pipeline (`api.assert_sessions_match`).

Run (either form works from the repo root):

    python examples/quickstart.py
    python -m examples.quickstart
"""
try:
    from examples import _bootstrap  # noqa: F401  (python -m examples.quickstart)
except ImportError:
    import _bootstrap  # noqa: F401  (python examples/quickstart.py)

import numpy as np

from repro import api
from repro.core.global_tensor import GlobalTensor, matmul
from repro.core.graph import LogicalGraph
from repro.core.placement import Placement


def table4_program():
    """Paper Table 4: data-parallel matmul0 -> boxing -> model-parallel
    matmul1, written with placements and SBP signatures only."""
    placement = Placement(("data", "model"), (2, 4), device_kind="cpu")
    mesh = placement.to_mesh()
    rng = np.random.default_rng(0)

    A0 = GlobalTensor.from_global(
        rng.normal(size=(4, 8)).astype(np.float32), placement, "S(0),B", mesh)
    B0 = GlobalTensor.from_global(
        rng.normal(size=(8, 8)).astype(np.float32), placement, "B,B", mesh)
    Y0 = matmul(A0, B0)                      # deduced: (S(0), B) - data parallel
    print(f"Y0 = A0 @ B0          -> sbp {Y0.sbp}")

    Y0b = Y0.to_global("B,B")                # to_consistent: boxing (all-gather)
    print(f"Y0.to_global('B,B')   -> sbp {Y0b.sbp}  (boxing op inserted)")

    B1 = GlobalTensor.from_global(
        rng.normal(size=(8, 8)).astype(np.float32), placement, "B,S(1)", mesh)
    Y1 = matmul(Y0b, B1)                     # deduced: (B, S(1)) - model parallel
    print(f"Y1 = Y0 @ B1          -> sbp {Y1.sbp}")
    print("Y1 logical value:\n", Y1.numpy()[:2])


def compile_demo():
    """One logical graph, one compile call, one Session — whatever the
    backend. The planner picks megatron-style signatures for the MLP; the
    stage partition, register quotas, and executor come from compile()."""
    placement = Placement(("data", "model"), (2, 4), device_kind="cpu")
    g = LogicalGraph(placement)
    x = g.input("x", (64, 128), sbp="S(0),B")
    w1 = g.input("w1", (128, 512))           # free: the planner decides
    w2 = g.input("w2", (512, 128))
    h = g.matmul(x, w1, name="mm1")
    a = g.unary(h, "relu", name="relu")
    y = g.matmul(a, w2, name="mm2")

    # actor-pipelined and monolithic sessions from the same graph
    pipe = api.compile(g, mode="infer", backend="actors", stages=2,
                       num_microbatches=4, microbatch_inputs=["x"])
    mono = api.compile(g, mode="infer", backend="monolithic",
                       num_microbatches=4, microbatch_inputs=["x"])
    print("\n" + pipe.describe())

    rng = np.random.default_rng(1)
    inputs = {"x": rng.normal(size=(64, 128)).astype(np.float32),
              "w1": rng.normal(size=(128, 512)).astype(np.float32),
              "w2": rng.normal(size=(512, 128)).astype(np.float32)}
    out = pipe.run(**inputs)[y.name]
    ref = np.maximum(inputs["x"] @ inputs["w1"], 0) @ inputs["w2"]
    print("physical == logical:",
          np.allclose(out, ref, rtol=1e-3, atol=1e-2))  # fp32 sum order
    api.assert_sessions_match(pipe, mono, inputs)
    print("actors == monolithic: bit-identical")


if __name__ == "__main__":
    table4_program()
    compile_demo()
