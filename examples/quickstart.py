"""Quickstart: the paper's Table-4 program in this framework.

Shows the three layers of the reproduction:
  1. GlobalTensor + SBP signatures + to_global (the user API),
  2. the planner choosing signatures by Table-2 cost,
  3. the lowered physical program (explicit boxing collectives).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core.global_tensor import GlobalTensor, matmul
from repro.core.graph import LogicalGraph
from repro.core.lowering import lower_plan
from repro.core.placement import Placement
from repro.core.planner import plan


def table4_program():
    """Paper Table 4: data-parallel matmul0 -> boxing -> model-parallel
    matmul1, written with placements and SBP signatures only."""
    placement = Placement(("data", "model"), (2, 4), device_kind="cpu")
    mesh = placement.to_mesh()
    rng = np.random.default_rng(0)

    A0 = GlobalTensor.from_global(
        rng.normal(size=(4, 8)).astype(np.float32), placement, "S(0),B", mesh)
    B0 = GlobalTensor.from_global(
        rng.normal(size=(8, 8)).astype(np.float32), placement, "B,B", mesh)
    Y0 = matmul(A0, B0)                      # deduced: (S(0), B) - data parallel
    print(f"Y0 = A0 @ B0          -> sbp {Y0.sbp}")

    Y0b = Y0.to_global("B,B")                # to_consistent: boxing (all-gather)
    print(f"Y0.to_global('B,B')   -> sbp {Y0b.sbp}  (boxing op inserted)")

    B1 = GlobalTensor.from_global(
        rng.normal(size=(8, 8)).astype(np.float32), placement, "B,S(1)", mesh)
    Y1 = matmul(Y0b, B1)                     # deduced: (B, S(1)) - model parallel
    print(f"Y1 = Y0 @ B1          -> sbp {Y1.sbp}")
    print("Y1 logical value:\n", Y1.numpy()[:2])


def planner_demo():
    """The compiler picks megatron-style signatures for an MLP by itself."""
    placement = Placement(("data", "model"), (2, 4), device_kind="cpu")
    g = LogicalGraph(placement)
    x = g.input("x", (64, 128), sbp="S(0),B")
    w1 = g.input("w1", (128, 512))           # free: the planner decides
    w2 = g.input("w2", (512, 128))
    h = g.matmul(x, w1, name="mm1")
    a = g.unary(h, "relu", name="relu")
    y = g.matmul(a, w2, name="mm2")
    p = plan(g)
    print("\n" + p.describe())

    prog = lower_plan(g, p, placement.to_mesh())
    rng = np.random.default_rng(1)
    xv = rng.normal(size=(64, 128)).astype(np.float32)
    w1v = rng.normal(size=(128, 512)).astype(np.float32)
    w2v = rng.normal(size=(512, 128)).astype(np.float32)
    out = np.asarray(prog(xv, w1v, w2v)[0])  # programs return a sink tuple
    ref = np.maximum(xv @ w1v, 0) @ w2v
    print("physical == logical:",
          np.allclose(out, ref, rtol=1e-3, atol=1e-2))  # fp32 sum order


if __name__ == "__main__":
    table4_program()
    planner_demo()
