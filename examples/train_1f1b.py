"""1F1B pipeline-parallel *training* from register quotas (§4.3, §6.5).

Two `api.compile` calls on the same logical graph — `backend="actors"` and
`backend="monolithic"` — give two Sessions with the same `step()` surface.
The actor one cuts the graph into stages, differentiates each with a
per-stage ``jax.vjp``, and streams microbatches through fwd/bwd stage
actors; no schedule table anywhere — the forward out-register quota
``R[s] = S - s`` alone produces the 1F1B overlap, and `regs="serial"` runs
the same graph fully serialized. Bit-identical numbers either way.

Run (either form works from the repo root):

    python examples/train_1f1b.py
    python -m examples.train_1f1b
"""
try:
    from examples import _bootstrap  # noqa: F401  (python -m examples.train_1f1b)
except ImportError:
    import _bootstrap  # noqa: F401  (python examples/train_1f1b.py)

import numpy as np

from repro import api
from repro.core.graph import LogicalGraph
from repro.core.placement import Placement

STAGES, MICROBATCHES, BATCH, WIDTH = 4, 8, 64, 128
STEPS = 5


def build():
    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (BATCH, WIDTH))
    labels = g.input("labels", (BATCH,), dtype="int32")
    for i in range(STAGES):
        w = g.input(f"w{i}", (WIDTH, WIDTH))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < STAGES - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")
    return g


def main():
    g = build()
    rng = np.random.default_rng(0)
    params = {f"w{i}": (rng.normal(size=(WIDTH, WIDTH)) * 0.1).astype(np.float32)
              for i in range(STAGES)}
    data = {"x": rng.normal(size=(BATCH, WIDTH)).astype(np.float32),
            "labels": rng.integers(0, WIDTH, size=(BATCH,)).astype(np.int32)}

    mono = api.compile(g, mode="train", backend="monolithic",
                       params=dict(params), num_microbatches=MICROBATCHES)
    pipe = api.compile(g, mode="train", backend="actors", stages=STAGES,
                       params=dict(params), num_microbatches=MICROBATCHES,
                       regs="1f1b")
    print(pipe.describe())
    for st in pipe.executor.tstaged.stages:
        print(f"  stage {st.index}: fwd {list(st.input_names)} -> "
              f"{list(st.output_names)}; params {list(st.param_names)}")

    for step in range(STEPS):
        mres = mono.step(**data)
        pres = pipe.step(**data)
        bit = (mres.loss == pres.loss) and all(
            bool(np.all(np.asarray(mres.grads[n]) ==
                        np.asarray(pres.grads[n])))
            for n in params)
        print(f"step {step}: loss {float(pres.loss):10.4f}   "
              f"makespan {pres.metrics['makespan'] * 1e3:6.1f} ms   "
              f"peak in-flight {pres.metrics['peak_inflight']}   "
              f"bit-identical to monolithic: {bool(bit)}")
    print("(loss falls, the pipeline and the monolithic step agree bitwise; "
          "api.assert_sessions_match(pipe, mono, data, steps=N) is the "
          "one-liner form; benchmarks/bench_1f1b_train.py adds emulated "
          "device latency and shows the 1F1B speedup over serialized)")


if __name__ == "__main__":
    main()
