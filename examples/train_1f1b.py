"""1F1B pipeline-parallel *training* from register quotas (§4.3, §6.5).

The compiler cuts an MLP+softmax-xent training graph into stages and lowers
forward/backward/optimizer programs per stage (backward via per-stage
``jax.vjp``); the actor runtime streams microbatches through fwd and bwd
stage actors. No schedule table anywhere: the forward out-register quota
``R[s] = S - s`` alone produces the 1F1B overlap, and the same graph with
``R = 1`` runs fully serialized — bit-identical numbers either way.

Run (either form works from the repo root):

    python examples/train_1f1b.py
    python -m examples.train_1f1b
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core.graph import LogicalGraph
from repro.core.placement import Placement
from repro.train.steps import make_graph_train_step, make_pipeline_train_step

STAGES, MICROBATCHES, BATCH, WIDTH = 4, 8, 64, 128
STEPS = 5


def build():
    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (BATCH, WIDTH))
    labels = g.input("labels", (BATCH,), dtype="int32")
    for i in range(STAGES):
        w = g.input(f"w{i}", (WIDTH, WIDTH))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < STAGES - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")
    return g


def main():
    g = build()
    rng = np.random.default_rng(0)
    params = {f"w{i}": (rng.normal(size=(WIDTH, WIDTH)) * 0.1).astype(np.float32)
              for i in range(STAGES)}
    data = {"x": rng.normal(size=(BATCH, WIDTH)).astype(np.float32),
            "labels": rng.integers(0, WIDTH, size=(BATCH,)).astype(np.int32)}

    mesh = g.placement.to_mesh()
    mono = make_graph_train_step(g, mesh, list(params), ["x", "labels"],
                                 MICROBATCHES)
    pipe = make_pipeline_train_step(g, dict(params), ["x", "labels"],
                                    MICROBATCHES, num_stages=STAGES,
                                    mesh=mesh)

    print(pipe.tstaged.partition.describe(g))
    for st in pipe.tstaged.stages:
        print(f"  stage {st.index}: fwd {list(st.input_names)} -> "
              f"{list(st.output_names)}; params {list(st.param_names)}")

    mono_params = dict(params)
    for step in range(STEPS):
        ml, mg, mono_params = mono.step(mono_params, data)
        pl, pg, _ = pipe.step(data)
        bit = (ml == pl) and all(bool(np.all(np.asarray(mg[n]) ==
                                             np.asarray(pg[n])))
                                 for n in params)
        print(f"step {step}: loss {float(pl):10.4f}   "
              f"makespan {pipe.last_makespan * 1e3:6.1f} ms   "
              f"peak in-flight {pipe.peak_inflight_activations}   "
              f"bit-identical to monolithic: {bool(bit)}")
    print("(loss falls, the pipeline and the monolithic step agree bitwise; "
          "benchmarks/bench_1f1b_train.py adds emulated device latency and "
          "shows the 1F1B speedup over the serialized R=1 quota)")


if __name__ == "__main__":
    main()
