"""Actor-runtime examples: Fig-6 pipelining, Fig-2 resource safety, and
compile-time register planning for a 1F1B pipeline (§4.3).

Run (either form works from the repo root):

    python examples/pipeline_planning.py
    python -m examples.pipeline_planning
"""
try:
    from examples import _bootstrap  # noqa: F401  (python -m examples.pipeline_planning)
except ImportError:
    import _bootstrap  # noqa: F401  (python examples/pipeline_planning.py)

from repro.runtime import ActorSpec, CommModel, simulate
from repro.runtime.pipeline import analyze, plan_registers


def figure6():
    print("== Fig 6: pipelining from out-register counts ==")
    for regs in (1, 3):
        specs = [
            ActorSpec("a1", lambda: 0, (), out_regs=regs, max_fires=12,
                      duration=1.0, thread=0),
            ActorSpec("a2", lambda x: 0, ("a1",), out_regs=max(1, regs - 1),
                      duration=1.0, thread=1),
            ActorSpec("a3", lambda x: 0, ("a2",), out_regs=max(1, regs - 1),
                      duration=1.0, thread=2),
        ]
        res = simulate(specs, comm=CommModel(same_node=0.0))
        print(f"  out_regs={regs}: makespan {res.makespan:.0f} "
              f"(serial bound 36, pipelined bound 14)")


def figure2():
    print("== Fig 2: no deadlock under shared-resource contention ==")
    specs = [
        ActorSpec("M1", lambda: 0, (), out_regs=1, max_fires=6, thread=0,
                  duration=0.2),
        ActorSpec("M2", lambda: 0, (), out_regs=1, max_fires=6, thread=0,
                  duration=0.2),
        ActorSpec("O1", lambda x: 0, ("M1",), out_regs=1, duration=1.0,
                  thread=1),
        ActorSpec("O2", lambda x: 0, ("M2",), out_regs=2, duration=0.5,
                  thread=1),
    ]
    res = simulate(specs)
    print(f"  completed: {res.fires}  deadlocked: {res.deadlocked}")


def pipeline_plan():
    print("== §4.3: register quota = pipeline schedule ==")
    S, M = 4, 16
    gpipe = analyze(S, M, regs=[M] * S)
    onef1b = analyze(S, M, regs=[S] * S)
    print(f"  GPipe-style (quota={M}): makespan {gpipe.makespan:.1f}, "
          f"peak activations {max(gpipe.peak_activation_regs.values())}")
    print(f"  1F1B (quota={S}):        makespan {onef1b.makespan:.1f}, "
          f"peak activations {max(onef1b.peak_activation_regs.values())}")
    plan = plan_registers(S, M)
    print(f"  auto plan: quota={plan.regs[0]} bubble={plan.bubble_fraction:.2f}")


if __name__ == "__main__":
    figure6()
    figure2()
    pipeline_plan()
