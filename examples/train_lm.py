"""End-to-end example: train a language model with the actor data pipeline,
ZeRO/FSDP optimizer sharding, and checkpointing.

CPU demo (a ~15M-param qwen3-family model, loss must drop):
    python examples/train_lm.py

~100M model, a few hundred steps (hours on 1 CPU core; minutes on devices):
    python examples/train_lm.py --d-model 512 --layers 8 \
        --steps 300 --batch 8 --seq 256
"""
try:
    from examples import _bootstrap  # noqa: F401  (python -m examples.train_lm)
except ImportError:
    import _bootstrap  # noqa: F401  (python examples/train_lm.py)

import argparse
import dataclasses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    import jax

    from repro.configs.registry import get_config
    from repro.data.pipeline import ActorDataPipeline, SyntheticLM
    from repro.optim.adamw import AdamWConfig
    from repro.train.checkpoint import load_checkpoint, save_checkpoint
    from repro.train.steps import make_train_step

    cfg = dataclasses.replace(
        get_config("qwen3-1.7b").reduced(),
        num_layers=args.layers, d_model=args.d_model,
        d_ff=args.d_model * 3, vocab_size=4096)
    n_params = cfg.param_count()
    print(f"model: {n_params/1e6:.1f}M params "
          f"({cfg.num_layers}L d={cfg.d_model})")

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ts = make_train_step(cfg, mesh, optimizer=AdamWConfig(lr=3e-4), zero=True)
    params = ts.init_params(jax.random.PRNGKey(0))
    masters = ts.shard_params_fn(params)
    opt = ts.init_opt(masters)

    pipe = ActorDataPipeline(SyntheticLM(cfg.vocab_size, args.batch, args.seq),
                             num_batches=args.steps, buffers=2)
    losses = []
    for step, tokens in enumerate(pipe):
        masters, opt, metrics = ts.step_fn(masters, opt, {"tokens": tokens})
        losses.append(float(metrics["loss"]))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {losses[-1]:.4f}  "
                  f"grad_norm {float(metrics['grad_norm']):.3f}")

    assert losses[-1] < losses[0], "loss did not improve"
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}  OK")

    if args.ckpt:
        full = ts.gather_params_fn(masters)
        save_checkpoint(args.ckpt, {"params": full}, step=args.steps)
        restored, step = load_checkpoint(args.ckpt, {"params": full})
        print(f"checkpoint round-trip at step {step}: OK")


if __name__ == "__main__":
    main()
