"""Runnable examples. Each script imports :mod:`examples._bootstrap` first
(``src/`` on ``sys.path`` + 8 virtual CPU devices), so both invocations work
from the repo root:

    python examples/<name>.py
    python -m examples.<name>

The pipeline examples all go through the :mod:`repro.api` frontend — one
``api.compile(graph, ...)`` call per Session, whatever the mode/backend.
"""
