"""Runnable examples. Each script inserts ``src/`` on ``sys.path`` itself, so
both invocations work from the repo root:

    python examples/<name>.py
    python -m examples.<name>
"""
