"""Stateful AdamW 1F1B pipeline with cross-stage global-norm clipping.

The optimizer subsystem through the `repro.api` frontend: pass an
`OptimizerSpec` to `api.compile` and each stage's ``opt{s}`` actor consumes
three register streams — the summed gradients from ``acc{s}``, the
persistent AdamW state from ``state{s}`` (step count + moments, surviving
across ``step()`` calls on the Session), and the broadcast clip scale from
the ``norm`` actor, which sums every stage's squared-norm partials
(OneFlow's P→B boxing expressed as an actor). The lr schedule is a
step-indexed callable resolved on the host once per step.

Every step is checked bit-identical to the monolithic AdamW Session:
same loss, same post-clip gradients, same params, same AdamWState.

Run (either form works from the repo root):

    python examples/train_adamw_pipeline.py
    python -m examples.train_adamw_pipeline
"""
try:
    from examples import _bootstrap  # noqa: F401  (python -m examples.train_adamw_pipeline)
except ImportError:
    import _bootstrap  # noqa: F401  (python examples/train_adamw_pipeline.py)

import numpy as np

from repro import api
from repro.core.graph import LogicalGraph
from repro.core.lowering import OptimizerSpec
from repro.core.placement import Placement

STAGES, MICROBATCHES, BATCH, WIDTH = 4, 8, 64, 128
STEPS = 5


def build():
    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (BATCH, WIDTH))
    labels = g.input("labels", (BATCH,), dtype="int32")
    for i in range(STAGES):
        w = g.input(f"w{i}", (WIDTH, WIDTH))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < STAGES - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")
    return g


def main():
    g = build()
    rng = np.random.default_rng(0)
    params = {f"w{i}": (rng.normal(size=(WIDTH, WIDTH)) * 0.5).astype(np.float32)
              for i in range(STAGES)}
    data = {"x": rng.normal(size=(BATCH, WIDTH)).astype(np.float32),
            "labels": rng.integers(0, WIDTH, size=(BATCH,)).astype(np.int32)}

    opt = OptimizerSpec.adamw(lr=lambda step: 1e-3 * (0.9 ** step),
                              grad_clip=1.0)
    mono = api.compile(g, mode="train", backend="monolithic",
                       params=dict(params), num_microbatches=MICROBATCHES,
                       optimizer=opt)
    pipe = api.compile(g, mode="train", backend="actors", stages=STAGES,
                       params=dict(params), num_microbatches=MICROBATCHES,
                       optimizer=opt)
    print(pipe.describe())

    for step in range(STEPS):
        mres = mono.step(**data)
        pres = pipe.step(**data)
        st = pipe.opt_state
        bit = (mres.loss == pres.loss) and all(
            bool(np.all(np.asarray(mres.grads[n]) ==
                        np.asarray(pres.grads[n])))
            for n in params)
        print(f"step {step}: loss {float(pres.loss):10.4f}   "
              f"grad norm {float(pres.metrics['grad_norm']):9.1f} (clipped to "
              f"{opt.grad_clip})   lr {pres.metrics['lr']:.2e}   "
              f"adamw step {int(st.step)}   "
              f"bit-identical: {bool(bit)}")
    print("(the norm actor sums per-stage squared-norm partials and "
          "broadcasts one clip scale to every opt actor; AdamW state rides "
          "its own register stream and persists across Session steps)")


if __name__ == "__main__":
    main()
