"""Stateful AdamW 1F1B pipeline with cross-stage global-norm clipping (PR 3).

Extends examples/train_1f1b.py with the optimizer subsystem: each stage's
``opt{s}`` actor consumes three register streams — the summed gradients from
``acc{s}``, the persistent AdamW state from ``state{s}`` (step count + first
and second moments, surviving across ``step()`` calls), and the broadcast
clip scale from the ``norm`` actor, which sums every stage's squared-norm
partials (OneFlow's P→B boxing expressed as an actor — the first *sideways*
cross-stage edge in this repo). The lr schedule is a step-indexed callable
resolved on the host once per step.

Every step is checked bit-identical to the monolithic AdamW reference:
same loss, same post-clip gradients, same params, same AdamWState.

Run (either form works from the repo root):

    python examples/train_adamw_pipeline.py
    python -m examples.train_adamw_pipeline
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core.graph import LogicalGraph
from repro.core.lowering import OptimizerSpec
from repro.core.placement import Placement
from repro.train.steps import make_graph_train_step, make_pipeline_train_step

STAGES, MICROBATCHES, BATCH, WIDTH = 4, 8, 64, 128
STEPS = 5


def build():
    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (BATCH, WIDTH))
    labels = g.input("labels", (BATCH,), dtype="int32")
    for i in range(STAGES):
        w = g.input(f"w{i}", (WIDTH, WIDTH))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < STAGES - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")
    return g


def main():
    g = build()
    rng = np.random.default_rng(0)
    params = {f"w{i}": (rng.normal(size=(WIDTH, WIDTH)) * 0.5).astype(np.float32)
              for i in range(STAGES)}
    data = {"x": rng.normal(size=(BATCH, WIDTH)).astype(np.float32),
            "labels": rng.integers(0, WIDTH, size=(BATCH,)).astype(np.int32)}

    opt = OptimizerSpec.adamw(lr=lambda step: 1e-3 * (0.9 ** step),
                              grad_clip=1.0)
    mesh = g.placement.to_mesh()
    mono = make_graph_train_step(g, mesh, list(params), ["x", "labels"],
                                 MICROBATCHES, optimizer=opt)
    pipe = make_pipeline_train_step(g, dict(params), ["x", "labels"],
                                    MICROBATCHES, num_stages=STAGES,
                                    mesh=mesh, optimizer=opt)

    print(pipe.tstaged.partition.describe(g))
    print(f"optimizer: {opt.kind}, grad_clip={opt.grad_clip}, "
          f"lr(0)={opt.lr_at(0):.2e} decaying 0.9x/step")

    mono_params = dict(params)
    for step in range(STEPS):
        ml, mg, mono_params = mono.step(mono_params, data)
        pl, pg, _ = pipe.step(data)
        st = pipe.opt_state
        bit = (ml == pl) and all(bool(np.all(np.asarray(mg[n]) ==
                                             np.asarray(pg[n])))
                                 for n in params)
        print(f"step {step}: loss {float(pl):10.4f}   "
              f"grad norm {float(pipe.last_grad_norm):9.1f} (clipped to "
              f"{opt.grad_clip})   adamw step {int(st.step)}   "
              f"|mu| {sum(float(np.abs(np.asarray(st.mu[n])).sum()) for n in params):8.3f}   "
              f"bit-identical: {bool(bit)}")
    print("(the norm actor sums per-stage squared-norm partials and "
          "broadcasts one clip scale to every opt actor; AdamW state rides "
          "its own register stream and persists across steps)")


if __name__ == "__main__":
    main()
