"""repro.api — the single compile/run frontend (paper §2, §4).

OneFlow's central usability claim: the user writes ONE logical graph with
placement and SBP annotations, and a single compile step produces the
runnable artifact — the framework, not the user, decides how to lower and
execute it. This module is that frontend for the reproduction. The four
historical entry paths (``lower_plan``, ``lower_stages`` +
``ActorPipelineExecutor``, ``make_graph_train_step``,
``make_pipeline_train_step`` + ``TrainPipelineExecutor``) are all reachable
through one call::

    from repro import api

    sess = api.compile(g, mode="train", params=init_params,
                       num_microbatches=8,
                       optimizer=OptimizerSpec.adamw(grad_clip=1.0))
    for batch in batches:
        res = sess.step(**batch)          # StepResult(loss, metrics, ...)
    sess.params, sess.opt_state, print(sess.describe())

Every option is declarative and inferred when omitted: ``plan`` via
:func:`repro.core.planner.plan`, the stage ``partition`` via
:func:`repro.core.graph.partition_stages` (user ``g.stage(k)`` annotations or
cost-balanced), register quotas via
:func:`repro.runtime.pipeline.plan_registers` (the paper's compile-time
resource planning, §2.3), ``microbatch_inputs`` as the non-param graph
inputs in train mode.

``backend="actors"`` runs stages as actors (1F1B emerging from register
quotas, §4.3/§6.5) on a runtime chosen by ``runtime=``: ``"threads"`` drives
every actor on OS threads in this process, ``"processes"`` gives each
pipeline stage its own worker process (paper Fig 7/8 — the node field of the
64-bit actor address becomes a real OS process) with payloads crossing
stages over a real transport. ``backend="monolithic"`` runs the same
:class:`Session` surface over whole-graph jitted programs (``lower_plan`` /
``lower_train_plan``) with identical microbatch chunking, so
pipeline-vs-monolithic bit-identity checks are one-liners
(:func:`assert_sessions_match`).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.graph import (LogicalGraph, StagePartition, partition_stages)
from repro.core.lowering import (OptimizerSpec, PrecisionPolicy, lower_plan,
                                 lower_serve_stages, lower_stages,
                                 lower_train_plan, lower_train_stages,
                                 reassemble_sinks, split_microbatches)
from repro.core.planner import Plan, plan as plan_sbp
from repro.runtime.base import RUNTIME_KINDS
from repro.runtime.pipeline import (
    ActorPipelineExecutor, InlineServeEngine, PipelinePlan,
    ServePipelineExecutor, TrainPipelineExecutor, check_run_inputs,
    plan_registers)
from repro.runtime.recipes import (InferRecipe, MeshSpec, ServeRecipe,
                                   TrainRecipe)

MODES = ("infer", "train", "serve")
BACKENDS = ("actors", "monolithic")

#: named register-quota policies accepted by ``compile(regs=...)`` — the
#: paper's schedules as declarative one-words instead of hand-built lists
REG_POLICIES = ("1f1b", "gpipe", "serial")


@dataclasses.dataclass
class StepResult:
    """One training step's outcome, uniform across backends.

    ``metrics`` always carries ``step`` (0-based index of the step just
    taken), ``lr`` (the schedule resolved at that step), and ``grad_norm``
    (pre-clip global norm; None when clipping is off). Actor-backend sessions
    add ``makespan`` (wall-clock seconds) and ``peak_inflight`` (peak forward
    registers in use — the in-flight microbatch count the quota bounds).
    """

    loss: Any
    metrics: Dict[str, Any]
    grads: Dict[str, Any]
    params: Dict[str, Any]


def _canonical_params(graph: LogicalGraph, params: Dict[str, Any]
                      ) -> Dict[str, Any]:
    """Reorder a param dict into graph-input order — the canonical order
    both backends use for the global-norm sum, so clipping is bit-identical
    no matter how the caller built the dict."""
    input_names = [t.name for t in graph.inputs]
    unknown = sorted(set(params) - set(input_names))
    if unknown:
        raise ValueError(f"params entries are not graph inputs: {unknown}")
    return {n: params[n] for n in input_names if n in params}


class _MonolithicInferEngine:
    """``backend="monolithic"`` inference: one whole-graph jitted program
    (:func:`repro.core.lowering.lower_plan`), run once per microbatch chunk
    with the same :func:`split_microbatches` chunking as the actor pipeline
    so the two backends agree bitwise."""

    def __init__(self, graph: LogicalGraph, plan: Plan, mesh,
                 microbatch_inputs: Sequence[str], num_microbatches: int):
        self.graph = graph
        self.program = lower_plan(graph, plan, mesh)
        self.input_names = [t.name for t in graph.inputs]
        self.microbatch_inputs = list(microbatch_inputs)
        self.num_microbatches = num_microbatches
        for n in self.microbatch_inputs:
            if n not in self.input_names:
                raise ValueError(f"{n} is not a graph input")
        self.last_makespan: Optional[float] = None

    def run(self, inputs: Dict[str, Any], timeout: float = 0.0) -> Tuple:
        check_run_inputs(inputs, self.input_names)
        t0 = time.perf_counter()
        if not self.microbatch_inputs:
            chunks = [dict(inputs)]
        else:
            chunks = split_microbatches(inputs, self.microbatch_inputs,
                                        self.num_microbatches)
        mb = set(self.microbatch_inputs)
        sink_names = [t.name for t in self.program.sinks]
        per_chunk = [
            dict(zip(sink_names,
                     self.program(*(c[n] if n in mb else inputs[n]
                                    for n in self.input_names))))
            for c in chunks]
        results = reassemble_sinks(self.graph, self.program.sinks,
                                   self.microbatch_inputs, per_chunk)
        self.last_makespan = time.perf_counter() - t0
        return results


class _MonolithicTrainEngine:
    """``backend="monolithic"`` training: whole-graph value-and-grad
    (:func:`repro.core.lowering.lower_train_plan`) with the exact microbatch
    chunking, fp32 accumulation, canonical-order global-norm clipping, and
    :class:`OptimizerSpec` kernels of the actor pipeline — the reference its
    numbers are checked against, owned by the same :class:`Session` surface.
    """

    def __init__(self, graph: LogicalGraph, plan: Plan, mesh,
                 params: Dict[str, Any], microbatch_inputs: Sequence[str],
                 num_microbatches: int, optimizer: OptimizerSpec,
                 loss=None):
        self.graph = graph
        self.params = _canonical_params(graph, params)
        self.param_names = tuple(self.params)
        self.optimizer = optimizer
        self._scaling = optimizer.loss_scaling is not None
        self.vg = lower_train_plan(graph, plan, mesh, list(self.param_names),
                                   loss=loss, scaled=self._scaling)
        self.input_names = [t.name for t in graph.inputs]
        self.microbatch_inputs = list(microbatch_inputs)
        self.num_microbatches = num_microbatches
        self._opt_state = None
        self.step_count = 0
        self.last_grad_norm = None
        self.last_makespan: Optional[float] = None
        # loss-scaling mirror — same trajectory as the pipelined scale actor
        self.loss_scale = (optimizer.initial_scale()
                           if self._scaling else None)
        self.scale_good_steps = 0
        self.last_skipped = False
        self.last_scale = None
        # mixed precision: fp32 masters (flat ZeRO shards or dense) are the
        # optimizer's view; ``_compute`` is the cast copy fwd/bwd see
        self._masters = None
        self._compute = None
        self._refresh_masters()

    def _refresh_masters(self) -> None:
        import jax.numpy as jnp

        opt = self.optimizer
        if not opt.mixed_precision:
            self._masters = self._compute = None
            return
        if opt.zero:
            self._masters = opt.shard_masters(self.params)
            self._compute = opt.gather_params(self._masters,
                                              dtype=opt.compute_dtype)
            # re-canonicalize params through the same shard/gather (bitwise
            # identity for fp32 inputs: pad-then-truncate is pure layout)
            self.params = opt.gather_params(self._masters)
        else:
            self._masters = {n: jnp.asarray(v).astype(jnp.float32)
                             for n, v in self.params.items()}
            self._compute = {n: v.astype(jnp.dtype(opt.compute_dtype))
                             for n, v in self._masters.items()}
            self.params = dict(self._masters)

    @property
    def opt_state(self):
        """Merged (full-tensor) optimizer state — flat ZeRO shards are
        gathered so the surface is partition- and zero-agnostic."""
        st = self._opt_state
        if st is None or not self.optimizer.zero:
            return st
        return self.optimizer.merge_states([st])

    def load_params(self, params: Dict[str, Any]) -> None:
        missing = [n for n in self.param_names if n not in params]
        if missing:
            raise ValueError(f"missing params: {missing}")
        self.params = {n: params[n] for n in self.param_names}
        self._refresh_masters()

    def load_state(self, params: Optional[Dict[str, Any]] = None,
                   opt_state=None, step: Optional[int] = None) -> None:
        """Restore full training state (e.g. from a snapshot): params,
        optimizer state, and the step counter the lr schedule indexes.
        ``opt_state`` is always the merged full-tensor form; a ZeRO
        optimizer re-shards it flat on arrival."""
        if params is not None:
            self.load_params(params)
        if opt_state is not None:
            if not self.optimizer.stateful:
                raise ValueError(
                    "opt_state= for a stateless optimizer "
                    f"({self.optimizer.kind})")
            if self.optimizer.zero:
                opt_state = self.optimizer.split_state(
                    opt_state, {0: list(self.param_names)})[0]
            self._opt_state = opt_state
        if step is not None:
            self.step_count = int(step)

    def step(self, data_inputs: Dict[str, Any], timeout: float = 0.0):
        import numpy as np

        import jax.numpy as jnp

        from repro.core.lowering import loss_scale_update
        from repro.optim.adamw import (clip_scale, global_norm_from_partials,
                                       scale_grad, sqnorm_partials)

        check_run_inputs(
            data_inputs,
            [n for n in self.input_names if n not in self.params],
            owned=self.param_names)
        t0 = time.perf_counter()
        chunks = split_microbatches(data_inputs, self.microbatch_inputs,
                                    self.num_microbatches)
        mb = set(self.microbatch_inputs)
        opt = self.optimizer
        compute = self._compute if self._compute is not None else self.params
        loss_total, grads = None, None
        for chunk in chunks:
            vals = [chunk[n] if n in mb
                    else (compute[n] if n in compute
                          else data_inputs[n])
                    for n in self.input_names]
            if self._scaling:
                loss_vec, g = self.vg(np.float32(self.loss_scale), *vals)
            else:
                loss_vec, g = self.vg(*vals)
            ls = jnp.sum(loss_vec)
            loss_total = ls if loss_total is None else loss_total + ls
            g32 = [x.astype(jnp.float32) for x in g]
            grads = (g32 if grads is None
                     else [a + b for a, b in zip(grads, g32)])
        gdict = dict(zip(self.param_names, grads))
        if self._scaling:
            # unscale ONCE after accumulation (exact for power-of-two
            # scales) — same op order as the pipelined acc actors
            inv = np.float32(np.float32(1.0) / np.float32(self.loss_scale))
            gdict = {n: scale_grad(g, inv) for n, g in gdict.items()}
        need_norm = bool(opt.grad_clip) or opt.dynamic_scaling
        if need_norm:
            norm = global_norm_from_partials(sqnorm_partials(gdict),
                                             self.param_names)
            cscale = clip_scale(norm, opt.grad_clip)
            gdict = {n: scale_grad(g, cscale) for n, g in gdict.items()}
            self.last_grad_norm = norm
        self.last_scale = self.loss_scale
        if opt.dynamic_scaling:
            finite = bool(np.isfinite(np.float32(norm)))
            skip, nxt, good = loss_scale_update(
                opt.precision, self.loss_scale, self.scale_good_steps,
                finite)
            self.loss_scale, self.scale_good_steps = nxt, good
            self.last_skipped = skip
            if skip:
                # non-finite grads: leave params/masters/state untouched —
                # the same no-op the pipelined opt actors perform
                self.last_makespan = time.perf_counter() - t0
                return loss_total, {}, dict(self.params)
        else:
            self.last_skipped = False
        masters = self._masters if self._masters is not None else dict(
            self.params)
        if opt.stateful and self._opt_state is None:
            self._opt_state = opt.init_state(masters)
        new_masters, self._opt_state = opt.update(
            masters, gdict, self._opt_state, opt.lr_at(self.step_count))
        if opt.mixed_precision:
            self._masters = new_masters
            if opt.zero:
                self.params = opt.gather_params(new_masters)
                self._compute = opt.gather_params(new_masters,
                                                  dtype=opt.compute_dtype)
            else:
                self.params = dict(new_masters)
                self._compute = {
                    n: v.astype(jnp.dtype(opt.compute_dtype))
                    for n, v in new_masters.items()}
        else:
            self.params = new_masters
        self.step_count += 1
        self.last_makespan = time.perf_counter() - t0
        return loss_total, gdict, dict(self.params)

    def opt_state_bytes(self) -> Dict[int, int]:
        """Monolithic counterpart of
        :meth:`repro.runtime.pipeline.TrainPipelineExecutor.opt_state_bytes`:
        one entry (stage 0) of per-device optimizer-held fp32 bytes."""
        import numpy as np

        opt = self.optimizer
        zero_dp = opt.zero_dp if opt.zero else 1
        total = 0
        st = self._opt_state
        if st is not None:
            for tree in (st.mu, st.nu):
                total += sum(int(np.asarray(v).nbytes)
                             for v in tree.values())
        if opt.mixed_precision:
            for n in self.param_names:
                nelem = int(np.asarray(self.params[n]).size)
                total += -(-nelem // zero_dp) * zero_dp * 4
        return {0: total // zero_dp}


class Session:
    """The uniform run/step surface every compile path returns.

    * ``mode="infer"``: :meth:`run` maps graph-input values to a dict of
      sink values (named by sink tensor).
    * ``mode="train"``: :meth:`step` takes the non-param inputs and returns
      a :class:`StepResult`; the session owns ``params`` and any optimizer
      state across steps.

    ``describe()`` reports the SBP plan, the stage partition with register
    quotas, and the simulated register plan (building on
    :meth:`repro.core.graph.StagePartition.describe`) — the compiled
    artifact, human-readable. ``history`` accumulates one record per
    :meth:`run`/:meth:`step` call.

    Sessions are built by :func:`compile`, never directly.
    """

    def __init__(self, *, graph: LogicalGraph, mode: str, backend: str,
                 engine, plan: Plan, partition: Optional[StagePartition],
                 regs: Optional[List[int]], reg_plan: Optional[PipelinePlan],
                 optimizer: Optional[OptimizerSpec],
                 microbatch_inputs: List[str], num_microbatches: int,
                 timeout: float = 300.0, runtime: Optional[str] = None):
        self.graph = graph
        self.mode = mode
        self.backend = backend
        self.runtime = runtime        # "threads"/"processes"; None: monolithic
        self.plan = plan
        self.partition = partition
        self.regs = regs
        self.reg_plan = reg_plan
        self.optimizer = optimizer
        self.microbatch_inputs = microbatch_inputs
        self.num_microbatches = num_microbatches
        self.timeout = timeout
        self.history: List[Dict[str, Any]] = []
        self.static_report = None     # repro.analysis.StaticReport
        self._engine = engine
        self._sinks = graph.sinks()

    # -- the executor/engine underneath, for callers that need the guts ----
    @property
    def executor(self):
        """The backing executor/engine: an
        :class:`repro.runtime.pipeline.ActorPipelineExecutor` or
        :class:`~repro.runtime.pipeline.TrainPipelineExecutor` for
        ``backend="actors"``, the monolithic engine otherwise."""
        return self._engine

    @property
    def params(self) -> Optional[Dict[str, Any]]:
        """Current trainable params (None for inference sessions)."""
        if self.mode != "train":
            return None
        return dict(self._engine.params)

    @property
    def opt_state(self):
        """Optimizer state over all params (merged across stages for the
        actor backend; None for SGD or inference)."""
        if self.mode != "train":
            return None
        return self._engine.opt_state

    @property
    def step_count(self) -> int:
        return getattr(self._engine, "step_count", 0)

    @property
    def last_makespan(self) -> Optional[float]:
        return self._engine.last_makespan

    @property
    def last_edge_bytes(self) -> Dict[Any, int]:
        """Per-edge serialized payload bytes from the last step/run —
        ``{(producer, consumer): bytes}`` from the actor runtime; empty for
        monolithic engines (one program, no edges)."""
        return dict(getattr(self._engine, "last_edge_bytes", None) or {})

    def load_params(self, params: Dict[str, Any]) -> None:
        """Replace the session-owned params (e.g. checkpoint restore);
        optimizer state is untouched."""
        if self.mode != "train":
            raise RuntimeError("load_params() on an inference session")
        self._engine.load_params(params)

    def load_state(self, params: Optional[Dict[str, Any]] = None,
                   opt_state=None, step: Optional[int] = None) -> None:
        """Restore full training state — params, merged optimizer state,
        and the step counter — e.g. from
        :func:`repro.runtime.snapshot.load_snapshot`. Each piece is optional
        and independent; the actor backend re-splits ``opt_state`` by *this*
        session's stage partition, so a snapshot taken under one partition
        restores onto another (elastic resume)."""
        if self.mode != "train":
            raise RuntimeError("load_state() on an inference session")
        self._engine.load_state(params=params, opt_state=opt_state,
                                step=step)

    def close(self) -> None:
        """Release the engine's workers (actor threads or worker processes).
        Monolithic engines have none; the call is a no-op there."""
        close = getattr(self._engine, "close", None)
        if close is not None:
            close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def run(self, **inputs) -> Dict[str, Any]:
        """Execute the compiled inference program over ``inputs`` (one
        keyword per graph input) and return ``{sink name: value}``."""
        if self.mode != "train":
            outs = self._engine.run(inputs, timeout=self.timeout)
            self.history.append({"kind": "run",
                                 "makespan": self._engine.last_makespan})
            return {t.name: v for t, v in zip(self._sinks, outs)}
        raise RuntimeError(
            "run() on a train-mode session; use step(**batch) "
            "(or compile with mode='infer')")

    def step(self, **batch) -> StepResult:
        """Run one training step over the session-owned params and return a
        :class:`StepResult`. ``batch`` maps every non-param graph input to
        its value; the names in ``microbatch_inputs`` are split into
        ``num_microbatches`` chunks along axis 0."""
        if self.mode != "train":
            raise RuntimeError(
                "step() on an infer-mode session; use run(**inputs) "
                "(or compile with mode='train', params=...)")
        index = self._engine.step_count
        loss, grads, params = self._engine.step(batch, timeout=self.timeout)
        metrics = {
            "step": index,
            "lr": (self.optimizer.lr_at(index)
                   if self.optimizer is not None else None),
            "grad_norm": self._engine.last_grad_norm,
            "makespan": self._engine.last_makespan,
        }
        if (self.optimizer is not None
                and self.optimizer.loss_scaling is not None):
            ls = getattr(self._engine, "last_scale", None)
            metrics["loss_scale"] = None if ls is None else float(ls)
            metrics["skipped"] = bool(getattr(self._engine, "last_skipped",
                                              False))
        if self.backend == "actors":
            metrics["peak_inflight"] = self._engine.peak_inflight_activations
        # history holds host floats only, so a long training loop never
        # pins device arrays
        gn = metrics["grad_norm"]
        self.history.append({"kind": "step", "loss": float(loss), **metrics,
                             "grad_norm": None if gn is None else float(gn)})
        return StepResult(loss=loss, metrics=metrics, grads=grads,
                          params=params)

    def describe(self) -> str:
        """Human-readable report of the compiled artifact: graph shape, SBP
        plan, stage partition + register quotas, optimizer."""
        g = self.graph
        rt = f" runtime={self.runtime}" if self.runtime is not None else ""
        lines = [f"=== repro.api session: mode={self.mode} "
                 f"backend={self.backend}{rt} ===",
                 f"graph: {len(g.ops)} ops, "
                 f"inputs {[t.name for t in g.inputs]}, "
                 f"sinks {[t.name for t in self._sinks]}",
                 f"microbatches: {self.num_microbatches} over "
                 f"{self.microbatch_inputs or '(none)'}"]
        if self.mode == "train":
            opt = self.optimizer
            lines.append(
                f"optimizer: {opt.kind} (grad_clip={opt.grad_clip}, "
                f"stateful={opt.stateful})" if opt is not None
                else "optimizer: none")
            if opt is not None and opt.mixed_precision:
                scaling = opt.loss_scaling
                lines.append(
                    f"precision: compute={opt.compute_dtype} "
                    f"masters=float32 "
                    f"loss_scale={'off' if scaling is None else scaling}")
            if opt is not None and opt.zero:
                lines.append(
                    f"zero: dp={opt.zero_dp} — flat (dp, 1, chunk) fp32 "
                    "master/moment shards held by the opt actors")
            bytes_fn = getattr(self._engine, "opt_state_bytes", None)
            if opt is not None and opt.stateful and bytes_fn is not None:
                per = bytes_fn()
                if per:
                    per_s = " ".join(f"stage{s}={per[s]}"
                                     for s in sorted(per))
                    lines.append(
                        "optimizer-state bytes/device: "
                        f"{per_s} (total {sum(per.values())})")
        lines.append(self.plan.describe())
        if self.partition is not None:
            lines.append(self.partition.describe(g, regs=self.regs))
        else:
            lines.append("single whole-graph jitted program "
                         "(no stage partition)")
        if self.reg_plan is not None:
            rp = self.reg_plan
            lines.append(
                f"register plan (simulated): quota={rp.regs[0]} "
                f"makespan={rp.makespan:.1f} "
                f"bubble={rp.bubble_fraction:.2f}")
        if self.static_report is not None:
            lines.append(self.static_report.describe())
        return "\n".join(lines)

    def __repr__(self):
        return (f"Session(mode={self.mode!r}, backend={self.backend!r}, "
                f"stages={self.partition.num_stages if self.partition else 1}, "
                f"num_microbatches={self.num_microbatches})")


# ---------------------------------------------------------------------------
# mode="serve": continuous-batching autoregressive decode (ROADMAP "serving
# batching" seam — stage = model shard, microbatch = request group).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeRequest:
    """One generation request: prompt token ids + how many tokens to decode
    (the first generated token, from the prefill logits, counts)."""

    tokens: Any
    max_new_tokens: int


class ServeSession:
    """The serving counterpart of :class:`Session`: pipelined,
    continuously-batched greedy decode over the actor runtime.

    :meth:`generate` runs a set of :class:`ServeRequest`\\ s to completion:
    requests are packed into ``num_groups * group_size`` decode slots, each
    round advances every live group by one token (one :class:`DecodeWork`
    per group streamed down the stage actors), finished requests retire
    their slot and queued ones are admitted mid-flight with a
    :class:`PrefillWork` that scatters the new request's caches into the
    group cache. Retired/empty slots are *parked*: they decode a dummy
    token at the reserved position ``cache_len - 1``, which no live
    request's attention window ever reaches, so the group program keeps one
    fixed shape and nothing is masked inside the model.

    Mirrors the :class:`Session` conventions: ``describe()`` reports the
    compiled artifact, ``history`` accumulates one record per round, and
    ``executor`` exposes the backing engine.
    """

    def __init__(self, *, cfg, mesh, backend: str, engine, sstaged,
                 num_groups: int, group_size: int, cache_len: int,
                 max_prompt_len: int, max_new_tokens: int,
                 regs: Optional[List[int]], timeout: float = 300.0,
                 runtime: Optional[str] = None, cache: str = "dense",
                 cache_spec=None, sampling=None,
                 prefill_chunk: Optional[int] = None,
                 share_prefix: bool = False):
        self.cfg = cfg
        self.mesh = mesh
        self.mode = "serve"
        self.backend = backend
        self.runtime = runtime        # "threads"/"processes"; None: monolithic
        self.sstaged = sstaged
        self.num_groups = num_groups
        self.group_size = group_size
        self.cache_len = cache_len
        self.max_prompt_len = max_prompt_len
        self.max_new_tokens = max_new_tokens
        self.regs = regs
        self.timeout = timeout
        self.cache = cache            # "dense" | "paged"
        self.cache_spec = cache_spec  # PagedCacheSpec when paged
        self.sampling = sampling      # SamplingSpec; None: greedy
        self.prefill_chunk = prefill_chunk
        self.share_prefix = share_prefix
        self.history: List[Dict[str, Any]] = []
        self.last_stats: Optional[Dict[str, Any]] = None
        self.static_report = None     # repro.analysis.StaticReport
        self._engine = engine

    @property
    def executor(self):
        """The backing engine: a
        :class:`repro.runtime.pipeline.ServePipelineExecutor` for
        ``backend="actors"``, the inline monolithic engine otherwise."""
        return self._engine

    @property
    def last_makespan(self) -> Optional[float]:
        return self._engine.last_makespan

    def close(self) -> None:
        """Release the engine's workers (no-op for the inline engine)."""
        close = getattr(self._engine, "close", None)
        if close is not None:
            close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    @staticmethod
    def _normalize(requests) -> List[ServeRequest]:
        out = []
        for r in requests:
            if isinstance(r, ServeRequest):
                out.append(r)
            else:
                toks, gen = r
                out.append(ServeRequest(toks, int(gen)))
        return out

    def generate(self, requests) -> List[Any]:
        """Run ``requests`` (ServeRequests or ``(tokens, max_new_tokens)``
        pairs) to completion with continuous batching; returns one int32
        token array per request, in submission order. Round planning and
        slot/page bookkeeping live in
        :class:`repro.serve.admission.AdmissionScheduler`; this loop only
        validates, drives the engine, and turns round results into
        tokens."""
        import numpy as np

        from repro.serve.admission import AdmissionScheduler

        reqs = self._normalize(requests)
        V = self.cfg.vocab_size
        prompts = []
        for i, r in enumerate(reqs):
            toks = np.asarray(r.tokens, dtype=np.int32)
            if toks.ndim != 1 or toks.size == 0:
                raise ValueError(f"request {i}: prompt must be a non-empty "
                                 f"1-d token array, got shape {toks.shape}")
            if toks.size > self.max_prompt_len:
                raise ValueError(
                    f"request {i}: prompt length {toks.size} exceeds "
                    f"max_prompt_len={self.max_prompt_len}")
            if (toks < 0).any() or (toks >= V).any():
                raise ValueError(f"request {i}: prompt ids must be in "
                                 f"[0, {V})")
            if not (1 <= r.max_new_tokens <= self.max_new_tokens):
                raise ValueError(
                    f"request {i}: max_new_tokens={r.max_new_tokens} must "
                    f"be in [1, {self.max_new_tokens}]")
            prompts.append(toks)

        pool = None
        if self.cache == "paged":
            from repro.serve.paged_cache import PagePool

            pool = PagePool(self.cache_spec)
        sched = AdmissionScheduler(
            prompts, [r.max_new_tokens for r in reqs],
            num_groups=self.num_groups, group_size=self.group_size,
            cache_len=self.cache_len, pool=pool,
            prefill_chunk=self.prefill_chunk,
            share_prefix=self.share_prefix)
        t0 = time.perf_counter()
        while not sched.done():
            work, meta = sched.plan_round()
            results = self._engine.run_round(work, timeout=self.timeout)
            for m, res in zip(meta, results):
                sched.absorb(m, self._pick_tokens(m, res))
            self.history.append({"kind": "round", "items": len(work),
                                 "makespan": self._engine.last_makespan})

        wall = time.perf_counter() - t0
        total = sum(len(o) for o in sched.outputs)
        self.last_stats = {
            "requests": len(reqs), "tokens": total,
            "rounds": self._engine.rounds, "wall_s": wall,
            "tok_per_s": total / wall if wall > 0 else float("inf"),
            "admitted_mid_flight": sched.admitted_mid_flight,
        }
        if pool is not None:
            self.last_stats["peak_pages"] = pool.peak_pages
            self.last_stats["shared_pages"] = sched.shared_pages
        self.history.append({"kind": "generate", **self.last_stats})
        return [np.asarray(o, np.int32) for o in sched.outputs]

    def _pick_tokens(self, m, res):
        """One round result -> the item's token vector (``None`` for a
        non-final chunk). With sampling on, the engine already sampled in
        the last stage; otherwise greedy the logits here, exactly the PR-5
        driver-side path."""
        import numpy as np

        from repro.train.steps import greedy_from_logits

        if self.sampling is not None:
            toks = res["tokens"]
            return None if toks is None else np.asarray(toks)
        if m[0] == "chunk":
            if not m[3]:
                return None
            res = res[-1]        # the chunk's last position feeds the head
        return np.asarray(greedy_from_logits(res, self.cfg.vocab_size))

    def cache_bytes(self) -> int:
        """Analytic persistent cache bytes across all stages: the full
        dense reservation (``num_groups`` group blocks) or the paged pool
        (slabs + page table + cursors), from ``jax.eval_shape`` — nothing
        is allocated."""
        import jax
        import jax.numpy as jnp

        from repro.serve.paged_cache import dense_bytes, slab_bytes

        total = 0
        tok = jax.ShapeDtypeStruct((self.group_size,), jnp.int32)
        for stage in self.sstaged.stages:
            template = jax.eval_shape(stage.init_caches, tok)
            if self.cache == "paged":
                total += slab_bytes(template, self.cache_spec)
            else:
                total += dense_bytes(template, self.num_groups)
        return total

    def describe(self) -> str:
        """Human-readable report of the compiled serving artifact."""
        cfg = self.cfg
        rt = f" runtime={self.runtime}" if self.runtime is not None else ""
        lines = [f"=== repro.api session: mode=serve "
                 f"backend={self.backend}{rt} ===",
                 f"model: {cfg.name} ({cfg.num_layers} layers, "
                 f"d_model={cfg.d_model}, vocab={cfg.vocab_size} "
                 f"padded to {cfg.padded_vocab()})",
                 f"slots: {self.num_groups} groups x {self.group_size} "
                 f"(cache_len={self.cache_len}, "
                 f"max_prompt_len={self.max_prompt_len}, "
                 f"max_new_tokens={self.max_new_tokens})",
                 self.sstaged.describe()]
        if self.cache == "paged":
            sp = self.cache_spec
            extra = (f" prefill_chunk={self.prefill_chunk}"
                     if self.prefill_chunk is not None else "")
            lines.insert(3, f"cache: paged ({sp.num_pages} pages x "
                            f"page_len={sp.page_len}, "
                            f"{sp.pages_per_req} pages/request, "
                            f"share_prefix={self.share_prefix}){extra}")
        else:
            lines.insert(3, "cache: dense (one group block per slot group)")
        if self.sampling is not None:
            sp = self.sampling
            lines.insert(4, f"sampling: temperature={sp.temperature} "
                            f"top_k={sp.top_k} top_p={sp.top_p} "
                            f"seed={sp.seed}")
        if self.regs is not None:
            lines.append(f"register quotas: {self.regs}")
        if self.static_report is not None:
            lines.append(self.static_report.describe())
        return "\n".join(lines)

    def __repr__(self):
        return (f"ServeSession(backend={self.backend!r}, "
                f"stages={self.sstaged.num_stages}, "
                f"groups={self.num_groups}x{self.group_size})")


def _serve_options(*, num_groups, group_size, cache_len, max_prompt_len,
                   max_new_tokens, cache, page_len, num_pages, sampling,
                   prefill_chunk, tp: int):
    """Resolve defaults and validate every serve-only compile option at
    compile time (a bad geometry must fail here, not as a shape error in
    the middle of ``generate``). Returns ``(num_groups, group_size,
    cache_len, max_prompt_len, max_new_tokens, cache, cache_spec)``."""
    import math

    num_groups = 2 if num_groups is None else num_groups
    group_size = 2 if group_size is None else group_size
    max_prompt_len = 64 if max_prompt_len is None else max_prompt_len
    max_new_tokens = 64 if max_new_tokens is None else max_new_tokens
    if num_groups < 1 or group_size < 1:
        raise ValueError(f"num_groups={num_groups} and "
                         f"group_size={group_size} must be >= 1")
    if max_prompt_len < 1 or max_new_tokens < 1:
        raise ValueError(f"max_prompt_len={max_prompt_len} and "
                         f"max_new_tokens={max_new_tokens} must be >= 1")
    if cache_len is None:
        cache_len = max_prompt_len + max_new_tokens + 9
        cache_len += -cache_len % tp
    elif cache_len <= max_prompt_len + max_new_tokens:
        # the last cache position is the parking slot for retired requests
        raise ValueError(
            f"cache_len={cache_len} must exceed max_prompt_len + "
            f"max_new_tokens = {max_prompt_len + max_new_tokens} "
            "(the final position is reserved for parked slots); lower "
            "max_prompt_len= or max_new_tokens=, or raise cache_len=")
    cache = "dense" if cache is None else cache
    if cache not in ("dense", "paged"):
        raise ValueError(f"cache={cache!r}; expected 'dense' or 'paged'")
    if sampling is not None:
        from repro.serve.sampler import SamplingSpec
        if not isinstance(sampling, SamplingSpec):
            raise ValueError(
                "sampling= takes a repro.serve.sampler.SamplingSpec, got "
                f"{type(sampling).__name__}")
    cache_spec = None
    if cache == "dense":
        paged_only = {"page_len": page_len, "num_pages": num_pages,
                      "prefill_chunk": prefill_chunk}
        bad = [k for k, v in paged_only.items() if v is not None]
        if bad:
            raise ValueError(f"{bad[0]}= requires cache='paged' (the dense "
                             "cache has no page geometry)")
    else:
        from repro.serve.paged_cache import PagedCacheSpec
        if page_len is None:
            # largest divisor of cache_len not exceeding 16
            page_len = max(d for d in range(1, min(16, cache_len) + 1)
                           if cache_len % d == 0)
        if page_len < 1 or cache_len % page_len:
            raise ValueError(
                f"page_len={page_len} must be a positive divisor of "
                f"cache_len={cache_len} (every mapped page must be fully "
                "overwritten by the admission prefill)")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk={prefill_chunk} must be >= 1")
        max_requests = num_groups * group_size
        pages_per_req = cache_len // page_len
        # worst-case single request: prompt + all decode writes must fit,
        # or admission could stall forever on an empty pool
        min_pages = math.ceil((max_prompt_len + max_new_tokens - 1)
                              / page_len)
        if num_pages is None:
            num_pages = max_requests * pages_per_req
        if num_pages < min_pages:
            raise ValueError(
                f"num_pages={num_pages} cannot hold one worst-case request "
                f"({min_pages} pages of page_len={page_len} for "
                f"max_prompt_len + max_new_tokens - 1 = "
                f"{max_prompt_len + max_new_tokens - 1} positions)")
        cache_spec = PagedCacheSpec(page_len=page_len, num_pages=num_pages,
                                    max_requests=max_requests,
                                    pages_per_req=pages_per_req)
    return (num_groups, group_size, cache_len, max_prompt_len,
            max_new_tokens, cache, cache_spec)


def _compile_serve(cfg, *, backend: str, stages: Optional[int], regs,
                   params: Optional[Dict[str, Any]], mesh, fn_wrap,
                   timeout: float, num_groups: Optional[int],
                   group_size: Optional[int], cache_len: Optional[int],
                   max_prompt_len: Optional[int],
                   max_new_tokens: Optional[int],
                   runtime: str = "threads", cache: Optional[str] = None,
                   page_len: Optional[int] = None,
                   num_pages: Optional[int] = None, sampling=None,
                   prefill_chunk: Optional[int] = None,
                   check: str = "static") -> ServeSession:
    import jax

    from repro.configs.base import ModelConfig
    from repro.models.model_zoo import build_model
    from repro.models.transformer import stack_layout
    from repro.train.steps import plan_from_mesh

    if isinstance(cfg, str):
        from repro.configs.registry import get_config
        cfg = get_config(cfg)
    if not isinstance(cfg, ModelConfig):
        raise ValueError(
            "mode='serve' compiles a repro.configs.base.ModelConfig (or an "
            f"--arch name), got {type(cfg).__name__}")
    if mesh is None:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = plan_from_mesh(mesh)
    tp = plan.tp
    (num_groups, group_size, cache_len, max_prompt_len, max_new_tokens,
     cache, cache_spec) = _serve_options(
        num_groups=num_groups, group_size=group_size, cache_len=cache_len,
        max_prompt_len=max_prompt_len, max_new_tokens=max_new_tokens,
        cache=cache, page_len=page_len, num_pages=num_pages,
        sampling=sampling, prefill_chunk=prefill_chunk, tp=tp)
    if cache == "paged" and (tp != 1 or plan.dp != 1):
        raise ValueError(
            "cache='paged' requires a 1x1 mesh (the page gather/scatter "
            f"programs are single-device); got dp={plan.dp}, tp={tp}")

    lay = stack_layout(cfg)
    n_units = len(lay.prologue) + lay.n_periods
    if backend == "monolithic":
        if stages not in (None, 1):
            raise ValueError("backend='monolithic' serves the whole stack "
                             "as one stage; use backend='actors' for "
                             f"stages={stages}")
        stages = 1
    elif stages is None:
        stages = min(2, n_units)

    if params is None:
        params = build_model(cfg, plan_from_mesh(mesh)).init(
            jax.random.PRNGKey(0))
    sstaged = lower_serve_stages(cfg, mesh, params, num_stages=stages,
                                 cache_len=cache_len,
                                 max_prompt_len=max_prompt_len,
                                 group_size=group_size)
    if isinstance(regs, str):
        regs = _policy_regs(regs, stages, num_groups)
    # shared-prefix pages assume a prompt prefix's cache values are
    # independent of the suffix — true for causal attention/SSM stacks, not
    # under MoE capacity routing (expert drop counts see the whole prompt)
    share_prefix = (cache == "paged"
                    and getattr(cfg, "num_experts", 0) == 0)
    if backend == "monolithic":
        if fn_wrap is not None:
            raise ValueError("fn_wrap requires backend='actors' "
                             "(there are no stage actors to wrap)")
        engine = InlineServeEngine(sstaged, cache_spec=cache_spec,
                                   sampling=sampling)
        regs = None
        runtime = None
    else:
        recipe = None
        if runtime == "processes":
            # workers re-lower from data: ship host copies of the params and
            # the mesh as device ids (repro.runtime.recipes)
            recipe = ServeRecipe(cfg, jax.device_get(params),
                                 num_stages=stages, cache_len=cache_len,
                                 max_prompt_len=max_prompt_len,
                                 group_size=group_size,
                                 mesh=MeshSpec.capture(mesh))
        engine = ServePipelineExecutor(sstaged, regs=regs, fn_wrap=fn_wrap,
                                       runtime=runtime, recipe=recipe,
                                       cache_spec=cache_spec,
                                       sampling=sampling)
        regs = engine.regs if engine.regs is not None else \
            _policy_regs("1f1b", stages, num_groups)
    sess = ServeSession(cfg=cfg, mesh=mesh, backend=backend, engine=engine,
                        sstaged=sstaged, num_groups=num_groups,
                        group_size=group_size, cache_len=cache_len,
                        max_prompt_len=max_prompt_len,
                        max_new_tokens=max_new_tokens, regs=regs,
                        timeout=timeout, runtime=runtime, cache=cache,
                        cache_spec=cache_spec, sampling=sampling,
                        prefill_chunk=prefill_chunk,
                        share_prefix=share_prefix)
    return _attach_static_report(sess, check)


def _resolve_partition(graph: LogicalGraph,
                       partition: Optional[StagePartition],
                       stages: Optional[int]) -> StagePartition:
    if partition is not None:
        if stages is not None and stages != partition.num_stages:
            raise ValueError(
                f"stages={stages} contradicts partition.num_stages="
                f"{partition.num_stages}; pass one or the other")
        return partition
    if stages is None and all(op.stage is None for op in graph.ops):
        raise ValueError(
            "graph has no stage annotations; pass stages= (a count for "
            "cost-balanced cutting) or partition=, or use "
            "backend='monolithic'")
    return partition_stages(graph, stages)


def _policy_regs(policy: str, num_stages: int, width: int) -> List[int]:
    """Map a :data:`REG_POLICIES` name to per-stage quotas. ``width`` is
    what ``"gpipe"`` admits everywhere: the microbatch count in graph
    modes, the request-group count in serve mode."""
    if policy == "1f1b":
        return [max(1, num_stages - s) for s in range(num_stages)]
    if policy == "gpipe":
        return [width] * num_stages
    if policy == "serial":
        return [1] * num_stages
    raise ValueError(f"unknown regs policy {policy!r}; "
                     f"pass one of {REG_POLICIES} or an explicit list")


def _resolve_regs(regs, partition: StagePartition, num_microbatches: int,
                  mode: str) -> Tuple[List[int], Optional[PipelinePlan]]:
    """Turn the declarative ``regs`` option into per-stage quotas.

    None -> compile-time resource planning (:func:`plan_registers`, §2.3);
    a policy name from :data:`REG_POLICIES` -> the corresponding schedule;
    an explicit sequence -> validated pass-through.
    """
    S = partition.num_stages
    if regs is None:
        bwd = 2.0 if mode == "train" else 0.0
        rp = plan_registers(S, num_microbatches, fwd_time=1.0,
                            bwd_time=max(bwd, 1e-3))
        return list(rp.regs), rp
    if isinstance(regs, str):
        return _policy_regs(regs, S, num_microbatches), None
    regs = list(regs)
    if len(regs) != S:
        raise ValueError(f"need {S} register quotas, got {len(regs)}")
    return regs, None


def _fold_precision_options(graph, optimizer: OptimizerSpec,
                            params: Dict[str, Any], *, zero, precision,
                            loss_scale) -> OptimizerSpec:
    """Resolve ``compile()``'s ``zero=``/``precision=``/``loss_scale=`` into
    the :class:`OptimizerSpec` fields the lowering and runtime layers read
    (``zero``/``zero_dp``/``zero_shapes``/``precision``). The spec's own
    ``__post_init__`` re-validates the folded result (zero requires AdamW;
    loss scaling requires bf16 compute over fp32 masters)."""
    import numpy as np

    if not zero and precision is None and loss_scale is None:
        return optimizer
    policy = precision
    if isinstance(policy, str):
        aliases = {"bf16": "bfloat16", "bfloat16": "bfloat16",
                   "fp32": "float32", "float32": "float32"}
        if policy not in aliases:
            raise ValueError(
                f"unknown precision {policy!r}; expected 'bf16'/'bfloat16', "
                "'fp32'/'float32', or a PrecisionPolicy")
        policy = PrecisionPolicy(compute_dtype=aliases[policy],
                                 loss_scale=loss_scale)
    elif isinstance(policy, PrecisionPolicy):
        if loss_scale is not None:
            policy = dataclasses.replace(policy, loss_scale=loss_scale)
    elif policy is not None:
        raise ValueError(
            f"precision= must be a dtype string or PrecisionPolicy, "
            f"got {type(policy).__name__}")
    elif loss_scale is not None:
        raise ValueError(
            "loss_scale= without precision= — loss scaling only exists to "
            "keep bf16 cotangents representable; pass precision='bf16' "
            "(fp32 compute never needs a scaled backward seed)")
    zero_dp, zero_shapes = 1, None
    if zero:
        pl = graph.placement
        sizes = dict(zip(pl.axis_names, pl.axis_sizes))
        if "data" in sizes:
            zero_dp = int(sizes["data"])
        elif len(pl.axis_names) == 1:
            # a sole placement axis doubles as the data axis
            zero_dp = int(pl.axis_sizes[0])
        else:
            raise ValueError(
                "zero=True requires a data axis to shard the optimizer "
                "state over: name one placement axis 'data' (placement "
                f"axes are {tuple(pl.axis_names)})")
        zero_shapes = tuple(
            (n, tuple(int(d) for d in np.shape(v)))
            for n, v in params.items())
    return dataclasses.replace(optimizer, zero=bool(zero), zero_dp=zero_dp,
                               zero_shapes=zero_shapes, precision=policy)


def _attach_static_report(sess, check: str):
    """Run the static plan verifier over a freshly compiled session
    (``check="static"``, the default) and attach the report for
    ``describe()``; a FAIL verdict closes the session's workers and raises
    :class:`repro.analysis.AnalysisError` naming the offending cycle/edge.
    ``check="off"`` records a SKIPPED report and returns immediately."""
    from repro import analysis

    if check == "off":
        sess.static_report = analysis.StaticReport(verdict="SKIPPED")
        return sess
    report = analysis.run_session_checks(sess)
    sess.static_report = report
    if report.verdict == "FAIL":
        sess.close()
        raise analysis.AnalysisError(report)
    return sess


def _apply_restore(sess: "Session", restore) -> "Session":
    """Resolve ``compile(restore=<snapshot dir>)``: load the newest completed
    snapshot and install it as the session's full training state — including
    the loss-scale trajectory when the snapshot recorded one."""
    if restore is None:
        return sess
    from repro.runtime.snapshot import load_snapshot

    params, opt_state, step, meta = load_snapshot(str(restore))
    sess.load_state(params=params, opt_state=opt_state, step=step)
    eng = sess._engine
    if (meta.get("loss_scale") is not None
            and getattr(eng, "loss_scale", None) is not None):
        eng.loss_scale = float(meta["loss_scale"])
        eng.scale_good_steps = int(meta.get("scale_good_steps", 0))
    return sess


def compile(graph, *, mode: str = "infer",
            backend: str = "actors", runtime: Optional[str] = None,
            plan: Optional[Plan] = None,
            partition: Optional[StagePartition] = None,
            stages: Optional[int] = None, num_microbatches: int = 1,
            microbatch_inputs: Optional[Sequence[str]] = None,
            regs=None, optimizer: Optional[OptimizerSpec] = None,
            params: Optional[Dict[str, Any]] = None, loss=None,
            lr: float = 1e-2, mesh=None, stage_meshes=None,
            fn_wrap=None, timeout: float = 300.0,
            snapshot_dir=None, snapshot_every: int = 1,
            restore=None, faults=None,
            zero: bool = False, precision=None, loss_scale=None,
            num_groups: Optional[int] = None,
            group_size: Optional[int] = None,
            cache_len: Optional[int] = None,
            max_prompt_len: Optional[int] = None,
            max_new_tokens: Optional[int] = None,
            cache: Optional[str] = None,
            page_len: Optional[int] = None,
            num_pages: Optional[int] = None,
            sampling=None,
            prefill_chunk: Optional[int] = None,
            check: str = "static"):
    """Compile a :class:`~repro.core.graph.LogicalGraph` into a runnable
    :class:`Session` — the single frontend over every lowering/executor path.

    ``mode="serve"`` instead compiles a
    :class:`repro.configs.base.ModelConfig` (or ``--arch`` name) into a
    :class:`ServeSession` running pipelined continuous-batching greedy
    decode: the stack is cut into ``stages`` model shards
    (:func:`repro.core.lowering.lower_serve_stages`), requests are packed
    into ``num_groups * group_size`` decode slots, and
    :meth:`ServeSession.generate` admits/retires requests mid-flight.
    Serve-only options: ``num_groups``, ``group_size``, ``cache_len``,
    ``max_prompt_len``, ``max_new_tokens``; ``params`` are the model params
    (default: ``build_model(...).init(PRNGKey(0))``), ``regs`` the
    per-stage quotas (list or policy), ``backend="monolithic"`` the
    whole-stack single-program reference. ``cache="paged"`` swaps the dense
    per-group cache blocks for the preallocated page pool of
    :mod:`repro.serve.paged_cache` (geometry via ``page_len=`` /
    ``num_pages=``, token-identical to dense), ``sampling=`` takes a
    :class:`repro.serve.sampler.SamplingSpec` (default: greedy), and
    ``prefill_chunk=`` (paged only) admits long prompts as bounded chunks
    interleaved with decode rounds.

    Declarative options (everything omitted is inferred):

    * ``mode``: ``"infer"`` (:meth:`Session.run`) or ``"train"``
      (:meth:`Session.step`; requires ``params``).
    * ``backend``: ``"actors"`` — per-stage jitted programs driven by stage
      actors with register-quota back-pressure (§4.3); ``"monolithic"`` —
      one whole-graph jitted program with identical microbatch semantics
      (the bit-identity reference).
    * ``runtime`` (actors backend only): ``"threads"`` (default) drives the
      actors on OS threads in this process; ``"processes"`` spawns one
      worker process per pipeline stage — stage state (placed params,
      optimizer state, serve caches) lives in the owning worker, payloads
      cross stages as serialized host arrays, and each worker re-lowers its
      stages from a picklable recipe (:mod:`repro.runtime.recipes`). With
      ``"processes"``, ``fn_wrap`` and a schedule-callable ``lr`` must be
      picklable (module-level, not lambdas/closures).
    * ``plan``: an SBP :class:`~repro.core.planner.Plan`; default
      :func:`repro.core.planner.plan` (Table-2 boxing-cost minimization).
    * ``partition`` / ``stages``: an explicit
      :class:`~repro.core.graph.StagePartition`, or a stage count for
      cost-balanced cutting; default: the graph's ``g.stage(k)``
      annotations. Actors backend only.
    * ``num_microbatches`` / ``microbatch_inputs``: how the batch streams
      through the pipeline. ``microbatch_inputs`` defaults to the non-param
      graph inputs in train mode; inference with ``num_microbatches > 1``
      must name them explicitly.
    * ``regs``: per-stage out-register quotas — an explicit list, a policy
      from :data:`REG_POLICIES` (``"1f1b"``, ``"gpipe"``, ``"serial"``), or
      None for compile-time resource planning via
      :func:`repro.runtime.pipeline.plan_registers` (§2.3).
    * ``optimizer``: an :class:`~repro.core.lowering.OptimizerSpec`
      (train mode only; default SGD at ``lr``).
    * ``params``: ``{graph input name: initial value}`` for every trainable
      input (train mode only); the session owns them across steps.
    * ``loss``: the sink to differentiate (default: the sole sink).
    * ``mesh`` / ``stage_meshes``: one shared device mesh (default
      ``graph.placement.to_mesh()``) or one mesh per stage — the paper's
      MPMD placement (actors backend only).
    * ``fn_wrap``: optional stage-body decorator (benchmarks use it to
      emulate device latency; actors backend only).
    * ``snapshot_dir`` / ``snapshot_every`` (train + actors only): write an
      async snapshot every N steps — one ``snap{s}`` actor per parameterized
      stage serializes that stage's post-update params + optimizer state off
      the schedule's hot path (:mod:`repro.runtime.snapshot`).
    * ``restore`` (train only): a ``snapshot_dir`` from an earlier session;
      the newest *completed* snapshot there becomes the session's initial
      params/optimizer state/step counter. Partition-agnostic — a snapshot
      taken on 4 stages restores onto 2 stages or the monolithic backend.
      ``params=`` is still required (shapes/ordering) but is overridden.
    * ``faults`` (train + actors only): a
      :class:`repro.runtime.chaos.FaultPlan` injected into the runtime —
      kill a named actor at its Nth fire, delay/duplicate a Req, drop an
      ack. The fault-tolerance tests drive kill-and-resume through this.
    * ``zero`` (train only): shard the optimizer's fp32 master params and
      AdamW moments across the placement's data axis as flat
      ``(dp, 1, chunk)`` tensors (§6.4, ZeRO-DP from SBP) — the opt actors'
      persistent register stream holds the shards; the forward sees gathered
      weights cast to the compute dtype (the Fig-14 ``cast`` placed *before*
      the gather, halving wire cost). Requires an AdamW optimizer and a data
      axis (an axis named ``"data"``, or a 1-d placement). Bit-identical to
      the dense path.
    * ``precision`` (train only): ``"bf16"``/``"bfloat16"`` runs
      forward/backward in bfloat16 over fp32 master params (cotangents and
      gradient accumulation stay fp32); ``"fp32"``/``"float32"`` is the
      default full-precision path; or pass a
      :class:`~repro.core.lowering.PrecisionPolicy` directly.
    * ``loss_scale`` (train only, requires ``precision="bf16"``): a float
      scales the loss backward seed statically (unscaled once after fp32
      accumulation — exact for powers of two); ``"dynamic"`` adds the
      ``scale`` actor riding the norm actor's stream: non-finite grad norms
      skip the update and back the scale off, sustained finite steps grow
      it.

    The monolithic backend accepts but does not use the schedule hints
    ``partition``/``stages``/``regs`` (so one kwargs dict can sweep both
    backends); ``stage_meshes`` and ``fn_wrap`` would change its execution
    and are rejected.

    ``check="static"`` (the default) runs the :mod:`repro.analysis` plan
    verifier over the compiled artifacts before returning — deadlock
    saturation of the actor network, SBP-legality of every edge, and the
    static per-device memory bound — and raises
    :class:`repro.analysis.AnalysisError` on a FAIL verdict (the offending
    cycle/edge is named; nothing has fired). ``check="off"`` skips it.
    """
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}; expected one of {MODES}")
    if check not in ("static", "off"):
        raise ValueError(
            f"unknown check {check!r}; expected 'static' (run the "
            "repro.analysis plan verifier at compile time) or 'off'")
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if runtime is not None and runtime not in RUNTIME_KINDS:
        raise ValueError(
            f"unknown runtime {runtime!r}; expected one of {RUNTIME_KINDS}")
    if backend == "monolithic" and runtime is not None:
        raise ValueError(
            "runtime= requires backend='actors' (the monolithic backend "
            "runs one jitted program in-process, there is no actor runtime "
            "to choose)")
    if runtime is None and backend == "actors":
        runtime = "threads"
    if mode != "train" and (zero or precision is not None
                            or loss_scale is not None):
        raise ValueError(
            "zero=/precision=/loss_scale= are only meaningful for "
            "mode='train' (they shape the optimizer's master/moment state "
            "and the backward seed; nothing is updated in other modes)")
    if mode != "train":
        train_only = {"snapshot_dir": snapshot_dir, "restore": restore,
                      "faults": faults}
        bad = [k for k, v in train_only.items() if v is not None]
        if bad or snapshot_every != 1:
            bad = bad or ["snapshot_every"]
            raise ValueError(
                f"{bad[0]}= is only meaningful for mode='train' "
                "(snapshots/restore/fault injection act on training state)")
    else:
        if backend != "actors":
            if snapshot_dir is not None:
                raise ValueError(
                    "snapshot_dir= requires backend='actors' (snapshots are "
                    "written by per-stage snap actors; checkpoint a "
                    "monolithic session with repro.train.checkpoint)")
            if faults is not None:
                raise ValueError(
                    "faults= requires backend='actors' (there are no "
                    "workers or messages to inject faults into)")
        if snapshot_every < 1:
            raise ValueError(
                f"snapshot_every must be >= 1, got {snapshot_every}")
        if snapshot_dir is None and snapshot_every != 1:
            raise ValueError("snapshot_every= without snapshot_dir=")
    if mode == "serve":
        rejected = {"plan": plan, "partition": partition,
                    "optimizer": optimizer, "loss": loss,
                    "microbatch_inputs": microbatch_inputs,
                    "stage_meshes": stage_meshes}
        bad = [k for k, v in rejected.items() if v is not None]
        if bad or num_microbatches != 1:
            bad = bad or ["num_microbatches"]
            raise ValueError(
                f"{bad[0]}= is not meaningful for mode='serve' (serving "
                "compiles a ModelConfig; schedule/optimizer options belong "
                "to graph modes)")
        return _compile_serve(
            graph, backend=backend, stages=stages, regs=regs, params=params,
            mesh=mesh, fn_wrap=fn_wrap, timeout=timeout,
            num_groups=num_groups, group_size=group_size,
            cache_len=cache_len, max_prompt_len=max_prompt_len,
            max_new_tokens=max_new_tokens, runtime=runtime, cache=cache,
            page_len=page_len, num_pages=num_pages, sampling=sampling,
            prefill_chunk=prefill_chunk, check=check)
    serve_only = {"num_groups": num_groups, "group_size": group_size,
                  "cache_len": cache_len, "max_prompt_len": max_prompt_len,
                  "max_new_tokens": max_new_tokens, "cache": cache,
                  "page_len": page_len, "num_pages": num_pages,
                  "sampling": sampling, "prefill_chunk": prefill_chunk}
    bad = [k for k, v in serve_only.items() if v is not None]
    if bad:
        raise ValueError(
            f"{bad[0]}= is only meaningful for mode='serve'")
    if num_microbatches < 1:
        raise ValueError(
            f"num_microbatches must be >= 1, got {num_microbatches}")
    if mode == "infer":
        if optimizer is not None:
            raise ValueError(
                "optimizer= is only meaningful for mode='train' "
                "(inference sessions never update params)")
        if params is not None:
            raise ValueError(
                "params= is only meaningful for mode='train'; inference "
                "sessions take every graph input at run() time")
        if loss is not None:
            raise ValueError(
                "loss= is only meaningful for mode='train' "
                "(nothing is differentiated in inference)")
    else:
        if params is None:
            raise ValueError(
                "mode='train' requires params= "
                "({graph input name: initial value})")
        params = _canonical_params(graph, params)
        if optimizer is None:
            optimizer = OptimizerSpec.sgd(lr)
        optimizer = _fold_precision_options(graph, optimizer, params,
                                            zero=zero, precision=precision,
                                            loss_scale=loss_scale)

    if plan is None:
        plan = plan_sbp(graph)

    input_names = [t.name for t in graph.inputs]
    if microbatch_inputs is None:
        if mode == "train":
            microbatch_inputs = [n for n in input_names if n not in params]
        elif num_microbatches > 1:
            raise ValueError(
                "num_microbatches > 1 needs microbatch_inputs= naming the "
                "graph inputs to split along axis 0")
        else:
            microbatch_inputs = []
    microbatch_inputs = list(microbatch_inputs)
    for n in microbatch_inputs:
        if n not in input_names:
            raise ValueError(f"{n} is not a graph input")

    if backend == "monolithic":
        # partition/stages/regs are schedule *hints* — harmless to accept so
        # a backend sweep can reuse one kwargs dict — but fn_wrap and
        # stage_meshes change execution and cannot be honored here
        if stage_meshes is not None:
            raise ValueError("stage_meshes requires backend='actors' "
                             "(the monolithic program runs on one mesh)")
        if fn_wrap is not None:
            raise ValueError("fn_wrap requires backend='actors' "
                             "(there are no stage bodies to wrap)")
        if mesh is None:
            mesh = graph.placement.to_mesh()
        if mode == "infer":
            engine = _MonolithicInferEngine(graph, plan, mesh,
                                            microbatch_inputs,
                                            num_microbatches)
        else:
            engine = _MonolithicTrainEngine(graph, plan, mesh, params,
                                            microbatch_inputs,
                                            num_microbatches, optimizer,
                                            loss=loss)
        sess = Session(graph=graph, mode=mode, backend=backend,
                       engine=engine, plan=plan, partition=None, regs=None,
                       reg_plan=None, optimizer=optimizer,
                       microbatch_inputs=microbatch_inputs,
                       num_microbatches=num_microbatches, timeout=timeout)
        sess = _attach_static_report(sess, check)
        return _apply_restore(sess, restore)

    part = _resolve_partition(graph, partition, stages)
    regs, reg_plan = _resolve_regs(regs, part, num_microbatches, mode)
    # the recipe captures the *user's* mesh choice (None -> each worker
    # defaults to graph.placement.to_mesh() itself, device-table agnostic)
    mesh_spec = MeshSpec.capture(mesh)
    stage_mesh_specs = (None if stage_meshes is None else
                        tuple(MeshSpec.capture(m) for m in stage_meshes))
    if mesh is None and stage_meshes is None:
        mesh = graph.placement.to_mesh()
    if mode == "infer":
        staged = lower_stages(graph, plan, part, mesh=mesh,
                              stage_meshes=stage_meshes)
        recipe = None
        if runtime == "processes":
            recipe = InferRecipe(graph, plan, part, mesh=mesh_spec,
                                 stage_meshes=stage_mesh_specs)
        engine = ActorPipelineExecutor(staged, microbatch_inputs,
                                       num_microbatches, regs=regs,
                                       fn_wrap=fn_wrap, runtime=runtime,
                                       recipe=recipe)
    else:
        tstaged = lower_train_stages(graph, plan, part, list(params),
                                     loss=loss, mesh=mesh,
                                     stage_meshes=stage_meshes,
                                     optimizer=optimizer)
        recipe = None
        if runtime == "processes":
            recipe = TrainRecipe(graph, plan, part, list(params), loss=loss,
                                 mesh=mesh_spec,
                                 stage_meshes=stage_mesh_specs,
                                 optimizer=optimizer)
        engine = TrainPipelineExecutor(tstaged, params, microbatch_inputs,
                                       num_microbatches, lr=lr, regs=regs,
                                       fn_wrap=fn_wrap, optimizer=optimizer,
                                       runtime=runtime, recipe=recipe,
                                       snapshot_dir=snapshot_dir,
                                       snapshot_every=snapshot_every,
                                       faults=faults)
    sess = Session(graph=graph, mode=mode, backend=backend, engine=engine,
                   plan=plan, partition=part, regs=regs, reg_plan=reg_plan,
                   optimizer=optimizer, microbatch_inputs=microbatch_inputs,
                   num_microbatches=num_microbatches, timeout=timeout,
                   runtime=runtime)
    sess = _attach_static_report(sess, check)
    return _apply_restore(sess, restore)


def _assert_tree_equal(name: str, a, b, context: str) -> None:
    import numpy as np

    a, b = np.asarray(a), np.asarray(b)
    if a.dtype != b.dtype or a.shape != b.shape or not np.array_equal(a, b):
        diff = ""
        if a.shape == b.shape and a.dtype == b.dtype:
            delta = np.max(np.abs(a.astype(np.float64)
                                  - b.astype(np.float64)))
            diff = f" (max abs diff {delta:g})"
        raise AssertionError(
            f"sessions disagree on {name} at {context}: "
            f"{a.dtype}{list(a.shape)} vs {b.dtype}{list(b.shape)}{diff}")


def assert_sessions_match(a: Session, b: Session, inputs: Dict[str, Any],
                          steps: int = 1) -> None:
    """Bit-identity check between two sessions compiled from the same graph
    (typically ``backend="actors"`` vs ``backend="monolithic"``).

    Inference sessions: run both on ``inputs`` and compare every sink
    bitwise. Training sessions: step both ``steps`` times on the same batch
    and compare loss, post-clip grads, updated params, and (when stateful)
    the merged optimizer state after every step. Raises ``AssertionError``
    naming the first mismatching tensor.
    """
    if a.mode != b.mode:
        raise ValueError(f"cannot compare mode={a.mode!r} with {b.mode!r}")
    if a.mode == "infer":
        ra, rb = a.run(**inputs), b.run(**inputs)
        for name in ra:
            _assert_tree_equal(f"sink {name!r}", ra[name], rb[name], "run")
        return
    import numpy as np

    for k in range(steps):
        sa, sb = a.step(**inputs), b.step(**inputs)
        ctx = f"step {k}"
        _assert_tree_equal("loss", sa.loss, sb.loss, ctx)
        for n in sa.grads:
            _assert_tree_equal(f"grad {n!r}", sa.grads[n], sb.grads[n], ctx)
        for n in sa.params:
            _assert_tree_equal(f"param {n!r}", sa.params[n], sb.params[n],
                               ctx)
        oa, ob = a.opt_state, b.opt_state
        if (oa is None) != (ob is None):
            raise AssertionError(
                f"sessions disagree on opt_state presence at {ctx}")
        if oa is not None:
            if int(oa.step) != int(ob.step):
                raise AssertionError(
                    f"opt_state.step differs at {ctx}: "
                    f"{int(oa.step)} vs {int(ob.step)}")
            for n in oa.mu:
                _assert_tree_equal(f"opt mu {n!r}", oa.mu[n], ob.mu[n], ctx)
                _assert_tree_equal(f"opt nu {n!r}", oa.nu[n], ob.nu[n], ctx)
