"""Paged KV/SSM cache pool: the thin-stack trick applied to serving state.

The dense serve path allocates one ``(group_size, cache_len, ...)`` cache
block per slot group per stage — a request occupying a slot reserves its
worst-case decode window for its whole lifetime. This module replaces that
reservation with the paper's preallocated-register discipline:

* **One page slab per stage.** Every *positional* cache tensor (GQA
  ``k``/``v``, MLA ``c``/``kpe``) is stored as a fixed
  ``(num_pages, page_len, *feat)`` slab, allocated once. A request's cache
  window is a sequence of pages named by an int32 **page table** row
  ``(pages_per_req,)``; entry ``-1`` means unmapped. Non-positional
  per-request state (SSM ``h``, conv tails) lives in a
  ``(max_requests, *feat)`` row pool indexed by slot id.
* **Host plans, device executes.** Page allocation/free/refcounting is
  driver-side numpy bookkeeping (:class:`PagePool`); the stage only ever
  runs three jitted fixed-shape programs — gather a slot group's windows
  into the dense layout the unchanged stage decode program expects, scatter
  the one written position back, scatter a freshly prefilled request into
  its pages. One stage program therefore serves any mix of request lengths.
* **Bit identity with the dense path.** A gathered window agrees with the
  dense group cache at every position a live request's decode can observe:
  positions ``<= pos`` hold the identical prefill/decode writes, positions
  ``> pos`` are masked by the attention kernels (finite values, exactly
  zero weight). Unmapped pages gather as zeros — the same zero padding the
  dense prefill scatter leaves behind. Retired/parked slots carry slot id
  ``-1``: their gathers fill zeros and their scatters drop.
* **Shared-prefix pages are refcounted.** When a new request repeats a live
  request's page-aligned token prefix (equal prompt lengths, so both
  prefills are the same jitted program — same math bitwise), its table row
  points at the owner's pages, the refcount rises, and its prefill scatter
  masks those entries so the owner is never written.

The ``pages_per_req``/``page_len`` geometry requires
``page_len * pages_per_req == cache_len`` so every mapped page is fully
overwritten by the admission prefill — recycled pages never need zeroing.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Sequence, Tuple

#: cache leaves whose axis after the batch axis is the cache *position* —
#: these are paged. Everything else (``h``/``tail_x``/``tail_bc``/cross
#: ``xk``/``xv``) is whole-request state and lives in the per-slot row pool.
POSITIONAL_KEYS = frozenset({"k", "v", "c", "kpe", "pos"})


@dataclasses.dataclass(frozen=True)
class PagedCacheSpec:
    """The paged-pool geometry, picklable so it rides spec builders into
    ``runtime="processes"`` workers."""

    page_len: int
    num_pages: int
    max_requests: int                 # num_groups * group_size slot ids
    pages_per_req: int                # cache_len // page_len

    def __post_init__(self):
        for name in ("page_len", "num_pages", "max_requests",
                     "pages_per_req"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"{name} must be a positive int, got {v!r}")

    @property
    def cache_len(self) -> int:
        return self.page_len * self.pages_per_req

    def pages_needed(self, need_len: int) -> int:
        """Pages covering ``need_len`` cache positions."""
        return max(1, math.ceil(need_len / self.page_len))


def map_cache_tree(tree, fn):
    """Map ``fn(key, leaf, stacked)`` over a serve cache tree
    ``{"prologue": [{k: leaf}, ...], "body": [{k: leaf}, ...]}``. ``body``
    leaves carry a leading periods axis (``stacked=True``)."""
    pro = [{k: fn(k, v, False) for k, v in blk.items()}
           for blk in tree["prologue"]]
    body = [{k: fn(k, v, True) for k, v in blk.items()}
            for blk in tree["body"]]
    return {"prologue": pro, "body": body}


def map2_cache_tree(a, b, fn):
    """Two-tree variant of :func:`map_cache_tree` (same structure)."""
    pro = [{k: fn(k, x[k], y[k], False) for k in x}
           for x, y in zip(a["prologue"], b["prologue"])]
    body = [{k: fn(k, x[k], y[k], True) for k in x}
            for x, y in zip(a["body"], b["body"])]
    return {"prologue": pro, "body": body}


def slab_bytes(template, spec: PagedCacheSpec) -> int:
    """Persistent paged-pool bytes for one stage, from the dense group-cache
    ``jax.eval_shape`` template: page slabs for positional leaves, row pools
    for state leaves, plus the page table and cursor tensors."""
    total = 0

    def add(k, leaf, stacked):
        nonlocal total
        shape = _slab_shape(k, leaf.shape, stacked, spec)
        n = 1
        for d in shape:
            n *= int(d)
        total += n * leaf.dtype.itemsize
        return None

    map_cache_tree(template, add)
    total += spec.max_requests * spec.pages_per_req * 4   # page table int32
    total += spec.max_requests * 2 * 4                    # cursors + lengths
    return total


def dense_bytes(template, num_groups: int) -> int:
    """Persistent dense-cache bytes for one stage: one group cache block per
    slot group."""
    total = 0

    def add(k, leaf, stacked):
        nonlocal total
        n = 1
        for d in leaf.shape:
            n *= int(d)
        total += n * leaf.dtype.itemsize
        return None

    map_cache_tree(template, add)
    return total * num_groups


def _slab_shape(key: str, dense_shape, stacked: bool,
                spec: PagedCacheSpec) -> Tuple[int, ...]:
    """Dense group-cache leaf shape -> slab/pool shape.

    Positional leaves: ``(B, L, *f)`` -> ``(num_pages, page_len, *f)``
    (body: leading periods axis kept). State leaves: ``(B, *f)`` ->
    ``(max_requests, *f)``."""
    if key in POSITIONAL_KEYS:
        if stacked:
            return ((dense_shape[0], spec.num_pages, spec.page_len)
                    + tuple(dense_shape[3:]))
        return (spec.num_pages, spec.page_len) + tuple(dense_shape[2:])
    if stacked:
        return (dense_shape[0], spec.max_requests) + tuple(dense_shape[2:])
    return (spec.max_requests,) + tuple(dense_shape[1:])


class PagePool:
    """Driver-side page bookkeeping: the page table, the free stack and the
    per-page refcounts. Pure numpy — the device only ever sees table *rows*
    shipped inside work items, so the pool state never needs to live in (or
    be synchronized across) the stage workers."""

    def __init__(self, spec: PagedCacheSpec):
        import numpy as np

        self.spec = spec
        self.page_table = np.full(
            (spec.max_requests, spec.pages_per_req), -1, np.int32)
        self.ref_counts = np.zeros((spec.num_pages,), np.int32)
        self.req_len = np.zeros((spec.max_requests,), np.int32)
        self._free: List[int] = list(range(spec.num_pages - 1, -1, -1))
        self.peak_pages = 0

    def free_count(self) -> int:
        return len(self._free)

    def used_pages(self) -> int:
        return self.spec.num_pages - len(self._free)

    def alloc(self, sid: int, n_own: int, shared: Sequence[int] = ()):
        """Map slot ``sid``: ``shared`` page ids first (refcounted, owned by
        another live request) then ``n_own`` fresh pages. Returns the int32
        *write row*: the full row with the shared entries masked to ``-1``,
        so the admission prefill scatter never touches the owner's pages."""
        import numpy as np

        spec = self.spec
        if not (0 <= sid < spec.max_requests):
            raise ValueError(f"slot id {sid} outside [0, {spec.max_requests})")
        if (self.page_table[sid] >= 0).any():
            raise ValueError(f"slot id {sid} is already mapped; free it first")
        n_shared = len(shared)
        if n_shared + n_own > spec.pages_per_req:
            raise ValueError(
                f"request needs {n_shared + n_own} pages but pages_per_req="
                f"{spec.pages_per_req} (cache_len / page_len)")
        if n_own > len(self._free):
            raise ValueError(
                f"page pool exhausted: need {n_own} pages, {len(self._free)} "
                f"free of {spec.num_pages}")
        row = np.full((spec.pages_per_req,), -1, np.int32)
        write_row = row.copy()
        for i, p in enumerate(shared):
            if self.ref_counts[p] < 1:
                raise ValueError(f"cannot share unreferenced page {p}")
            row[i] = p
            self.ref_counts[p] += 1
        for i in range(n_own):
            p = self._free.pop()
            row[n_shared + i] = p
            write_row[n_shared + i] = p
            self.ref_counts[p] = 1
        self.page_table[sid] = row
        self.req_len[sid] = 0
        self.peak_pages = max(self.peak_pages, self.used_pages())
        return write_row

    def free(self, sid: int) -> None:
        """Unmap slot ``sid``; pages return to the free stack when their
        refcount hits zero (shared-prefix pages outlive their allocator)."""
        for p in self.page_table[sid]:
            p = int(p)
            if p < 0:
                continue
            self.ref_counts[p] -= 1
            if self.ref_counts[p] == 0:
                self._free.append(p)
            elif self.ref_counts[p] < 0:
                raise AssertionError(f"page {p} refcount underflow")
        self.page_table[sid] = -1
        self.req_len[sid] = 0

    def row(self, sid: int):
        import numpy as np

        return np.array(self.page_table[sid], np.int32)

    def rows(self, sids: Sequence[int]):
        """Stack table rows for a slot group; ``sid < 0`` (parked) rows are
        all ``-1`` so their gathers fill zeros and their scatters drop."""
        import numpy as np

        out = np.full((len(sids), self.spec.pages_per_req), -1, np.int32)
        for i, sid in enumerate(sids):
            if sid >= 0:
                out[i] = self.page_table[sid]
        return out


class PagedStageCache:
    """One stage's paged serving state: the page slabs + row pools, and the
    jitted gather/scatter programs that bridge them to the unchanged dense
    stage programs. Built lazily (like the dense per-group caches) in
    whichever worker owns the stage."""

    def __init__(self, stage, group_size: int, cache_len: int,
                 spec: PagedCacheSpec):
        if spec.cache_len != cache_len:
            raise ValueError(
                f"page_len={spec.page_len} * pages_per_req="
                f"{spec.pages_per_req} = {spec.cache_len} must equal "
                f"cache_len={cache_len}")
        self.stage = stage
        self.group_size = group_size
        self.cache_len = cache_len
        self.spec = spec
        self.slabs = None
        self._fns = None

    # -- lazy slab + program construction ---------------------------------

    def _ensure(self) -> None:
        if self.slabs is not None:
            return
        import jax
        import jax.numpy as jnp

        spec = self.spec
        tok = jnp.zeros((self.group_size,), jnp.int32)
        template = jax.eval_shape(self.stage.init_caches, tok)
        self.slabs = map_cache_tree(
            template,
            lambda k, leaf, stacked: jnp.zeros(
                _slab_shape(k, leaf.shape, stacked, spec), leaf.dtype))
        self._fns = _build_paged_ops(spec, self.group_size, self.cache_len)

    # -- the three work kinds ---------------------------------------------

    def run_decode(self, work, xin):
        """Gather the group's windows, run the unchanged dense decode
        program, scatter back the one position each live slot wrote (plus
        the full per-request state rows)."""
        import jax

        self._ensure()
        window = self._fns["gather"](self.slabs, work.rows, work.sids)
        xout, new_window = self.stage.decode(self.stage.params, window,
                                             xin, work.pos)
        xout = jax.block_until_ready(xout)
        self.slabs = self._fns["scatter_decode"](
            self.slabs, work.rows, work.sids, work.pos, new_window)
        return xout

    def write_prefill(self, work, slot_caches) -> None:
        """Scatter a freshly prefilled request into its mapped pages.
        ``work.row`` is the *write* row — shared-prefix entries are ``-1``
        so the prefix owner's pages are read-only."""
        import jax.numpy as jnp

        self._ensure()
        self.slabs = self._fns["scatter_prefill"](
            self.slabs, jnp.asarray(work.row), jnp.int32(work.sid),
            slot_caches)

    def run_chunk(self, work, xin):
        """One chunked-prefill step: gather (state rows read via
        ``sids_in``, ``-1`` on the first chunk so recurrent state starts
        from exact zeros), run the stage's scan-of-decode chunk program,
        scatter the chunk's positions and the final state row back."""
        import jax

        self._ensure()
        window = self._fns["gather"](self.slabs, work.rows, work.sids_in)
        xout, new_window = self.stage.chunk(self.stage.params, window,
                                            xin, work.pos0, work.adv)
        xout = jax.block_until_ready(xout)
        self.slabs = self._fns["scatter_chunk"](
            int(work.toks.shape[0]), self.slabs, work.rows, work.sids_out,
            work.pos0, work.adv, new_window)
        return xout


def _build_paged_ops(spec: PagedCacheSpec, group_size: int, cache_len: int):
    """Jit the fixed-shape gather/scatter programs for one stage.

    Physical index math: cache position ``pos`` of the slot with table row
    ``row`` lives at flat slab index ``row[pos // page_len] * page_len +
    pos % page_len``. Unmapped pages (entry ``-1``) and parked slots
    (``sid < 0``) are redirected to the out-of-bounds sentinel
    ``num_pages * page_len`` — gathers fill 0 there, scatters drop."""
    import jax
    import jax.numpy as jnp

    B, L, pl = group_size, cache_len, spec.page_len
    total = spec.num_pages * pl
    mr = spec.max_requests

    def _flat(slab, stacked):
        if stacked:
            return slab.reshape((slab.shape[0], total) + slab.shape[3:])
        return slab.reshape((total,) + slab.shape[2:])

    def gather(slabs, rows, sids):
        rows = jnp.asarray(rows, jnp.int32)
        sids = jnp.asarray(sids, jnp.int32)
        pos = jnp.arange(L)
        page = rows[:, pos // pl]                        # (B, L)
        phys = jnp.where(page >= 0, page * pl + pos[None, :] % pl, total)
        sid_idx = jnp.where(sids >= 0, sids, mr)         # OOB -> fill 0

        def g(k, slab, stacked):
            if k in POSITIONAL_KEYS:
                return jnp.take(_flat(slab, stacked), phys,
                                axis=1 if stacked else 0,
                                mode="fill", fill_value=0)
            return jnp.take(slab, sid_idx, axis=1 if stacked else 0,
                            mode="fill", fill_value=0)
        return map_cache_tree(slabs, g)

    def scatter_decode(slabs, rows, sids, pos, window):
        rows = jnp.asarray(rows, jnp.int32)
        sids = jnp.asarray(sids, jnp.int32)
        pos = jnp.asarray(pos, jnp.int32)
        b = jnp.arange(B)
        page = rows[b, pos // pl]                        # (B,)
        ok = (page >= 0) & (sids >= 0)
        phys = jnp.where(ok, page * pl + pos % pl, total)
        sid_idx = jnp.where(sids >= 0, sids, mr)         # OOB -> drop

        def sc(k, slab, win, stacked):
            if k in POSITIONAL_KEYS:
                flat = _flat(slab, stacked)
                if stacked:
                    val = win[:, b, pos]                 # (P, B, *f)
                    flat = flat.at[:, phys].set(val.astype(slab.dtype),
                                                mode="drop")
                else:
                    val = win[b, pos]                    # (B, *f)
                    flat = flat.at[phys].set(val.astype(slab.dtype),
                                             mode="drop")
                return flat.reshape(slab.shape)
            if stacked:
                return slab.at[:, sid_idx].set(win.astype(slab.dtype),
                                               mode="drop")
            return slab.at[sid_idx].set(win.astype(slab.dtype), mode="drop")
        return map2_cache_tree(slabs, window, sc)

    def scatter_prefill(slabs, write_row, sid, slot_caches):
        write_row = jnp.asarray(write_row, jnp.int32)
        pos = jnp.arange(L)
        page = write_row[pos // pl]
        phys = jnp.where(page >= 0, page * pl + pos % pl, total)
        sid_idx = jnp.where(sid >= 0, sid, mr)

        def sc(k, slab, sc_leaf, stacked):
            if k in POSITIONAL_KEYS:
                flat = _flat(slab, stacked)
                if stacked:
                    flat = flat.at[:, phys].set(
                        sc_leaf[:, 0].astype(slab.dtype), mode="drop")
                else:
                    flat = flat.at[phys].set(sc_leaf[0].astype(slab.dtype),
                                             mode="drop")
                return flat.reshape(slab.shape)
            if stacked:
                return slab.at[:, sid_idx].set(
                    sc_leaf[:, 0].astype(slab.dtype), mode="drop")
            return slab.at[sid_idx].set(sc_leaf[0].astype(slab.dtype),
                                        mode="drop")
        return map2_cache_tree(slabs, slot_caches, sc)

    def make_scatter_chunk(T: int):
        b = jnp.arange(B)

        def scatter_chunk_T(slabs, rows, sids, pos0, adv, window):
            rows = jnp.asarray(rows, jnp.int32)
            sids = jnp.asarray(sids, jnp.int32)
            pos0 = jnp.asarray(pos0, jnp.int32)
            adv = jnp.asarray(adv, jnp.int32)
            pos_m = pos0[:, None] + jnp.arange(T)[None, :] * adv[:, None]
            page = jnp.take_along_axis(rows, pos_m // pl, axis=1)  # (B, T)
            ok = (page >= 0) & (sids >= 0)[:, None]
            phys = jnp.where(ok, page * pl + pos_m % pl, total)
            sid_idx = jnp.where(sids >= 0, sids, mr)

            def sc(k, slab, win, stacked):
                if k in POSITIONAL_KEYS:
                    flat = _flat(slab, stacked)
                    if stacked:
                        val = win[:, b[:, None], pos_m]  # (P, B, T, *f)
                        flat = flat.at[:, phys].set(val.astype(slab.dtype),
                                                    mode="drop")
                    else:
                        val = win[b[:, None], pos_m]     # (B, T, *f)
                        flat = flat.at[phys].set(val.astype(slab.dtype),
                                                 mode="drop")
                    return flat.reshape(slab.shape)
                if stacked:
                    return slab.at[:, sid_idx].set(win.astype(slab.dtype),
                                                   mode="drop")
                return slab.at[sid_idx].set(win.astype(slab.dtype),
                                            mode="drop")
            return map2_cache_tree(slabs, window, sc)
        return jax.jit(scatter_chunk_T)

    chunk_fns: Dict[int, Any] = {}

    def scatter_chunk_dispatch(T, slabs, rows, sids, pos0, adv, window):
        # one jit specialization per chunk length (mirrors the per-length
        # prefill specializations of the dense path)
        if T not in chunk_fns:
            chunk_fns[T] = make_scatter_chunk(T)
        return chunk_fns[T](slabs, rows, sids, pos0, adv, window)

    return {"gather": jax.jit(gather),
            "scatter_decode": jax.jit(scatter_decode),
            "scatter_prefill": jax.jit(scatter_prefill),
            "scatter_chunk": scatter_chunk_dispatch}
