"""Temperature/top-k/top-p sampling as an actor-borne RNG register stream.

The sampler state is ONE ``jax.random`` key carried by the actor that owns
the decode head (the last stage actor under ``backend="actors"``, the
inline engine under ``backend="monolithic"``) — the same persistent-state
pattern as the AdamW moments in training pipelines. Every work item that
produces tokens (a prefill, a decode round, the *final* chunk of a chunked
prefill) splits the stream exactly once, and slots inside the item fold
their slot index into the subkey. Because every backend/runtime drives the
identical round structure and the last stage fires in FIFO submission
order, a fixed seed yields token-identical streams across
actors/monolithic x threads/processes — sampled decode is as reproducible
as greedy.

``temperature == 0`` delegates to the existing
:func:`repro.train.steps.greedy_from_logits` verbatim, so greedy sampling
is bitwise-identical to the default (no-sampler) path.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SamplingSpec:
    """Declarative sampling knobs for ``api.compile(..., sampling=)``.

    ``temperature=0`` is exact greedy; ``top_k=0`` / ``top_p=1.0`` disable
    the respective filters. ``seed`` seeds the actor-borne key stream."""

    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(
                f"temperature={self.temperature} must be >= 0 "
                "(0 = greedy)")
        if not isinstance(self.top_k, int) or self.top_k < 0:
            raise ValueError(f"top_k={self.top_k!r} must be an int >= 0 "
                             "(0 = disabled)")
        if not (0.0 < self.top_p <= 1.0):
            raise ValueError(f"top_p={self.top_p} must be in (0, 1] "
                             "(1.0 = disabled)")
        if not isinstance(self.seed, int):
            raise ValueError(f"seed={self.seed!r} must be an int")


def _build_sample_fn(spec: SamplingSpec, vocab_size: int):
    """Jit the per-round sampling program: mask the padded-vocab columns
    (same mask as ``greedy_from_logits``), apply temperature, top-k, then
    top-p (nucleus, always keeping the most likely token), and draw one
    categorical sample per slot with a per-slot folded key."""
    import jax
    import jax.numpy as jnp

    t, k, p = spec.temperature, spec.top_k, spec.top_p

    def one(key, logits):
        vp = logits.shape[-1]
        z = jnp.where(jnp.arange(vp) >= vocab_size, -jnp.inf,
                      logits.astype(jnp.float32))
        z = z / t
        if 0 < k < vocab_size:
            kth = jax.lax.top_k(z, k)[0][-1]
            z = jnp.where(z < kth, -jnp.inf, z)
        if p < 1.0:
            sz = jnp.sort(z)[::-1]
            probs = jax.nn.softmax(sz)
            keep = jnp.cumsum(probs) - probs < p     # top-1 always kept
            thr = jnp.min(jnp.where(keep, sz, jnp.inf))
            z = jnp.where(z < thr, -jnp.inf, z)
        return jax.random.categorical(key, z).astype(jnp.int32)

    def batch(key, logits):
        keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
            jnp.arange(logits.shape[0]))
        return jax.vmap(one)(keys, logits)

    return jax.jit(batch)


class SamplerStream:
    """The persistent sampler register: a key split once per sampled work
    item. Lives in the last stage actor's closure (resident in that stage's
    worker under ``runtime="processes"``) or in the inline engine."""

    def __init__(self, spec: SamplingSpec, vocab_size: int):
        import jax

        self.spec = spec
        self.vocab_size = vocab_size
        self.key = jax.random.PRNGKey(spec.seed)
        self._fn = (None if spec.temperature == 0
                    else _build_sample_fn(spec, vocab_size))

    def sample(self, logits):
        """Draw one token per row of ``(B, padded_vocab)`` logits, advancing
        the key stream. ``temperature == 0`` is exact greedy — bitwise the
        existing ``greedy_from_logits`` path (the stream still advances so
        greedy and sampled sessions consume keys identically)."""
        import jax

        self.key, sub = jax.random.split(self.key)
        if self._fn is None:
            from repro.train.steps import greedy_from_logits

            return greedy_from_logits(logits, self.vocab_size)
        return self._fn(sub, logits)
