"""Continuous-batching admission scheduling, including chunked prefill.

:class:`AdmissionScheduler` is the driver-side round planner extracted from
``ServeSession.generate``: it owns the slot table, the FIFO admission
queue, and the per-request cursors, and each round emits the work-item
list that the engines (inline or actor pipeline) execute. The dense path
runs through it unchanged — same items, same order, token for token.

Under ``cache="paged"`` it additionally owns the :class:`PagePool`
handshake: admission allocates a request's worst-case page budget
(``prompt_len + max_new_tokens - 1`` positions) up front, shares
page-aligned common prefixes with live equal-length requests, applies
backpressure (the queue head waits, in order) when the pool is short, and
frees pages at retirement.

**Chunked prefill** (paged-only, ``prefill_chunk=``): a prompt longer than
the chunk budget is admitted as a sequence of bounded
:class:`~repro.runtime.pipeline.PrefillChunkWork` items — one per round,
interleaved with every group's decode work — so a long prompt never
head-of-line-blocks decoding. Each chunk drives the stage's scan-of-decode
program over at most ``prefill_chunk`` positions; recurrent state persists
between chunks in the request's pool row (read via ``sids_in``, ``-1`` on
the first chunk so SSM/conv state starts from exact zeros), and the final
chunk's last-position logits produce the request's first token.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple


class AdmissionScheduler:
    """Plan rounds of serve work items and absorb their sampled tokens.

    Drive it as::

        while not sched.done():
            work, meta = sched.plan_round()
            results = engine.run_round(work)
            for m, toks in zip(meta, tokens_of(results)):
                sched.absorb(m, toks)

    ``prompts`` are validated int32 arrays, ``gens`` the per-request new
    token budgets. ``pool`` (a :class:`repro.serve.paged_cache.PagePool`)
    switches the paged admission path on; ``prefill_chunk`` and
    ``share_prefix`` require it.
    """

    def __init__(self, prompts, gens, *, num_groups: int, group_size: int,
                 cache_len: int, pool=None, prefill_chunk: Optional[int] = None,
                 share_prefix: bool = False):
        if (prefill_chunk is not None or share_prefix) and pool is None:
            raise ValueError("prefill_chunk/share_prefix require a PagePool "
                             "(cache='paged')")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk={prefill_chunk} must be >= 1")
        self.prompts = list(prompts)
        self.gens = [int(g) for g in gens]
        self.num_groups = num_groups
        self.group_size = group_size
        self.cache_len = cache_len
        self.park = cache_len - 1          # never inside a live window
        self.pool = pool
        self.prefill_chunk = prefill_chunk
        self.share_prefix = share_prefix
        self.queue: List[int] = list(range(len(self.prompts)))
        self.slots: List[List[Optional[Dict[str, Any]]]] = [
            [None] * group_size for _ in range(num_groups)]
        self.outputs: List[List[int]] = [[] for _ in self.prompts]
        self.admitted_mid_flight = 0
        self.shared_pages = 0
        self._first_round = True
        # live, fully-prefilled requests eligible as prefix donors: req -> sid
        self._registry: Dict[int, int] = {}

    def done(self) -> bool:
        return not self.queue and all(
            st is None for grp in self.slots for st in grp)

    # -- round planning ----------------------------------------------------

    def plan_round(self) -> Tuple[List[Any], List[Tuple]]:
        """One round: admissions for empty slots (FIFO, with page
        backpressure), one chunk item per mid-chunk slot, then one decode
        item per group with live slots. Returns ``(work, meta)``; meta
        tuples are ``("prefill", g, b)``, ``("chunk", g, b, final)`` and
        ``("decode", g, live_slots)``."""
        import jax.numpy as jnp

        from repro.runtime.pipeline import DecodeWork, PrefillWork

        work: List[Any] = []
        meta: List[Tuple] = []
        blocked = False                   # pool backpressure: head waits
        for g in range(self.num_groups):
            for b in range(self.group_size):
                if self.slots[g][b] is None and self.queue and not blocked:
                    blocked = not self._admit(g, b, work, meta)
                st = self.slots[g][b]
                if st is not None and st.get("chunk_off") is not None:
                    work.append(self._chunk_work(g, b))
                    off = st["chunk_off"]
                    T = min(self.prefill_chunk,
                            self.prompts[st["req"]].size - off)
                    meta.append(("chunk", g, b,
                                 off + T == self.prompts[st["req"]].size))
            live = [b for b in range(self.group_size)
                    if self.slots[g][b] is not None
                    and self.slots[g][b]["pos"] is not None]
            if live:
                tok = [self.slots[g][b]["tok"] if b in live else 0
                       for b in range(self.group_size)]
                pos = [self.slots[g][b]["pos"] if b in live else self.park
                       for b in range(self.group_size)]
                kw = {}
                if self.pool is not None:
                    sids = [self.slots[g][b]["sid"] if b in live else -1
                            for b in range(self.group_size)]
                    kw = {"sids": jnp.asarray(sids, jnp.int32),
                          "rows": jnp.asarray(self.pool.rows(sids))}
                work.append(DecodeWork(group=g,
                                       tok=jnp.asarray(tok, jnp.int32),
                                       pos=jnp.asarray(pos, jnp.int32), **kw))
                meta.append(("decode", g, live))
        self._first_round = False
        if not work and not self.done():
            raise RuntimeError(
                "admission stalled: queued requests but no admissible work "
                "(page pool too small for the queue head?)")
        return work, meta

    def _admit(self, g: int, b: int, work, meta) -> bool:
        """Admit the queue head into slot ``(g, b)``; returns False when the
        page pool can't cover it yet (FIFO backpressure)."""
        import jax.numpy as jnp

        from repro.runtime.pipeline import PrefillWork

        r = self.queue[0]
        toks = self.prompts[r]
        st: Dict[str, Any] = {"req": r, "pos": None, "tok": 0,
                              "remaining": self.gens[r]}
        sid, row = -1, None
        chunked = (self.prefill_chunk is not None
                   and toks.size > self.prefill_chunk)
        if self.pool is not None:
            spec = self.pool.spec
            sid = g * self.group_size + b
            n_pages = spec.pages_needed(toks.size + max(0, self.gens[r] - 1))
            shared = []
            if self.share_prefix and not chunked:
                shared = self._prefix_pages(toks, spec.page_len)
            if self.pool.free_count() < n_pages - len(shared):
                return False
            row = self.pool.alloc(sid, n_pages - len(shared), shared)
            self.shared_pages += len(shared)
            st["sid"] = sid
        self.queue.pop(0)
        if not self._first_round:
            self.admitted_mid_flight += 1
        if chunked:
            st["chunk_off"] = 0            # emitted by the caller's loop
        else:
            # natural length, no padding: right-padding would poison
            # recurrent SSM/conv state (attention caches are positional,
            # SSM state is not); each distinct prompt length costs one jit
            # specialization
            work.append(PrefillWork(group=g, slot=b,
                                    tokens=jnp.asarray(toks[None]),
                                    last_index=toks.size - 1,
                                    sid=sid, row=row))
            meta.append(("prefill", g, b))
        self.slots[g][b] = st
        return True

    def _prefix_pages(self, toks, page_len: int) -> List[int]:
        """Whole pages of ``toks`` already held by a live, fully-prefilled
        request with the *same prompt length* (equal lengths share one jit
        specialization, so the shared positions are bitwise-identical).
        Returns the donor's page ids for the common page-aligned prefix."""
        import numpy as np

        best: List[int] = []
        for r, sid in self._registry.items():
            other = self.prompts[r]
            if other.size != toks.size:
                continue
            ne = np.nonzero(other != toks)[0]
            common = int(ne[0]) if ne.size else toks.size
            n_sh = common // page_len
            if n_sh > len(best):
                best = [int(p) for p in self.pool.page_table[sid][:n_sh]]
        return best

    def _chunk_work(self, g: int, b: int):
        """One bounded prefill chunk for slot ``(g, b)``: the group-shaped
        item whose non-owner columns are parked no-ops (``adv == 0``, table
        row ``-1``) so the chunk program keeps the group's fixed shape."""
        import numpy as np

        import jax.numpy as jnp

        from repro.runtime.pipeline import PrefillChunkWork

        st = self.slots[g][b]
        toks = self.prompts[st["req"]]
        off = st["chunk_off"]
        T = min(self.prefill_chunk, toks.size - off)
        B = self.group_size
        mat = np.zeros((T, B), np.int32)
        mat[:, b] = toks[off:off + T]
        pos0 = np.full((B,), self.park, np.int32)
        pos0[b] = off
        adv = np.zeros((B,), np.int32)
        adv[b] = 1
        sids_in = np.full((B,), -1, np.int32)
        if off > 0:                        # first chunk starts from zeros
            sids_in[b] = st["sid"]
        sids_out = np.full((B,), -1, np.int32)
        sids_out[b] = st["sid"]
        rows = np.full((B, self.pool.spec.pages_per_req), -1, np.int32)
        rows[b] = self.pool.row(st["sid"])
        return PrefillChunkWork(
            group=g, slot=b, toks=jnp.asarray(mat),
            pos0=jnp.asarray(pos0), adv=jnp.asarray(adv),
            rows=jnp.asarray(rows), sids_in=jnp.asarray(sids_in),
            sids_out=jnp.asarray(sids_out), final=off + T == toks.size)

    # -- result absorption ---------------------------------------------------

    def absorb(self, m: Tuple, toks) -> None:
        """Fold one work item's tokens back into the slot table. ``toks`` is
        the item's sampled/greedy token vector (``None`` for a non-final
        chunk, which produces no token)."""
        if m[0] == "prefill":
            _, g, b = m
            self._emit(g, b, int(toks[0]),
                       self.prompts[self.slots[g][b]["req"]].size)
        elif m[0] == "chunk":
            _, g, b, final = m
            st = self.slots[g][b]
            L = self.prompts[st["req"]].size
            if not final:
                st["chunk_off"] += min(self.prefill_chunk,
                                       L - st["chunk_off"])
                return
            st["chunk_off"] = None
            self._emit(g, b, int(toks[b]), L)
        else:
            _, g, live = m
            for b in live:
                st = self.slots[g][b]
                self._emit(g, b, int(toks[b]), st["pos"] + 1)

    def _emit(self, g: int, b: int, tok: int, next_pos: int) -> None:
        """Record one generated token for slot ``(g, b)``; retire the slot
        (freeing its pages) when its budget is spent, otherwise advance its
        cursor to ``next_pos``."""
        st = self.slots[g][b]
        self.outputs[st["req"]].append(tok)
        st["remaining"] -= 1
        if st["remaining"] == 0:
            if self.pool is not None:
                self.pool.free(st["sid"])
                self._registry.pop(st["req"], None)
            self.slots[g][b] = None
            return
        if st["pos"] is None and self.share_prefix and "chunk_off" not in st:
            # fully prefilled by the one-shot prefill program: eligible as a
            # prefix donor (chunk-built caches use different math, so
            # chunked sessions never donate)
            self._registry[st["req"]] = st["sid"]
        st["pos"] = next_pos
        st["tok"] = tok
