"""Production-scale serving subsystem (ROADMAP: paged KV caches, sampling,
chunked prefill).

The dense serve path (PR 5) holds one ``(group_size, cache_len, ...)`` cache
block per slot group per stage — every admitted request reserves its
worst-case window whether it uses it or not. This package replaces that
reservation with the paper's register discipline applied to serving state:

* :mod:`repro.serve.paged_cache` — one preallocated page slab per stage
  (``(num_pages, page_len, ...)`` per KV tensor) plus an int32 page table
  and per-request cursors; alloc/free are host bookkeeping, gather/scatter
  are jitted fixed-shape programs, shared-prefix pages are refcounted.
* :mod:`repro.serve.sampler` — temperature/top-k/top-p sampling as an
  actor-borne RNG register stream (keys split per sampled work item), so
  sampled decode is reproducible and identical across
  actors/monolithic x threads/processes.
* :mod:`repro.serve.admission` — the continuous-batching admission
  scheduler, including chunked prefill: long prompts become bounded work
  items interleaved with decode rounds.

Everything is reached through ``api.compile(cfg, mode="serve",
cache="paged", page_len=..., num_pages=..., sampling=...)``; the dense path
stays untouched as the bit-identity reference.
"""
from repro.serve.admission import AdmissionScheduler
from repro.serve.paged_cache import PagedCacheSpec, PagedStageCache, PagePool
from repro.serve.sampler import SamplerStream, SamplingSpec

__all__ = ["AdmissionScheduler", "PagedCacheSpec", "PagedStageCache",
           "PagePool", "SamplerStream", "SamplingSpec"]
