"""Lowering — turn (LogicalGraph, Plan) into an executable SPMD program.

This is the compiler's final stage (paper Fig 1/5): every op runs *locally* on
its shard under ``shard_map``; wherever producer SBP != consumer SBP, the
planner's boxing edge becomes an explicit ``jax.lax`` collective
(:func:`repro.core.boxing.boxing_fn`). Partial-value tensors flow through as
real unreduced per-device arrays, so deferred reduction (§3.3) happens exactly
as planned.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.core.boxing import boxing_fn
from repro.core.graph import LogicalGraph, LOp
from repro.core.planner import Plan
from repro.core.sbp import Broadcast, NdSbp, Partial, Split


def _split_axes_for(sig: NdSbp, tensor_axis: int, axis_names: Sequence[str]) -> List[str]:
    """Mesh axis names on which ``tensor_axis`` is split under ``sig``."""
    return [name for comp, name in zip(sig, axis_names)
            if isinstance(comp, Split) and comp.axis == tensor_axis]


def _partial_axes(sig: NdSbp, axis_names: Sequence[str]) -> List[str]:
    return [name for comp, name in zip(sig, axis_names) if comp.is_partial]


_UNARY_FNS = {
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "tanh": jnp.tanh,
    "neg": jnp.negative,
    "identity": lambda x: x,
    "scale2": lambda x: 2.0 * x,
}


def _local_op(op: LOp, in_sigs: Tuple[NdSbp, ...], out_sig: NdSbp,
              axis_names: Sequence[str], mesh_shape: Sequence[int]):
    """Return fn(local_inputs) -> local_output implementing op under the sigs."""
    kind = op.spec.name
    attrs = op.spec.attrs

    if kind == "matmul":
        def f(x, w):
            return jnp.dot(x, w)
        return f

    if kind == "ew_binary":
        opn = attrs.get("op", "add")
        fn = {"add": jnp.add, "mul": jnp.multiply}[opn]
        return fn

    if kind == "ew_unary":
        return _UNARY_FNS[attrs.get("fn", "identity")]

    if kind == "bias_add":
        return lambda x, b: x + b[None, :]

    if kind == "reduce":
        axis, red = attrs["axis"], attrs.get("op", "sum")
        jfn = {"sum": jnp.sum, "max": jnp.max, "min": jnp.min}[red]
        return lambda x: jfn(x, axis=axis, keepdims=True)

    if kind == "softmax":
        # hierarchical softmax (paper Fig 11b): local max/sum + global combine
        red_axes = _split_axes_for(in_sigs[0], 1, axis_names)

        def f(x):
            m = jnp.max(x, axis=1, keepdims=True)
            for ax in red_axes:
                m = jax.lax.pmax(m, ax)
            e = jnp.exp(x - m)
            s = jnp.sum(e, axis=1, keepdims=True)
            for ax in red_axes:
                s = jax.lax.psum(s, ax)
            return e / s
        return f

    if kind == "softmax_xent":
        red_axes = _split_axes_for(in_sigs[0], 1, axis_names)
        vocab_frac = 1
        for name, size in zip(axis_names, mesh_shape):
            if name in red_axes:
                vocab_frac *= size
        local_c = op.inputs[0].shape[1] // vocab_frac

        def f(logits, labels):
            m = jnp.max(logits, axis=1, keepdims=True)
            for ax in red_axes:
                m = jax.lax.pmax(m, ax)
            e = jnp.exp(logits - m)
            s = jnp.sum(e, axis=1, keepdims=True)
            for ax in red_axes:
                s = jax.lax.psum(s, ax)
            # local gather of the label logit (zero when out of shard range)
            if red_axes:
                offset = jnp.zeros((), jnp.int32)
                stride = 1
                for name, size in reversed(list(zip(axis_names, mesh_shape))):
                    if name in red_axes:
                        offset = offset + jax.lax.axis_index(name) * stride * local_c
                        stride *= size
                local_ids = labels - offset
                in_range = (local_ids >= 0) & (local_ids < local_c)
                safe = jnp.clip(local_ids, 0, local_c - 1)
                picked = jnp.take_along_axis(logits, safe[:, None], axis=1)
                z = jnp.where(in_range[:, None], picked - m, 0.0)
                # output is P(sum) over red_axes: exactly one shard contributes
                return jnp.log(s) - z
            z = jnp.take_along_axis(logits, labels[:, None], axis=1)
            return jnp.log(s) - (z - m)
        return f

    if kind == "embedding":
        red_axes = _split_axes_for(in_sigs[0], 0, axis_names)  # vocab split
        hid_split = _split_axes_for(in_sigs[0], 1, axis_names)

        def f(table, ids):
            if red_axes:
                local_v = table.shape[0]
                offset = jnp.zeros((), jnp.int32)
                stride = 1
                for name, size in reversed(list(zip(axis_names, mesh_shape))):
                    if name in red_axes:
                        offset = offset + jax.lax.axis_index(name) * stride * local_v
                        stride *= size
                local_ids = ids - offset
                in_range = (local_ids >= 0) & (local_ids < local_v)
                safe = jnp.clip(local_ids, 0, local_v - 1)
                out = table[safe]
                return jnp.where(in_range[:, None], out, 0.0)  # P(sum)
            return table[ids]
        return f

    raise NotImplementedError(f"no local lowering for op kind {kind}")


def lower_plan(graph: LogicalGraph, plan: Plan, mesh) -> "PhysicalProgram":
    axis_names = tuple(mesh.axis_names)
    mesh_shape = tuple(mesh.devices.shape)

    in_specs, out_specs = [], []
    for t in graph.inputs:
        sig = plan.tensor_sbp[t.name]
        if sig.has_partial:
            raise ValueError(f"graph input {t.name} planned as partial-value")
        in_specs.append(graph.placement.partition_spec(sig))

    consumed = set()
    for op in graph.ops:
        for t in op.inputs:
            consumed.add(t.name)
    sinks = [op.output for op in graph.ops if op.output.name not in consumed]
    for t in sinks:
        sig = plan.tensor_sbp[t.name]
        if sig.has_partial:
            raise ValueError(f"graph output {t.name} planned as partial-value; "
                             "planner should have boxed it")
        out_specs.append(graph.placement.partition_spec(sig))

    def local_program(*local_inputs):
        env = {t.name: v for t, v in zip(graph.inputs, local_inputs)}
        for op in graph.topo_ops():
            in_sigs = plan.op_in_sbp[op.name]
            raw_sig = plan.op_out_sbp[op.name]
            stored_sig = plan.tensor_sbp[op.output.name]
            args = []
            for t, want in zip(op.inputs, in_sigs):
                have = plan.tensor_sbp[t.name]
                v = env[t.name]
                if have != want:
                    v = boxing_fn(have, want, axis_names, mesh_shape, t.shape)(v)
                args.append(v)
            fn = _local_op(op, in_sigs, raw_sig, axis_names, mesh_shape)
            val = fn(*args)
            if raw_sig != stored_sig:  # epilogue boxing (e.g. P materialization)
                val = boxing_fn(raw_sig, stored_sig, axis_names, mesh_shape,
                                op.output.shape)(val)
            env[op.output.name] = val
        return tuple(env[t.name] for t in sinks)

    mapped = jax.shard_map(local_program, mesh=mesh,
                           in_specs=tuple(in_specs), out_specs=tuple(out_specs),
                           check_vma=False)
    return PhysicalProgram(graph, plan, mesh, mapped, sinks)


class PhysicalProgram:
    """Executable physical graph: shard_map program + metadata."""

    def __init__(self, graph, plan, mesh, fn, sinks):
        self.graph, self.plan, self.mesh = graph, plan, mesh
        self._fn = jax.jit(fn)
        self.sinks = sinks

    def __call__(self, *global_inputs):
        outs = self._fn(*global_inputs)
        return outs if len(outs) > 1 else outs[0]

    def lower(self, *global_inputs):
        return self._fn.lower(*global_inputs)
