"""Lowering — turn (LogicalGraph, Plan) into an executable SPMD program.

This is the compiler's final stage (paper Fig 1/5): every op runs *locally* on
its shard under ``shard_map``; wherever producer SBP != consumer SBP, the
planner's boxing edge becomes an explicit ``jax.lax`` collective
(:func:`repro.core.boxing.boxing_fn`). Partial-value tensors flow through as
real unreduced per-device arrays, so deferred reduction (§3.3) happens exactly
as planned.

Two entry points share one subgraph lowerer:

* :func:`lower_plan` — the whole graph as one jitted ``shard_map`` program
  (:class:`PhysicalProgram`).
* :func:`lower_stages` — the graph cut by a
  :class:`repro.core.graph.StagePartition` into per-stage jitted programs
  (:class:`StagedProgram`), with boxing at stage boundaries. This is the
  compiler half of actor-driven pipeline execution (§4.3): the runtime half
  lives in :mod:`repro.runtime.pipeline`.

These (and the training variants :func:`lower_train_plan` /
:func:`lower_train_stages`) are compiler internals; user code reaches them
through the :mod:`repro.api` frontend — ``api.compile(graph, ...)`` picks
the plan/partition/quotas and wraps the result in a uniform ``Session``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.boxing import boxing_fn
from repro.core.graph import LogicalGraph, LOp, LTensor, StagePartition
from repro.core.planner import Plan
from repro.core.sbp import Broadcast, NdSbp, Split

from repro.compat import shard_map


def _split_axes_for(sig: NdSbp, tensor_axis: int, axis_names: Sequence[str]) -> List[str]:
    """Mesh axis names on which ``tensor_axis`` is split under ``sig``."""
    return [name for comp, name in zip(sig, axis_names)
            if isinstance(comp, Split) and comp.axis == tensor_axis]


def _partial_axes(sig: NdSbp, axis_names: Sequence[str]) -> List[str]:
    return [name for comp, name in zip(sig, axis_names) if comp.is_partial]


_UNARY_FNS = {
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "tanh": jnp.tanh,
    "neg": jnp.negative,
    "identity": lambda x: x,
    "scale2": lambda x: 2.0 * x,
}


def _local_op(op: LOp, in_sigs: Tuple[NdSbp, ...], out_sig: NdSbp,
              axis_names: Sequence[str], mesh_shape: Sequence[int]):
    """Return fn(local_inputs) -> local_output implementing op under the sigs."""
    kind = op.spec.name
    attrs = op.spec.attrs

    if kind == "matmul":
        def f(x, w):
            return jnp.dot(x, w)
        return f

    if kind == "ew_binary":
        opn = attrs.get("op", "add")
        fn = {"add": jnp.add, "mul": jnp.multiply}[opn]
        return fn

    if kind == "ew_unary":
        return _UNARY_FNS[attrs.get("fn", "identity")]

    if kind == "bias_add":
        return lambda x, b: x + b[None, :]

    if kind == "reduce":
        axis, red = attrs["axis"], attrs.get("op", "sum")
        jfn = {"sum": jnp.sum, "max": jnp.max, "min": jnp.min}[red]
        return lambda x: jfn(x, axis=axis, keepdims=True)

    if kind == "softmax":
        # hierarchical softmax (paper Fig 11b): local max/sum + global combine
        red_axes = _split_axes_for(in_sigs[0], 1, axis_names)

        def f(x):
            m = jnp.max(x, axis=1, keepdims=True)
            for ax in red_axes:
                m = jax.lax.pmax(m, ax)
            e = jnp.exp(x - m)
            s = jnp.sum(e, axis=1, keepdims=True)
            for ax in red_axes:
                s = jax.lax.psum(s, ax)
            return e / s
        return f

    if kind == "softmax_xent":
        red_axes = _split_axes_for(in_sigs[0], 1, axis_names)
        vocab_frac = 1
        for name, size in zip(axis_names, mesh_shape):
            if name in red_axes:
                vocab_frac *= size
        local_c = op.inputs[0].shape[1] // vocab_frac

        def f(logits, labels):
            m = jnp.max(logits, axis=1, keepdims=True)
            for ax in red_axes:
                m = jax.lax.pmax(m, ax)
            e = jnp.exp(logits - m)
            s = jnp.sum(e, axis=1, keepdims=True)
            for ax in red_axes:
                s = jax.lax.psum(s, ax)
            # local gather of the label logit (zero when out of shard range)
            if red_axes:
                offset = jnp.zeros((), jnp.int32)
                stride = 1
                for name, size in reversed(list(zip(axis_names, mesh_shape))):
                    if name in red_axes:
                        offset = offset + jax.lax.axis_index(name) * stride * local_c
                        stride *= size
                local_ids = labels - offset
                in_range = (local_ids >= 0) & (local_ids < local_c)
                safe = jnp.clip(local_ids, 0, local_c - 1)
                picked = jnp.take_along_axis(logits, safe[:, None], axis=1)
                z = jnp.where(in_range[:, None], picked - m, 0.0)
                # output is P(sum) over red_axes: exactly one shard contributes
                return jnp.log(s) - z
            z = jnp.take_along_axis(logits, labels[:, None], axis=1)
            return jnp.log(s) - (z - m)
        return f

    if kind == "embedding":
        red_axes = _split_axes_for(in_sigs[0], 0, axis_names)  # vocab split
        hid_split = _split_axes_for(in_sigs[0], 1, axis_names)

        def f(table, ids):
            if red_axes:
                local_v = table.shape[0]
                offset = jnp.zeros((), jnp.int32)
                stride = 1
                for name, size in reversed(list(zip(axis_names, mesh_shape))):
                    if name in red_axes:
                        offset = offset + jax.lax.axis_index(name) * stride * local_v
                        stride *= size
                local_ids = ids - offset
                in_range = (local_ids >= 0) & (local_ids < local_v)
                safe = jnp.clip(local_ids, 0, local_v - 1)
                out = table[safe]
                return jnp.where(in_range[:, None], out, 0.0)  # P(sum)
            return table[ids]
        return f

    raise NotImplementedError(f"no local lowering for op kind {kind}")


def _materialized(sig: NdSbp) -> NdSbp:
    """Partial-free storage signature: P components become B (all-reduce).

    Tensors that cross a jit boundary (graph outputs, pipeline-stage
    boundaries) must be real globally-addressable arrays — partial-value only
    exists *inside* a shard_map program.
    """
    return NdSbp(tuple(Broadcast() if c.is_partial else c for c in sig))


def _lower_subgraph(graph: LogicalGraph, plan: Plan, mesh,
                    ops: Sequence[LOp],
                    in_tensors: Sequence[LTensor],
                    out_tensors: Sequence[LTensor],
                    in_sbp: Dict[str, NdSbp],
                    out_sbp: Dict[str, NdSbp]):
    """shard_map program running ``ops`` from ``in_tensors`` to ``out_tensors``.

    ``in_sbp``/``out_sbp`` give the *stored* (partial-free) signatures at the
    subgraph boundary; inside, tensors follow the plan exactly, including
    partial-value storage.
    """
    axis_names = tuple(mesh.axis_names)
    mesh_shape = tuple(mesh.devices.shape)

    for t in in_tensors:
        if in_sbp[t.name].has_partial:
            raise ValueError(f"boundary input {t.name} stored as partial-value")
    for t in out_tensors:
        if out_sbp[t.name].has_partial:
            raise ValueError(f"boundary output {t.name} stored as partial-value")

    in_specs = tuple(graph.placement.partition_spec(in_sbp[t.name])
                     for t in in_tensors)
    out_specs = tuple(graph.placement.partition_spec(out_sbp[t.name])
                      for t in out_tensors)

    def local_program(*local_inputs):
        env = {t.name: v for t, v in zip(in_tensors, local_inputs)}
        cur_sbp = {t.name: in_sbp[t.name] for t in in_tensors}
        for op in ops:
            in_sigs = plan.op_in_sbp[op.name]
            raw_sig = plan.op_out_sbp[op.name]
            stored_sig = plan.tensor_sbp[op.output.name]
            args = []
            for t, want in zip(op.inputs, in_sigs):
                have = cur_sbp[t.name]
                v = env[t.name]
                if have != want:
                    v = boxing_fn(have, want, axis_names, mesh_shape, t.shape)(v)
                args.append(v)
            fn = _local_op(op, in_sigs, raw_sig, axis_names, mesh_shape)
            val = fn(*args)
            if raw_sig != stored_sig:  # epilogue boxing (e.g. P materialization)
                val = boxing_fn(raw_sig, stored_sig, axis_names, mesh_shape,
                                op.output.shape)(val)
            env[op.output.name] = val
            cur_sbp[op.output.name] = stored_sig
        outs = []
        for t in out_tensors:
            v, have, want = env[t.name], cur_sbp[t.name], out_sbp[t.name]
            if have != want:  # boundary boxing (e.g. P -> B materialization)
                v = boxing_fn(have, want, axis_names, mesh_shape, t.shape)(v)
            outs.append(v)
        return tuple(outs)

    return shard_map(local_program, mesh=mesh,
                     in_specs=in_specs, out_specs=out_specs)


def lower_plan(graph: LogicalGraph, plan: Plan, mesh) -> "PhysicalProgram":
    for t in graph.inputs:
        if plan.tensor_sbp[t.name].has_partial:
            raise ValueError(f"graph input {t.name} planned as partial-value")
    sinks = graph.sinks()
    for t in sinks:
        if plan.tensor_sbp[t.name].has_partial:
            raise ValueError(f"graph output {t.name} planned as partial-value; "
                             "planner should have boxed it")
    boundary = {t.name: plan.tensor_sbp[t.name] for t in list(graph.inputs) + sinks}
    mapped = _lower_subgraph(graph, plan, mesh, graph.topo_ops(),
                             graph.inputs, sinks, boundary, boundary)
    return PhysicalProgram(graph, plan, mesh, mapped, sinks)


class PhysicalProgram:
    """Executable physical graph: shard_map program + metadata.

    Calling it always returns a tuple of sink values, in ``self.sinks``
    order — including for single-sink graphs.
    """

    def __init__(self, graph, plan, mesh, fn, sinks):
        self.graph, self.plan, self.mesh = graph, plan, mesh
        self._fn = jax.jit(fn)
        self.sinks = sinks

    def __call__(self, *global_inputs) -> Tuple:
        return tuple(self._fn(*global_inputs))

    def lower(self, *global_inputs):
        return self._fn.lower(*global_inputs)


# ---------------------------------------------------------------------------
# Stage-partitioned lowering (paper §4.3): each pipeline stage becomes its own
# jitted program; tensors crossing a stage boundary are stored partial-free.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StageProgram:
    """One lowered pipeline stage: a jitted callable plus its interface.

    ``fn(*values)`` takes one value per ``input_names`` entry (graph inputs
    and/or boundary tensors from earlier stages) and returns a tuple with one
    value per ``output_names`` entry (boundary tensors and/or graph sinks).
    """

    index: int
    fn: Callable
    input_names: Tuple[str, ...]
    output_names: Tuple[str, ...]
    mesh: object = None
    in_shardings: Optional[Tuple] = None    # set when stages own distinct meshes

    def place_inputs(self, values: Sequence) -> List:
        """Transfer boundary values onto this stage's devices (the explicit
        cross-stage send; a no-op when all stages share one mesh)."""
        if self.in_shardings is None:
            return list(values)
        return [jax.device_put(v, sh)
                for v, sh in zip(values, self.in_shardings)]


class StagedProgram:
    """A pipeline of independently-jitted stage programs.

    Sequential execution (``__call__``) is the reference semantics; the actor
    runtime adapter (:mod:`repro.runtime.pipeline`) drives the same stage
    callables concurrently, one actor per stage, with register quotas bounding
    in-flight microbatches.
    """

    def __init__(self, graph: LogicalGraph, plan: Plan,
                 partition: StagePartition, stages: List[StageProgram],
                 sinks: List[LTensor], boundary_sbp: Dict[str, NdSbp]):
        self.graph, self.plan, self.partition = graph, plan, partition
        self.stages = stages
        self.sinks = sinks
        self.boundary_sbp = boundary_sbp

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    @property
    def input_names(self) -> List[str]:
        return [t.name for t in self.graph.inputs]

    def __call__(self, *global_inputs) -> Tuple:
        if len(global_inputs) != len(self.graph.inputs):
            raise ValueError(f"expected {len(self.graph.inputs)} inputs, "
                             f"got {len(global_inputs)}")
        env = {t.name: v for t, v in zip(self.graph.inputs, global_inputs)}
        for stage in self.stages:
            args = stage.place_inputs([env[n] for n in stage.input_names])
            outs = stage.fn(*args)
            env.update(zip(stage.output_names, outs))
        return tuple(env[t.name] for t in self.sinks)


@dataclasses.dataclass
class _StageInterface:
    """Boundary interface of one pipeline stage: which tensors enter and
    leave it, with their stored (partial-free) signatures."""

    ops: List[LOp]
    in_tensors: List[LTensor]
    out_tensors: List[LTensor]
    in_sbp: Dict[str, NdSbp]
    out_sbp: Dict[str, NdSbp]


def _stage_interfaces(graph: LogicalGraph, plan: Plan,
                      partition: StagePartition):
    """Compute every stage's boundary: ``(sinks, boundary_sbp, interfaces)``.

    Shared by forward-only (:func:`lower_stages`) and training
    (:func:`lower_train_stages`) lowering.

    ``boundary_sbp`` maps every stage-crossing (or sink) tensor to its
    *materialized* signature (``_materialized`` rewrites P components to B),
    which is the invariant the static verifier leans on:
    :func:`repro.analysis.sbp_check.check_sbp` treats these signatures as
    the stage-boundary ground truth (no partial value crosses a stage), and
    :mod:`repro.analysis.membound` prices register payloads from them.
    """
    sinks = graph.sinks()
    sink_names = {t.name for t in sinks}
    producer_stage = {t.name: partition.stage_of[t.producer.name]
                      for t in graph.tensors if t.producer is not None}

    # tensors leaving each stage: consumed by a later stage, or graph sinks
    stage_out: Dict[int, List[LTensor]] = {s: [] for s in range(partition.num_stages)}
    boundary_sbp: Dict[str, NdSbp] = {}
    for op in graph.topo_ops():
        t = op.output
        ps = producer_stage[t.name]
        consumer_stages = {partition.stage_of[c.name] for c in graph.consumers(t)}
        crosses = any(cs > ps for cs in consumer_stages)
        if crosses or t.name in sink_names:
            stage_out[ps].append(t)
            boundary_sbp[t.name] = _materialized(plan.tensor_sbp[t.name])

    for t in graph.inputs:
        if plan.tensor_sbp[t.name].has_partial:
            raise ValueError(f"graph input {t.name} planned as partial-value")

    interfaces: List[_StageInterface] = []
    for s in range(partition.num_stages):
        ops = partition.ops_in(graph, s)
        in_here = {t.name for op in ops for t in op.inputs}
        produced_here = {op.output.name for op in ops}
        # stage inputs in deterministic order: graph inputs first, then
        # boundary tensors in production (topo) order
        in_tensors: List[LTensor] = [
            t for t in graph.inputs if t.name in in_here]
        in_tensors += [
            t for sp in range(s) for t in stage_out[sp]
            if t.name in in_here and t.name not in produced_here]
        in_sbp = {}
        for t in in_tensors:
            in_sbp[t.name] = (plan.tensor_sbp[t.name] if t.producer is None
                              else boundary_sbp[t.name])
        out_tensors = stage_out[s]
        out_sbp = {t.name: boundary_sbp[t.name] for t in out_tensors}
        interfaces.append(_StageInterface(ops, in_tensors, out_tensors,
                                          in_sbp, out_sbp))
    return sinks, boundary_sbp, interfaces


def _boundary_shardings(placement, mesh, tensors: Sequence[LTensor],
                        sbp: Dict[str, NdSbp]) -> Tuple:
    """NamedShardings for boundary tensors on one stage's mesh — used for
    the explicit cross-stage transfers when stages own distinct meshes."""
    return tuple(
        jax.sharding.NamedSharding(mesh, placement.partition_spec(sbp[t.name]))
        for t in tensors)


def _resolve_meshes(partition: StagePartition, mesh,
                    stage_meshes: Optional[Sequence]):
    if stage_meshes is not None:
        if len(stage_meshes) != partition.num_stages:
            raise ValueError(f"need {partition.num_stages} stage meshes, "
                             f"got {len(stage_meshes)}")
        return list(stage_meshes)
    if mesh is None:
        raise ValueError("pass either mesh or stage_meshes")
    return [mesh] * partition.num_stages


def lower_stages(graph: LogicalGraph, plan: Plan, partition: StagePartition,
                 mesh=None, stage_meshes: Optional[Sequence] = None
                 ) -> StagedProgram:
    """Lower each pipeline stage of ``partition`` independently.

    ``mesh`` lowers every stage onto the same device mesh (stages share
    devices; pipelining overlaps host work and microbatches). Alternatively
    ``stage_meshes`` gives one mesh per stage — same axis names/sizes but
    possibly *disjoint* devices, the paper's placement of one stage per device
    group. Tensors crossing a stage boundary are stored with their
    :func:`_materialized` (partial-free) signature and boxed on exit.
    """
    meshes = _resolve_meshes(partition, mesh, stage_meshes)
    sinks, boundary_sbp, interfaces = _stage_interfaces(graph, plan, partition)

    stages: List[StageProgram] = []
    for s, iface in enumerate(interfaces):
        mapped = _lower_subgraph(graph, plan, meshes[s], iface.ops,
                                 iface.in_tensors, iface.out_tensors,
                                 iface.in_sbp, iface.out_sbp)
        in_shardings = None
        if stage_meshes is not None:
            in_shardings = _boundary_shardings(
                graph.placement, meshes[s], iface.in_tensors, iface.in_sbp)
        stages.append(StageProgram(
            index=s, fn=jax.jit(mapped),
            input_names=tuple(t.name for t in iface.in_tensors),
            output_names=tuple(t.name for t in iface.out_tensors),
            mesh=meshes[s], in_shardings=in_shardings))
    return StagedProgram(graph, plan, partition, stages, sinks, boundary_sbp)


# ---------------------------------------------------------------------------
# Training lowering (paper §4.3 + the JaxPP-style MPMD fwd/bwd decomposition):
# each forward stage is differentiated with jax.vjp so residuals/activations
# stay stage-local (they live inside the returned vjp closure, a pytree the
# runtime stashes in the forward actor's out register) while cotangents flow
# backward across stage boundaries. The optimizer update is its own tiny
# program per stage. The runtime half lives in repro.runtime.pipeline.
# ---------------------------------------------------------------------------

@jax.jit
def sgd_update(w, g, lr):
    """The per-stage optimizer-update program: plain SGD.

    One shared jitted callable so the pipelined step and the monolithic
    reference (:func:`lower_train_plan`) apply a *bit-identical* update.
    fp32 math, result cast back to the param dtype (bf16 params train with
    fp32-accumulated gradients).
    """
    return (w.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(w.dtype)


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Mixed-precision policy for a training session (paper Fig 14, §6.4).

    ``compute_dtype`` is what fwd/bwd see: params are cast at the
    forward-stage boundary (the Fig-14 ``cast`` op — one cast per step, so a
    sharded master crosses the wire at compute width), while the optimizer
    keeps fp32 *masters* and fp32 moments. ``loss_scale`` is ``None`` (off),
    a static float (the backward seed is ``scale`` instead of ones;
    accumulated grads are unscaled by ``1/scale`` before the norm), or
    ``"dynamic"``: start at ``init_scale``, multiply by ``backoff_factor``
    and skip the update when the grad norm goes non-finite, multiply by
    ``growth_factor`` after ``growth_interval`` consecutive finite steps.
    Masters are always fp32 — that is what makes bf16 compute lossless to
    round-trip (every bf16 value is exactly representable in fp32).
    """

    compute_dtype: str = "bfloat16"       # "float32" | "bfloat16"
    loss_scale: Any = None                # None | float | "dynamic"
    init_scale: float = 2.0 ** 15         # dynamic mode's starting scale
    growth_interval: int = 2000           # finite steps before scale grows
    growth_factor: float = 2.0
    backoff_factor: float = 0.5

    def __post_init__(self):
        if self.compute_dtype not in ("float32", "bfloat16"):
            raise ValueError(
                f"unsupported compute_dtype {self.compute_dtype!r} "
                "(use 'float32' or 'bfloat16')")
        ls = self.loss_scale
        if ls is not None and ls != "dynamic":
            if not isinstance(ls, (int, float)) or float(ls) <= 0:
                raise ValueError(
                    f"loss_scale must be None, a positive number, or "
                    f"'dynamic'; got {ls!r}")
        if self.growth_interval < 1:
            raise ValueError("growth_interval must be >= 1")


def loss_scale_update(policy: PrecisionPolicy, scale: float, good_steps: int,
                      grads_finite: bool) -> Tuple[bool, float, int]:
    """One dynamic-loss-scale transition: ``(skip, next_scale, next_good)``.

    Shared by the pipelined ``scale`` actor and the monolithic engine so the
    scale trajectories (and skip decisions) are identical on every backend.
    """
    if not grads_finite:
        return True, float(scale) * float(policy.backoff_factor), 0
    good = int(good_steps) + 1
    if good >= int(policy.growth_interval):
        return False, float(scale) * float(policy.growth_factor), 0
    return False, float(scale), good


@dataclasses.dataclass(frozen=True)
class OptimizerSpec:
    """Pluggable optimizer for staged training programs (SGD or AdamW).

    ``lr`` is either a float or a step-indexed callable ``lr(step) -> float``
    (``step`` counts optimizer steps from 0 — the schedule is resolved on the
    host once per step and broadcast into every stage's update program).
    ``grad_clip`` > 0 enables *global*-norm clipping: the pipeline wires a
    ``norm`` actor that sums per-stage squared-norm partials (P→B boxing
    expressed as an actor) and broadcasts the clip scale back to every
    ``opt{s}``. AdamW carries persistent :class:`repro.optim.adamw.AdamWState`
    (step count, mu, nu) per stage — the second register stream.

    ``zero=True`` (AdamW only) shards that stream ZeRO-style (paper §6.4):
    the optimizer holds flat ``(dp, 1, chunk)`` fp32 master/moment shards
    (:mod:`repro.optim.zero`) instead of dense params + ``AdamWState``, and
    ``update`` takes/returns masters in that layout. ``zero_dp`` is the
    data-axis fold, ``zero_shapes`` the original param shapes the gather
    restores (``api.compile`` records both). ``precision`` adds a
    :class:`PrecisionPolicy` on top — bf16 compute params gathered from fp32
    masters each step, with optional loss scaling.
    """

    kind: str = "sgd"                     # "sgd" | "adamw"
    lr: Any = 1e-2                        # float or fn(step) -> float
    beta1: float = 0.9                    # adamw only below
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 0.0                # 0 disables global-norm clipping
    zero: bool = False                    # ZeRO-shard masters + moments
    zero_dp: int = 1                      # data-axis fold of the flat shards
    zero_shapes: Any = None               # ((name, shape), ...) for gathers
    precision: Optional[PrecisionPolicy] = None

    def __post_init__(self):
        if self.kind not in ("sgd", "adamw"):
            raise ValueError(f"unknown optimizer kind {self.kind!r}")
        if self.zero and self.kind != "adamw":
            raise ValueError(
                "zero=True shards AdamW state; it requires kind='adamw'")
        if self.zero and self.zero_dp < 1:
            raise ValueError(f"zero_dp must be >= 1, got {self.zero_dp}")
        if self.precision is not None and not isinstance(self.precision,
                                                         PrecisionPolicy):
            raise ValueError("precision must be a PrecisionPolicy")
        if (self.precision is not None and self.precision.loss_scale is not None
                and self.precision.compute_dtype == "float32"):
            raise ValueError(
                "loss_scale requires compute_dtype='bfloat16' (fp32 compute "
                "has nothing to rescue from underflow)")

    @classmethod
    def sgd(cls, lr: Any = 1e-2, grad_clip: float = 0.0) -> "OptimizerSpec":
        return cls(kind="sgd", lr=lr, grad_clip=grad_clip)

    @classmethod
    def adamw(cls, lr: Any = 3e-4, beta1: float = 0.9, beta2: float = 0.95,
              eps: float = 1e-8, weight_decay: float = 0.1,
              grad_clip: float = 1.0) -> "OptimizerSpec":
        return cls(kind="adamw", lr=lr, beta1=beta1, beta2=beta2, eps=eps,
                   weight_decay=weight_decay, grad_clip=grad_clip)

    @property
    def stateful(self) -> bool:
        return self.kind == "adamw"

    def lr_at(self, step: int) -> float:
        return float(self.lr(step)) if callable(self.lr) else float(self.lr)

    # -- mixed-precision / ZeRO accessors -----------------------------------

    @property
    def mixed_precision(self) -> bool:
        """True when the optimizer holds explicit fp32 masters (a precision
        policy is set, or ZeRO sharding is on)."""
        return self.precision is not None or self.zero

    @property
    def compute_dtype(self) -> Optional[str]:
        """The dtype fwd/bwd see params in, or None to keep the param dtype
        as given (the legacy no-masters behavior)."""
        if self.precision is not None:
            return self.precision.compute_dtype
        return "float32" if self.zero else None

    @property
    def loss_scaling(self) -> Any:
        """None (off), a static float, or ``"dynamic"``."""
        return None if self.precision is None else self.precision.loss_scale

    @property
    def dynamic_scaling(self) -> bool:
        return self.loss_scaling == "dynamic"

    def initial_scale(self) -> float:
        ls = self.loss_scaling
        if ls is None:
            return 1.0
        if ls == "dynamic":
            return float(self.precision.init_scale)
        return float(ls)

    @property
    def zero_shape_map(self) -> Dict[str, Tuple[int, ...]]:
        """Param name -> original shape, for gathering flat ZeRO shards."""
        if self.zero_shapes is None:
            raise ValueError(
                "OptimizerSpec.zero_shapes is unset; api.compile records the "
                "param shapes when zero=True")
        items = (self.zero_shapes.items()
                 if isinstance(self.zero_shapes, dict) else self.zero_shapes)
        return {n: tuple(int(d) for d in s) for n, s in items}

    def shard_masters(self, params: Dict[str, Any]) -> Dict[str, Any]:
        """Full params -> flat fp32 ``(dp, 1, chunk)`` master shards."""
        from repro.optim.zero import shard_flat
        return {n: shard_flat(jnp.asarray(v), dp=self.zero_dp)
                for n, v in params.items()}

    def gather_params(self, masters: Dict[str, Any], dtype: str = "float32",
                      shapes: Optional[Dict[str, Tuple[int, ...]]] = None):
        """Flat master shards -> full params in ``dtype`` (the Fig-14 cast
        happens *before* the reshape-gather, so a bf16 gather moves half the
        bytes of an fp32 one)."""
        from repro.optim.zero import gather_flat
        shapes = self.zero_shape_map if shapes is None else shapes
        return {n: gather_flat(m, shape=tuple(shapes[n]), dtype=dtype)
                for n, m in masters.items()}

    def init_state(self, params: Dict[str, Any]):
        """Fresh optimizer state for ``params`` (None for stateless SGD).

        With ``zero=True``, ``params`` are the *flat master shards* and the
        returned state is a flat :class:`repro.optim.zero.ZeroState`."""
        if self.kind == "sgd":
            return None
        if self.zero:
            from repro.optim.zero import init_zero_flat
            return init_zero_flat(dict(params))
        from repro.optim.adamw import init_adamw
        return init_adamw(dict(params))

    def update(self, params: Dict[str, Any], grads: Dict[str, Any], state,
               lr_now: float):
        """Apply one optimizer step to ``params`` given already-clipped fp32
        ``grads``. Returns ``(new_params, new_state)``.

        Per-tensor math runs through shared jitted kernels
        (:func:`sgd_update` / :func:`repro.optim.adamw.adamw_param_update`),
        so applying this to per-stage param subsets (the opt actors) or to
        the full param dict (the monolithic reference) yields bit-identical
        values tensor by tensor.
        """
        if self.kind == "sgd":
            return {n: sgd_update(params[n], grads[n], lr_now)
                    for n in params}, None
        if self.zero:
            from repro.optim.zero import zero_stage_update
            if state is None:
                state = self.init_state(params)
            return zero_stage_update(
                params, grads, state, lr_now, dp=self.zero_dp,
                beta1=self.beta1, beta2=self.beta2, eps=self.eps,
                weight_decay=self.weight_decay)
        from repro.optim.adamw import AdamWState, adamw_param_update
        if state is None:
            state = self.init_state(params)
        new_step = state.step + 1
        new_p, new_mu, new_nu = {}, {}, {}
        for n in params:
            new_p[n], new_mu[n], new_nu[n] = adamw_param_update(
                params[n], grads[n], state.mu[n], state.nu[n], new_step,
                lr_now, beta1=self.beta1, beta2=self.beta2, eps=self.eps,
                weight_decay=self.weight_decay)
        return new_p, AdamWState(new_step, new_mu, new_nu)

    def split_state(self, state, stage_param_names: Dict[int, Sequence[str]]):
        """Split a merged optimizer state into per-stage states keyed by
        stage index (the snapshot-restore tap: a state saved under one
        stage partition re-splits under another). ``stage_param_names``
        maps stage index -> that stage's param names. Stateless optimizers
        split to None entries."""
        if not self.stateful or state is None:
            return {s: None for s in stage_param_names}
        out = {}
        if self.zero:
            # A merged state is always the *full* AdamWState (load_snapshot
            # gathers shards on the host), so re-splitting is shape-agnostic:
            # shard each stage's moments flat at this spec's dp fold.
            from repro.optim.zero import ZeroState, shard_flat
            for s, names in stage_param_names.items():
                missing = [n for n in names if n not in state.mu]
                if missing:
                    raise ValueError(
                        f"optimizer state missing moments for params {missing}")
                out[s] = ZeroState(
                    state.step,
                    {n: shard_flat(state.mu[n], dp=self.zero_dp)
                     for n in names},
                    {n: shard_flat(state.nu[n], dp=self.zero_dp)
                     for n in names})
            return out
        from repro.optim.adamw import AdamWState
        for s, names in stage_param_names.items():
            missing = [n for n in names if n not in state.mu]
            if missing:
                raise ValueError(
                    f"optimizer state missing moments for params {missing}")
            out[s] = AdamWState(state.step,
                                {n: state.mu[n] for n in names},
                                {n: state.nu[n] for n in names})
        return out

    def merge_states(self, states: Sequence[Any]):
        """Inverse of :meth:`split_state`: merge per-stage states into one
        state over all params (None for a stateless optimizer)."""
        if not self.stateful:
            return None
        from repro.optim.adamw import AdamWState
        states = [s for s in states if s is not None]
        if not states:
            return None
        mu: Dict[str, Any] = {}
        nu: Dict[str, Any] = {}
        if self.zero:
            # Flat per-stage ZeroStates gather back to a full AdamWState so
            # the merged form is partition- and zero-agnostic.
            shapes = self.zero_shape_map
            for st in states:
                mu.update(self.gather_params(st.mu, shapes=shapes))
                nu.update(self.gather_params(st.nu, shapes=shapes))
            return AdamWState(states[0].step, mu, nu)
        for st in states:
            mu.update(st.mu)
            nu.update(st.nu)
        return AdamWState(states[0].step, mu, nu)


def _zero_cot(v):
    """Zero cotangent matching ``v``: zeros for inexact dtypes, a float0
    array for integer outputs (what jax.vjp requires for non-diff outputs)."""
    import numpy as np
    v = jnp.asarray(v)
    if jnp.issubdtype(v.dtype, jnp.inexact):
        return jnp.zeros_like(v)
    return np.zeros(v.shape, dtype=jax.dtypes.float0)


def split_microbatches(inputs: Dict[str, Any], microbatch_names: Sequence[str],
                       num_microbatches: int) -> List[Dict[str, Any]]:
    """Split each named input into ``num_microbatches`` equal chunks along
    axis 0 — one payload dict per microbatch, in version order.

    Both the actor pipeline and the monolithic reference step chunk with this
    one helper so their gradient accumulation orders are bit-identical.
    """
    import numpy as np
    for n in microbatch_names:
        if inputs[n].shape[0] % num_microbatches:
            raise ValueError(
                f"input {n} axis 0 ({inputs[n].shape[0]}) not divisible by "
                f"num_microbatches={num_microbatches}")
    payloads: List[Dict[str, Any]] = [dict() for _ in range(num_microbatches)]
    for n in microbatch_names:
        for k, chunk in enumerate(np.split(np.asarray(inputs[n]),
                                           num_microbatches, axis=0)):
            payloads[k][n] = chunk
    return payloads


def reassemble_sinks(graph: LogicalGraph, sinks: Sequence[LTensor],
                     microbatch_inputs: Sequence[str],
                     per_chunk: Sequence[Dict[str, Any]]) -> Tuple:
    """Reassemble graph sinks from per-microbatch results (the inverse of
    :func:`split_microbatches`), one value per ``sinks`` entry.

    Sinks downstream of a microbatched input are per-chunk slices ->
    concatenate along the batch axis; anything else (e.g. a weights-only
    sink) is recomputed identically every chunk -> take one copy. Shared by
    the actor pipeline and the monolithic backend so the two reassemble
    bit-identically.
    """
    import numpy as np

    mb_dependent = graph.downstream_of(microbatch_inputs)
    results = []
    for t in sinks:
        if t.name in mb_dependent:
            results.append(np.concatenate(
                [np.asarray(d[t.name]) for d in per_chunk], axis=0))
        else:
            results.append(np.asarray(per_chunk[0][t.name]))
    return tuple(results)


def _scatter_args(diff_idx: Sequence[int], nondiff_idx: Sequence[int],
                  n_in: int, diff_vals: Sequence,
                  nondiff_vals: Sequence) -> List:
    """Rebuild a positional argument list from its diff/nondiff partition.

    One helper shared by :func:`lower_train_plan` and
    :func:`lower_train_stages` so the monolithic reference and the pipelined
    stages assemble ``jax.vjp`` arguments identically — the bit-identity
    contract depends on these staying in lockstep.
    """
    args = [None] * n_in
    for i, v in zip(diff_idx, diff_vals):
        args[i] = v
    for i, v in zip(nondiff_idx, nondiff_vals):
        args[i] = v
    return args


def _resolve_loss(graph: LogicalGraph, loss) -> LTensor:
    sinks = graph.sinks()
    if loss is None:
        if len(sinks) != 1:
            raise ValueError(
                f"graph has {len(sinks)} sinks "
                f"({[t.name for t in sinks]}); pass loss= explicitly")
        return sinks[0]
    name = loss.name if isinstance(loss, LTensor) else loss
    for t in sinks:
        if t.name == name:
            return t
    raise ValueError(f"loss {name!r} is not a graph sink "
                     f"(sinks: {[t.name for t in sinks]})")


def _resolve_params(graph: LogicalGraph, params) -> List[LTensor]:
    by_name = {t.name: t for t in graph.inputs}
    out = []
    for p in params:
        name = p.name if isinstance(p, LTensor) else p
        if name not in by_name:
            raise ValueError(f"param {name!r} is not a graph input")
        t = by_name[name]
        if t.dtype not in ("float32", "bfloat16", "float16"):
            raise ValueError(f"param {name!r} has non-float dtype {t.dtype}")
        out.append(t)
    return out


@dataclasses.dataclass
class TrainStageProgram:
    """One pipeline stage of a training graph: forward, backward, interface.

    ``fwd(*values)`` takes one value per ``input_names`` entry and returns
    ``(outputs, vjp)`` — the stage outputs (one per ``output_names``) plus the
    stage's vjp closure. The closure is a jax pytree (``tree_util.Partial``)
    holding the stage-local residuals/activations; the actor runtime stashes
    it in the forward actor's out register so it is recycled exactly when the
    backward actor acks (the paper's stashed-activation register).

    ``bwd(vjp, cotangents)`` takes that closure plus one cotangent per output
    (see :meth:`output_cotangents`) and returns one cotangent per
    ``diff_input_names`` entry: gradients for this stage's params, upstream
    cotangents for boundary activations from earlier stages. ``bwd`` is None
    for a stage with no differentiable inputs.
    """

    index: int
    fwd: Callable
    bwd: Optional[Callable]
    input_names: Tuple[str, ...]
    output_names: Tuple[str, ...]
    diff_input_names: Tuple[str, ...]
    param_names: Tuple[str, ...]
    mesh: object = None
    in_shardings: Optional[Tuple] = None
    cot_shardings: Optional[Dict[str, Any]] = None

    def place_inputs(self, values: Sequence) -> List:
        """Transfer forward boundary values onto this stage's devices (the
        explicit cross-stage send; no-op when all stages share one mesh)."""
        if self.in_shardings is None:
            return list(values)
        return [jax.device_put(v, sh)
                for v, sh in zip(values, self.in_shardings)]

    def output_cotangents(self, outputs: Dict[str, Any],
                          cotangents: Dict[str, Any],
                          loss_name: str, loss_seed=None) -> Tuple:
        """Assemble the vjp seed for this stage: ones for the loss sink (the
        objective is the *sum* of the loss tensor over each microbatch),
        incoming cotangents for outputs consumed downstream, zeros for the
        rest. ``loss_seed`` overrides the ones-seed with a constant (the
        loss-scale: seeding ``scale`` instead of 1 multiplies every cotangent
        by it, which keeps bf16 grads out of the underflow range). Cross-mesh
        cotangents are transferred onto this stage's devices first (the
        explicit backward cross-stage send)."""
        seeds = []
        for name in self.output_names:
            if name == loss_name:
                if loss_seed is None:
                    seeds.append(jnp.ones_like(outputs[name]))
                else:
                    seeds.append(jnp.full_like(outputs[name], loss_seed))
            elif name in cotangents:
                v = cotangents[name]
                if self.cot_shardings is not None and name in self.cot_shardings:
                    v = jax.device_put(v, self.cot_shardings[name])
                seeds.append(v)
            else:
                seeds.append(_zero_cot(outputs[name]))
        return tuple(seeds)


class TrainStagedProgram:
    """A training graph cut into forward / backward / optimizer programs.

    Produced by :func:`lower_train_stages`. ``stages[s]`` holds stage s's
    forward and backward programs; ``opt_update`` is the shared per-tensor
    SGD program (:func:`sgd_update`), and ``optimizer`` is the pluggable
    :class:`OptimizerSpec` (None means the executor's default SGD).
    :meth:`reference_step` is the sequential reference semantics; the
    concurrent actor-driven execution (1F1B from register quotas) lives in
    :class:`repro.runtime.pipeline.TrainPipelineExecutor`.
    """

    def __init__(self, graph: LogicalGraph, plan: Plan,
                 partition: StagePartition, stages: List[TrainStageProgram],
                 loss: LTensor, param_names: Tuple[str, ...],
                 boundary_sbp: Dict[str, NdSbp],
                 optimizer: Optional[OptimizerSpec] = None):
        self.graph, self.plan, self.partition = graph, plan, partition
        self.stages = stages
        self.loss = loss
        self.param_names = param_names
        self.boundary_sbp = boundary_sbp
        self.opt_update = sgd_update
        self.optimizer = optimizer

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    @property
    def loss_name(self) -> str:
        return self.loss.name

    @property
    def input_names(self) -> List[str]:
        return [t.name for t in self.graph.inputs]

    def stage_of_param(self, name: str) -> int:
        for st in self.stages:
            if name in st.param_names:
                return st.index
        raise KeyError(name)

    def reference_step(self, inputs: Dict[str, Any],
                       microbatch_inputs: Sequence[str],
                       num_microbatches: int, lr: float = 1e-2,
                       optimizer: Optional[OptimizerSpec] = None,
                       opt_state=None, step_index: Optional[int] = None):
        """Sequential (non-actor) execution of one training step.

        Runs every microbatch through all forward stages, then all backward
        stages, accumulating gradients in fp32 in microbatch order, and
        applies the optimizer update. Returns ``(loss, grads, new_params)``
        with the same bit-exact semantics as the actor pipeline: the
        objective is the sum of the loss tensor over the whole batch.

        When an optimizer is in play (``optimizer=`` here or set on the
        program), returns ``(loss, grads, new_params, new_state)`` instead —
        ``grads`` post-clip, ``new_state`` None for SGD. Pass the previous
        ``opt_state`` to continue a stateful (AdamW) run; the lr schedule
        resolves at ``step_index`` (default: ``opt_state.step`` when stateful,
        else 0 — a stateless SGD schedule needs ``step_index`` passed
        explicitly on every call after the first).
        """
        chunks = split_microbatches(inputs, microbatch_inputs,
                                    num_microbatches)
        mb_names = set(microbatch_inputs)
        loss_total = None
        grads: Dict[str, Any] = {}
        for chunk in chunks:
            env = {n: (chunk[n] if n in mb_names else inputs[n])
                   for n in self.input_names}
            vjps = {}
            for st in self.stages:
                args = st.place_inputs([env[n] for n in st.input_names])
                outs, vjp = st.fwd(*args)
                env.update(zip(st.output_names, outs))
                vjps[st.index] = vjp
            cots: Dict[str, Any] = {}
            for st in reversed(self.stages):
                if st.bwd is None:
                    continue
                seeds = st.output_cotangents(env, cots, self.loss_name)
                in_cots = st.bwd(vjps[st.index], seeds)
                for name, c in zip(st.diff_input_names, in_cots):
                    if name in st.param_names:
                        c32 = c.astype(jnp.float32)
                        grads[name] = (grads[name] + c32 if name in grads
                                       else c32)
                    else:
                        cots[name] = (cots[name] + c if name in cots else c)
            ls = jnp.sum(env[self.loss_name])
            loss_total = ls if loss_total is None else loss_total + ls
        opt = optimizer if optimizer is not None else self.optimizer
        if opt is not None and (opt.zero or opt.precision is not None):
            raise NotImplementedError(
                "reference_step does not model zero/mixed precision; compare "
                "against the api.compile monolithic backend instead")
        if opt is None:
            new_params = {n: self.opt_update(inputs[n], grads[n], lr)
                          for n in self.param_names}
            return loss_total, grads, new_params
        from repro.optim.adamw import (clip_scale, global_norm_from_partials,
                                       scale_grad, sqnorm_partials)
        if opt.grad_clip:
            norm = global_norm_from_partials(sqnorm_partials(grads),
                                             self.param_names)
            scale = clip_scale(norm, opt.grad_clip)
            grads = {n: scale_grad(g, scale) for n, g in grads.items()}
        if opt.stateful and opt_state is None:
            opt_state = opt.init_state({n: inputs[n]
                                        for n in self.param_names})
        if step_index is None:
            step_index = int(opt_state.step) if opt_state is not None else 0
        new_params, new_state = opt.update(
            {n: inputs[n] for n in self.param_names}, grads, opt_state,
            opt.lr_at(step_index))
        return loss_total, grads, new_params, new_state


def lower_train_plan(graph: LogicalGraph, plan: Plan, mesh, params,
                     loss=None, scaled: bool = False) -> Callable:
    """Monolithic training program — the reference the pipeline is checked
    against. Returns a jitted ``fn(*graph_input_values) -> (loss_vec, grads)``
    where ``loss_vec`` is the (unreduced) loss sink and ``grads`` holds
    ``d(sum(loss_vec))/d(param)`` for each param, in ``params`` order.

    Differentiation seeds ``ones_like(loss_vec)`` exactly like the pipelined
    backward stages, so per-microbatch gradients are bit-identical to the
    composed per-stage vjps. With ``scaled=True`` the returned function takes
    ``fn(loss_seed, *graph_input_values)`` and seeds ``full_like(loss_vec,
    loss_seed)`` instead — the loss-scaling hook, matching the pipelined
    :meth:`TrainStageProgram.output_cotangents` seed exactly.
    """
    loss_t = _resolve_loss(graph, loss)
    param_ts = _resolve_params(graph, params)
    sinks = graph.sinks()
    for t in sinks:
        if plan.tensor_sbp[t.name].has_partial:
            raise ValueError(f"graph output {t.name} planned as partial-value")
    boundary = {t.name: plan.tensor_sbp[t.name]
                for t in list(graph.inputs) + sinks}
    mapped = _lower_subgraph(graph, plan, mesh, graph.topo_ops(),
                             graph.inputs, sinks, boundary, boundary)
    loss_pos = [t.name for t in sinks].index(loss_t.name)
    n_in = len(graph.inputs)
    diff_idx = [i for i, t in enumerate(graph.inputs)
                if t.name in {p.name for p in param_ts}]
    # keep grads in the caller's `params` order, not graph-input order
    order = {graph.inputs[i].name: j for j, i in enumerate(diff_idx)}
    perm = [order[p.name] for p in param_ts]

    nondiff_idx = [i for i in range(n_in) if i not in set(diff_idx)]

    def value_and_grad(*all_ins):
        diff_vals = [all_ins[i] for i in diff_idx]
        nondiff_vals = [all_ins[i] for i in nondiff_idx]

        def f(*dv):
            return mapped(*_scatter_args(diff_idx, nondiff_idx, n_in, dv,
                                         nondiff_vals))[loss_pos]

        loss_vec, vjp = jax.vjp(f, *diff_vals)
        raw = vjp(jnp.ones_like(loss_vec))
        return loss_vec, tuple(raw[j] for j in perm)

    def value_and_grad_scaled(loss_seed, *all_ins):
        diff_vals = [all_ins[i] for i in diff_idx]
        nondiff_vals = [all_ins[i] for i in nondiff_idx]

        def f(*dv):
            return mapped(*_scatter_args(diff_idx, nondiff_idx, n_in, dv,
                                         nondiff_vals))[loss_pos]

        loss_vec, vjp = jax.vjp(f, *diff_vals)
        raw = vjp(jnp.full_like(loss_vec, loss_seed))
        return loss_vec, tuple(raw[j] for j in perm)

    return jax.jit(value_and_grad_scaled if scaled else value_and_grad)


def lower_train_stages(graph: LogicalGraph, plan: Plan,
                       partition: StagePartition, params, loss=None,
                       mesh=None, stage_meshes: Optional[Sequence] = None,
                       optimizer: Optional[OptimizerSpec] = None
                       ) -> TrainStagedProgram:
    """Cut a training graph into forward / backward / optimizer programs.

    Builds on :func:`lower_stages`' forward partition: each stage's lowered
    shard_map program is differentiated with ``jax.vjp`` over its
    *differentiable* inputs — the stage-local params plus any boundary
    activations derived from params. Residuals stay inside the per-stage vjp
    closure (stage-local); only cotangents cross stage boundaries, flowing
    backward along the same seams the activations flowed forward.

    ``params`` names the graph inputs to be trained; each must be consumed by
    ops of exactly one stage (pipeline parallelism shards params by stage).
    ``loss`` names the graph sink to differentiate (default: the sole sink).
    ``mesh`` / ``stage_meshes`` as in :func:`lower_stages`. ``optimizer`` is
    an optional :class:`OptimizerSpec` carried on the program (the executor
    falls back to plain SGD when absent).
    """
    meshes = _resolve_meshes(partition, mesh, stage_meshes)
    loss_t = _resolve_loss(graph, loss)
    param_ts = _resolve_params(graph, params)
    param_names = {t.name for t in param_ts}

    for p in param_ts:
        stages_using = {partition.stage_of[c.name]
                        for c in graph.consumers(p)}
        if len(stages_using) != 1:
            raise ValueError(
                f"param {p.name!r} is consumed by stages "
                f"{sorted(stages_using)}; pipeline training requires each "
                "param to live on exactly one stage")

    requires_grad = graph.downstream_of(param_names)
    loss_anc = graph.ancestors(loss_t)
    for p in param_ts:
        if p.name not in loss_anc:
            raise ValueError(
                f"param {p.name!r} does not feed the loss {loss_t.name!r}; "
                "its gradient would be identically zero — drop it from "
                "params or pick the right loss sink")

    def diff(name: str) -> bool:
        return name in requires_grad and name in loss_anc

    _, boundary_sbp, interfaces = _stage_interfaces(graph, plan, partition)

    stages: List[TrainStageProgram] = []
    for s, iface in enumerate(interfaces):
        mapped = _lower_subgraph(graph, plan, meshes[s], iface.ops,
                                 iface.in_tensors, iface.out_tensors,
                                 iface.in_sbp, iface.out_sbp)
        in_names = tuple(t.name for t in iface.in_tensors)
        n_in = len(in_names)
        diff_idx = [i for i, t in enumerate(iface.in_tensors)
                    if diff(t.name)]
        nondiff_idx = [i for i in range(n_in) if i not in set(diff_idx)]
        diff_in = tuple(in_names[i] for i in diff_idx)
        stage_params = tuple(n for n in diff_in if n in param_names)

        if diff_idx:
            def fwd_py(*ins, _mapped=mapped, _diff=tuple(diff_idx),
                       _nondiff=tuple(nondiff_idx), _n=n_in):
                diff_vals = [ins[i] for i in _diff]
                nondiff_vals = [ins[i] for i in _nondiff]

                def f(*dv):
                    return _mapped(*_scatter_args(_diff, _nondiff, _n, dv,
                                                  nondiff_vals))

                return jax.vjp(f, *diff_vals)

            fwd = jax.jit(fwd_py)
            bwd = jax.jit(lambda vjp, cots: vjp(cots))
        else:
            fwd = jax.jit(lambda *ins, _mapped=mapped: (_mapped(*ins), None))
            bwd = None

        in_shardings = None
        cot_shardings = None
        if stage_meshes is not None:
            in_shardings = _boundary_shardings(
                graph.placement, meshes[s], iface.in_tensors, iface.in_sbp)
            cot_shardings = dict(zip(
                (t.name for t in iface.out_tensors),
                _boundary_shardings(graph.placement, meshes[s],
                                    iface.out_tensors, iface.out_sbp)))
        stages.append(TrainStageProgram(
            index=s, fwd=fwd, bwd=bwd,
            input_names=in_names,
            output_names=tuple(t.name for t in iface.out_tensors),
            diff_input_names=diff_in, param_names=stage_params,
            mesh=meshes[s], in_shardings=in_shardings,
            cot_shardings=cot_shardings))

    all_params = tuple(p.name for p in param_ts)
    return TrainStagedProgram(graph, plan, partition, stages, loss_t,
                              all_params, boundary_sbp, optimizer=optimizer)


# ---------------------------------------------------------------------------
# Serve lowering (paper §4.3 applied to serving): the autoregressive decode
# step cut into per-stage jitted programs. Stage s owns a contiguous slice of
# the layer stack; its KV/SSM caches never leave the stage — they are a
# persistent stage-local register stream (the same pattern as the optimizer
# state in training pipelines), updated in place by every decode fire. The
# request-admission runtime half lives in repro.runtime.pipeline
# (ServePipelineExecutor).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeStage:
    """One lowered decode/prefill pipeline stage.

    ``decode(params, caches, xin, pos) -> (xout, new_caches)``: one token for
    a full slot group. ``xin`` is the token ids (B,) on the first stage, the
    hidden (B, 1, d) elsewhere; ``xout`` is the model-sharded logits
    (B, padded_vocab) on the last stage, the hidden elsewhere.

    ``prefill(params, xin, last_index) -> (xout, slot_caches)``: run one
    admitted request's prompt (batch-replicated, typically B=1) through the
    slice and build its caches; the last stage returns the first-token logits
    gathered at ``last_index`` (the prompt's final position) through the SAME
    head math as ``decode``. ``init_caches(tok) -> caches`` allocates the
    zeroed group cache; ``write_slot(caches, slot_caches, slot)`` scatters a
    freshly prefilled request into slot ``slot`` of the group cache.

    ``chunk(params, caches, xin, pos0, adv) -> (stacked_out, new_caches)``:
    one bounded chunked-prefill step — a ``lax.scan`` of the decode step
    over ``xin``'s leading chunk axis, slot ``b`` visiting positions
    ``pos0[b] + t * adv[b]`` (parked slots pass ``adv == 0``). The stacked
    output's last entry is the decode output at the chunk's final position,
    so the final chunk's logits feed first-token sampling through the same
    head math as ``decode``.
    """

    index: int
    decode: Callable
    prefill: Callable
    init_caches: Callable
    write_slot: Callable
    params: Dict[str, Any]
    units: Tuple[int, int]              # [lo, hi) over prologue+period units
    first: bool
    last: bool
    mesh: object = None
    chunk: Callable = None


class ServeStagedProgram:
    """A pipeline of independently-jitted decode-stage programs.

    Built by :func:`lower_serve_stages`; run sequentially (num_stages == 1 is
    the monolithic serve engine) or concurrently by
    :class:`repro.runtime.pipeline.ServePipelineExecutor`, one actor per
    stage, with caches as stage-local persistent state.
    """

    def __init__(self, cfg, plan, mesh, stages: List[ServeStage],
                 cache_len: int, max_prompt_len: int, group_size: int):
        self.cfg = cfg
        self.plan = plan
        self.mesh = mesh
        self.stages = stages
        self.cache_len = cache_len
        self.max_prompt_len = max_prompt_len
        self.group_size = group_size

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    #: uniform with Staged/TrainStagedProgram for _StagedExecutorBase
    input_names: Tuple[str, ...] = ()

    def describe(self) -> str:
        lines = [f"serve pipeline: {self.num_stages} stages over "
                 f"{self.stages[-1].units[1]} stack units "
                 f"(cache_len={self.cache_len}, "
                 f"group_size={self.group_size})"]
        for st in self.stages:
            extra = []
            if st.first:
                extra.append("embed")
            if st.last:
                extra.append("final_norm+head")
            lines.append(f"  stage {st.index}: units "
                         f"[{st.units[0]}, {st.units[1]})"
                         + (f" + {'+'.join(extra)}" if extra else ""))
        return "\n".join(lines)


def _serve_subtree(tree, lo: int, hi: int, n_pro: int, slice_periods: bool):
    """Slice a {"prologue": [...], "body": [per-slot stacked trees]} pytree
    to units [lo, hi). ``slice_periods`` slices the stacked leading period
    dim (params/caches); spec trees keep their per-slot entries whole."""
    pro = list(tree["prologue"][lo:min(hi, n_pro)])
    plo, phi = max(lo - n_pro, 0), max(hi - n_pro, 0)
    body = []
    if phi > plo:
        if slice_periods:
            body = [jax.tree.map(lambda a: a[plo:phi], slot)
                    for slot in tree["body"]]
        else:
            body = list(tree["body"])
    return {"prologue": pro, "body": body}


def lower_serve_stages(cfg, mesh, params: Dict[str, Any], num_stages: int,
                       cache_len: int, max_prompt_len: int, group_size: int,
                       sliding_window: int = 0) -> ServeStagedProgram:
    """Cut the decode step of a :class:`repro.configs.base.ModelConfig`
    model into ``num_stages`` jitted stage programs (stage = contiguous
    slice of the layer stack; tensor parallelism via shard_map *inside*
    every stage, exactly like :func:`repro.train.steps.make_serve_step`).

    ``params`` are the full model params (as built by
    ``repro.models.model_zoo.build_model(cfg, plan).init``); each stage gets
    its slice, plus the embedding on the first stage and the final norm +
    unembedding head on the last.
    """
    from jax.sharding import PartitionSpec as P

    from repro.models import transformer as T
    from repro.models.common import MeshPlan
    from repro.models.model_zoo import cache_specs, make_decode_caches

    if cfg.encoder_decoder or cfg.embed_frontend:
        raise ValueError(
            f"{cfg.name}: pipelined serving needs a token frontend "
            "(encoder-decoder / embed-frontend archs are not supported)")
    plan = MeshPlan(tuple(mesh.axis_names), tuple(mesh.devices.shape))
    if cache_len < 2:
        # retired/empty slots decode a dummy token "parked" at the reserved
        # position cache_len - 1; with cache_len < 2 that position would
        # collide with position 0 of every live request's window
        raise ValueError(
            f"cache_len={cache_len} must be >= 2: the final cache position "
            "(cache_len - 1) is reserved as the parking slot for "
            "retired/empty decode slots")
    if cache_len % plan.tp:
        raise ValueError(f"cache_len={cache_len} must be divisible by the "
                         f"model-parallel degree {plan.tp}")
    if group_size % plan.dp:
        raise ValueError(f"group_size={group_size} must be divisible by the "
                         f"data-parallel degree {plan.dp}")

    lay = T.stack_layout(cfg)
    n_pro = len(lay.prologue)
    n_units = n_pro + lay.n_periods
    if not (1 <= num_stages <= n_units):
        raise ValueError(f"num_stages={num_stages} must be in [1, {n_units}] "
                         f"(= prologue blocks + body periods for {cfg.name})")

    dp = plan.data_axes if len(plan.data_axes) > 1 else plan.data_axes[0]
    mx = plan.model_axis
    pspecs_full = T.model_specs(cfg, plan)
    cspecs_grp = cache_specs(cfg, plan, plan.data_axes)
    cspecs_one = cache_specs(cfg, plan, ())
    adt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
           "float16": jnp.float16}[cfg.dtype]

    # contiguous unit ranges, balanced by count
    sizes = [n_units // num_stages + (1 if s < n_units % num_stages else 0)
             for s in range(num_stages)]
    bounds, lo = [], 0
    for sz in sizes:
        bounds.append((lo, lo + sz))
        lo += sz

    stages: List[ServeStage] = []
    for s, (lo, hi) in enumerate(bounds):
        first, last = s == 0, s == num_stages - 1
        pro_kinds = lay.prologue[lo:min(hi, n_pro)]
        sparams = _serve_subtree(params, lo, hi, n_pro, True)
        sspecs = _serve_subtree(pspecs_full, lo, hi, n_pro, False)
        grp_cspecs = _serve_subtree(cspecs_grp, lo, hi, n_pro, False)
        one_cspecs = _serve_subtree(cspecs_one, lo, hi, n_pro, False)
        if first:
            sparams["embed"] = params["embed"]
            sspecs["embed"] = pspecs_full["embed"]
        if last:
            for k in ("final_norm", "unembed"):
                sparams[k] = params[k]
                sspecs[k] = pspecs_full[k]

        def local_decode(p, caches, xin, pos, _first=first, _last=last,
                         _kinds=pro_kinds):
            if _first:
                x = T.embed_tokens(p["embed"], xin[:, None], plan).astype(adt)
            else:
                x = xin
            x, new_caches = T.decode_stack_slice(
                p, caches, x, pos, cfg, plan, _kinds,
                sliding_window=sliding_window)
            if _last:
                x = T.rms_norm(x, p["final_norm"].astype(x.dtype),
                               cfg.norm_eps)
                out = x[:, 0] @ p["unembed"].astype(x.dtype)
            else:
                out = x
            return out, new_caches

        xin_spec = P(dp)                 # token ids (B,) or hidden (B, 1, d)
        xout_spec = P(dp, mx) if last else P(dp)
        decode = jax.jit(shard_map(
            local_decode, mesh=mesh,
            in_specs=(sspecs, grp_cspecs, xin_spec, P(dp)),
            out_specs=(xout_spec, grp_cspecs), check=False))

        def local_prefill(p, xin, last_index, _first=first, _last=last,
                          _kinds=pro_kinds):
            if _first:
                x = T.embed_tokens(p["embed"], xin, plan).astype(adt)
            else:
                x = xin
            positions = jnp.arange(x.shape[1])
            x, caches = T.prefill_stack_slice(
                p, x, positions, cfg, plan, _kinds, cache_len,
                sliding_window=sliding_window)
            if _last:
                x = T.rms_norm(x, p["final_norm"].astype(x.dtype),
                               cfg.norm_eps)
                idx = jnp.broadcast_to(last_index[:, None, None],
                                       (x.shape[0], 1, x.shape[-1]))
                h = jnp.take_along_axis(x, idx, axis=1)
                out = h[:, 0] @ p["unembed"].astype(x.dtype)
            else:
                out = x
            return out, caches

        pre_out_spec = P(None, mx) if last else P()
        prefill = jax.jit(shard_map(
            local_prefill, mesh=mesh,
            in_specs=(sspecs, P(), P()),
            out_specs=(pre_out_spec, one_cspecs), check=False))

        def local_chunk(p, caches, xin, pos0, adv, _ld=local_decode):
            # chunked prefill: scan the decode step over the chunk axis —
            # slot b visits pos0[b] + t * adv[b] (parked slots: adv == 0)
            def step(caches, inp):
                xt, t = inp
                out, caches = _ld(p, caches, xt, pos0 + t * adv)
                return caches, out

            ts = jnp.arange(xin.shape[0], dtype=jnp.int32)
            caches, outs = jax.lax.scan(step, caches, (xin, ts))
            return outs, caches

        chunk_out_spec = P(None, dp, mx) if last else P(None, dp)
        chunk = jax.jit(shard_map(
            local_chunk, mesh=mesh,
            in_specs=(sspecs, grp_cspecs, P(None, dp), P(dp), P(dp)),
            out_specs=(chunk_out_spec, grp_cspecs), check=False))

        def local_init(tok, _lo=lo, _hi=hi):
            full = make_decode_caches(cfg, plan, tok.shape[0], cache_len)
            return _serve_subtree(full, _lo, _hi, n_pro, True)

        init_caches = jax.jit(shard_map(
            local_init, mesh=mesh, in_specs=(P(dp),),
            out_specs=grp_cspecs, check=False))

        def write_slot(caches, slot_caches, slot: int):
            # prologue leaves are (B, ...); body leaves are stacked over
            # periods, (periods, B, ...) — the batch slot is axis 1 there
            pro = jax.tree.map(
                lambda gc, sc: gc.at[slot].set(sc[0].astype(gc.dtype)),
                caches["prologue"], slot_caches["prologue"])
            body = jax.tree.map(
                lambda gc, sc: gc.at[:, slot].set(sc[:, 0].astype(gc.dtype)),
                caches["body"], slot_caches["body"])
            return {"prologue": pro, "body": body}

        stages.append(ServeStage(
            index=s, decode=decode, prefill=prefill,
            init_caches=init_caches, write_slot=write_slot,
            params=sparams, units=(lo, hi), first=first, last=last,
            mesh=mesh, chunk=chunk))
    return ServeStagedProgram(cfg, plan, mesh, stages, cache_len,
                              max_prompt_len, group_size)
