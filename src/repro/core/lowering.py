"""Lowering — turn (LogicalGraph, Plan) into an executable SPMD program.

This is the compiler's final stage (paper Fig 1/5): every op runs *locally* on
its shard under ``shard_map``; wherever producer SBP != consumer SBP, the
planner's boxing edge becomes an explicit ``jax.lax`` collective
(:func:`repro.core.boxing.boxing_fn`). Partial-value tensors flow through as
real unreduced per-device arrays, so deferred reduction (§3.3) happens exactly
as planned.

Two entry points share one subgraph lowerer:

* :func:`lower_plan` — the whole graph as one jitted ``shard_map`` program
  (:class:`PhysicalProgram`).
* :func:`lower_stages` — the graph cut by a
  :class:`repro.core.graph.StagePartition` into per-stage jitted programs
  (:class:`StagedProgram`), with boxing at stage boundaries. This is the
  compiler half of actor-driven pipeline execution (§4.3): the runtime half
  lives in :mod:`repro.runtime.pipeline`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.core.boxing import boxing_fn
from repro.core.graph import LogicalGraph, LOp, LTensor, StagePartition
from repro.core.planner import Plan
from repro.core.sbp import Broadcast, NdSbp, Partial, Split

from repro.compat import shard_map


def _split_axes_for(sig: NdSbp, tensor_axis: int, axis_names: Sequence[str]) -> List[str]:
    """Mesh axis names on which ``tensor_axis`` is split under ``sig``."""
    return [name for comp, name in zip(sig, axis_names)
            if isinstance(comp, Split) and comp.axis == tensor_axis]


def _partial_axes(sig: NdSbp, axis_names: Sequence[str]) -> List[str]:
    return [name for comp, name in zip(sig, axis_names) if comp.is_partial]


_UNARY_FNS = {
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "tanh": jnp.tanh,
    "neg": jnp.negative,
    "identity": lambda x: x,
    "scale2": lambda x: 2.0 * x,
}


def _local_op(op: LOp, in_sigs: Tuple[NdSbp, ...], out_sig: NdSbp,
              axis_names: Sequence[str], mesh_shape: Sequence[int]):
    """Return fn(local_inputs) -> local_output implementing op under the sigs."""
    kind = op.spec.name
    attrs = op.spec.attrs

    if kind == "matmul":
        def f(x, w):
            return jnp.dot(x, w)
        return f

    if kind == "ew_binary":
        opn = attrs.get("op", "add")
        fn = {"add": jnp.add, "mul": jnp.multiply}[opn]
        return fn

    if kind == "ew_unary":
        return _UNARY_FNS[attrs.get("fn", "identity")]

    if kind == "bias_add":
        return lambda x, b: x + b[None, :]

    if kind == "reduce":
        axis, red = attrs["axis"], attrs.get("op", "sum")
        jfn = {"sum": jnp.sum, "max": jnp.max, "min": jnp.min}[red]
        return lambda x: jfn(x, axis=axis, keepdims=True)

    if kind == "softmax":
        # hierarchical softmax (paper Fig 11b): local max/sum + global combine
        red_axes = _split_axes_for(in_sigs[0], 1, axis_names)

        def f(x):
            m = jnp.max(x, axis=1, keepdims=True)
            for ax in red_axes:
                m = jax.lax.pmax(m, ax)
            e = jnp.exp(x - m)
            s = jnp.sum(e, axis=1, keepdims=True)
            for ax in red_axes:
                s = jax.lax.psum(s, ax)
            return e / s
        return f

    if kind == "softmax_xent":
        red_axes = _split_axes_for(in_sigs[0], 1, axis_names)
        vocab_frac = 1
        for name, size in zip(axis_names, mesh_shape):
            if name in red_axes:
                vocab_frac *= size
        local_c = op.inputs[0].shape[1] // vocab_frac

        def f(logits, labels):
            m = jnp.max(logits, axis=1, keepdims=True)
            for ax in red_axes:
                m = jax.lax.pmax(m, ax)
            e = jnp.exp(logits - m)
            s = jnp.sum(e, axis=1, keepdims=True)
            for ax in red_axes:
                s = jax.lax.psum(s, ax)
            # local gather of the label logit (zero when out of shard range)
            if red_axes:
                offset = jnp.zeros((), jnp.int32)
                stride = 1
                for name, size in reversed(list(zip(axis_names, mesh_shape))):
                    if name in red_axes:
                        offset = offset + jax.lax.axis_index(name) * stride * local_c
                        stride *= size
                local_ids = labels - offset
                in_range = (local_ids >= 0) & (local_ids < local_c)
                safe = jnp.clip(local_ids, 0, local_c - 1)
                picked = jnp.take_along_axis(logits, safe[:, None], axis=1)
                z = jnp.where(in_range[:, None], picked - m, 0.0)
                # output is P(sum) over red_axes: exactly one shard contributes
                return jnp.log(s) - z
            z = jnp.take_along_axis(logits, labels[:, None], axis=1)
            return jnp.log(s) - (z - m)
        return f

    if kind == "embedding":
        red_axes = _split_axes_for(in_sigs[0], 0, axis_names)  # vocab split
        hid_split = _split_axes_for(in_sigs[0], 1, axis_names)

        def f(table, ids):
            if red_axes:
                local_v = table.shape[0]
                offset = jnp.zeros((), jnp.int32)
                stride = 1
                for name, size in reversed(list(zip(axis_names, mesh_shape))):
                    if name in red_axes:
                        offset = offset + jax.lax.axis_index(name) * stride * local_v
                        stride *= size
                local_ids = ids - offset
                in_range = (local_ids >= 0) & (local_ids < local_v)
                safe = jnp.clip(local_ids, 0, local_v - 1)
                out = table[safe]
                return jnp.where(in_range[:, None], out, 0.0)  # P(sum)
            return table[ids]
        return f

    raise NotImplementedError(f"no local lowering for op kind {kind}")


def _materialized(sig: NdSbp) -> NdSbp:
    """Partial-free storage signature: P components become B (all-reduce).

    Tensors that cross a jit boundary (graph outputs, pipeline-stage
    boundaries) must be real globally-addressable arrays — partial-value only
    exists *inside* a shard_map program.
    """
    return NdSbp(tuple(Broadcast() if c.is_partial else c for c in sig))


def _lower_subgraph(graph: LogicalGraph, plan: Plan, mesh,
                    ops: Sequence[LOp],
                    in_tensors: Sequence[LTensor],
                    out_tensors: Sequence[LTensor],
                    in_sbp: Dict[str, NdSbp],
                    out_sbp: Dict[str, NdSbp]):
    """shard_map program running ``ops`` from ``in_tensors`` to ``out_tensors``.

    ``in_sbp``/``out_sbp`` give the *stored* (partial-free) signatures at the
    subgraph boundary; inside, tensors follow the plan exactly, including
    partial-value storage.
    """
    axis_names = tuple(mesh.axis_names)
    mesh_shape = tuple(mesh.devices.shape)

    for t in in_tensors:
        if in_sbp[t.name].has_partial:
            raise ValueError(f"boundary input {t.name} stored as partial-value")
    for t in out_tensors:
        if out_sbp[t.name].has_partial:
            raise ValueError(f"boundary output {t.name} stored as partial-value")

    in_specs = tuple(graph.placement.partition_spec(in_sbp[t.name])
                     for t in in_tensors)
    out_specs = tuple(graph.placement.partition_spec(out_sbp[t.name])
                      for t in out_tensors)

    def local_program(*local_inputs):
        env = {t.name: v for t, v in zip(in_tensors, local_inputs)}
        cur_sbp = {t.name: in_sbp[t.name] for t in in_tensors}
        for op in ops:
            in_sigs = plan.op_in_sbp[op.name]
            raw_sig = plan.op_out_sbp[op.name]
            stored_sig = plan.tensor_sbp[op.output.name]
            args = []
            for t, want in zip(op.inputs, in_sigs):
                have = cur_sbp[t.name]
                v = env[t.name]
                if have != want:
                    v = boxing_fn(have, want, axis_names, mesh_shape, t.shape)(v)
                args.append(v)
            fn = _local_op(op, in_sigs, raw_sig, axis_names, mesh_shape)
            val = fn(*args)
            if raw_sig != stored_sig:  # epilogue boxing (e.g. P materialization)
                val = boxing_fn(raw_sig, stored_sig, axis_names, mesh_shape,
                                op.output.shape)(val)
            env[op.output.name] = val
            cur_sbp[op.output.name] = stored_sig
        outs = []
        for t in out_tensors:
            v, have, want = env[t.name], cur_sbp[t.name], out_sbp[t.name]
            if have != want:  # boundary boxing (e.g. P -> B materialization)
                v = boxing_fn(have, want, axis_names, mesh_shape, t.shape)(v)
            outs.append(v)
        return tuple(outs)

    return shard_map(local_program, mesh=mesh,
                     in_specs=in_specs, out_specs=out_specs)


def lower_plan(graph: LogicalGraph, plan: Plan, mesh) -> "PhysicalProgram":
    for t in graph.inputs:
        if plan.tensor_sbp[t.name].has_partial:
            raise ValueError(f"graph input {t.name} planned as partial-value")
    sinks = graph.sinks()
    for t in sinks:
        if plan.tensor_sbp[t.name].has_partial:
            raise ValueError(f"graph output {t.name} planned as partial-value; "
                             "planner should have boxed it")
    boundary = {t.name: plan.tensor_sbp[t.name] for t in list(graph.inputs) + sinks}
    mapped = _lower_subgraph(graph, plan, mesh, graph.topo_ops(),
                             graph.inputs, sinks, boundary, boundary)
    return PhysicalProgram(graph, plan, mesh, mapped, sinks)


class PhysicalProgram:
    """Executable physical graph: shard_map program + metadata.

    Calling it always returns a tuple of sink values, in ``self.sinks``
    order — including for single-sink graphs.
    """

    def __init__(self, graph, plan, mesh, fn, sinks):
        self.graph, self.plan, self.mesh = graph, plan, mesh
        self._fn = jax.jit(fn)
        self.sinks = sinks

    def __call__(self, *global_inputs) -> Tuple:
        return tuple(self._fn(*global_inputs))

    def lower(self, *global_inputs):
        return self._fn.lower(*global_inputs)


# ---------------------------------------------------------------------------
# Stage-partitioned lowering (paper §4.3): each pipeline stage becomes its own
# jitted program; tensors crossing a stage boundary are stored partial-free.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StageProgram:
    """One lowered pipeline stage: a jitted callable plus its interface.

    ``fn(*values)`` takes one value per ``input_names`` entry (graph inputs
    and/or boundary tensors from earlier stages) and returns a tuple with one
    value per ``output_names`` entry (boundary tensors and/or graph sinks).
    """

    index: int
    fn: Callable
    input_names: Tuple[str, ...]
    output_names: Tuple[str, ...]
    mesh: object = None
    in_shardings: Optional[Tuple] = None    # set when stages own distinct meshes

    def place_inputs(self, values: Sequence) -> List:
        """Transfer boundary values onto this stage's devices (the explicit
        cross-stage send; a no-op when all stages share one mesh)."""
        if self.in_shardings is None:
            return list(values)
        return [jax.device_put(v, sh)
                for v, sh in zip(values, self.in_shardings)]


class StagedProgram:
    """A pipeline of independently-jitted stage programs.

    Sequential execution (``__call__``) is the reference semantics; the actor
    runtime adapter (:mod:`repro.runtime.pipeline`) drives the same stage
    callables concurrently, one actor per stage, with register quotas bounding
    in-flight microbatches.
    """

    def __init__(self, graph: LogicalGraph, plan: Plan,
                 partition: StagePartition, stages: List[StageProgram],
                 sinks: List[LTensor], boundary_sbp: Dict[str, NdSbp]):
        self.graph, self.plan, self.partition = graph, plan, partition
        self.stages = stages
        self.sinks = sinks
        self.boundary_sbp = boundary_sbp

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    @property
    def input_names(self) -> List[str]:
        return [t.name for t in self.graph.inputs]

    def __call__(self, *global_inputs) -> Tuple:
        if len(global_inputs) != len(self.graph.inputs):
            raise ValueError(f"expected {len(self.graph.inputs)} inputs, "
                             f"got {len(global_inputs)}")
        env = {t.name: v for t, v in zip(self.graph.inputs, global_inputs)}
        for stage in self.stages:
            args = stage.place_inputs([env[n] for n in stage.input_names])
            outs = stage.fn(*args)
            env.update(zip(stage.output_names, outs))
        return tuple(env[t.name] for t in self.sinks)


def lower_stages(graph: LogicalGraph, plan: Plan, partition: StagePartition,
                 mesh=None, stage_meshes: Optional[Sequence] = None
                 ) -> StagedProgram:
    """Lower each pipeline stage of ``partition`` independently.

    ``mesh`` lowers every stage onto the same device mesh (stages share
    devices; pipelining overlaps host work and microbatches). Alternatively
    ``stage_meshes`` gives one mesh per stage — same axis names/sizes but
    possibly *disjoint* devices, the paper's placement of one stage per device
    group. Tensors crossing a stage boundary are stored with their
    :func:`_materialized` (partial-free) signature and boxed on exit.
    """
    if stage_meshes is not None:
        if len(stage_meshes) != partition.num_stages:
            raise ValueError(f"need {partition.num_stages} stage meshes, "
                             f"got {len(stage_meshes)}")
        meshes = list(stage_meshes)
    else:
        if mesh is None:
            raise ValueError("pass either mesh or stage_meshes")
        meshes = [mesh] * partition.num_stages

    sinks = graph.sinks()
    sink_names = {t.name for t in sinks}
    producer_stage = {t.name: partition.stage_of[t.producer.name]
                      for t in graph.tensors if t.producer is not None}

    # tensors leaving each stage: consumed by a later stage, or graph sinks
    stage_out: Dict[int, List[LTensor]] = {s: [] for s in range(partition.num_stages)}
    boundary_sbp: Dict[str, NdSbp] = {}
    for op in graph.topo_ops():
        t = op.output
        ps = producer_stage[t.name]
        consumer_stages = {partition.stage_of[c.name] for c in graph.consumers(t)}
        crosses = any(cs > ps for cs in consumer_stages)
        if crosses or t.name in sink_names:
            stage_out[ps].append(t)
            boundary_sbp[t.name] = _materialized(plan.tensor_sbp[t.name])

    for t in graph.inputs:
        if plan.tensor_sbp[t.name].has_partial:
            raise ValueError(f"graph input {t.name} planned as partial-value")

    stages: List[StageProgram] = []
    for s in range(partition.num_stages):
        ops = partition.ops_in(graph, s)
        in_here = {t.name for op in ops for t in op.inputs}
        produced_here = {op.output.name for op in ops}
        # stage inputs in deterministic order: graph inputs first, then
        # boundary tensors in production (topo) order
        in_tensors: List[LTensor] = [
            t for t in graph.inputs if t.name in in_here]
        in_tensors += [
            t for sp in range(s) for t in stage_out[sp]
            if t.name in in_here and t.name not in produced_here]
        in_sbp = {}
        for t in in_tensors:
            in_sbp[t.name] = (plan.tensor_sbp[t.name] if t.producer is None
                              else boundary_sbp[t.name])
        out_tensors = stage_out[s]
        out_sbp = {t.name: boundary_sbp[t.name] for t in out_tensors}
        mapped = _lower_subgraph(graph, plan, meshes[s], ops,
                                 in_tensors, out_tensors, in_sbp, out_sbp)
        in_shardings = None
        if stage_meshes is not None:
            in_shardings = tuple(
                jax.sharding.NamedSharding(
                    meshes[s], graph.placement.partition_spec(in_sbp[t.name]))
                for t in in_tensors)
        stages.append(StageProgram(
            index=s, fn=jax.jit(mapped),
            input_names=tuple(t.name for t in in_tensors),
            output_names=tuple(t.name for t in out_tensors),
            mesh=meshes[s], in_shardings=in_shardings))
    return StagedProgram(graph, plan, partition, stages, sinks, boundary_sbp)
