"""Boxing — data-routing ops between mismatched SBP signatures (paper §3.2).

Two pieces:

1. :func:`transition_cost` — the *exact* Table 2 communication-cost model for a
   single-axis ``SBP₁ → SBP₂`` transition (same-devices and disjoint-devices
   columns), plus its Nd generalization used by the planner.
2. :func:`boxing_fn` — the physical transform: given ``src`` and ``dst`` NdSbp
   over a named mesh axis, return a function usable *inside* ``shard_map`` that
   converts a local shard from the src layout to the dst layout using
   ``jax.lax`` collectives (all_gather / psum / psum_scatter / all_to_all /
   static slice). This is the compiler-inserted "boxing op".
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple, Union

from repro.core.sbp import B, Broadcast, NdSbp, Partial, Sbp, Split


# ---------------------------------------------------------------------------
# Table 2: communication cost of a single-axis transition.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BoxingCost:
    """Bytes moved per device group + the collective primitive chosen."""

    volume: float           # total bytes transferred (Table 2 entry)
    primitive: str          # name of the collective ("none" when free)


def transition_cost(src: Sbp, dst: Sbp, tensor_bytes: float,
                    p1: int, p2: Optional[int] = None,
                    disjoint: bool = False) -> BoxingCost:
    """Table 2, verbatim.

    ``tensor_bytes`` is |T| (logical tensor size in bytes), ``p1``/``p2`` the
    producer/consumer device counts for this mesh axis. ``disjoint`` selects the
    right-hand column (producer and consumer on disjoint device sets).
    """
    p2 = p1 if p2 is None else p2
    if not disjoint and p2 != p1:
        raise ValueError(
            f"same-device transition requires p2 == p1 (got p1={p1}, p2={p2}); "
            "pass disjoint=True for transitions between distinct device sets")
    T = float(tensor_bytes)
    s, d = src, dst

    if disjoint:
        if isinstance(s, Split) and isinstance(d, Split):
            return BoxingCost(T, "gather+scatter")
        if isinstance(s, Split) and isinstance(d, Broadcast):
            return BoxingCost(p2 * T, "gather+broadcast")
        if isinstance(s, Split) and isinstance(d, Partial):
            return BoxingCost(T, "gather+scatter")
        if isinstance(s, Broadcast) and isinstance(d, Split):
            return BoxingCost(T, "scatter")
        if isinstance(s, Broadcast) and isinstance(d, Broadcast):
            return BoxingCost(p2 * T, "broadcast")
        if isinstance(s, Broadcast) and isinstance(d, Partial):
            return BoxingCost(T, "copy")
        if isinstance(s, Partial) and isinstance(d, Split):
            return BoxingCost(p1 * T, "reduce+scatter")
        if isinstance(s, Partial) and isinstance(d, Broadcast):
            return BoxingCost((p1 + p2 - 1) * T, "reduce+broadcast")
        if isinstance(s, Partial) and isinstance(d, Partial):
            return BoxingCost(p1 * T, "reduce+copy")
        raise ValueError(f"unhandled transition {s} -> {d}")

    # same device set -----------------------------------------------------------
    if isinstance(s, Split) and isinstance(d, Split):
        if s.axis == d.axis:
            return BoxingCost(0.0, "none")
        return BoxingCost((p1 - 1) / p1 * T, "all_to_all")
    if isinstance(s, Split) and isinstance(d, Broadcast):
        return BoxingCost((p1 - 1) * T, "all_gather")
    if isinstance(s, Split) and isinstance(d, Partial):
        # S -> P is free: place the shard in its slice, zeros elsewhere
        return BoxingCost(0.0, "pad_zero")
    if isinstance(s, Broadcast) and isinstance(d, Split):
        return BoxingCost(0.0, "slice")
    if isinstance(s, Broadcast) and isinstance(d, Broadcast):
        return BoxingCost(0.0, "none")
    if isinstance(s, Broadcast) and isinstance(d, Partial):
        return BoxingCost(0.0, "mask_to_partial")
    if isinstance(s, Partial) and isinstance(d, Split):
        return BoxingCost((p1 - 1) * T, "reduce_scatter")
    if isinstance(s, Partial) and isinstance(d, Broadcast):
        return BoxingCost(2 * (p1 - 1) * T, "all_reduce")
    if isinstance(s, Partial) and isinstance(d, Partial):
        if s.op == d.op:
            return BoxingCost(0.0, "none")
        return BoxingCost(2 * (p1 - 1) * T, "all_reduce")  # must materialize
    raise ValueError(f"unhandled transition {s} -> {d}")


def nd_transition_cost(src: NdSbp, dst: NdSbp, tensor_bytes: float,
                       mesh_shape: Sequence[int]) -> float:
    """Generalize Table 2 to NdSbp: sum per-mesh-axis transition costs.

    Axis ``k``'s transition happens over groups of ``mesh_shape[k]`` devices
    while all other axes index independent groups, so the per-axis |T| is the
    tensor's *local* size with respect to the other axes' splits. We use the
    conservative (sequential, axis-by-axis) decomposition, the same one
    OneFlow's compiler uses to decompose an Nd boxing into 1-d primitives.
    """
    total = 0.0
    cur = list(src.components)
    for k in range(len(mesh_shape)):
        if cur[k] == dst[k]:
            continue
        # bytes of the tensor held per group on axis k = |T| / prod(other splits)
        denom = 1
        for j, comp in enumerate(cur):
            if j != k and isinstance(comp, Split):
                denom *= mesh_shape[j]
        axis_T = tensor_bytes / denom
        total += transition_cost(cur[k], dst[k], axis_T, mesh_shape[k]).volume
        cur[k] = dst[k]
    return total


# ---------------------------------------------------------------------------
# Physical boxing: collective transforms usable inside shard_map.
# ---------------------------------------------------------------------------

def _axis_index(axis_name: str):
    import jax

    return jax.lax.axis_index(axis_name)


def _one_axis_boxing(x, src: Sbp, dst: Sbp, axis_name: str, axis_size: int,
                     global_shape: Tuple[int, ...]):
    """Transform a local shard from src to dst layout along one mesh axis."""
    import jax
    import jax.numpy as jnp

    if src == dst:
        return x

    if isinstance(src, Split) and isinstance(dst, Split):
        if src.axis == dst.axis:
            return x
        # all_to_all: concat on src.axis, split on dst.axis
        return jax.lax.all_to_all(x, axis_name, split_axis=dst.axis,
                                  concat_axis=src.axis, tiled=True)
    if isinstance(src, Split) and isinstance(dst, Broadcast):
        return jax.lax.all_gather(x, axis_name, axis=src.axis, tiled=True)
    if isinstance(src, Split) and isinstance(dst, Partial):
        if dst.op != "sum":
            raise NotImplementedError("S->P only for sum")
        # free locally: embed shard into zeros at its slice offset
        idx = _axis_index(axis_name)
        full = jnp.zeros(global_shape, x.dtype)
        start = [0] * x.ndim
        start[src.axis] = idx * x.shape[src.axis]
        return jax.lax.dynamic_update_slice(full, x, tuple(start))
    if isinstance(src, Broadcast) and isinstance(dst, Split):
        idx = _axis_index(axis_name)
        size = x.shape[dst.axis] // axis_size
        start = [0] * x.ndim
        start[dst.axis] = idx * size
        sizes = list(x.shape)
        sizes[dst.axis] = size
        return jax.lax.dynamic_slice(x, tuple(start), tuple(sizes))
    if isinstance(src, Broadcast) and isinstance(dst, Partial):
        if dst.op == "sum":
            idx = _axis_index(axis_name)
            return jnp.where(idx == 0, x, jnp.zeros_like(x))
        # max/min: identity is fine only if reduce op is idempotent — it is.
        return x
    if isinstance(src, Partial) and isinstance(dst, Split):
        if src.op != "sum":
            raise NotImplementedError("P->S reduce_scatter only for sum")
        return jax.lax.psum_scatter(x, axis_name, scatter_dimension=dst.axis,
                                    tiled=True)
    if isinstance(src, Partial) and isinstance(dst, Broadcast):
        if src.op == "sum":
            return jax.lax.psum(x, axis_name)
        if src.op == "max":
            return jax.lax.pmax(x, axis_name)
        if src.op == "min":
            return jax.lax.pmin(x, axis_name)
    if isinstance(src, Partial) and isinstance(dst, Partial):
        if src.op == dst.op:
            return x
        # materialize then re-partialize
        red = _one_axis_boxing(x, src, B, axis_name, axis_size, global_shape)
        return _one_axis_boxing(red, B, dst, axis_name, axis_size, global_shape)
    raise ValueError(f"unhandled boxing {src} -> {dst}")


def boxing_fn(src: Union[str, NdSbp], dst: Union[str, NdSbp],
              axis_names: Sequence[str], mesh_shape: Sequence[int],
              logical_shape: Sequence[int]) -> Callable:
    """Build ``local -> local`` transform converting ``src`` NdSbp to ``dst``.

    The returned function must be called *inside* shard_map over a mesh with
    ``axis_names``.

    Layout convention: when several mesh axes split the same tensor axis, the
    earlier mesh axis is the MAJOR block index (matches
    ``Placement.partition_spec`` which lists mesh axes in mesh order).

    Algorithm (correct under that convention):

    * *cheap path* — when mesh axis ``k``'s transition touches tensor axes not
      shared with any other mesh axis (in src or dst), emit the direct
      primitive (all_to_all / all_gather / psum_scatter / slice / psum).
    * otherwise, *release phase* (descending mesh order): gather every
      conflicting axis to B — descending order guarantees each release
      concatenates contiguous (minor-most) blocks; then *impose phase*
      (ascending mesh order): slice/mask B into the destination components —
      ascending order makes earlier mesh axes major, as the convention wants.
    """
    src, dst = NdSbp.parse(src), NdSbp.parse(dst)
    n = len(axis_names)
    if not (len(src) == len(dst) == n == len(mesh_shape)):
        raise ValueError("rank mismatch in boxing_fn")

    def split_axis_of(c: Sbp) -> Optional[int]:
        return c.axis if isinstance(c, Split) else None

    # -- plan which mesh axes change, forcing conflicting bystanders ----------
    changing = {k for k in range(n) if src[k] != dst[k]}
    while True:
        touched = set()
        for k in changing:
            for c in (src[k], dst[k]):
                a = split_axis_of(c)
                if a is not None:
                    touched.add(a)
        forced = {
            j for j in range(n) if j not in changing
            and split_axis_of(src[j]) in touched
        }
        if not forced:
            break
        changing |= forced

    # cheap-path eligibility per changing axis: its tensor axes are exclusive
    def exclusive(k: int) -> bool:
        axes_k = {a for a in (split_axis_of(src[k]), split_axis_of(dst[k]))
                  if a is not None}
        if not axes_k:
            return True
        for j in range(n):
            if j == k:
                continue
            for c in (src[j], dst[j]):
                if split_axis_of(c) in axes_k:
                    return False
        return True

    def shape_under(components) -> Tuple[int, ...]:
        out = list(logical_shape)
        for comp, size in zip(components, mesh_shape):
            if isinstance(comp, Split):
                out[comp.axis] //= size
        return tuple(out)

    def transform(x):
        cur = list(src.components)

        def gshape_for(k):
            inter = list(cur)
            inter[k] = Broadcast()
            return shape_under(inter)

        # cheap direct transitions first (no shared tensor axes)
        for k in sorted(changing):
            if exclusive(k):
                x = _one_axis_boxing(x, cur[k], dst[k], axis_names[k],
                                     mesh_shape[k], gshape_for(k))
                cur[k] = dst[k]
        remaining = [k for k in changing if cur[k] != dst[k]]

        # release phase: descending mesh order -> concat minor blocks first
        for k in sorted(remaining, reverse=True):
            if not (cur[k].is_broadcast):
                x = _one_axis_boxing(x, cur[k], B, axis_names[k],
                                     mesh_shape[k], gshape_for(k))
                cur[k] = B
        # impose phase: ascending mesh order -> earlier axes become major
        for k in sorted(remaining):
            if cur[k] != dst[k]:
                x = _one_axis_boxing(x, B, dst[k], axis_names[k],
                                     mesh_shape[k], gshape_for(k))
                cur[k] = dst[k]
        return x

    return transform
