"""2-D SUMMA matmul via multi-dimensional SBP (paper §3.3, Table 3).

Table 3 row 1:  X:(S(0), B) × W:(B, S(1)) → Y:(S(0), S(1))
Table 3 row 2:  X:(S(0), S(1)) × W:(B, S(0)) → Y:(S(0), P)

:func:`summa_matmul` implements the classic 2-D algorithm on a (rows, cols)
mesh: X is (S(0), S(1))-sharded, W is (S(1)... expressed per Table 3 —
each step broadcasts one K-panel of X along rows and one of W along columns
and accumulates local outer products. The SBP view of each panel broadcast is
a ``B``-transition on one mesh axis; the accumulated result is the Table-3
row-2 ``P`` that a final psum (or deferred consumer) materializes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def summa_matmul(x_local, w_local, *, row_axis: str, col_axis: str,
                 n_row: int, n_col: int, reduce_out: bool = True):
    """2-D SUMMA inside shard_map.

    x_local: (M/r, K/c) — X sharded (S(0) over rows, S(1) over cols);
    w_local: (K/r, N/c) — W sharded (S(0) over rows, S(1) over cols).
    Returns Y (M/r, N/c) sharded (S(0), S(1)) when ``reduce_out`` (row 1 of
    Table 3 composed over panels), or the unreduced row-2 partial.
    """
    Ml, Kc = x_local.shape
    Kr, Nl = w_local.shape
    acc = jnp.zeros((Ml, Nl), jnp.promote_types(x_local.dtype, w_local.dtype))

    # K panels: iterate over the column (for X) / row (for W) shards.
    # panel p: broadcast X[:, panel p] along the col axis from owner col p,
    #          broadcast W[panel p, :] along the row axis from owner row p.
    # (pbroadcast sources are static, so the panel loop is unrolled.)
    assert n_col == n_row, "summa demo assumes K split equally on both axes"

    def bcast(v, axis, src):
        # collective-broadcast as masked psum (pbroadcast has no CPU lowering)
        i = jax.lax.axis_index(axis)
        return jax.lax.psum(jnp.where(i == src, v, jnp.zeros_like(v)), axis)

    for p in range(n_col):
        xp = bcast(x_local, col_axis, p)   # panel p of X: S(1) -> B
        wp = bcast(w_local, row_axis, p)   # panel p of W: S(0) -> B
        acc = acc + xp @ wp
    return acc
