"""SBP signature planner — the compiler's parallelism-strategy selection.

Given a :class:`LogicalGraph` with some tensors pinned (user annotations,
paper Table 4), choose an NdSbp for every tensor and an op signature for every
op, minimizing total Table-2 boxing cost + per-op internal communication
(paper §3.2: "selecting SBP signatures incurring the lowest communication
costs").

Algorithm: Viterbi-style dynamic programming over the topologically ordered
DAG. Each tensor keeps a table ``{NdSbp: best cumulative cost}``. For an op,
every valid Nd signature (cartesian product of 1-d rules, Table 3) is priced as

    sum_i  min_{s in table(in_i)} [ table(in_i)[s] + boxing(s -> sig_i) ]
    + internal_comm(sig)

For tensors consumed by multiple ops the DP relaxes to a greedy approximation
(each consumer boxes independently from the producer's committed best
signature) — the same decomposition OneFlow's compiler applies when it inserts
one boxing op per mismatched consumer edge.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Sequence, Tuple

from repro.core.boxing import nd_transition_cost
from repro.core.graph import LogicalGraph, LTensor
from repro.core.sbp import Broadcast, NdSbp, Partial, Sbp, Split


@dataclasses.dataclass
class Plan:
    """The chosen physical plan: signatures per tensor + boxing edges.

    ``op_out_sbp`` is the signature the op's rule *produces*; ``tensor_sbp``
    is the signature the tensor is *stored* with. They differ only when the
    planner inserted an epilogue boxing (e.g. materializing a partial-value
    sink via all-reduce / reduce-scatter).
    """

    tensor_sbp: Dict[str, NdSbp]
    op_in_sbp: Dict[str, Tuple[NdSbp, ...]]
    op_out_sbp: Dict[str, NdSbp]
    boxings: List[Tuple[str, str, NdSbp, NdSbp, float]]  # (tensor, consumer_op, src, dst, cost)
    total_cost: float

    def describe(self) -> str:
        lines = ["=== SBP plan ==="]
        for name, sbp in self.tensor_sbp.items():
            lines.append(f"  {name:<28} {sbp}")
        if self.boxings:
            lines.append("--- boxing ops (compiler-inserted collectives) ---")
            for tname, opname, src, dst, cost in self.boxings:
                lines.append(
                    f"  {tname} -> {opname}: {src} => {dst}   cost={cost:,.0f} B")
        lines.append(f"total comm cost = {self.total_cost:,.0f} bytes")
        return "\n".join(lines)


def _candidate_sigs(t: LTensor, mesh_shape: Sequence[int]) -> List[NdSbp]:
    """Enumerate NdSbp candidates valid for this tensor's shape."""
    if t.pinned_sbp is not None:
        return [t.pinned_sbp]
    per_axis: List[Sbp] = [Broadcast(), Partial("sum")]
    per_axis += [Split(i) for i in range(len(t.shape))]
    cands = []
    for combo in itertools.product(per_axis, repeat=len(mesh_shape)):
        sig = NdSbp(tuple(combo))
        try:
            sig.validate_for_shape(t.shape, mesh_shape)
        except ValueError:
            continue
        cands.append(sig)
    return cands


def plan(graph: LogicalGraph, *, forbid_partial_outputs: bool = True) -> Plan:
    mesh_shape = graph.placement.mesh_shape()
    mesh_ndim = len(mesh_shape)

    # DP tables: tensor name -> {NdSbp: (cost, backpointer)}
    table: Dict[str, Dict[NdSbp, float]] = {}
    # committed signature choices filled during backward pass
    chosen: Dict[str, NdSbp] = {}
    op_choice: Dict[str, Tuple[Tuple[NdSbp, ...], NdSbp]] = {}
    back: Dict[str, Dict[NdSbp, Tuple[Tuple[NdSbp, ...], float]]] = {}

    for t in graph.inputs:
        cands = _candidate_sigs(t, mesh_shape)
        table[t.name] = {c: 0.0 for c in cands}

    consumers_count = {t.name: len(graph.consumers(t)) for t in graph.tensors}

    for op in graph.topo_ops():
        out = op.output
        out_table: Dict[NdSbp, float] = {}
        out_back: Dict[NdSbp, Tuple[Tuple[NdSbp, ...], float]] = {}
        allowed_out = None
        if out.pinned_sbp is not None:
            allowed_out = out.pinned_sbp
        for in_sigs, out_sig, internal in op.spec.nd_signatures(mesh_ndim):
            if allowed_out is not None and out_sig != allowed_out:
                continue
            # shape validity for all tensors under this signature
            try:
                out_sig.validate_for_shape(out.shape, mesh_shape)
                for t, s in zip(op.inputs, in_sigs):
                    s.validate_for_shape(t.shape, mesh_shape)
            except ValueError:
                continue
            cost = 0.0
            feasible = True
            for t, s in zip(op.inputs, in_sigs):
                tin = table.get(t.name)
                if not tin:
                    feasible = False
                    break
                best = math.inf
                for src_sig, src_cost in tin.items():
                    c = src_cost + nd_transition_cost(src_sig, s, t.nbytes, mesh_shape)
                    best = min(best, c)
                if math.isinf(best):
                    feasible = False
                    break
                cost += best
            if not feasible:
                continue
            for k, fn in enumerate(internal):
                if fn is not None:
                    cost += fn(mesh_shape[k]) * out.nbytes
            if out_sig not in out_table or cost < out_table[out_sig]:
                out_table[out_sig] = cost
                out_back[out_sig] = (in_sigs, cost)
        if not out_table:
            raise ValueError(f"no feasible SBP signature for op {op}")
        table[out.name] = out_table
        back[out.name] = out_back

    # -- backward pass: commit choices from graph outputs -----------------------
    consumed = set()
    for op in graph.ops:
        for t in op.inputs:
            consumed.add(t.name)
    sink_names = {op.output.name for op in graph.ops if op.output.name not in consumed}

    def _materializations(sig: NdSbp, t: LTensor) -> List[NdSbp]:
        """Candidate partial-free signatures reachable from ``sig``: replace
        every P component by B or by any shape-valid split."""
        axis_opts: List[List] = []
        for comp in sig:
            if comp.is_partial:
                opts = [Broadcast()] + [Split(i) for i in range(len(t.shape))]
            else:
                opts = [comp]
            axis_opts.append(opts)
        outs = []
        for combo in itertools.product(*axis_opts):
            cand = NdSbp(tuple(combo))
            try:
                cand.validate_for_shape(t.shape, mesh_shape)
            except ValueError:
                continue
            outs.append(cand)
        return outs

    epilogue: Dict[str, Tuple[NdSbp, NdSbp, float]] = {}  # out -> (raw, stored, cost)

    for op in reversed(graph.topo_ops()):
        out = op.output
        if out.name not in chosen:
            # sink (or dead output): pick the best signature, pricing the
            # epilogue boxing needed to materialize partial-value results.
            opts = table[out.name]
            best = None  # (total_cost, raw_sig, stored_sig, epi_cost)
            for sig, c in opts.items():
                if sig.has_partial and forbid_partial_outputs and out.name in sink_names:
                    for mat in _materializations(sig, out):
                        epi = nd_transition_cost(sig, mat, out.nbytes, mesh_shape)
                        cand = (c + epi, sig, mat, epi)
                        if best is None or cand[0] < best[0]:
                            best = cand
                else:
                    cand = (c, sig, sig, 0.0)
                    if best is None or cand[0] < best[0]:
                        best = cand
            _, raw, stored, epi = best
            chosen[out.name] = stored
            if raw != stored:
                epilogue[out.name] = (raw, stored, epi)
            op_raw_sig = raw
        else:
            # a consumer already demanded a stored signature; find the best
            # rule output 'raw' such that raw -> stored boxing + rule cost min
            stored = chosen[out.name]
            best = None
            for sig, c in table[out.name].items():
                epi = nd_transition_cost(sig, stored, out.nbytes, mesh_shape)
                cand = (c + epi, sig, epi)
                if best is None or cand[0] < best[0]:
                    best = cand
            _, op_raw_sig, epi = best
            if op_raw_sig != stored:
                epilogue[out.name] = (op_raw_sig, stored, epi)
        in_sigs, _ = back[out.name][op_raw_sig]
        op_choice[op.name] = (in_sigs, op_raw_sig)
        for t, s in zip(op.inputs, in_sigs):
            if t.name not in chosen:
                # choose producer-side signature minimizing (producer cost + box)
                tin = table[t.name]
                best_sig, best_c = None, math.inf
                for src_sig, src_cost in tin.items():
                    c = src_cost + nd_transition_cost(src_sig, s, t.nbytes, mesh_shape)
                    if c < best_c:
                        best_sig, best_c = src_sig, c
                chosen[t.name] = best_sig

    # -- collect boxing edges -----------------------------------------------------
    boxings = []
    grand_total = 0.0
    for op in graph.topo_ops():
        in_sigs, out_raw = op_choice[op.name]
        for t, s in zip(op.inputs, in_sigs):
            src = chosen[t.name]
            if src != s:
                c = nd_transition_cost(src, s, t.nbytes, mesh_shape)
                boxings.append((t.name, op.name, src, s, c))
                grand_total += c
        if op.output.name in epilogue:
            raw, stored, c = epilogue[op.output.name]
            boxings.append((op.output.name, "__epilogue__", raw, stored, c))
            grand_total += c

    return Plan(tensor_sbp=chosen,
                op_in_sbp={name: sigs for name, (sigs, _) in op_choice.items()},
                op_out_sbp={name: raw for name, (_, raw) in op_choice.items()},
                boxings=boxings, total_cost=grand_total)
