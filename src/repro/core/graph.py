"""Logical computation graph IR (paper §2/§3: logical graph -> physical plan).

A :class:`LogicalGraph` is a DAG of :class:`LTensor` values produced by ops
from the registry in :mod:`repro.core.ops`. Tensors may be *pinned* to a
specific NdSbp (the user's annotations, paper Table 4); the planner fills in
the rest minimizing Table-2 boxing cost.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core import ops as ops_mod
from repro.core.placement import Placement
from repro.core.sbp import NdSbp, ndsbp


_counter = itertools.count()


@dataclasses.dataclass
class LTensor:
    """A logical tensor: symbolic value in the graph."""

    graph: "LogicalGraph"
    shape: Tuple[int, ...]
    dtype: str
    name: str
    producer: Optional["LOp"] = None
    pinned_sbp: Optional[NdSbp] = None

    @property
    def itemsize(self) -> int:
        return {"float32": 4, "bfloat16": 2, "float16": 2, "int32": 4,
                "int64": 8, "int8": 1}[self.dtype]

    @property
    def nbytes(self) -> int:
        n = self.itemsize
        for s in self.shape:
            n *= s
        return n

    def pin(self, sbp: Union[str, NdSbp]) -> "LTensor":
        self.pinned_sbp = ndsbp(sbp)
        self.pinned_sbp.validate_for_shape(self.shape, self.graph.placement.mesh_shape())
        return self

    def __repr__(self):
        return f"LTensor({self.name}:{self.dtype}{list(self.shape)})"


@dataclasses.dataclass
class LOp:
    """A logical op instance in the graph."""

    spec: ops_mod.OpSpec
    inputs: Tuple[LTensor, ...]
    output: LTensor
    name: str

    def __repr__(self):
        ins = ", ".join(t.name for t in self.inputs)
        return f"LOp({self.name}: {self.spec.name}({ins}) -> {self.output.name})"


class LogicalGraph:
    """Builder + container for the logical DAG."""

    def __init__(self, placement: Placement):
        self.placement = placement
        self.tensors: List[LTensor] = []
        self.ops: List[LOp] = []
        self.inputs: List[LTensor] = []

    # -- construction ------------------------------------------------------
    def input(self, name: str, shape: Sequence[int], dtype: str = "float32",
              sbp: Optional[Union[str, NdSbp]] = None) -> LTensor:
        t = LTensor(self, tuple(shape), dtype, name)
        if sbp is not None:
            t.pin(sbp)
        self.tensors.append(t)
        self.inputs.append(t)
        return t

    def apply(self, op_name: str, inputs: Sequence[LTensor],
              attrs: Optional[Dict] = None, out_dtype: Optional[str] = None,
              name: Optional[str] = None) -> LTensor:
        opdef = ops_mod.get(op_name)
        if len(inputs) != opdef.n_in:
            raise ValueError(f"{op_name} expects {opdef.n_in} inputs")
        spec = ops_mod.OpSpec(opdef, dict(attrs or {}))
        out_shape = opdef.infer_shape(spec, [t.shape for t in inputs])
        idx = next(_counter)
        oname = name or f"{op_name}_{idx}"
        out = LTensor(self, tuple(out_shape), out_dtype or inputs[0].dtype,
                      f"{oname}.out")
        op = LOp(spec, tuple(inputs), out, oname)
        out.producer = op
        self.tensors.append(out)
        self.ops.append(op)
        return out

    # -- sugar ---------------------------------------------------------------
    def matmul(self, x: LTensor, w: LTensor, name=None) -> LTensor:
        return self.apply("matmul", [x, w], name=name)

    def add(self, a: LTensor, b: LTensor, name=None) -> LTensor:
        return self.apply("ew_binary", [a, b],
                          attrs={"ndim": len(a.shape), "op": "add"}, name=name)

    def unary(self, x: LTensor, fn: str = "relu", linear: bool = False,
              name=None) -> LTensor:
        return self.apply("ew_unary", [x],
                          attrs={"ndim": len(x.shape), "fn": fn, "linear": linear},
                          name=name)

    def bias_add(self, x: LTensor, b: LTensor, name=None) -> LTensor:
        return self.apply("bias_add", [x, b], name=name)

    def softmax(self, x: LTensor, name=None) -> LTensor:
        return self.apply("softmax", [x], attrs={"ndim": len(x.shape)}, name=name)

    def reduce(self, x: LTensor, axis: int, op: str = "sum", name=None) -> LTensor:
        return self.apply("reduce", [x],
                          attrs={"ndim": len(x.shape), "axis": axis, "op": op},
                          name=name)

    def softmax_xent(self, logits: LTensor, labels: LTensor, name=None) -> LTensor:
        return self.apply("softmax_xent", [logits, labels], name=name)

    def embedding(self, table: LTensor, ids: LTensor, name=None) -> LTensor:
        return self.apply("embedding", [table, ids], name=name)

    # -- queries ---------------------------------------------------------------
    def consumers(self, t: LTensor) -> List[LOp]:
        return [op for op in self.ops if t in op.inputs]

    def topo_ops(self) -> List[LOp]:
        return list(self.ops)  # construction order is already topological
