"""Logical computation graph IR (paper §2/§3: logical graph -> physical plan).

A :class:`LogicalGraph` is a DAG of :class:`LTensor` values produced by ops
from the registry in :mod:`repro.core.ops`. Tensors may be *pinned* to a
specific NdSbp (the user's annotations, paper Table 4); the planner fills in
the rest minimizing Table-2 boxing cost.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core import ops as ops_mod
from repro.core.placement import Placement
from repro.core.sbp import NdSbp, ndsbp


_counter = itertools.count()


@dataclasses.dataclass
class LTensor:
    """A logical tensor: symbolic value in the graph."""

    graph: "LogicalGraph"
    shape: Tuple[int, ...]
    dtype: str
    name: str
    producer: Optional["LOp"] = None
    pinned_sbp: Optional[NdSbp] = None

    @property
    def itemsize(self) -> int:
        return {"float32": 4, "bfloat16": 2, "float16": 2, "int32": 4,
                "int64": 8, "int8": 1}[self.dtype]

    @property
    def nbytes(self) -> int:
        n = self.itemsize
        for s in self.shape:
            n *= s
        return n

    def pin(self, sbp: Union[str, NdSbp]) -> "LTensor":
        self.pinned_sbp = ndsbp(sbp)
        self.pinned_sbp.validate_for_shape(self.shape, self.graph.placement.mesh_shape())
        return self

    def __repr__(self):
        return f"LTensor({self.name}:{self.dtype}{list(self.shape)})"


@dataclasses.dataclass
class LOp:
    """A logical op instance in the graph."""

    spec: ops_mod.OpSpec
    inputs: Tuple[LTensor, ...]
    output: LTensor
    name: str
    stage: Optional[int] = None             # pipeline-stage annotation (§4.3)

    def __repr__(self):
        ins = ", ".join(t.name for t in self.inputs)
        return f"LOp({self.name}: {self.spec.name}({ins}) -> {self.output.name})"


class LogicalGraph:
    """Builder + container for the logical DAG."""

    def __init__(self, placement: Placement):
        self.placement = placement
        self.tensors: List[LTensor] = []
        self.ops: List[LOp] = []
        self.inputs: List[LTensor] = []
        self._current_stage: Optional[int] = None

    @contextlib.contextmanager
    def stage(self, index: int):
        """Annotate ops built inside the block as pipeline stage ``index``."""
        if index < 0:
            raise ValueError(f"stage index must be >= 0, got {index}")
        prev, self._current_stage = self._current_stage, index
        try:
            yield self
        finally:
            self._current_stage = prev

    # -- construction ------------------------------------------------------
    def input(self, name: str, shape: Sequence[int], dtype: str = "float32",
              sbp: Optional[Union[str, NdSbp]] = None) -> LTensor:
        t = LTensor(self, tuple(shape), dtype, name)
        if sbp is not None:
            t.pin(sbp)
        self.tensors.append(t)
        self.inputs.append(t)
        return t

    def apply(self, op_name: str, inputs: Sequence[LTensor],
              attrs: Optional[Dict] = None, out_dtype: Optional[str] = None,
              name: Optional[str] = None) -> LTensor:
        opdef = ops_mod.get(op_name)
        if len(inputs) != opdef.n_in:
            raise ValueError(f"{op_name} expects {opdef.n_in} inputs")
        spec = ops_mod.OpSpec(opdef, dict(attrs or {}))
        out_shape = opdef.infer_shape(spec, [t.shape for t in inputs])
        idx = next(_counter)
        oname = name or f"{op_name}_{idx}"
        out = LTensor(self, tuple(out_shape), out_dtype or inputs[0].dtype,
                      f"{oname}.out")
        op = LOp(spec, tuple(inputs), out, oname, stage=self._current_stage)
        out.producer = op
        self.tensors.append(out)
        self.ops.append(op)
        return out

    # -- sugar ---------------------------------------------------------------
    def matmul(self, x: LTensor, w: LTensor, name=None) -> LTensor:
        return self.apply("matmul", [x, w], name=name)

    def add(self, a: LTensor, b: LTensor, name=None) -> LTensor:
        return self.apply("ew_binary", [a, b],
                          attrs={"ndim": len(a.shape), "op": "add"}, name=name)

    def unary(self, x: LTensor, fn: str = "relu", linear: bool = False,
              name=None) -> LTensor:
        return self.apply("ew_unary", [x],
                          attrs={"ndim": len(x.shape), "fn": fn, "linear": linear},
                          name=name)

    def bias_add(self, x: LTensor, b: LTensor, name=None) -> LTensor:
        return self.apply("bias_add", [x, b], name=name)

    def softmax(self, x: LTensor, name=None) -> LTensor:
        return self.apply("softmax", [x], attrs={"ndim": len(x.shape)}, name=name)

    def reduce(self, x: LTensor, axis: int, op: str = "sum", name=None) -> LTensor:
        return self.apply("reduce", [x],
                          attrs={"ndim": len(x.shape), "axis": axis, "op": op},
                          name=name)

    def softmax_xent(self, logits: LTensor, labels: LTensor, name=None) -> LTensor:
        return self.apply("softmax_xent", [logits, labels], name=name)

    def embedding(self, table: LTensor, ids: LTensor, name=None) -> LTensor:
        return self.apply("embedding", [table, ids], name=name)

    # -- queries ---------------------------------------------------------------
    def consumers(self, t: LTensor) -> List[LOp]:
        return [op for op in self.ops if t in op.inputs]

    def topo_ops(self) -> List[LOp]:
        return list(self.ops)  # construction order is already topological

    def sinks(self) -> List[LTensor]:
        """Graph outputs: op outputs never consumed by another op."""
        consumed = {t.name for op in self.ops for t in op.inputs}
        return [op.output for op in self.ops if op.output.name not in consumed]

    def downstream_of(self, names) -> set:
        """Names of the given tensors plus every tensor transitively
        computed from them (one forward pass over the topo order)."""
        dep = set(names)
        for op in self.topo_ops():
            if any(t.name in dep for t in op.inputs):
                dep.add(op.output.name)
        return dep

    def ancestors(self, t: LTensor) -> set:
        """Names of ``t`` and every tensor it transitively depends on."""
        seen: set = set()
        stack = [t]
        while stack:
            cur = stack.pop()
            if cur.name in seen:
                continue
            seen.add(cur.name)
            if cur.producer is not None:
                stack.extend(cur.producer.inputs)
        return seen

    # -- compilation -----------------------------------------------------------
    def compile(self, **options):
        """Compile this graph into a runnable :class:`repro.api.Session` —
        shorthand for ``repro.api.compile(graph, **options)``, the single
        frontend over every lowering/executor path (paper §2, §4)."""
        from repro.api import compile as _compile
        return _compile(self, **options)


# ---------------------------------------------------------------------------
# Pipeline-stage partitioning (paper §4.3: the compiler cuts the physical
# graph into stages; the actor protocol's register quotas then pipeline them).
# ---------------------------------------------------------------------------

def op_cost(op: LOp) -> float:
    """Rough FLOP estimate used to balance stages when the user didn't
    annotate. Matmul dominates real graphs; everything else counts its
    output elements once."""
    kind = op.spec.name
    out_elems = 1
    for s in op.output.shape:
        out_elems *= s
    if kind == "matmul":
        k = op.inputs[0].shape[-1]
        return 2.0 * out_elems * k
    if kind == "embedding":
        return float(out_elems)
    return float(out_elems)


@dataclasses.dataclass
class StagePartition:
    """A cut of the logical DAG into ``num_stages`` pipeline stages.

    ``stage_of`` maps op name -> stage index. The assignment is *monotone*:
    every edge goes from a stage to the same or a later stage, so the stage
    graph is acyclic and each stage can be lowered (and executed by an actor)
    independently.
    """

    num_stages: int
    stage_of: Dict[str, int]

    def ops_in(self, graph: "LogicalGraph", stage: int) -> List[LOp]:
        return [op for op in graph.topo_ops() if self.stage_of[op.name] == stage]

    def describe(self, graph: "LogicalGraph",
                 regs: Optional[Sequence[int]] = None) -> str:
        """Report the cut: ops and cost per stage, plus — when ``regs`` is
        given — each stage's out-register quota (the in-flight microbatch
        bound its pipeline schedule emerges from)."""
        lines = [f"=== stage partition ({self.num_stages} stages) ==="]
        for s in range(self.num_stages):
            ops = self.ops_in(graph, s)
            cost = sum(op_cost(op) for op in ops)
            quota = f"  regs={regs[s]}" if regs is not None else ""
            lines.append(f"  stage {s}: {[op.name for op in ops]}"
                         f"  (~{cost:,.0f} flop){quota}")
        return "\n".join(lines)


def _validate_partition(graph: LogicalGraph, stage_of: Dict[str, int],
                        num_stages: int) -> None:
    for op in graph.ops:
        if op.name not in stage_of:
            raise ValueError(f"op {op.name} has no stage assignment")
        s = stage_of[op.name]
        if not 0 <= s < num_stages:
            raise ValueError(f"op {op.name} assigned stage {s}, outside "
                             f"[0, {num_stages})")
        for t in op.inputs:
            if t.producer is not None and stage_of[t.producer.name] > s:
                raise ValueError(
                    f"non-monotone stage assignment: {t.producer.name} "
                    f"(stage {stage_of[t.producer.name]}) feeds {op.name} "
                    f"(stage {s}); producers must not be in a later stage")
    used = {stage_of[op.name] for op in graph.ops}
    for s in range(num_stages):
        if s not in used:
            raise ValueError(f"stage {s} is empty")


def partition_stages(graph: LogicalGraph,
                     num_stages: Optional[int] = None) -> StagePartition:
    """Cut the graph into pipeline stages.

    If any op carries a user annotation (built inside ``graph.stage(k)``),
    every op must be annotated and the annotation is validated for
    monotonicity. Otherwise the topologically ordered op list is split into
    ``num_stages`` contiguous segments of near-equal :func:`op_cost`
    (contiguity in topo order makes monotonicity automatic).
    """
    annotated = [op for op in graph.ops if op.stage is not None]
    if annotated:
        if len(annotated) != len(graph.ops):
            missing = [op.name for op in graph.ops if op.stage is None]
            raise ValueError(
                f"mixed stage annotation: ops {missing} have no stage; "
                "annotate every op or none")
        stage_of = {op.name: op.stage for op in graph.ops}
        n = max(stage_of.values()) + 1
        if num_stages is not None and num_stages != n:
            raise ValueError(f"num_stages={num_stages} but annotations span "
                             f"{n} stages")
        _validate_partition(graph, stage_of, n)
        return StagePartition(n, stage_of)

    if num_stages is None:
        raise ValueError("graph has no stage annotations; pass num_stages")
    ops = graph.topo_ops()
    if not 1 <= num_stages <= len(ops):
        raise ValueError(f"num_stages={num_stages} not in [1, {len(ops)}]")
    costs = [op_cost(op) for op in ops]
    total = sum(costs)
    stage_of: Dict[str, int] = {}
    acc, s, count_in_stage = 0.0, 0, 0
    for i, (op, c) in enumerate(zip(ops, costs)):
        remaining = len(ops) - i         # ops left, including this one
        # cut before this op when the current stage is non-empty and either
        # (a) the stages after s would otherwise run out of ops, or (b) this
        # op crosses the equal-cost boundary by more than half its cost
        if count_in_stage > 0 and s < num_stages - 1 and (
                remaining <= num_stages - s - 1
                or acc + c / 2 > total * (s + 1) / num_stages):
            s += 1
            count_in_stage = 0
        stage_of[op.name] = s
        acc += c
        count_in_stage += 1
    _validate_partition(graph, stage_of, num_stages)
    return StagePartition(num_stages, stage_of)
