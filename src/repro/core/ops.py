"""Logical op registry with SBP deduction rules (paper §3.1, Tables 1 & 3).

Each op declares its *1-d* SBP rules: tuples ``(input_sbps, output_sbp)`` valid
on a single mesh axis. The multi-dimensional rule (Table 3) is the per-axis
cartesian product of 1-d rules — e.g. matmul with ``X:(S(0),B)  W:(B,S(1))``
satisfies row-1 of Table 1 on mesh axis 0 and row-2 on mesh axis 1, giving
``Y:(S(0),S(1))`` — exactly the 2-D SUMMA-style signature of Table 3.

Some signatures carry *internal* communication (e.g. softmax split along its
reduction axis performs a local max/sum then a global combine — paper Fig 11b);
ops can price that via ``internal_comm``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.sbp import B, NdSbp, P, Partial, Sbp, Split


@dataclasses.dataclass(frozen=True)
class Rule:
    """One valid 1-d signature: input SBPs -> output SBP (single mesh axis)."""

    ins: Tuple[Sbp, ...]
    out: Sbp
    # fraction of the OUTPUT logical tensor bytes communicated internally by
    # the op itself under this rule, as a function of the axis size p.
    internal_comm: Optional[Callable[[int], float]] = None


@dataclasses.dataclass
class OpDef:
    name: str
    n_in: int
    rules_fn: Callable[["OpSpec"], List[Rule]]
    infer_shape: Callable[["OpSpec", Sequence[Tuple[int, ...]]], Tuple[int, ...]]
    flops: Optional[Callable[["OpSpec", Sequence[Tuple[int, ...]]], float]] = None


def _opspec_from_registry(name: str, attrs: Dict) -> "OpSpec":
    """Pickle reconstructor for :class:`OpSpec` — resolve the op definition
    from :data:`REGISTRY` by name (op defs carry lambdas and cannot cross a
    process boundary; the registry contents are identical in every worker)."""
    return OpSpec(REGISTRY[name], attrs)


@dataclasses.dataclass
class OpSpec:
    """An op instance: definition + static attributes (axes, shapes...)."""

    opdef: OpDef
    attrs: Dict = dataclasses.field(default_factory=dict)

    def __reduce__(self):
        return (_opspec_from_registry, (self.opdef.name, self.attrs))

    @property
    def name(self):
        return self.opdef.name

    def rules(self) -> List[Rule]:
        return self.opdef.rules_fn(self)

    def nd_signatures(self, mesh_ndim: int):
        """All valid Nd signatures = product of 1-d rules over mesh axes.

        Yields ``(in_ndsbps: tuple[NdSbp], out_ndsbp: NdSbp, internal_fns)``.
        """
        rules = self.rules()
        for combo in itertools.product(rules, repeat=mesh_ndim):
            ins = tuple(
                NdSbp(tuple(r.ins[i] for r in combo)) for i in range(self.opdef.n_in))
            out = NdSbp(tuple(r.out for r in combo))
            internal = tuple(r.internal_comm for r in combo)
            yield ins, out, internal


REGISTRY: Dict[str, OpDef] = {}


def register(opdef: OpDef) -> OpDef:
    REGISTRY[opdef.name] = opdef
    return opdef


# ---------------------------------------------------------------------------
# MatMul — Table 1 verbatim.
# ---------------------------------------------------------------------------

def _matmul_rules(spec: OpSpec) -> List[Rule]:
    return [
        Rule((Split(0), B), Split(0)),           # data parallel
        Rule((B, Split(1)), Split(1)),           # model parallel (col)
        Rule((Split(1), Split(0)), P),           # contraction split -> partial
        Rule((P, B), P),                         # defer reduction (§3.3)
        Rule((B, P), P),
        Rule((B, B), B),
    ]


def _matmul_shape(spec: OpSpec, shapes) -> Tuple[int, ...]:
    (m, k), (k2, n) = shapes
    if k != k2:
        raise ValueError(f"matmul inner dims {k} != {k2}")
    return (m, n)


register(OpDef("matmul", 2, _matmul_rules, _matmul_shape,
               flops=lambda spec, shapes: 2.0 * shapes[0][0] * shapes[0][1] * shapes[1][1]))


# ---------------------------------------------------------------------------
# Elementwise ops.
# ---------------------------------------------------------------------------

def _ew_unary_rules(spec: OpSpec) -> List[Rule]:
    ndim = spec.attrs["ndim"]
    rules = [Rule((B,), B)]
    rules += [Rule((Split(i),), Split(i)) for i in range(ndim)]
    if spec.attrs.get("linear", False):
        # linear maps commute with summation -> P passes through
        rules.append(Rule((P,), P))
    return rules


register(OpDef("ew_unary", 1, _ew_unary_rules, lambda spec, shapes: shapes[0]))


def _ew_binary_rules(spec: OpSpec) -> List[Rule]:
    ndim = spec.attrs["ndim"]
    rules = [Rule((B, B), B)]
    rules += [Rule((Split(i), Split(i)), Split(i)) for i in range(ndim)]
    if spec.attrs.get("op", "add") == "add":
        rules.append(Rule((P, P), P))  # (x1+x2)+(y1+y2) == (x1+y1)+(x2+y2)
    return rules


def _ew_binary_shape(spec: OpSpec, shapes):
    if shapes[0] != shapes[1]:
        raise ValueError(f"elementwise shape mismatch {shapes}")
    return shapes[0]


register(OpDef("ew_binary", 2, _ew_binary_rules, _ew_binary_shape))


# bias_add: (M, N) + (N,) — bias must be B (or S(0) matching lhs S(1)).
def _bias_add_rules(spec: OpSpec) -> List[Rule]:
    # NOTE: (P, B) -> P is deliberately absent: adding a broadcast bias to every
    # partial shard would apply the bias p times after reduction.
    return [
        Rule((B, B), B),
        Rule((Split(0), B), Split(0)),
        Rule((Split(1), Split(0)), Split(1)),
    ]


register(OpDef("bias_add", 2, _bias_add_rules,
               lambda spec, shapes: shapes[0]))


# ---------------------------------------------------------------------------
# Reductions.
# ---------------------------------------------------------------------------

def _reduce_rules(spec: OpSpec) -> List[Rule]:
    ndim = spec.attrs["ndim"]
    axis = spec.attrs["axis"]
    red = spec.attrs.get("op", "sum")
    rules = [Rule((B,), B)]
    for i in range(ndim):
        if i == axis:
            # reducing over the split axis -> partial values
            if red in ("sum", "max", "min"):
                rules.append(Rule((Split(i),), Partial(red)))
        else:
            rules.append(Rule((Split(i),), Split(i)))  # keepdims=True contract
    if red == "sum":
        rules.append(Rule((P,), P))
    return rules


def _reduce_shape(spec: OpSpec, shapes):
    out = list(shapes[0])
    out[spec.attrs["axis"]] = 1
    return tuple(out)


register(OpDef("reduce", 1, _reduce_rules, _reduce_shape))


# ---------------------------------------------------------------------------
# Softmax (rowwise over last axis) — Fig 11b hierarchical reduction.
# ---------------------------------------------------------------------------

def _softmax_rules(spec: OpSpec) -> List[Rule]:
    ndim = spec.attrs.get("ndim", 2)
    assert ndim == 2
    return [
        Rule((B,), B),
        Rule((Split(0),), Split(0)),
        # split along the reduced (class) axis: local max/sum + global combine;
        # internal comm = 2 rows-sized all-reduces ~= 2*2*(p-1)/p of a column.
        Rule((Split(1),), Split(1),
             internal_comm=lambda p: 4.0 * (p - 1) / p * spec.attrs.get(
                 "stat_frac", 1e-3)),
    ]


register(OpDef("softmax", 1, _softmax_rules, lambda spec, shapes: shapes[0]))


# sparse softmax cross entropy: logits (N, C), labels (N,) -> loss (N, 1)
def _xent_rules(spec: OpSpec) -> List[Rule]:
    return [
        Rule((B, B), B),
        Rule((Split(0), Split(0)), Split(0)),
        # vocab-split logits, broadcast labels: local max/sum/gather + combine
        Rule((Split(1), B), P,
             internal_comm=lambda p: 0.0),
    ]


register(OpDef("softmax_xent", 2, _xent_rules,
               lambda spec, shapes: (shapes[0][0], 1)))


# ---------------------------------------------------------------------------
# Embedding lookup: table (V, D), ids (N,) -> (N, D)   (HugeCTR case, §6.3.2)
# ---------------------------------------------------------------------------

def _embedding_rules(spec: OpSpec) -> List[Rule]:
    return [
        Rule((B, B), B),
        Rule((B, Split(0)), Split(0)),          # data parallel over ids
        Rule((Split(1), B), Split(1)),          # split hidden dim
        # split vocab: each shard holds its id range, emits zeros elsewhere -> P
        Rule((Split(0), B), P),
    ]


register(OpDef("embedding", 2, _embedding_rules,
               lambda spec, shapes: (shapes[1][0], shapes[0][1])))


def get(name: str) -> OpDef:
    return REGISTRY[name]
