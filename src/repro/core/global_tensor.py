"""GlobalTensor — the user-facing "consistent tensor" API (paper §3.4, Table 4).

A :class:`GlobalTensor` pairs a physical ``jax.Array`` with a
(:class:`Placement`, :class:`NdSbp`) annotation. Ops on GlobalTensors infer the
output SBP from the deduction rules and execute the *local* computation under
``shard_map``; :meth:`to_global` is OneFlow's ``to_consistent`` — an explicit
boxing op changing sbp (and in the future, placement).

Unlike the graph/planner path (compile whole graphs), this is the eager path:
each op immediately builds and runs its one-op physical program. Partial-value
tensors are kept as physically-unreduced arrays stacked on a leading mesh-axis
dimension? No — they stay *sharded semantics*: the jax.Array is laid out
replicated but each replica holds a different partial term, which we track via
``_partial_context`` (only valid while staying inside this module's ops).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.compat import shard_map
from repro.core.boxing import boxing_fn
from repro.core.placement import Placement
from repro.core.sbp import Broadcast, NdSbp, Partial, Split, ndsbp


@dataclasses.dataclass
class GlobalTensor:
    """A logically-global tensor physically laid out per (placement, sbp)."""

    data: jax.Array                 # the *global* array view (addressable layout)
    placement: Placement
    sbp: NdSbp
    mesh: object                    # jax.sharding.Mesh
    logical_shape: Tuple[int, ...]

    # -- constructors --------------------------------------------------------
    @staticmethod
    def from_global(array, placement: Placement, sbp: Union[str, NdSbp],
                    mesh=None) -> "GlobalTensor":
        """Place a host/global array with the given SBP (paper: flow.randn(...,
        placement=..., sbp=...))."""
        sbp = ndsbp(sbp)
        mesh = mesh if mesh is not None else placement.to_mesh()
        if sbp.has_partial:
            raise ValueError("cannot construct a partial-value tensor from a "
                             "global array; partials arise from ops")
        sbp.validate_for_shape(array.shape, placement.mesh_shape())
        sharding = jax.sharding.NamedSharding(mesh, placement.partition_spec(sbp))
        arr = jax.device_put(array, sharding)
        return GlobalTensor(arr, placement, sbp, mesh, tuple(array.shape))

    # -- conversion (to_consistent / boxing) ----------------------------------
    def to_global(self, sbp: Union[str, NdSbp]) -> "GlobalTensor":
        """Explicit boxing: transform to a new SBP on the same placement."""
        dst = ndsbp(sbp)
        if dst == self.sbp:
            return self
        dst.validate_for_shape(self.logical_shape, self.placement.mesh_shape())
        if dst.has_partial:
            raise ValueError("to_global target with partial-value is not "
                             "materializable at the API boundary")
        axis_names = self.placement.axis_names
        mesh_shape = self.placement.mesh_shape()
        fn = boxing_fn(self.sbp, dst, axis_names, mesh_shape, self.logical_shape)
        out = jax.jit(shard_map(
            fn, mesh=self.mesh,
            in_specs=(self._pspec(self.sbp),),
            out_specs=self._pspec(dst), check=False))(self.data)
        return GlobalTensor(out, self.placement, dst, self.mesh, self.logical_shape)

    def _pspec(self, sbp: NdSbp) -> PartitionSpec:
        """PartitionSpec for shard_map; Partial maps to replicated layout
        (each replica holds one partial term)."""
        cleaned = NdSbp(tuple(Broadcast() if c.is_partial else c for c in sbp))
        return self.placement.partition_spec(cleaned)

    # -- numpy-ish ----------------------------------------------------------
    def numpy(self):
        """Materialize the logical value (reduces partials if any)."""
        if self.sbp.has_partial:
            return self.to_global(NdSbp(tuple(
                Broadcast() if c.is_partial else c
                for c in self.sbp)))._materialize_partial_free()
        return self._materialize_partial_free()

    def _materialize_partial_free(self):
        import numpy as np
        return np.asarray(jax.device_get(self.data))

    @property
    def shape(self) -> Tuple[int, ...]:
        return self.logical_shape

    @property
    def dtype(self):
        return self.data.dtype

    def __repr__(self):
        return (f"GlobalTensor(shape={self.logical_shape}, sbp={self.sbp}, "
                f"placement={self.placement})")


# ---------------------------------------------------------------------------
# Eager consistent ops (enough to express the paper's Table 4 program).
# ---------------------------------------------------------------------------

def _deduce_matmul(sx: NdSbp, sw: NdSbp) -> NdSbp:
    """Apply Table 1 per mesh axis; raises if a (sx,sw) pair has no rule."""
    out = []
    for cx, cw in zip(sx, sw):
        if isinstance(cx, Split) and cx.axis == 0 and cw.is_broadcast:
            out.append(Split(0))
        elif cx.is_broadcast and isinstance(cw, Split) and cw.axis == 1:
            out.append(Split(1))
        elif isinstance(cx, Split) and cx.axis == 1 and isinstance(cw, Split) and cw.axis == 0:
            out.append(Partial("sum"))
        elif cx.is_partial and cw.is_broadcast:
            out.append(Partial("sum"))
        elif cx.is_broadcast and cw.is_partial:
            out.append(Partial("sum"))
        elif cx.is_broadcast and cw.is_broadcast:
            out.append(Broadcast())
        else:
            raise ValueError(f"matmul: no Table-1 rule for X:{cx}, W:{cw}")
    return NdSbp(tuple(out))


def matmul(x: GlobalTensor, w: GlobalTensor) -> GlobalTensor:
    """Consistent matmul: output SBP deduced per Table 1; local dot under
    shard_map; partial-value output stays unreduced (deferred reduction §3.3)."""
    if x.placement != w.placement:
        raise ValueError("cross-placement matmul requires boxing via to_global")
    out_sbp = _deduce_matmul(x.sbp, w.sbp)
    out_shape = (x.logical_shape[0], w.logical_shape[1])

    def local(xl, wl):
        return jnp.dot(xl, wl)

    fn = jax.jit(shard_map(
        local, mesh=x.mesh,
        in_specs=(x._pspec(x.sbp), w._pspec(w.sbp)),
        out_specs=x._pspec(out_sbp), check=False))
    data = fn(x.data, w.data)
    return GlobalTensor(data, x.placement, out_sbp, x.mesh, out_shape)


def reduce_partial(x: GlobalTensor) -> GlobalTensor:
    """Materialize partial-value axes to broadcast (an all-reduce boxing)."""
    if not x.sbp.has_partial:
        return x
    axis_names = x.placement.axis_names
    mesh_shape = x.placement.mesh_shape()
    dst = NdSbp(tuple(Broadcast() if c.is_partial else c for c in x.sbp))
    fn = boxing_fn(x.sbp, dst, axis_names, mesh_shape, x.logical_shape)
    out = jax.jit(shard_map(
        fn, mesh=x.mesh, in_specs=(x._pspec(x.sbp),),
        out_specs=x._pspec(dst), check=False))(x.data)
    return GlobalTensor(out, x.placement, dst, x.mesh, x.logical_shape)
