"""Placement — which mesh axes / device groups a logical op runs on (paper §3).

OneFlow's ``flow.placement("cuda", {0:[0,1]})`` names nodes and device ids. On a
TPU pod the natural equivalent is a *named mesh* (axes like ``pod``, ``data``,
``model``) plus, optionally, a sub-mesh selection. We keep placement lightweight:
a named axis tuple + sizes, convertible to a real ``jax.sharding.Mesh`` lazily so
importing this module never touches device state.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

from repro.core.sbp import NdSbp, Partial, Split


@dataclasses.dataclass(frozen=True)
class Placement:
    """A named logical mesh: ``axis_names[i]`` has ``axis_sizes[i]`` devices."""

    axis_names: Tuple[str, ...]
    axis_sizes: Tuple[int, ...]
    device_kind: str = "tpu"

    def __post_init__(self):
        if len(self.axis_names) != len(self.axis_sizes):
            raise ValueError("axis_names and axis_sizes must align")
        if len(set(self.axis_names)) != len(self.axis_names):
            raise ValueError("duplicate mesh axis names")

    @property
    def ndim(self) -> int:
        return len(self.axis_names)

    @property
    def num_devices(self) -> int:
        return math.prod(self.axis_sizes)

    def axis_size(self, name: str) -> int:
        return self.axis_sizes[self.axis_names.index(name)]

    def mesh_shape(self) -> Tuple[int, ...]:
        return self.axis_sizes

    def to_mesh(self, devices=None):
        """Materialize a ``jax.sharding.Mesh`` (lazy jax import)."""
        import jax
        import numpy as np

        if devices is None:
            devices = jax.devices()
        n = self.num_devices
        if len(devices) < n:
            raise ValueError(f"need {n} devices, have {len(devices)}")
        arr = np.array(devices[:n]).reshape(self.axis_sizes)
        return jax.sharding.Mesh(arr, self.axis_names)

    # -- SBP -> PartitionSpec ---------------------------------------------------
    def partition_spec(self, sbp: NdSbp):
        """Lower an NdSbp on this placement to a ``jax.sharding.PartitionSpec``.

        ``Partial`` is NOT representable as a PartitionSpec: partial-value only
        exists *inside* a shard_map program (as unreduced per-device arrays).
        Callers lowering graph *inputs/outputs* must first box P away.
        """
        from jax.sharding import PartitionSpec

        if len(sbp) != self.ndim:
            raise ValueError(f"{sbp} rank != placement rank {self.ndim}")
        # tensor axis -> list of mesh axis names sharding it (order = mesh order)
        per_axis: Dict[int, list] = {}
        for comp, name in zip(sbp, self.axis_names):
            if isinstance(comp, Partial):
                raise ValueError(
                    f"{sbp} contains partial-value; box it before lowering to "
                    "PartitionSpec (P exists only inside shard_map)")
            if isinstance(comp, Split):
                per_axis.setdefault(comp.axis, []).append(name)
        if not per_axis:
            return PartitionSpec()
        max_axis = max(per_axis)
        entries = []
        for ax in range(max_axis + 1):
            names = per_axis.get(ax, [])
            if not names:
                entries.append(None)
            elif len(names) == 1:
                entries.append(names[0])
            else:
                entries.append(tuple(names))
        return PartitionSpec(*entries)

    def named_sharding(self, sbp: NdSbp, mesh=None):
        import jax

        mesh = mesh if mesh is not None else self.to_mesh()
        return jax.sharding.NamedSharding(mesh, self.partition_spec(sbp))

    def __repr__(self) -> str:
        dims = ", ".join(f"{n}={s}" for n, s in zip(self.axis_names, self.axis_sizes))
        return f"Placement[{self.device_kind}]({dims})"


def single_pod_placement(data: int = 16, model: int = 16) -> Placement:
    return Placement(("data", "model"), (data, model))


def multi_pod_placement(pod: int = 2, data: int = 16, model: int = 16) -> Placement:
    return Placement(("pod", "data", "model"), (pod, data, model))
