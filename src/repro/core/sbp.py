"""SBP (split / broadcast / partial-value) abstraction — OneFlow §3.1, §3.3.

An :class:`Sbp` describes how ONE mesh axis maps a logical tensor to physical
shards:

* ``Split(axis)``  — physical tensors are balanced slices of the logical tensor
  along tensor dimension ``axis``.
* ``Broadcast()``  — each physical tensor is a full replica.
* ``Partial(op)``  — physical tensors have the logical shape; the logical value
  is the elementwise reduction ``op`` (sum/max/min) of all physical tensors.

A :class:`NdSbp` is a tuple of :class:`Sbp`, one per mesh axis (multi-dim SBP,
paper §3.3), e.g. ``NdSbp.parse("S(0),B")`` over a ``(data, model)`` mesh means
"split batch over data axis, replicate over model axis".
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Sequence, Tuple, Union


class Sbp:
    """Base class for a single-axis SBP component."""

    __slots__ = ()

    # -- classification helpers ------------------------------------------------
    @property
    def is_split(self) -> bool:
        return isinstance(self, Split)

    @property
    def is_broadcast(self) -> bool:
        return isinstance(self, Broadcast)

    @property
    def is_partial(self) -> bool:
        return isinstance(self, Partial)

    # -- parsing ----------------------------------------------------------------
    _PAT = re.compile(r"^\s*(?:S\((\d+)\)|B|P(?:\((\w+)\))?)\s*$", re.IGNORECASE)

    @staticmethod
    def parse(text: Union[str, "Sbp"]) -> "Sbp":
        if isinstance(text, Sbp):
            return text
        m = Sbp._PAT.match(text)
        if not m:
            raise ValueError(f"unparsable SBP component: {text!r}")
        if m.group(1) is not None:
            return Split(int(m.group(1)))
        if text.strip().upper().startswith("B"):
            return Broadcast()
        return Partial(m.group(2) or "sum")


@dataclasses.dataclass(frozen=True)
class Split(Sbp):
    """S(axis): balanced split of the logical tensor along ``axis``."""

    axis: int

    def __post_init__(self):
        if self.axis < 0:
            raise ValueError("split axis must be non-negative (logical axes)")

    def __repr__(self) -> str:
        return f"S({self.axis})"


@dataclasses.dataclass(frozen=True)
class Broadcast(Sbp):
    """B: full replica on every device of the axis."""

    def __repr__(self) -> str:
        return "B"


@dataclasses.dataclass(frozen=True)
class Partial(Sbp):
    """P(op): physical tensors reduce elementwise (by ``op``) to the logical one."""

    op: str = "sum"

    _VALID = ("sum", "max", "min")

    def __post_init__(self):
        if self.op not in self._VALID:
            raise ValueError(f"unsupported partial reduction {self.op!r}")

    def __repr__(self) -> str:
        return f"P({self.op})"


# Convenient singletons / constructors
B = Broadcast()
P = Partial("sum")


def S(axis: int) -> Split:
    return Split(axis)


@dataclasses.dataclass(frozen=True)
class NdSbp:
    """Multi-dimensional SBP: one component per mesh axis (paper §3.3)."""

    components: Tuple[Sbp, ...]

    def __post_init__(self):
        object.__setattr__(self, "components", tuple(Sbp.parse(c) for c in self.components))

    # -- construction ----------------------------------------------------------
    @staticmethod
    def of(*components: Union[str, Sbp]) -> "NdSbp":
        return NdSbp(tuple(Sbp.parse(c) for c in components))

    @staticmethod
    def parse(text: Union[str, "NdSbp", Sequence[Union[str, Sbp]]]) -> "NdSbp":
        if isinstance(text, NdSbp):
            return text
        if isinstance(text, (list, tuple)):
            return NdSbp.of(*text)
        # split on commas that are not inside parentheses: "S(0), P(sum)" etc.
        parts = [p for p in re.findall(r"S\(\d+\)|P\(\w+\)|P|B", text, re.I)]
        if not parts:
            raise ValueError(f"unparsable NdSbp: {text!r}")
        return NdSbp.of(*parts)

    @staticmethod
    def broadcast(ndim_mesh: int) -> "NdSbp":
        return NdSbp.of(*(["B"] * ndim_mesh))

    # -- introspection ----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.components)

    def __iter__(self):
        return iter(self.components)

    def __getitem__(self, i: int) -> Sbp:
        return self.components[i]

    @property
    def has_partial(self) -> bool:
        return any(c.is_partial for c in self.components)

    @property
    def has_split(self) -> bool:
        return any(c.is_split for c in self.components)

    def split_axes(self) -> Tuple[int, ...]:
        return tuple(c.axis for c in self.components if isinstance(c, Split))

    def replace(self, mesh_axis: int, comp: Union[str, Sbp]) -> "NdSbp":
        comps = list(self.components)
        comps[mesh_axis] = Sbp.parse(comp)
        return NdSbp(tuple(comps))

    def __repr__(self) -> str:
        return "(" + ", ".join(repr(c) for c in self.components) + ")"

    # -- shape logic -------------------------------------------------------------
    def validate_for_shape(self, shape: Sequence[int], mesh_shape: Sequence[int]) -> None:
        """Check this NdSbp is applicable to a logical ``shape`` on ``mesh_shape``.

        Splits must address existing tensor axes and divide evenly (we require
        even division — OneFlow balances uneven splits, we keep the stricter
        contract so physical shards are uniform for shard_map).
        """
        if len(self.components) != len(mesh_shape):
            raise ValueError(
                f"NdSbp rank {len(self.components)} != mesh rank {len(mesh_shape)}")
        # accumulate division per tensor axis (two mesh axes may split the same
        # tensor axis — the division factors multiply)
        divisor = [1] * len(shape)
        for comp, size in zip(self.components, mesh_shape):
            if isinstance(comp, Split):
                if comp.axis >= len(shape):
                    raise ValueError(f"{comp} addresses axis beyond shape {tuple(shape)}")
                divisor[comp.axis] *= size
        for ax, d in enumerate(divisor):
            if shape[ax] % d != 0:
                raise ValueError(
                    f"axis {ax} of shape {tuple(shape)} not divisible by {d} for {self}")

    def local_shape(self, shape: Sequence[int], mesh_shape: Sequence[int]) -> Tuple[int, ...]:
        """The physical (per-device) shard shape of a logical ``shape``."""
        self.validate_for_shape(shape, mesh_shape)
        out = list(shape)
        for comp, size in zip(self.components, mesh_shape):
            if isinstance(comp, Split):
                out[comp.axis] //= size
        return tuple(out)

    def num_replicas(self, mesh_shape: Sequence[int]) -> int:
        """Number of identical copies of each element across the mesh (B axes)."""
        n = 1
        for comp, size in zip(self.components, mesh_shape):
            if comp.is_broadcast:
                n *= size
        return n

    def bytes_per_device(self, shape: Sequence[int], mesh_shape: Sequence[int],
                         itemsize: int) -> int:
        return itemsize * math.prod(self.local_shape(shape, mesh_shape))


def ndsbp(spec: Union[str, NdSbp, Sequence[Union[str, Sbp]]]) -> NdSbp:
    """Public helper: parse anything NdSbp-ish."""
    return NdSbp.parse(spec)
