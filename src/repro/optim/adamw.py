"""AdamW in pure JAX (pytree-structured, no optax dependency).

Besides the pytree-at-once :func:`adamw_update` (the SPMD/ZeRO path), this
module exposes the *per-stage* entry points the pipeline optimizer actors are
built from (paper §3.3: partial-value reductions as first-class dataflow):

* :func:`sqnorm_partials` — each pipeline stage's contribution to the global
  gradient norm, one fp32 scalar per tensor (a P partial);
* :func:`global_norm_from_partials` — the P→B combine: sum the partials in
  one canonical order on the host (stage partials may live on disjoint
  device meshes) and take the square root;
* :func:`clip_scale` / :func:`scale_grad` — the broadcast clip factor and
  its per-tensor application;
* :func:`adamw_param_update` — one tensor's AdamW update given a pre-clipped
  gradient and an explicit step count.

The monolithic reference (:func:`repro.train.steps.make_graph_train_step`)
and the pipeline's per-stage optimizer actors call the *same* jitted kernels
with the same canonical summation order, which is what makes the pipelined
update bit-identical to the monolithic one.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Sequence

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any          # first moment (pytree like params)
    nu: Any          # second moment


def init_adamw(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float, pre_norm=None):
    norm = global_norm(grads) if pre_norm is None else pre_norm
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(cfg: AdamWConfig, params, grads, state: AdamWState,
                 lr_scale=1.0):
    """One AdamW step. All in fp32; returns (new_params, new_state, norm)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip:
        grads, norm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        norm = global_norm(grads)
    step = state.step + 1
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        return adamw_param_update(p, g, m, v, step, lr, beta1=cfg.beta1,
                                  beta2=cfg.beta2, eps=cfg.eps,
                                  weight_decay=cfg.weight_decay)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), norm


# ---------------------------------------------------------------------------
# Per-stage entry points for the pipeline optimizer actors (paper §3.3/§4.3).
# ---------------------------------------------------------------------------

_sqnorm = jax.jit(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))))
_scale = jax.jit(lambda g, s: g.astype(jnp.float32) * s)


def sqnorm_partials(grads: Dict[str, Any]) -> Dict[str, Any]:
    """One fp32 squared-norm scalar per gradient tensor — a pipeline stage's
    partial-value (P) contribution to the global gradient norm."""
    return {n: _sqnorm(g) for n, g in grads.items()}


def global_norm_from_partials(partials: Dict[str, Any],
                              order: Sequence[str]) -> np.float32:
    """The P→B combine: sum per-tensor partials in the canonical ``order``
    and take the square root.

    Runs in numpy on the host because the partials of different pipeline
    stages may be committed to *disjoint* device meshes; fp32 addition is not
    associative, so fixing one summation order is what lets the pipelined
    norm match the monolithic one bit for bit.
    """
    total = np.float32(0.0)
    for n in order:
        if n in partials:
            total = np.float32(total + np.float32(partials[n]))
    return np.float32(np.sqrt(total))


def clip_scale(norm, max_norm: float) -> np.float32:
    """Gradient scale factor for global-norm clipping: ``min(1, c/norm)``.
    Returns 1.0 when ``max_norm`` is falsy (clipping disabled)."""
    if not max_norm:
        return np.float32(1.0)
    return np.float32(min(1.0, float(max_norm) / max(float(norm), 1e-12)))


def scale_grad(g, scale):
    """Apply the broadcast clip factor to one gradient tensor (fp32)."""
    return _scale(g, scale)


def adamw_math(p32, g32, m, v, step, lr, beta1, beta2, eps, weight_decay):
    """The AdamW recurrence itself, fp32 in / fp32 out, traceable anywhere.

    Every AdamW path in the repo — the dense per-tensor kernel below, the
    flat ZeRO shard update (``optim/zero.py``), and the shard_map SPMD
    updates — runs exactly this op sequence; one shared body is what keeps
    dense vs. ZeRO vs. pipelined updates bit-identical (elementwise fp32 ops
    are layout-invariant). Returns ``(new_p32, new_m, new_v)``."""
    bc1 = 1.0 - beta1 ** step.astype(jnp.float32)
    bc2 = 1.0 - beta2 ** step.astype(jnp.float32)
    m = beta1 * m + (1 - beta1) * g32
    v = beta2 * v + (1 - beta2) * g32 * g32
    new_p = p32 - lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps)
                        + weight_decay * p32)
    return new_p, m, v


@partial(jax.jit, static_argnames=("beta1", "beta2", "eps", "weight_decay"))
def adamw_param_update(p, g, m, v, step, lr, *, beta1: float = 0.9,
                       beta2: float = 0.95, eps: float = 1e-8,
                       weight_decay: float = 0.1):
    """One tensor's AdamW update. ``g`` is the already-clipped fp32 gradient,
    ``step`` the *new* (1-based) step count, ``lr`` the schedule-resolved
    learning rate. All math in fp32; the returned param keeps ``p.dtype``.
    Returns ``(new_p, new_m, new_v)``."""
    new_p, m, v = adamw_math(p.astype(jnp.float32), g.astype(jnp.float32),
                             m, v, step, lr, beta1, beta2, eps, weight_decay)
    return new_p.astype(p.dtype), m, v
