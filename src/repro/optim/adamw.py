"""AdamW in pure JAX (pytree-structured, no optax dependency)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any          # first moment (pytree like params)
    nu: Any          # second moment


def init_adamw(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float, pre_norm=None):
    norm = global_norm(grads) if pre_norm is None else pre_norm
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(cfg: AdamWConfig, params, grads, state: AdamWState,
                 lr_scale=1.0):
    """One AdamW step. All in fp32; returns (new_params, new_state, norm)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip:
        grads, norm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        norm = global_norm(grads)
    step = state.step + 1
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        new_p = p.astype(jnp.float32) - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), norm
