"""ZeRO optimizer-state (and master-param) sharding in SBP (paper §6.4).

The paper's point: ZeRO-DP is ~2K LoC of engineering in PyTorch but falls out
of SBP annotations. Here the *master* fp32 parameters AND the Adam moments
live as ``S(0)``-over-data flat shards of shape ``(DP, TP, chunk)``; each step

1. casts the local shard to the compute dtype (the Fig-14 ``cast`` op) and
   boxes ``S(0) -> B`` over the data axes — an **all-gather of the
   half-precision weights** (Table 2 row S->B, at half the fp32 wire cost);
2. runs fwd/bwd on the gathered weights; the autodiff *transpose* of the
   all-gather is exactly the ``P(sum) -> S(0)`` **reduce-scatter** of
   gradients (Table 2 row P->S) — the compiler inserts it, nobody writes it;
3. updates the local master shard with Adam (fp32).

Replicated-over-model leaves keep one master copy per model shard; their
gradients need a model-axis combine before the update: a sum for leaves with
disjoint per-shard contributions (kv projections, router, ...), a mean for
leaves whose per-shard grads are identical (norm scales). See
``MODEL_SUM_LEAVES``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import MeshPlan
from repro.optim.adamw import AdamWConfig, adamw_math, adamw_param_update


class ZeroState(NamedTuple):
    step: jnp.ndarray
    mu: Any     # pytree of (DP, TP, chunk) fp32 — same layout as the masters
    nu: Any


# Model-replicated params whose per-device gradient contributions are
# DISJOINT (each model shard computes grads only through its kv-head /
# B,C-group / expert slice): combine = psum. All other replicated leaves have
# IDENTICAL per-shard grads: combine = pmean.
MODEL_SUM_LEAVES = frozenset(
    {"wk", "wv", "bk", "bv", "q_norm", "k_norm", "w_bc", "conv_bc", "router"})


def _chunk_size(local_size: int, dp: int) -> int:
    return math.ceil(local_size / dp)


def _leaf_name(path) -> str:
    for p in reversed(path):
        k = getattr(p, "key", None)
        if k is not None:
            return k
    return ""


def _spec_axes(spec):
    flat = []
    for entry in spec:
        names = entry if isinstance(entry, tuple) else (entry,)
        flat.extend(n for n in names if n is not None)
    return flat


def local_shape_of(global_shape, spec, plan: MeshPlan):
    shape = list(global_shape)
    for dim, entry in enumerate(spec):
        names = entry if isinstance(entry, tuple) else (entry,)
        for n in names:
            if n is not None:
                shape[dim] //= plan.axis_size(n)
    return tuple(shape)


# ---------------------------------------------------------------------------
# flat-shard layout
# ---------------------------------------------------------------------------

def master_specs(params_specs, plan: MeshPlan):
    """PartitionSpecs of the flat (DP, TP, chunk) master/moment leaves."""
    from jax.sharding import PartitionSpec as P

    dp_axes = plan.data_axes
    mx = plan.model_axis if plan.model_axis in plan.axis_names else None
    leaf = P(dp_axes if len(dp_axes) > 1 else dp_axes[0], mx, None)
    return jax.tree.map(lambda _: leaf, params_specs,
                        is_leaf=lambda s: isinstance(s, P))


def zero_state_specs(params_specs, plan: MeshPlan):
    m = master_specs(params_specs, plan)
    from jax.sharding import PartitionSpec as P

    return ZeroState(P(), m, jax.tree.map(lambda s: s, m))


def master_shapes(params_global, specs, plan: MeshPlan):
    """Global ShapeDtypeStructs of the flat master leaves."""
    def leaf(p, spec):
        n_loc = math.prod(local_shape_of(p.shape, spec, plan)) if p.shape else 1
        return jax.ShapeDtypeStruct(
            (plan.dp, plan.tp, _chunk_size(n_loc, plan.dp)), jnp.float32)

    from jax.sharding import PartitionSpec as P

    return jax.tree.map(leaf, params_global, specs,
                        is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct))


def zero_state_shapes(params_global, specs, plan: MeshPlan):
    m = master_shapes(params_global, specs, plan)
    return ZeroState(jax.ShapeDtypeStruct((), jnp.int32), m,
                     jax.tree.map(lambda x: x, m))


def shard_master_local(p_local, plan: MeshPlan):
    """(inside shard_map) full local param -> (1, 1, chunk) master shard."""
    dp = plan.dp
    flat = p_local.reshape(-1).astype(jnp.float32)
    chunk = _chunk_size(flat.size, dp)
    flat = jnp.pad(flat, (0, dp * chunk - flat.size))
    if dp > 1:
        axes = plan.data_axes if len(plan.data_axes) > 1 else plan.data_axes[0]
        idx = jax.lax.axis_index(axes)
        sh = jax.lax.dynamic_slice_in_dim(flat, idx * chunk, chunk)
    else:
        sh = flat
    return sh.reshape(1, 1, chunk)


def gather_master_local(m_local, local_shape, compute_dtype, plan: MeshPlan):
    """(inside shard_map) (1,1,chunk) master shard -> full local param.

    Implements Fig 14: fp32 master -> cast -> S(0)->B all-gather in the
    compute dtype (half the wire bytes of gathering fp32).
    """
    sh = m_local.reshape(-1).astype(compute_dtype)     # the Fig-14 cast op
    if plan.dp > 1:
        axes = plan.data_axes if len(plan.data_axes) > 1 else plan.data_axes[0]
        flat = jax.lax.all_gather(sh, axes, tiled=True)
    else:
        flat = sh
    n = math.prod(local_shape) if local_shape else 1
    return flat[:n].reshape(local_shape)


def init_zero_state_local(masters_local, plan: MeshPlan) -> ZeroState:
    mu = jax.tree.map(lambda m: jnp.zeros_like(m, jnp.float32), masters_local)
    return ZeroState(jnp.zeros((), jnp.int32), mu, jax.tree.map(jnp.copy, mu))


# ---------------------------------------------------------------------------
# global flat-shard kernels (no shard_map) — the per-stage entry points the
# pipelined opt actors and the monolithic train engine share. Same layout as
# the shard_map kernels above, but over the *global* array: the whole
# (dp, 1, chunk) flat master lives in one jax.Array (optionally committed to
# a NamedSharding over the leading dp axis, in which case XLA inserts the
# S(0)->B all-gather / its reduce-scatter transpose for free).
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("dp",))
def shard_flat(x, *, dp: int):
    """Full tensor -> flat ``(dp, 1, chunk)`` fp32 shards, zero-padded.

    The global-view dual of :func:`shard_master_local`. Padding stays exactly
    zero through AdamW updates (0 moments, 0 grad, 0 weight-decay term), so
    gather -> re-shard across different dp values is bitwise lossless.
    """
    flat = x.reshape(-1).astype(jnp.float32)
    chunk = _chunk_size(flat.size, dp)
    flat = jnp.pad(flat, (0, dp * chunk - flat.size))
    return flat.reshape(dp, 1, chunk)


@partial(jax.jit, static_argnames=("shape", "dtype"))
def gather_flat(m, *, shape, dtype):
    """Flat ``(dp, 1, chunk)`` shards -> full tensor in ``dtype``.

    The cast happens *before* the reshape — Fig 14's ``cast`` op ahead of the
    S(0)->B gather, so a sharded master crosses the wire at compute-dtype
    width, not fp32.
    """
    flat = m.astype(jnp.dtype(dtype)).reshape(-1)
    n = math.prod(shape) if shape else 1
    return flat[:n].reshape(shape)


def init_zero_flat(masters) -> ZeroState:
    """Zero moments in the masters' flat (dp, 1, chunk) layout."""
    mu = jax.tree.map(lambda m: jnp.zeros_like(m, jnp.float32), masters)
    return ZeroState(jnp.zeros((), jnp.int32), mu, jax.tree.map(jnp.copy, mu))


def zero_stage_update(masters: Dict[str, Any], grads: Dict[str, Any],
                      state: ZeroState, lr, *, dp: int, beta1: float,
                      beta2: float, eps: float, weight_decay: float):
    """One optimizer stage's ZeRO AdamW step on flat masters.

    ``masters``: ``{name: (dp, 1, chunk) fp32}``; ``grads``: ``{name:
    full-shape pre-clipped fp32}``. Per-element math is
    :func:`adamw_param_update` (via the shared ``adamw_math`` body), which is
    elementwise and therefore layout-invariant — the flat update is bitwise
    the dense update reshaped. Returns ``(new_masters, new ZeroState)``.
    """
    new_step = state.step + 1
    new_m: Dict[str, Any] = {}
    new_mu: Dict[str, Any] = {}
    new_nu: Dict[str, Any] = {}
    for n, m in masters.items():
        gf = shard_flat(grads[n], dp=dp)
        new_m[n], new_mu[n], new_nu[n] = adamw_param_update(
            m, gf, state.mu[n], state.nu[n], new_step, lr,
            beta1=beta1, beta2=beta2, eps=eps, weight_decay=weight_decay)
    return new_m, ZeroState(new_step, new_mu, new_nu)


# ---------------------------------------------------------------------------
# gradient combine over the model axis for replicated leaves
# ---------------------------------------------------------------------------

def model_combine_tree(params_specs, plan: MeshPlan):
    """Per-leaf model-axis gradient combine: 'none' | 'sum'.

    With gathered (varying) masters, EVERY model-replicated leaf's per-shard
    gradient contributions are disjoint partial sums (each shard's autodiff
    covers only its own branch of every psum-mediated path), so the combine
    is always a psum. Redundant non-psum-mediated loss terms (the MoE aux
    loss) are pmean-mediated in the model so this stays exact.
    """
    from jax.sharding import PartitionSpec as P
    import jax.tree_util as jtu

    def mode(path, spec):
        return "none" if plan.model_axis in _spec_axes(spec) else "sum"

    return jtu.tree_map_with_path(mode, params_specs,
                                  is_leaf=lambda s: isinstance(s, P))


def combine_model_grads(grads, combine, plan: MeshPlan):
    if plan.tp == 1:
        return grads

    def fix(g, mode):
        if mode == "sum":
            return jax.lax.psum(g, plan.model_axis)
        if mode == "mean":
            return jax.lax.pmean(g, plan.model_axis)
        return g

    return jax.tree.map(fix, grads, combine)


# ---------------------------------------------------------------------------
# the update (operates on flat shards)
# ---------------------------------------------------------------------------

def zero_adamw_update(cfg: AdamWConfig, masters, grads_flat, state: ZeroState,
                      plan: MeshPlan, replication, lr_scale=1.0):
    """Adam on (1,1,chunk) master shards. ``grads_flat`` has the same layout
    (already reduce-scattered over data and model-combined)."""
    dp = plan.dp
    tp = plan.tp
    axes = plan.data_axes if len(plan.data_axes) > 1 else plan.data_axes[0]

    sumsq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) / r
        for g, r in zip(jax.tree.leaves(grads_flat),
                        jax.tree.leaves(replication)))
    if dp > 1:
        sumsq = jax.lax.psum(sumsq, axes)
    if tp > 1:
        sumsq = jax.lax.psum(sumsq, plan.model_axis)
    norm = jnp.sqrt(sumsq)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(norm, 1e-12)) \
        if cfg.grad_clip else jnp.float32(1.0)

    step = state.step + 1
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        return adamw_math(p, g, m, v, step, lr, cfg.beta1, cfg.beta2,
                          cfg.eps, cfg.weight_decay)

    out = jax.tree.map(upd, masters, grads_flat, state.mu, state.nu)
    is3 = lambda t: isinstance(t, tuple) and len(t) == 3
    new_m = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
    return new_m, ZeroState(step, new_mu, new_nu), norm


# ---------------------------------------------------------------------------
# plain (non-ZeRO) data parallelism — the §6.2 baseline
# ---------------------------------------------------------------------------

def plain_dp_adamw_update(cfg: AdamWConfig, params, grads, state,
                          plan: MeshPlan, replication, lr_scale=1.0):
    """P(sum) -> B all-reduce of grads, replicated optimizer states."""
    from repro.optim.adamw import AdamWState

    dp = plan.dp
    axes = plan.data_axes if len(plan.data_axes) > 1 else plan.data_axes[0]

    def reduce_grad(g):
        g = g.astype(jnp.float32) / dp
        return jax.lax.psum(g, axes) if dp > 1 else g

    grads = jax.tree.map(reduce_grad, grads)
    sumsq = sum(
        jnp.sum(jnp.square(g)) / r
        for g, r in zip(jax.tree.leaves(grads), jax.tree.leaves(replication)))
    if plan.tp > 1:
        sumsq = jax.lax.psum(sumsq, plan.model_axis)
    norm = jnp.sqrt(sumsq)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(norm, 1e-12)) \
        if cfg.grad_clip else jnp.float32(1.0)

    step = state.step + 1
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        new_p, m, v = adamw_math(p.astype(jnp.float32), g * scale, m, v,
                                 step, lr, cfg.beta1, cfg.beta2, cfg.eps,
                                 cfg.weight_decay)
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    is3 = lambda t: isinstance(t, tuple) and len(t) == 3
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
    new_params = _certify_replicated(new_params, replication, plan)
    new_mu = _certify_replicated(new_mu, replication, plan)
    new_nu = _certify_replicated(new_nu, replication, plan)
    return new_params, AdamWState(step, new_mu, new_nu), norm


def _certify_replicated(tree, replication, plan: MeshPlan):
    """pmean leaves that are logically replicated over the model axis.

    Mathematically a no-op (values equal by construction); certifies
    replication to shard_map's vma checker, whose inference is conservative
    through remat/custom_vjp regions. Applies even when the model axis has
    size 1 (vma still tracks it).
    """
    if plan.model_axis not in plan.axis_names:
        return tree

    def fix(x, r):
        vma = getattr(jax.core.get_aval(x), "vma", frozenset())
        if plan.model_axis not in vma:
            return x
        if r <= 1 and plan.tp > 1:
            return x      # genuinely model-sharded leaf: varying is correct
        return jax.lax.pmean(x, plan.model_axis)

    return jax.tree.map(fix, tree, replication)
