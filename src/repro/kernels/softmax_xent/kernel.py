"""Pallas TPU kernel: fused sharded-vocab softmax cross-entropy local stats.

The paper's Fig 11b pattern: each vocab shard reduces LOCALLY (max, sum-exp,
label-logit gather) in one pass over VMEM tiles; the tiny (m, s, z) stats are
combined across shards by the SBP partial-value boxing outside.

Grid: (row_blocks, vocab_blocks) — vocab is the innermost (fastest) axis so
the running stats live in VMEM scratch across vocab tiles and are emitted on
the last tile. Tiles are MXU/VPU aligned: (block_rows x block_vocab) with
block_vocab a multiple of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _xent_kernel(logits_ref, labels_ref, voff_ref,
                 m_ref, s_ref, z_ref,
                 m_scr, s_scr, z_scr,
                 *, block_v: int, n_vblocks: int, vocab_local: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        s_scr[...] = jnp.zeros_like(s_scr)
        z_scr[...] = jnp.zeros_like(z_scr)

    x = logits_ref[...].astype(jnp.float32)          # (bR, bV)
    labels = labels_ref[...]                         # (bR,)
    voff = voff_ref[0]                               # global col of shard

    # mask the padding tail of the last vocab tile
    col = vi * block_v + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    valid = col < vocab_local
    x = jnp.where(valid, x, NEG_INF)

    m_old = m_scr[...]
    m_new = jnp.maximum(m_old, x.max(axis=1))
    scale = jnp.exp(m_old - m_new)
    s_scr[...] = s_scr[...] * scale + jnp.exp(x - m_new[:, None]).sum(axis=1)
    m_scr[...] = m_new

    # label gather: the label's local column may fall in this tile
    shard_col = labels - voff
    local_col = shard_col - vi * block_v
    hit = ((local_col >= 0) & (local_col < block_v)
           & (shard_col >= 0) & (shard_col < vocab_local))
    safe = jnp.clip(local_col, 0, block_v - 1)
    picked = jnp.take_along_axis(x, safe[:, None], axis=1)[:, 0]
    z_scr[...] = z_scr[...] + jnp.where(hit, picked, 0.0)

    @pl.when(vi == n_vblocks - 1)
    def _emit():
        m_ref[...] = m_scr[...]
        s_ref[...] = s_scr[...]
        z_ref[...] = z_scr[...]


def xent_local_stats_pallas(logits, labels, vocab_offset, *,
                            block_rows: int = 256, block_v: int = 512,
                            interpret: bool = True):
    """logits: (N, Vl); labels: (N,) global ids; vocab_offset: scalar.

    Returns (m, s, z) local stats, identical to
    :func:`repro.kernels.softmax_xent.ref.local_stats_ref`.
    """
    N, Vl = logits.shape
    block_rows = min(block_rows, N)
    block_v = min(block_v, max(128, Vl))
    pr = (-N) % block_rows
    pv = (-Vl) % block_v
    lp = jnp.pad(logits, ((0, pr), (0, pv)))
    lbl = jnp.pad(labels, (0, pr))
    Np, Vp = lp.shape
    n_r, n_v = Np // block_rows, Vp // block_v
    voff = jnp.asarray([vocab_offset], jnp.int32)

    kernel = functools.partial(_xent_kernel, block_v=block_v, n_vblocks=n_v,
                               vocab_local=Vl)
    m, s, z = pl.pallas_call(
        kernel,
        grid=(n_r, n_v),
        in_specs=[
            pl.BlockSpec((block_rows, block_v), lambda r, v: (r, v)),
            pl.BlockSpec((block_rows,), lambda r, v: (r,)),
            pl.BlockSpec((1,), lambda r, v: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows,), lambda r, v: (r,)),
            pl.BlockSpec((block_rows,), lambda r, v: (r,)),
            pl.BlockSpec((block_rows,), lambda r, v: (r,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np,), jnp.float32),
            jax.ShapeDtypeStruct((Np,), jnp.float32),
            jax.ShapeDtypeStruct((Np,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_rows,), jnp.float32),
            pltpu.VMEM((block_rows,), jnp.float32),
            pltpu.VMEM((block_rows,), jnp.float32),
        ],
        interpret=interpret,
    )(lp, lbl, voff)
    return m[:N], s[:N], z[:N]
