"""Pure-jnp oracle for sharded-vocab softmax cross-entropy (paper Fig 11b).

The unembedding is column-parallel: logits arrive vocab-sharded
(SBP ``S(vocab)`` on the model axis). The op reduces *locally* first (local
max, local sum-exp, local label gather) and combines globally with two tiny
collectives — never materializing gathered logits. The local part is the
Pallas kernel; the combine is the SBP partial-value reduction.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def local_stats_ref(logits, labels, vocab_offset):
    """Per-shard stats: (local_max, local_sumexp_given_max, local_label_logit).

    logits: (N, Vl) this shard's vocab slice; labels: (N,) global ids;
    vocab_offset: scalar — global id of this shard's column 0.
    Returns m: (N,), s: (N,) = sum exp(logit - m), z: (N,) label logit or 0.
    """
    N, Vl = logits.shape
    lf = logits.astype(jnp.float32)
    # stop_gradient is exact: d/dm [log sum exp(l - m) + m] == 0
    m = jax.lax.stop_gradient(lf.max(axis=-1))
    s = jnp.exp(lf - m[:, None]).sum(axis=-1)
    local_ids = labels - vocab_offset
    in_range = (local_ids >= 0) & (local_ids < Vl)
    safe = jnp.clip(local_ids, 0, Vl - 1)
    z = jnp.take_along_axis(lf, safe[:, None], axis=1)[:, 0]
    z = jnp.where(in_range, z, 0.0)
    return m, s, z


def combine_stats(m, s, z, axis_name: Optional[str] = None):
    """Combine per-shard stats into per-token loss.

    m is P(max); z is P(sum) (exactly one shard contributes); s must be
    rescaled by exp(m - m_global) before its P(sum) reduction.
    """
    if axis_name is not None:
        m_g = jax.lax.stop_gradient(jax.lax.pmax(m, axis_name))
        s_g = jax.lax.psum(s * jnp.exp(m - m_g), axis_name)
        z_g = jax.lax.psum(z, axis_name)
    else:
        m_g = m.max(axis=0)
        s_g = (s * jnp.exp(m - m_g[None])).sum(axis=0)
        z_g = z.sum(axis=0)
    return jnp.log(s_g) + m_g - z_g     # -log softmax[label]


def softmax_xent_ref(logits, labels):
    """Unsharded oracle: -log softmax(logits)[label] per row."""
    lf = logits.astype(jnp.float32)
    m = lf.max(axis=-1)
    lse = jnp.log(jnp.exp(lf - m[:, None]).sum(axis=-1)) + m
    z = jnp.take_along_axis(lf, labels[:, None], axis=1)[:, 0]
    return lse - z
