from repro.kernels.softmax_xent.ops import xent_local_stats
from repro.kernels.softmax_xent.ref import (combine_stats, local_stats_ref,
                                            softmax_xent_ref)
