"""jit'd public wrapper: dispatch Pallas kernel (TPU path) vs jnp ref."""
from functools import partial

import jax

from repro.kernels.softmax_xent.kernel import xent_local_stats_pallas
from repro.kernels.softmax_xent.ref import local_stats_ref


@partial(jax.jit, static_argnames=("vocab_offset", "use_pallas", "interpret"))
def xent_local_stats(logits, labels, vocab_offset=0, *, use_pallas=False,
                     interpret=True):
    if use_pallas:
        return xent_local_stats_pallas(logits, labels, vocab_offset,
                                       interpret=interpret)
    return local_stats_ref(logits, labels, vocab_offset)
