"""jit'd public wrapper: dispatch Pallas kernel (TPU path) vs jnp ref."""
from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


@partial(jax.jit, static_argnames=("causal", "sliding_window", "q_offset",
                                   "use_pallas", "interpret"))
def flash_attention(q, k, v, *, causal=True, sliding_window=0, q_offset=0,
                    use_pallas=False, interpret=True):
    if use_pallas:
        return flash_attention_pallas(q, k, v, causal=causal,
                                      sliding_window=sliding_window,
                                      q_offset=q_offset, interpret=interpret)
    return flash_attention_ref(q, k, v, causal=causal,
                               sliding_window=sliding_window,
                               q_offset=q_offset)
