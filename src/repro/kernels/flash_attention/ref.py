"""Pure-jnp oracle for blocked (flash-style) attention.

This is both the correctness reference for the Pallas kernel and the
implementation the models lower through on CPU / in the dry-run (so XLA's
cost analysis sees real attention FLOPs rather than a pallas_call black box).

Causal masking is applied per block; all (q-block, kv-block) rectangles are
computed (fixed trip counts keep the HLO static) — i.e. the baseline does 2x
the causal-minimum attention FLOPs. This is deliberate and is called out in
EXPERIMENTS.md §Roofline as optimization headroom.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _attend_block(q, k, v, mask, sm_scale):
    """One (q-block, kv-block) rectangle with running softmax state.

    q: (b, bq, h, d); k/v: (b, bk, h, d); mask: (bq, bk) or None.
    Returns (scores_max, exp_scores@v, sumexp) contributions.
    """
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * sm_scale
    if mask is not None:
        s = jnp.where(mask[None, None], s, NEG_INF)
    return s


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        sliding_window: int = 0, q_offset: int = 0,
                        block_q: int = 512, block_k: int = 512,
                        sm_scale: Optional[float] = None):
    """Blocked attention with online softmax.

    q: (B, Sq, H, D);  k, v: (B, Sk, KV, D) with H % KV == 0 (GQA).
    ``q_offset``: absolute position of q[0] (decode/prefill continuation).
    ``sliding_window`` > 0 limits attention to the last W positions.
    """
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    Dv = v.shape[-1]            # MLA: value head dim may differ from qk dim
    assert H % KV == 0, (H, KV)
    G = H // KV
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    # pad to block multiples
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // block_q, kp.shape[1] // block_k

    # GQA: expand kv heads to q heads (XLA fuses the broadcast into the dot)
    kp = jnp.repeat(kp, G, axis=2)
    vp = jnp.repeat(vp, G, axis=2)

    q_pos = q_offset + jnp.arange(nq * block_q)
    k_pos = jnp.arange(nk * block_k)

    qb = qp.reshape(B, nq, block_q, H, D)
    kb = kp.reshape(B, nk, block_k, H, D)
    vb = vp.reshape(B, nk, block_k, H, Dv)

    def q_block(carry, qi):
        qi_q = jax.lax.dynamic_index_in_dim(qb, qi, axis=1, keepdims=False)
        qpos = jax.lax.dynamic_slice_in_dim(q_pos, qi * block_q, block_q)

        def kv_block(state, ki):
            m, l, acc = state
            k_i = jax.lax.dynamic_index_in_dim(kb, ki, axis=1, keepdims=False)
            v_i = jax.lax.dynamic_index_in_dim(vb, ki, axis=1, keepdims=False)
            kpos = jax.lax.dynamic_slice_in_dim(k_pos, ki * block_k, block_k)
            s = jnp.einsum("bqhd,bkhd->bhqk", qi_q, k_i,
                           preferred_element_type=jnp.float32) * sm_scale
            mask = jnp.ones((block_q, block_k), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if sliding_window:
                mask &= kpos[None, :] > qpos[:, None] - sliding_window
            mask &= kpos[None, :] < Sk  # padding
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            scale = jnp.exp(m - m_new)
            l_new = l * scale + p.sum(axis=-1)
            acc_new = acc * scale[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, v_i, preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        # carry inits derive from qi_q so their vma (shard_map varying-axes
        # type) matches the scan body outputs under check_vma=True
        zq = (qi_q[:, :, :, 0] * 0).astype(jnp.float32).transpose(0, 2, 1)
        m0 = zq + NEG_INF
        l0 = zq
        a0 = jnp.zeros((B, H, block_q, Dv), jnp.float32) + zq[..., None]
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return carry, out.transpose(0, 2, 1, 3)  # (B, block_q, H, D)

    _, outs = jax.lax.scan(q_block, 0, jnp.arange(nq))
    # outs: (nq, B, block_q, H, D) -> (B, Sq, H, D)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * block_q, H, Dv)
    return out[:, :Sq].astype(q.dtype)


def attention_dense_ref(q, k, v, *, causal=True, sliding_window=0,
                        q_offset=0, sm_scale=None):
    """O(S^2)-memory direct attention — oracle for the oracle (tiny shapes)."""
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    k = jnp.repeat(k, G, axis=2)
    v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * sm_scale
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if sliding_window:
        mask &= kpos[None, :] > qpos[:, None] - sliding_window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    return out.astype(q.dtype)


def flash_attention_triangular(q, k, v, *, sliding_window: int = 0,
                               block_q: int = 512, block_k: int = 512,
                               sm_scale: Optional[float] = None):
    """Causal self-attention that SKIPS fully-masked (q, kv) block pairs.

    Perf hillclimb #2: the plain blocked implementation computes all
    nq x nk rectangles (2x the causal minimum). Here the scan runs over the
    static list of unmasked (qi, ki<=qi) pairs — nq(nq+1)/2 trips — so the
    lowered HLO carries half the attention FLOPs/bytes. With a sliding
    window, pairs outside the band are dropped too. Numerically identical to
    :func:`flash_attention_ref` (online softmax is order-invariant).

    Requires Sq == Sk (self-attention) and q_offset == 0.
    """
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    Dv = v.shape[-1]
    assert Sq == Sk, "triangular path is for square self-attention"
    G = H // KV
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    pq, pk = (-Sq) % block_q, (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // block_q, kp.shape[1] // block_k
    kp = jnp.repeat(kp, G, axis=2)
    vp = jnp.repeat(vp, G, axis=2)
    qb = qp.reshape(B, nq, block_q, H, D).transpose(1, 0, 2, 3, 4)
    kb = kp.reshape(B, nk, block_k, H, D).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nk, block_k, H, Dv).transpose(1, 0, 2, 3, 4)

    # static pair list: only blocks intersecting the causal (banded) region
    pairs = []
    for qi in range(nq):
        q_lo, q_hi = qi * block_q, (qi + 1) * block_q - 1
        for ki in range(nk):
            k_lo, k_hi = ki * block_k, (ki + 1) * block_k - 1
            if k_lo > q_hi:
                continue                       # strictly above the diagonal
            if sliding_window and k_hi <= q_lo - sliding_window:
                continue                       # entirely left of the band
            pairs.append((qi, ki))
    qi_arr = jnp.asarray([p[0] for p in pairs], jnp.int32)
    ki_arr = jnp.asarray([p[1] for p in pairs], jnp.int32)

    def step(state, pair):
        m, l, acc = state                      # (nq, B, H, bq[, Dv])
        qi, ki = pair
        q_i = jax.lax.dynamic_index_in_dim(qb, qi, 0, keepdims=False)
        k_i = jax.lax.dynamic_index_in_dim(kb, ki, 0, keepdims=False)
        v_i = jax.lax.dynamic_index_in_dim(vb, ki, 0, keepdims=False)
        s = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_i,
                       preferred_element_type=jnp.float32) * sm_scale
        qpos = qi * block_q + jnp.arange(block_q)
        kpos = ki * block_k + jnp.arange(block_k)
        mask = qpos[:, None] >= kpos[None, :]
        if sliding_window:
            mask &= kpos[None, :] > qpos[:, None] - sliding_window
        mask &= kpos[None, :] < Sk
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_old = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        l_old = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        a_old = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
        m_new = jnp.maximum(m_old, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m_old - m_new)
        l_new = l_old * scale + p.sum(axis=-1)
        a_new = a_old * scale[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, v_i, preferred_element_type=jnp.float32)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 0)
        return (m, l, acc), None

    # vma-tied zeros (scan carry must match body vma under shard_map)
    tie = (qb[:, :, 0, 0, 0] * 0).astype(jnp.float32)[:, :, None, None]
    m0 = jnp.full((nq, B, H, block_q), NEG_INF, jnp.float32) + tie
    l0 = jnp.zeros((nq, B, H, block_q), jnp.float32) + tie
    a0 = jnp.zeros((nq, B, H, block_q, Dv), jnp.float32) + tie[..., None]
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (qi_arr, ki_arr))
    out = acc / jnp.maximum(l, 1e-30)[..., None]      # (nq, B, H, bq, Dv)
    out = out.transpose(1, 0, 3, 2, 4).reshape(B, nq * block_q, H, Dv)
    return out[:, :Sq].astype(q.dtype)
