from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import (attention_dense_ref,
                                               flash_attention_ref)
