"""Pallas TPU kernel: blocked causal/sliding-window GQA flash attention.

Grid: (batch, q_heads, q_blocks, kv_blocks) with kv innermost; the online-
softmax state (m, l, acc) lives in VMEM scratch across kv tiles and the
output tile is emitted on the last kv tile. Block shapes default to
(128 q x 128 kv) — MXU-aligned (head_dim is the lane dim, multiples of 128
for all assigned archs except whisper's 64, still VPU-tileable).

GQA is expressed in the kv index_map: q head h reads kv head h * KV // H —
no materialized head broadcast.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, n_kblocks: int, seq_q: int,
                  seq_k: int, causal: bool, sliding_window: int,
                  q_offset: int, sm_scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)             # (block_q, d)
    k = k_ref[0, 0].astype(jnp.float32)             # (block_k, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    qpos = (q_offset + qi * block_q
            + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0))
    kpos = (ki * block_k
            + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1))
    mask = kpos < seq_k                             # kv padding
    row_valid = (qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)) < seq_q  # q padding
    mask &= row_valid
    if causal:
        mask &= qpos >= kpos
    if sliding_window:
        mask &= kpos > qpos - sliding_window
    s = jnp.where(mask, s, NEG_INF)

    m_old = m_scr[...]
    m_new = jnp.maximum(m_old, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    scale = jnp.exp(m_old - m_new)
    l_scr[...] = l_scr[...] * scale + p.sum(axis=1)
    acc_scr[...] = (acc_scr[...] * scale[:, None]
                    + jax.lax.dot_general(
                        p, v, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(ki == n_kblocks - 1)
    def _emit():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           sliding_window: int = 0, q_offset: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           sm_scale=None, interpret: bool = True):
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D), H % KV == 0.

    Matches :func:`repro.kernels.flash_attention.ref.flash_attention_ref`.
    """
    B, Sq, H, D = q.shape
    _, Sk, KV, Dv = k.shape[0], k.shape[1], k.shape[2], v.shape[3]
    assert H % KV == 0
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    block_q = min(block_q, max(8, Sq))
    block_k = min(block_k, max(8, Sk))
    pq, pk = (-Sq) % block_q, (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    # layout: (B, H, S, D) so the S x D tile is contiguous per (b, h)
    qp = qp.transpose(0, 2, 1, 3)
    kp = kp.transpose(0, 2, 1, 3)
    vp = vp.transpose(0, 2, 1, 3)
    nq, nk = qp.shape[2] // block_q, kp.shape[2] // block_k

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, n_kblocks=nk,
        seq_q=Sq, seq_k=Sk, causal=causal, sliding_window=sliding_window,
        q_offset=q_offset, sm_scale=sm_scale)

    group = H // KV
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, Dv),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dv),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * block_q, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out.transpose(0, 2, 1, 3)[:, :Sq]
