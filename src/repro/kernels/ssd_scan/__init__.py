from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import (ssd_chunked_ref, ssd_decode_step,
                                        ssd_sequential_ref)
