"""Pure-jnp oracles for the Mamba-2 SSD scan (arXiv:2405.21060).

Two implementations:

* :func:`ssd_sequential_ref` — the literal per-step recurrence (the oracle);
* :func:`ssd_chunked_ref`   — the chunked state-space-duality form: dense
  MXU-friendly intra-chunk attention-like compute + a short inter-chunk
  recurrence. This is what the model lowers through (and the shape the Pallas
  kernel implements).

Shapes (per shard):
  x : (B, L, H, P)    heads x head_dim
  dt: (B, L, H)       positive step sizes (post-softplus)
  A : (H,)            negative decay rates
  Bm: (B, L, G, N)    input projections (G groups; H % G == 0)
  Cm: (B, L, G, N)    output projections
  D : (H,)            skip connection
Returns y: (B, L, H, P) and the final state (B, H, P, N).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def _expand_groups(Bm, H):
    G = Bm.shape[2]
    assert H % G == 0
    return jnp.repeat(Bm, H // G, axis=2)


def ssd_sequential_ref(x, dt, A, Bm, Cm, D, h0=None):
    B_, L, H, P = x.shape
    N = Bm.shape[-1]
    Bh = _expand_groups(Bm, H).astype(jnp.float32)
    Ch = _expand_groups(Cm, H).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A[None, None, :])                     # (B, L, H)

    def step(h, inputs):
        xa, dta, da, ba, ca = inputs
        # h: (B, H, P, N)
        h = h * da[:, :, None, None] + (dta[:, :, None] * xa)[..., None] \
            * ba[:, :, None, :]
        y = jnp.einsum("bhn,bhpn->bhp", ca, h)
        return h, y

    if h0 is None:
        zh = (xf[:, 0, :, :, None] * Bh[:, 0, :, None, :] * 0).astype(jnp.float32)
        h0 = jnp.zeros((B_, H, P, N), jnp.float32) + zh
    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          dA.transpose(1, 0, 2), Bh.transpose(1, 0, 2, 3),
          Ch.transpose(1, 0, 2, 3))
    hT, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3) + xf * D[None, None, :, None]
    return y.astype(x.dtype), hT


def _segsum(a):
    """a: (..., Q) -> (..., Q, Q) lower-triangular cumulative sums:
    out[i, j] = sum(a[j+1 .. i]) for i >= j, -inf otherwise."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(Q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked_ref(x, dt, A, Bm, Cm, D, h0=None, chunk: int = 128):
    """Chunked SSD: O(L Q) memory, dense intra-chunk matmuls."""
    B_, L, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        def zf(t):
            return jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, dt, Bm, Cm = zf(x), zf(dt), zf(Bm), zf(Cm)
    Lp = x.shape[1]
    nc = Lp // Q

    Bh = _expand_groups(Bm, H).astype(jnp.float32)
    Ch = _expand_groups(Cm, H).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    # reshape to chunks: (B, nc, Q, ...)
    xc = xf.reshape(B_, nc, Q, H, P)
    dtc = dtf.reshape(B_, nc, Q, H)
    bc = Bh.reshape(B_, nc, Q, H, N)
    cc = Ch.reshape(B_, nc, Q, H, N)
    da_log = dtc * A[None, None, None, :]                    # (B, nc, Q, H)

    # intra-chunk ("diagonal block") attention-like term
    seg = _segsum(da_log.transpose(0, 1, 3, 2))              # (B, nc, H, Q, Q)
    Lmat = jnp.exp(seg)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", cc, bc) * Lmat
    y_diag = jnp.einsum("bchqk,bckh,bckhp->bcqhp", scores, dtc, xc)

    # per-chunk end states: S_c = sum_j decay(Q-1 -> j) dt_j B_j x_j
    total = da_log.sum(axis=2)                               # (B, nc, H)
    dec_to_end = jnp.exp(da_log.sum(axis=2, keepdims=True)
                         - jnp.cumsum(da_log, axis=2))       # (B, nc, Q, H)
    S = jnp.einsum("bcqh,bcqh,bcqhn,bcqhp->bchpn",
                   dec_to_end, dtc, bc, xc)                  # (B, nc, H, P, N)

    # inter-chunk recurrence over nc chunks
    def chunk_step(h, inputs):
        s_c, tot_c = inputs
        h_next = h * jnp.exp(tot_c)[..., None, None] + s_c
        return h_next, h                                     # emit state BEFORE chunk

    if h0 is None:
        zh = (xc[:, 0, 0, :, :, None] * bc[:, 0, 0, :, None, :] * 0
              ).astype(jnp.float32)                  # vma-tied zeros
        h0 = jnp.zeros((B_, H, P, N), jnp.float32) + zh
    hT, h_prevs = jax.lax.scan(
        chunk_step, h0,
        (S.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)))
    h_prev = h_prevs.transpose(1, 0, 2, 3, 4)                # (B, nc, H, P, N)

    # off-diagonal: contribution of the carried state to every position
    dec_from_start = jnp.exp(jnp.cumsum(da_log, axis=2))     # (B, nc, Q, H)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", cc, h_prev, dec_from_start)

    y = (y_diag + y_off).reshape(B_, Lp, H, P)[:, :L]
    y = y + xf[:, :L] * D[None, None, :, None]
    return y.astype(x.dtype), hT


def ssd_decode_step(x, dt, A, Bm, Cm, D, h):
    """Single-token recurrence for serving. x: (B, H, P); dt: (B, H);
    Bm, Cm: (B, G, N); h: (B, H, P, N) -> (y, h_next)."""
    H = x.shape[1]
    Bh = _expand_groups(Bm[:, None], H)[:, 0].astype(jnp.float32)
    Ch = _expand_groups(Cm[:, None], H)[:, 0].astype(jnp.float32)
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A[None, :])
    h = h * dA[..., None, None] + (dtf[..., None] * xf)[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhn,bhpn->bhp", Ch, h) + xf * D[None, :, None]
    return y.astype(x.dtype), h
