"""jit'd public wrapper: dispatch Pallas kernel (TPU path) vs jnp ref."""
from functools import partial

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_chunked_ref


@partial(jax.jit, static_argnames=("chunk", "use_pallas", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk=128, use_pallas=False,
             interpret=True):
    if use_pallas:
        return ssd_scan_pallas(x, dt, A, Bm, Cm, D, chunk=chunk,
                               interpret=interpret)
    return ssd_chunked_ref(x, dt, A, Bm, Cm, D, chunk=chunk)
