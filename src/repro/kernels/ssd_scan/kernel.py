"""Pallas TPU kernel: Mamba-2 SSD intra-chunk compute.

Grid: (batch, heads, chunks), sequential over chunks: the inter-chunk state
recurrence is carried in VMEM scratch (h: (P, N)), so one kernel launch
covers the whole sequence — intra-chunk work is dense MXU matmuls
(Q x Q decay-masked scores, Q x N state outer products), the recurrence is a
cheap elementwise update once per chunk.

This is the TPU adaptation of the SSD algorithm: the GPU version leans on
warp-level scans; on TPU the chunk-quadratic form feeds the MXU and the
cross-chunk dependency becomes a scalar-decay multiply in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,
                y_ref, hout_ref, h_scr, *, Q: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Q,)
    A = a_ref[0]                                 # scalar (negative)
    Bm = b_ref[0, 0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)         # (Q, N)
    D = d_ref[0]

    da = dt * A                                  # (Q,) log-decay per step
    cs = jnp.cumsum(da)                          # inclusive
    # intra-chunk decay matrix L[i, j] = exp(cs_i - cs_j) for i >= j
    diff = cs[:, None] - cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    Lmat = jnp.where(ii >= jj, jnp.exp(diff), 0.0)

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q, Q)
    w = scores * Lmat * dt[None, :]
    y_diag = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q, P)

    # contribution of the carried state: y_off[i] = exp(cs_i) * C_i . h
    h = h_scr[...]                               # (P, N)
    ch = jax.lax.dot_general(Cm, h, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)      # (Q, P)
    y_off = jnp.exp(cs)[:, None] * ch

    y_ref[0, 0] = (y_diag + y_off + x * D).astype(y_ref.dtype)

    # chunk-end state: h' = exp(sum da) * h + sum_j exp(cs_Q - cs_j) dt_j x_j B_j
    total = cs[Q - 1]
    dec = jnp.exp(total - cs) * dt               # (Q,)
    S = jax.lax.dot_general(x * dec[:, None], Bm, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # (P, N)
    h_scr[...] = jnp.exp(total) * h + S

    @pl.when(ci == n_chunks - 1)
    def _emit():
        hout_ref[0, 0] = h_scr[...]


def ssd_scan_pallas(x, dt, A, Bm, Cm, D, *, chunk: int = 128,
                    interpret: bool = True):
    """x: (B, L, H, P); dt: (B, L, H); A, D: (H,); Bm, Cm: (B, L, G, N).

    Returns (y, hT) matching
    :func:`repro.kernels.ssd_scan.ref.ssd_chunked_ref` (G groups expanded in
    the index map, no materialized repeat).
    """
    B, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        def zf(t):
            return jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, dt, Bm, Cm = zf(x), zf(dt), zf(Bm), zf(Cm)
    Lp = x.shape[1]
    nc = Lp // Q
    hg = H // G

    # layout: head-major so per-(b,h) tiles are contiguous
    xt = x.transpose(0, 2, 1, 3)                  # (B, H, Lp, P)
    dtt = dt.transpose(0, 2, 1)                   # (B, H, Lp)
    bt = Bm.transpose(0, 2, 1, 3)                 # (B, G, Lp, N)
    ct = Cm.transpose(0, 2, 1, 3)

    kernel = functools.partial(_ssd_kernel, Q=Q, n_chunks=nc)
    y, hT = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, h // hg, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, h // hg, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Lp, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A.astype(jnp.float32), bt, ct, D.astype(jnp.float32))
    return y.transpose(0, 2, 1, 3)[:, :L], hT
