"""Pallas TPU kernel: split-KV flash-decode partials for one-token decode.

Grid: (batch, kv_splits). Each split attends the query (all heads at once —
the (H, D) tile is MXU-friendly for H >= 8) over its KV-cache slice and
emits partial (m, l, acc). The partials are P(max)/P(sum) values combined by
the SBP boxing (pmax/psum) across devices and by
:func:`repro.kernels.flash_decode.ref.combine_partials` across splits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, m_ref, l_ref, acc_ref, *,
                   block_k: int, seq_k: int, k_offset: int,
                   sliding_window: int, sm_scale: float, group: int):
    si = pl.program_id(1)

    q = q_ref[0].astype(jnp.float32)                  # (H, D)
    k = k_ref[0].astype(jnp.float32)                  # (block_k, KV, D)
    v = v_ref[0].astype(jnp.float32)                  # (block_k, KV, Dv)
    cur = pos_ref[0]

    H = q.shape[0]
    KV = k.shape[1]
    # scores per q head against its GQA kv head: (H, block_k)
    kh = k.transpose(1, 0, 2)                         # (KV, block_k, D)
    kh = jnp.repeat(kh, group, axis=0)                # (H, block_k, D)
    s = jax.lax.dot_general(
        q[:, None, :], kh, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)[:, 0, :] * sm_scale

    kpos = (k_offset + si * block_k
            + jax.lax.broadcasted_iota(jnp.int32, (H, block_k), 1))
    mask = (kpos < k_offset + seq_k) & (kpos <= cur)
    if sliding_window:
        mask &= kpos > cur - sliding_window
    s = jnp.where(mask, s, NEG_INF)

    m = s.max(axis=1)                                 # (H,)
    p = jnp.where(jnp.isfinite(m)[:, None], jnp.exp(s - m[:, None]), 0.0)
    l = p.sum(axis=1)
    vh = v.transpose(1, 0, 2)
    vh = jnp.repeat(vh, group, axis=0)                # (H, block_k, Dv)
    acc = jax.lax.dot_general(
        p[:, None, :], vh, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)[:, 0, :]

    m_ref[0, 0] = m
    l_ref[0, 0] = l
    acc_ref[0, 0] = acc


def flash_decode_pallas(q, k, v, *, cur_pos, k_offset: int = 0,
                        sliding_window: int = 0, block_k: int = 512,
                        sm_scale=None, interpret: bool = True):
    """q: (B, H, D); k, v: (B, L, KV, D/Dv); cur_pos: (B,).

    Returns per-split partials combined over splits: (m, l, acc) with shapes
    (B, H), (B, H), (B, H, Dv) — identical to
    :func:`repro.kernels.flash_decode.ref.flash_decode_partial_ref`.
    """
    B, H, D = q.shape
    _, L, KV, Dv = k.shape[0], k.shape[1], k.shape[2], v.shape[3]
    group = H // KV
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    block_k = min(block_k, max(8, L))
    pk = (-L) % block_k
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    ns = kp.shape[1] // block_k

    kernel = functools.partial(
        _decode_kernel, block_k=block_k, seq_k=L, k_offset=k_offset,
        sliding_window=sliding_window, sm_scale=sm_scale, group=group)

    m, l, acc = pl.pallas_call(
        kernel,
        grid=(B, ns),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, block_k, KV, D), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, block_k, KV, Dv), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1,), lambda b, s: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, H), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, 1, H), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, 1, H, Dv), lambda b, s: (b, s, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, ns, H), jnp.float32),
            jax.ShapeDtypeStruct((B, ns, H), jnp.float32),
            jax.ShapeDtypeStruct((B, ns, H, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, kp, vp, cur_pos.astype(jnp.int32))

    # combine the split partials (second-level P(max)/P(sum) reduction)
    m_g = m.max(axis=1)                                        # (B, H)
    scale = jnp.where(jnp.isfinite(m), jnp.exp(m - m_g[:, None]), 0.0)
    l_g = (l * scale).sum(axis=1)
    acc_g = (acc * scale[..., None]).sum(axis=1)
    return m_g, l_g, acc_g
