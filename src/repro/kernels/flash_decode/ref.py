"""Pure-jnp oracle for single-token flash-decode with partial-value output.

The distributed decode path shards the KV cache along the sequence axis over
the ``model`` mesh axis (SBP ``S(seq)``). Each shard produces *partial*
attention statistics — exactly the paper's partial-value signature, with a
non-sum reduction:

    m_shard   : P(max)   running max of scores
    acc_shard : P(sum)   exp-weighted value accumulation (after rescale)
    l_shard   : P(sum)   exp sum

:func:`flash_decode_partial_ref` computes one shard's contribution;
:func:`combine_partials` is the logical reduction (what the boxing op
``P -> B`` performs, here as pmax/psum pairs).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_decode_partial_ref(q, k, v, *, k_offset: int = 0,
                             cur_pos=None, sliding_window: int = 0,
                             k_positions=None,
                             sm_scale: Optional[float] = None):
    """Partial attention of a 1-token query over one KV-cache shard.

    q: (B, H, D); k, v: (B, L, KV, D) — this shard's cache slice;
    ``k_offset``: absolute position of k[0]; ``cur_pos``: (B,) current decode
    position (entries at or beyond it are masked: cache may be pre-allocated).
    ``k_positions``: (B, L) explicit absolute position per slot (ring-buffer
    sliding-window caches; -1 = empty slot), overrides ``k_offset``.
    Returns (m, l, acc): (B,H), (B,H), (B,H,D) partials.
    """
    B, H, D = q.shape
    _, L, KV, _ = k.shape
    G = H // KV
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    k = jnp.repeat(k, G, axis=2)
    v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q, k).astype(jnp.float32) * sm_scale
    if k_positions is not None:
        kpos = k_positions                                   # (B, L)
        mask = kpos >= 0
    else:
        kpos = jnp.broadcast_to(k_offset + jnp.arange(L), (B, L))
        mask = jnp.ones((B, L), bool)
    if cur_pos is not None:
        mask &= kpos <= cur_pos[:, None]
        if sliding_window:
            mask &= kpos > cur_pos[:, None] - sliding_window
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    m = s.max(axis=-1)                             # (B, H)  P(max)
    p = jnp.exp(s - m[..., None])
    # fully-masked shards: m = -inf -> p = exp(-inf - -inf); force 0
    p = jnp.where(jnp.isfinite(m)[..., None], p, 0.0)
    l = p.sum(axis=-1)                             # (B, H)  P(sum) after rescale
    acc = jnp.einsum("bhk,bkhd->bhd", p, v.astype(jnp.float32))
    return m, l, acc


def combine_partials(m, l, acc, axis_name: Optional[str] = None):
    """Reduce shard partials to the attention output.

    With ``axis_name``: the distributed combine (pmax + psum inside
    shard_map). Without: combines a stacked leading shard axis (oracle mode).
    """
    if axis_name is not None:
        m_g = jax.lax.pmax(m, axis_name)
        scale = jnp.where(jnp.isfinite(m), jnp.exp(m - m_g), 0.0)
        l_g = jax.lax.psum(l * scale, axis_name)
        acc_g = jax.lax.psum(acc * scale[..., None], axis_name)
    else:
        m_g = m.max(axis=0)
        scale = jnp.where(jnp.isfinite(m), jnp.exp(m - m_g[None]), 0.0)
        l_g = (l * scale).sum(axis=0)
        acc_g = (acc * scale[..., None]).sum(axis=0)
    return (acc_g / jnp.maximum(l_g, 1e-30)[..., None])


def decode_attention_ref(q, k, v, cur_pos, *, sliding_window: int = 0,
                         sm_scale=None):
    """Single-shard (logical) decode attention oracle."""
    m, l, acc = flash_decode_partial_ref(
        q, k, v, k_offset=0, cur_pos=cur_pos, sliding_window=sliding_window,
        sm_scale=sm_scale)
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
