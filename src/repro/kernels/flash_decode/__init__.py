from repro.kernels.flash_decode.ops import flash_decode_partial
from repro.kernels.flash_decode.ref import (combine_partials,
                                            decode_attention_ref,
                                            flash_decode_partial_ref)
