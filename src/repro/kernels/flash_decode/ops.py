"""jit'd public wrapper: dispatch Pallas kernel (TPU path) vs jnp ref."""
from functools import partial

import jax

from repro.kernels.flash_decode.kernel import flash_decode_pallas
from repro.kernels.flash_decode.ref import flash_decode_partial_ref


@partial(jax.jit, static_argnames=("k_offset", "sliding_window",
                                   "use_pallas", "interpret"))
def flash_decode_partial(q, k, v, *, cur_pos, k_offset=0, sliding_window=0,
                         use_pallas=False, interpret=True):
    if use_pallas:
        return flash_decode_pallas(q, k, v, cur_pos=cur_pos,
                                   k_offset=k_offset,
                                   sliding_window=sliding_window,
                                   interpret=interpret)
    return flash_decode_partial_ref(q, k, v, cur_pos=cur_pos,
                                    k_offset=k_offset,
                                    sliding_window=sliding_window)
