"""Model assembly: embeddings, block stacks (scan-over-periods), loss, decode.

Layout rules:

* layers are grouped into a *prologue* (unrolled; e.g. DeepSeek's leading
  dense layers) and a *body* scanned over repeating periods
  (period = lcm(attn_every, moe_every); 1 for uniform stacks, 8 for Jamba);
* every block's params for slot j are stacked over periods (leading dim
  n_periods) so the whole body is one ``lax.scan`` — keeps the HLO small for
  the 61-layer/671B dry-runs;
* activations are SBP ``(S(0) batch over data axes, B over model)``;
  attention/MLP partial outputs are P(sum) over model; the residual add
  happens after ONE psum per branch pair when both branches are partial
  (deferred reduction, paper §3.3).

Vocab-parallel embedding + the hierarchical (local-reduce) softmax
cross-entropy are the paper's Fig 11b pattern.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.softmax_xent.ref import combine_stats, local_stats_ref
from repro.models.attention import (
    gqa_decode, gqa_forward, gqa_specs, init_gqa, init_mla,
    kv_to_seq_sharded, mla_decode, mla_forward, mla_specs, q_heads_local)
from repro.models.common import (MeshPlan, certified_pmean, dense_init,
                                 force_vary, rms_norm, split_keys)
from repro.models.mamba import (
    init_mamba, mamba_decode, mamba_forward, mamba_specs)
from repro.models.mlp import (dense_mlp_forward, dense_mlp_specs, init_dense_mlp,
                              init_moe, moe_forward, moe_specs)


# ---------------------------------------------------------------------------
# layer grouping
# ---------------------------------------------------------------------------

def _period(cfg: ModelConfig) -> int:
    p = 1
    if cfg.attn_every:
        p = cfg.attn_every
    if cfg.num_experts and cfg.moe_every > 1:
        p = math.lcm(p, cfg.moe_every)
    return p


@dataclasses.dataclass(frozen=True)
class StackLayout:
    prologue: Tuple[Tuple[str, str], ...]       # (kind, mlp_kind) per layer
    period_slots: Tuple[Tuple[str, str], ...]
    n_periods: int


def stack_layout(cfg: ModelConfig) -> StackLayout:
    kinds, mlps = cfg.layer_kinds(), cfg.mlp_kinds()
    n_pro = cfg.first_dense_layers
    P = _period(cfg)
    body = cfg.num_layers - n_pro
    assert body % P == 0, (cfg.name, body, P)
    slots = tuple((kinds[n_pro + j], mlps[n_pro + j]) for j in range(P))
    # periodicity sanity: every period must repeat the slot structure
    for i in range(body // P):
        for j in range(P):
            li = n_pro + i * P + j
            assert (kinds[li], mlps[li]) == slots[j], (cfg.name, li)
    return StackLayout(tuple((kinds[i], mlps[i]) for i in range(n_pro)),
                       slots, body // P)


# ---------------------------------------------------------------------------
# one block
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, plan: MeshPlan, kind: str, mlp_kind: str,
               cross: bool = False) -> Dict:
    d = cfg.d_model
    ks = split_keys(key, 4)
    p: Dict[str, Any] = {"ln1": jnp.ones((d,), jnp.float32)}
    if kind == "attn":
        p["attn"] = (init_mla(ks[0], cfg, plan) if cfg.use_mla
                     else init_gqa(ks[0], cfg, plan))
    else:
        p["ssm"] = init_mamba(ks[0], cfg, plan)
    if cross:
        p["ln_x"] = jnp.ones((d,), jnp.float32)
        p["xattn"] = init_gqa(ks[2], cfg, plan, cross=True)
    if mlp_kind == "dense":
        p["ln2"] = jnp.ones((d,), jnp.float32)
        p["mlp"] = init_dense_mlp(ks[1], d, cfg.d_ff)
    elif mlp_kind == "moe":
        p["ln2"] = jnp.ones((d,), jnp.float32)
        p["moe"] = init_moe(ks[1], cfg)
    return p


def block_specs(cfg: ModelConfig, plan: MeshPlan, kind: str, mlp_kind: str,
                cross: bool = False) -> Dict:
    from jax.sharding import PartitionSpec as P

    p: Dict[str, Any] = {"ln1": P()}
    if kind == "attn":
        p["attn"] = mla_specs(cfg, plan) if cfg.use_mla else gqa_specs(cfg, plan)
    else:
        p["ssm"] = mamba_specs(cfg, plan)
    if cross:
        p["ln_x"] = P()
        p["xattn"] = gqa_specs(cfg, plan, cross=True)
    if mlp_kind in ("dense", "moe"):
        p["ln2"] = P()
        p["mlp" if mlp_kind == "dense" else "moe"] = (
            dense_mlp_specs(plan) if mlp_kind == "dense"
            else moe_specs(cfg, plan))
    return p


def apply_block(p, x, cfg: ModelConfig, plan: MeshPlan, kind: str,
                mlp_kind: str, positions, causal: bool = True,
                sliding_window: int = 0, enc: Optional[jnp.ndarray] = None,
                want_cache: bool = False, cache_len: int = 0):
    """Returns (x, aux_loss, cache_or_None). x replicated over model axis.

    Branch psum outputs are tagged with ``checkpoint_name('boxed')`` so the
    remat policy can SAVE them: replaying a branch's compute in the backward
    pass is cheap, replaying its all-reduce is not (§Perf hillclimb #3)."""
    from jax.ad_checkpoint import checkpoint_name

    if plan.tp > 1:
        def psum(v):
            return checkpoint_name(jax.lax.psum(v, plan.model_axis), "boxed")
    else:
        def psum(v):
            return v
    aux = jnp.zeros((), jnp.float32)
    cache = None
    h = rms_norm(x, p["ln1"].astype(x.dtype), cfg.norm_eps)
    if kind == "attn":
        if cfg.use_mla:
            a, (c, kpe) = mla_forward(p["attn"], h, cfg, plan, positions,
                                      sliding_window)
            if want_cache:
                pad = cache_len - c.shape[1]
                cache = {"c": jnp.pad(c, ((0, 0), (0, pad), (0, 0))).astype(jnp.bfloat16),
                         "kpe": jnp.pad(kpe, ((0, 0), (0, pad), (0, 0))).astype(jnp.bfloat16)}
        else:
            a, (k, v) = gqa_forward(p["attn"], h, cfg, plan, positions,
                                    causal=causal,
                                    sliding_window=sliding_window)
            if want_cache:
                ck, cv = kv_to_seq_sharded(k.astype(jnp.bfloat16),
                                           v.astype(jnp.bfloat16), cfg, plan,
                                           cache_len)
                cache = {"k": ck, "v": cv}
        x = x + psum(a)
    else:
        if want_cache:
            a, (hstate, (tx, tbc)) = mamba_forward(p["ssm"], h, cfg, plan,
                                                   return_state=True)
            cache = {"h": hstate, "tail_x": tx, "tail_bc": tbc}
        else:
            a = mamba_forward(p["ssm"], h, cfg, plan)
        x = x + psum(a)
    if enc is not None and "xattn" in p:
        hx = rms_norm(x, p["ln_x"].astype(x.dtype), cfg.norm_eps)
        ax, (xk, xv) = gqa_forward(p["xattn"], hx, cfg, plan, positions,
                                   causal=False, kv_src=enc,
                                   kv_positions=jnp.arange(enc.shape[1]))
        if want_cache:
            cache = dict(cache or {})
            cache["xk"] = xk.astype(jnp.bfloat16)
            cache["xv"] = xv.astype(jnp.bfloat16)
        x = x + psum(ax)
    if mlp_kind == "dense":
        h2 = rms_norm(x, p["ln2"].astype(x.dtype), cfg.norm_eps)
        x = x + psum(dense_mlp_forward(p["mlp"], h2))
    elif mlp_kind == "moe":
        h2 = rms_norm(x, p["ln2"].astype(x.dtype), cfg.norm_eps)
        mo, a_aux = moe_forward(p["moe"], h2, cfg, plan)
        x = x + psum(mo)
        aux = aux + a_aux
    return x, aux, cache


def decode_block(p, x, cache, pos, cfg: ModelConfig, plan: MeshPlan,
                 kind: str, mlp_kind: str, sliding_window: int = 0):
    """Single-token step. Returns (x, new_cache)."""
    psum = (lambda v: jax.lax.psum(v, plan.model_axis)) if plan.tp > 1 \
        else (lambda v: v)
    h = rms_norm(x, p["ln1"].astype(x.dtype), cfg.norm_eps)
    new_cache = dict(cache)
    if kind == "attn":
        if cfg.use_mla:
            a, c, kpe = mla_decode(p["attn"], h, cache["c"], cache["kpe"],
                                   pos, cfg, plan, sliding_window)
            new_cache["c"], new_cache["kpe"] = c, kpe
        else:
            a, ck, cv, cp = gqa_decode(p["attn"], h, cache["k"], cache["v"],
                                       pos, cfg, plan, sliding_window,
                                       cache_pos=cache.get("pos"))
            new_cache["k"], new_cache["v"] = ck, cv
            if cp is not None:
                new_cache["pos"] = cp
        x = x + psum(a)
    else:
        a, (hs, tx, tbc) = mamba_decode(
            p["ssm"], h, (cache["h"], cache["tail_x"], cache["tail_bc"]),
            cfg, plan)
        new_cache["h"], new_cache["tail_x"], new_cache["tail_bc"] = hs, tx, tbc
        x = x + psum(a)
    if "xk" in cache:  # whisper cross-attention (static encoder cache)
        hx = rms_norm(x, p["ln_x"].astype(x.dtype), cfg.norm_eps)
        ax = _cross_attn_decode(p["xattn"], hx, cache["xk"], cache["xv"],
                                cfg, plan)
        x = x + psum(ax)
    if mlp_kind == "dense":
        h2 = rms_norm(x, p["ln2"].astype(x.dtype), cfg.norm_eps)
        x = x + psum(dense_mlp_forward(p["mlp"], h2))
    elif mlp_kind == "moe":
        h2 = rms_norm(x, p["ln2"].astype(x.dtype), cfg.norm_eps)
        mo, _ = moe_forward(p["moe"], h2, cfg, plan)
        x = x + psum(mo)
    return x, new_cache


def _cross_attn_decode(p, x, xk, xv, cfg, plan):
    """Decode-time cross attention: local q heads over the full (small)
    encoder sequence — no cache update, no seq shard."""
    from repro.kernels.flash_attention.ref import attention_dense_ref

    B = x.shape[0]
    hd = cfg.head_dim
    qh = q_heads_local(cfg, plan)
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, 1, qh, hd)
    out = attention_dense_ref(q, xk.astype(x.dtype), xv.astype(x.dtype),
                              causal=False)
    return out.reshape(B, 1, qh * hd) @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# whole model
# ---------------------------------------------------------------------------

def init_model(key, cfg: ModelConfig, plan: MeshPlan) -> Dict:
    d, Vp = cfg.d_model, cfg.padded_vocab()
    lay = stack_layout(cfg)
    ks = split_keys(key, 8 + len(lay.prologue))
    p: Dict[str, Any] = {
        "embed": dense_init(ks[0], (Vp, d), in_axis=1),
        "unembed": dense_init(ks[1], (d, Vp)),
        "final_norm": jnp.ones((d,), jnp.float32),
    }
    p["prologue"] = [
        init_block(ks[8 + i], cfg, plan, k, m)
        for i, (k, m) in enumerate(lay.prologue)]
    # body: stack per slot over periods
    body = []
    kb = split_keys(ks[2], max(1, lay.n_periods))
    for j, (kind, mlp_kind) in enumerate(lay.period_slots):
        per = [init_block(jax.random.fold_in(kb[i], j), cfg, plan, kind,
                          mlp_kind, cross=cfg.encoder_decoder)
               for i in range(lay.n_periods)]
        body.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
    p["body"] = body
    if cfg.encoder_decoder:
        enc = [init_block(jax.random.fold_in(ks[3], i), cfg, plan,
                          "attn", "dense")
               for i in range(cfg.num_encoder_layers)]
        p["enc_body"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc)
        p["enc_norm"] = jnp.ones((d,), jnp.float32)
    if cfg.mtp:
        p["mtp_norm_h"] = jnp.ones((d,), jnp.float32)
        p["mtp_norm_e"] = jnp.ones((d,), jnp.float32)
        p["mtp_proj"] = dense_init(ks[4], (2 * d, d))
        p["mtp_block"] = init_block(ks[5], cfg, plan, "attn", "dense")
    return p


def model_specs(cfg: ModelConfig, plan: MeshPlan) -> Dict:
    from jax.sharding import PartitionSpec as P

    lay = stack_layout(cfg)
    mx = plan.spec_model_axis
    p: Dict[str, Any] = {
        "embed": P(mx, None),        # vocab-parallel
        "unembed": P(None, mx),      # column-parallel logits
        "final_norm": P(),
    }
    p["prologue"] = [block_specs(cfg, plan, k, m) for (k, m) in lay.prologue]
    p["body"] = [
        jax.tree.map(lambda s: P(None, *s),   # leading period dim unsharded
                     block_specs(cfg, plan, kind, mlp_kind,
                                 cross=cfg.encoder_decoder),
                     is_leaf=lambda s: isinstance(s, P))
        for (kind, mlp_kind) in lay.period_slots]
    if cfg.encoder_decoder:
        p["enc_body"] = jax.tree.map(
            lambda s: P(None, *s), block_specs(cfg, plan, "attn", "dense"),
            is_leaf=lambda s: isinstance(s, P))
        p["enc_norm"] = P()
    if cfg.mtp:
        p.update({"mtp_norm_h": P(), "mtp_norm_e": P(),
                  "mtp_proj": P(mx, None),   # row-parallel (P(sum) output)
                  "mtp_block": block_specs(cfg, plan, "attn", "dense")})
    return p


def embed_tokens(p_embed, ids, plan: MeshPlan):
    """Vocab-parallel embedding: masked local gather -> P(sum) -> psum."""
    V_loc = p_embed.shape[0]
    if plan.tp > 1:
        m = jax.lax.axis_index(plan.model_axis)
        local = ids - m * V_loc
        ok = (local >= 0) & (local < V_loc)
        e = p_embed[jnp.clip(local, 0, V_loc - 1)]
        e = jnp.where(ok[..., None], e, 0.0)
        return jax.lax.psum(e, plan.model_axis)
    return p_embed[ids]


def lm_loss(p_unembed, h, labels, weights, plan: MeshPlan,
            cfg: ModelConfig):
    """Hierarchical sharded-vocab cross-entropy (paper Fig 11b).

    h: (B, S, d) replicated over model; labels/weights: (B, S).
    Returns mean loss over weighted tokens (still to be pmean'd over data).
    """
    B, S, d = h.shape
    logits = (h.reshape(B * S, d) @ p_unembed.astype(h.dtype))
    if plan.tp > 1:
        V_loc = p_unembed.shape[1]
        off = jax.lax.axis_index(plan.model_axis) * V_loc
        m_, s_, z_ = local_stats_ref(logits, labels.reshape(-1), off)
        tok = combine_stats(m_, s_, z_, axis_name=plan.model_axis)
    else:
        m_, s_, z_ = local_stats_ref(logits, labels.reshape(-1), 0)
        tok = combine_stats(m_[None], s_[None], z_[None])
    w = weights.reshape(-1).astype(jnp.float32)
    return jnp.sum(tok * w) / jnp.maximum(w.sum(), 1.0)


def _run_body(params, x, cfg, plan, positions, causal=True, sliding_window=0,
              enc=None, want_cache=False, cache_len=0, remat=True):
    lay = stack_layout(cfg)
    # scan carries must keep a consistent vma: force aux varying everywhere
    aux_total = force_vary((x[0, 0, 0] * 0).astype(jnp.float32),
                           plan.axis_names)
    pro_caches = []
    for p_blk, (kind, mlp_kind) in zip(params["prologue"], lay.prologue):
        x, aux, cache = apply_block(p_blk, x, cfg, plan, kind, mlp_kind,
                                    positions, causal, sliding_window, enc,
                                    want_cache, cache_len)
        aux_total += aux
        pro_caches.append(cache)

    def one_period(carry, stacked):
        x, aux = carry
        caches = []
        for j, (kind, mlp_kind) in enumerate(lay.period_slots):
            x, a, cache = apply_block(stacked[j], x, cfg, plan, kind,
                                      mlp_kind, positions, causal,
                                      sliding_window, enc, want_cache,
                                      cache_len)
            aux = aux + a
            caches.append(cache)
        return (force_vary(x, plan.axis_names),
                force_vary(aux, plan.axis_names)), caches

    if remat:
        # save the boxing-op (psum) outputs: backward recomputes the local
        # math but never re-runs the collectives
        policy = jax.checkpoint_policies.save_only_these_names("boxed")
        fn = jax.checkpoint(one_period, policy=policy)
    else:
        fn = one_period
    (x, aux_total), body_caches = jax.lax.scan(
        fn, (force_vary(x, plan.axis_names), aux_total),
        tuple(params["body"]))
    return x, aux_total, pro_caches, body_caches


def forward_loss(params, batch, cfg: ModelConfig, plan: MeshPlan,
                 remat: bool = True):
    """Training loss. batch: {"tokens": (B, S+1)} or for embed-frontend
    archs {"embeds": (B, S, d), "labels": (B, S+1...)} (+ "enc_embeds" for
    enc-dec). Returns (loss, metrics)."""
    if cfg.embed_frontend and not cfg.encoder_decoder:     # VLM
        x = batch["embeds"].astype(_adtype(cfg))
        labels = batch["labels"]
        positions = jnp.arange(x.shape[1])
        weights = jnp.ones_like(labels, jnp.float32)
    else:
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        positions = jnp.arange(inputs.shape[1])
        x = embed_tokens(params["embed"], inputs, plan).astype(_adtype(cfg))
        weights = jnp.ones_like(labels, jnp.float32)

    enc = None
    if cfg.encoder_decoder:
        enc = batch["enc_embeds"].astype(_adtype(cfg))
        enc_pos = jnp.arange(enc.shape[1])
        enc = enc + _sinusoid(enc.shape[1], cfg.d_model, enc.dtype)

        def enc_period(carry, p_blk):
            h, _ = carry
            h, _, _ = apply_block(p_blk, h, cfg, plan, "attn", "dense",
                                  enc_pos, causal=False)
            return (h, 0.0), None
        fn = jax.checkpoint(enc_period) if remat else enc_period
        (enc, _), _ = jax.lax.scan(fn, (enc, 0.0), params["enc_body"])
        enc = rms_norm(enc, params["enc_norm"].astype(enc.dtype), cfg.norm_eps)

    x, aux, _, _ = _run_body(params, x, cfg, plan, positions,
                             causal=True, enc=enc, remat=remat)
    # the router aux loss is computed redundantly on every model shard
    # WITHOUT a mediating psum; pmean keeps the value and makes the gradient
    # flow exactly once (cotangent 1/tp per shard, tp shards)
    aux = certified_pmean(aux, plan.model_axis)
    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    loss = lm_loss(params["unembed"], x, labels, weights, plan, cfg)
    metrics = {"lm_loss": loss, "aux_loss": aux}

    if cfg.mtp:
        # MTP (DeepSeek-V3): predict t+2 from [norm(h_t); norm(emb(t+1))]
        emb_next = embed_tokens(params["embed"], labels, plan).astype(x.dtype)
        hcat = jnp.concatenate(
            [rms_norm(x, params["mtp_norm_h"].astype(x.dtype), cfg.norm_eps),
             rms_norm(emb_next, params["mtp_norm_e"].astype(x.dtype),
                      cfg.norm_eps)], axis=-1)
        # row-parallel projection: slice the (replicated) input rows to match
        # the S(0)-sharded weight, local matmul -> P(sum) -> psum
        w_mtp = params["mtp_proj"].astype(x.dtype)
        if plan.tp > 1:
            rows = w_mtp.shape[0]
            start = jax.lax.axis_index(plan.model_axis) * rows
            hcat = jax.lax.dynamic_slice_in_dim(hcat, start, rows, axis=-1)
            hm = jax.lax.psum(hcat @ w_mtp, plan.model_axis)
        else:
            hm = hcat @ w_mtp
        hm, _, _ = apply_block(params["mtp_block"], hm, cfg, plan, "attn",
                               "dense", positions)
        mtp_labels = jnp.concatenate(
            [labels[:, 1:], labels[:, -1:]], axis=1)
        mtp_w = jnp.concatenate(
            [jnp.ones_like(labels[:, 1:], jnp.float32),
             jnp.zeros_like(labels[:, -1:], jnp.float32)], axis=1)
        mtp_loss = lm_loss(params["unembed"], hm, mtp_labels, mtp_w, plan, cfg)
        metrics["mtp_loss"] = mtp_loss
        loss = loss + cfg.mtp_weight * mtp_loss

    loss = loss + cfg.router_aux_weight * aux
    metrics["loss"] = loss
    return loss, metrics


def _adtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[cfg.dtype]


def _sinusoid(length: int, d: int, dtype):
    pos = jnp.arange(length)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[None].astype(dtype)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def prefill(params, batch, cfg: ModelConfig, plan: MeshPlan, cache_len: int,
            sliding_window: int = 0):
    """Run the prompt, return (last-position logits-equivalent hidden, caches,
    positions). caches are ready for decode at position = prompt_len."""
    if cfg.embed_frontend and not cfg.encoder_decoder:
        x = batch["embeds"].astype(_adtype(cfg))
        S = x.shape[1]
    elif cfg.encoder_decoder:
        x = embed_tokens(params["embed"], batch["tokens"], plan).astype(
            _adtype(cfg))
        x = x + _sinusoid(x.shape[1], cfg.d_model, x.dtype)
        S = x.shape[1]
    else:
        x = embed_tokens(params["embed"], batch["tokens"], plan).astype(
            _adtype(cfg))
        S = x.shape[1]
    positions = jnp.arange(S)

    enc = None
    if cfg.encoder_decoder:
        enc = batch["enc_embeds"].astype(_adtype(cfg))
        enc = enc + _sinusoid(enc.shape[1], cfg.d_model, enc.dtype)
        def enc_step(carry, p_blk):
            h = carry
            h, _, _ = apply_block(p_blk, h, cfg, plan, "attn", "dense",
                                  jnp.arange(enc.shape[1]), causal=False)
            return h, None
        enc, _ = jax.lax.scan(enc_step, enc, params["enc_body"])
        enc = rms_norm(enc, params["enc_norm"].astype(enc.dtype), cfg.norm_eps)

    x, _, pro_caches, body_caches = _run_body(
        params, x, cfg, plan, positions, causal=True,
        sliding_window=sliding_window, enc=enc, want_cache=True,
        cache_len=cache_len, remat=False)
    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    h_last = x[:, -1:]
    return h_last, {"prologue": pro_caches, "body": body_caches}


def decode_step(params, caches, tok, pos, cfg: ModelConfig, plan: MeshPlan,
                sliding_window: int = 0):
    """One decode step. tok: (B,) ids; pos: (B,) positions to write.
    Returns (logits_local (B, V_loc), new_caches)."""
    lay = stack_layout(cfg)
    x = embed_tokens(params["embed"], tok[:, None], plan).astype(_adtype(cfg))
    if cfg.encoder_decoder:
        # sinusoidal position for the current decode position
        d = cfg.d_model
        i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
        ang = pos[:, None].astype(jnp.float32) / jnp.power(10000.0, 2 * i / d)
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
        x = x + pe[:, None, :].astype(x.dtype)

    new_pro = []
    for p_blk, cache, (kind, mlp_kind) in zip(params["prologue"],
                                              caches["prologue"],
                                              lay.prologue):
        x, c = decode_block(p_blk, x, cache, pos, cfg, plan, kind, mlp_kind,
                            sliding_window)
        new_pro.append(c)

    def one_period(x, stacked):
        p_stk, c_stk = stacked
        new_caches = []
        for j, (kind, mlp_kind) in enumerate(lay.period_slots):
            x, c = decode_block(p_stk[j], x, c_stk[j], pos, cfg, plan,
                                kind, mlp_kind, sliding_window)
            new_caches.append(c)
        return x, new_caches

    x, new_body = jax.lax.scan(one_period, x,
                               (tuple(params["body"]), caches["body"]))
    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    logits_local = x[:, 0] @ params["unembed"].astype(x.dtype)
    return logits_local, {"prologue": new_pro, "body": new_body}


# ---------------------------------------------------------------------------
# stack slices — the building blocks of pipelined serving (one contiguous
# chunk of the layer stack per pipeline stage, caches stage-local)
# ---------------------------------------------------------------------------

def decode_stack_slice(params, caches, x, pos, cfg: ModelConfig,
                       plan: MeshPlan, pro_kinds, sliding_window: int = 0):
    """One decode step over a slice of the stack.

    ``params``/``caches`` hold ``"prologue"`` (a list of this slice's
    unrolled blocks, kinds given by ``pro_kinds``) and ``"body"`` (per-slot
    trees stacked over this slice's periods — possibly empty). x: (B, 1, d)
    hidden entering the slice. Returns (x, new_caches); composing the slices
    in order reproduces :func:`decode_step`'s layer loop exactly.
    """
    lay = stack_layout(cfg)
    new_pro = []
    for p_blk, cache, (kind, mlp_kind) in zip(params["prologue"],
                                              caches["prologue"], pro_kinds):
        x, c = decode_block(p_blk, x, cache, pos, cfg, plan, kind, mlp_kind,
                            sliding_window)
        new_pro.append(c)
    new_body = caches["body"]
    if params["body"]:
        def one_period(x, stacked):
            p_stk, c_stk = stacked
            new_caches = []
            for j, (kind, mlp_kind) in enumerate(lay.period_slots):
                x, c = decode_block(p_stk[j], x, c_stk[j], pos, cfg, plan,
                                    kind, mlp_kind, sliding_window)
                new_caches.append(c)
            return x, new_caches
        x, new_body = jax.lax.scan(one_period, x,
                                   (tuple(params["body"]), caches["body"]))
    return x, {"prologue": new_pro, "body": new_body}


def prefill_stack_slice(params, x, positions, cfg: ModelConfig,
                        plan: MeshPlan, pro_kinds, cache_len: int,
                        sliding_window: int = 0):
    """Prefill over a slice of the stack (same structure as
    :func:`decode_stack_slice`). x: (B, S, d) hidden entering the slice.
    Returns (x, caches) with the slice's decode caches ready at position S.
    """
    lay = stack_layout(cfg)
    pro_caches = []
    for p_blk, (kind, mlp_kind) in zip(params["prologue"], pro_kinds):
        x, _, cache = apply_block(p_blk, x, cfg, plan, kind, mlp_kind,
                                  positions, True, sliding_window, None,
                                  True, cache_len)
        pro_caches.append(cache)
    body_caches = []
    if params["body"]:
        def one_period(x, stacked):
            caches = []
            for j, (kind, mlp_kind) in enumerate(lay.period_slots):
                x, _, cache = apply_block(stacked[j], x, cfg, plan, kind,
                                          mlp_kind, positions, True,
                                          sliding_window, None, True,
                                          cache_len)
                caches.append(cache)
            return force_vary(x, plan.axis_names), caches
        x, body_caches = jax.lax.scan(one_period,
                                      force_vary(x, plan.axis_names),
                                      tuple(params["body"]))
    return x, {"prologue": pro_caches, "body": body_caches}
