"""MLP layers: dense SwiGLU (tensor-parallel) and MoE (expert-parallel).

SBP view (model axis):
  dense:  w_gate/w_up S(1) column-parallel, w_down S(0) row-parallel ->
          output P(sum), reduced by the caller.
  moe:    experts S(0) on the *expert* dimension (expert parallelism);
          each device routes the (replicated) token set to its local experts,
          processes up to ``capacity`` tokens per expert, scatter-adds back —
          the combine is P(sum) over the model axis. Shared experts are a
          dense row-parallel MLP whose partial output is summed into the same
          P(sum) before a single psum (deferred reduction, paper §3.3).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import MeshPlan, dense_init, split_keys, swiglu


# ---------------------------------------------------------------------------
# dense SwiGLU
# ---------------------------------------------------------------------------

def init_dense_mlp(key, d_model: int, d_ff: int) -> Dict:
    ks = split_keys(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff)),
        "w_up": dense_init(ks[1], (d_model, d_ff)),
        "w_down": dense_init(ks[2], (d_ff, d_model)),
    }


def dense_mlp_specs(plan: MeshPlan) -> Dict:
    from jax.sharding import PartitionSpec as P

    mx = plan.spec_model_axis
    return {"w_gate": P(None, mx), "w_up": P(None, mx), "w_down": P(mx, None)}


def dense_mlp_forward(p, x):
    """x: (..., d) replicated over model -> P(sum) partial output."""
    g = x @ p["w_gate"].astype(x.dtype)
    u = x @ p["w_up"].astype(x.dtype)
    return swiglu(g, u) @ p["w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig) -> Dict:
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), scale=0.1),
        "w_gate": dense_init(ks[1], (E, d, ff)),
        "w_up": dense_init(ks[2], (E, d, ff)),
        "w_down": dense_init(ks[3], (E, ff, d)),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_dense_mlp(ks[4], d,
                                     cfg.moe_d_ff * cfg.num_shared_experts)
    return p


def moe_specs(cfg: ModelConfig, plan: MeshPlan) -> Dict:
    from jax.sharding import PartitionSpec as P

    mx = plan.spec_model_axis
    p = {"router": P(),
         "w_gate": P(mx), "w_up": P(mx), "w_down": P(mx)}
    if cfg.num_shared_experts:
        p["shared"] = dense_mlp_specs(plan)
    return p


def moe_forward(p, x, cfg: ModelConfig, plan: MeshPlan
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) replicated over model axis.

    Returns (partial_out P(sum) over model, aux_load_balance_loss scalar).
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    tp = plan.tp
    assert E % tp == 0, (E, tp)
    E_loc = E // tp
    T = B * S
    t = x.reshape(T, d)

    logits = (t @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, K)                            # (T, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)              # (T, K, E)
    f = onehot.sum(axis=(0, 1)) / (T * K)        # fraction routed per expert
    pbar = probs.mean(axis=0)
    aux = E * jnp.sum(f * pbar)

    # local expert affinity matrix (T, E_loc)
    m_idx = jax.lax.axis_index(plan.model_axis) if tp > 1 else 0
    lo = m_idx * E_loc
    local = (idx >= lo) & (idx < lo + E_loc)
    col = jnp.where(local, idx - lo, 0)
    A = jnp.zeros((T, E_loc), jnp.float32)
    A = A.at[jnp.arange(T)[:, None], col].add(
        jnp.where(local, gates, 0.0).astype(jnp.float32))

    cap = max(1, int(math.ceil(T * K / E * cfg.capacity_factor)))
    cap = min(cap, T)
    vals, tok = jax.lax.top_k(A.T, cap)          # (E_loc, cap)

    xe = t[tok]                                  # (E_loc, cap, d)
    dt = x.dtype
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(dt))
    y = jnp.einsum("ecf,efd->ecd", swiglu(g, u), p["w_down"].astype(dt))
    y = y * vals[..., None].astype(dt)           # gate weight (0 => dropped)

    out = jnp.zeros((T, d), dt).at[tok.reshape(-1)].add(y.reshape(-1, d))

    if cfg.num_shared_experts:
        out = out + dense_mlp_forward(p["shared"], t)   # both P(sum): defer
    return out.reshape(B, S, d), aux
