"""Mamba-2 (SSD) block, tensor-parallel over SSM heads.

SBP view (model axis):
  w_x, w_z, w_dt     S(1)  column-parallel (head-structured dims)
  w_bc               B     replicated (G groups are shared by all heads)
  conv_x             S(0)  depthwise, channels follow the head split
  A_log, D, dt_bias  S(0)  per-head
  out_proj           S(0)  row-parallel -> P(sum), reduced by caller

The gated RMSNorm before out_proj normalizes over *local* channels — i.e.
GroupNorm with groups == tp (documented TPU adaptation; exact when tp == 1).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.ssd_scan.ref import ssd_chunked_ref, ssd_decode_step
from repro.models.common import MeshPlan, dense_init, rms_norm, split_keys


G_GROUPS = 1   # number of B/C groups (mamba2 default: 1)


def init_mamba(key, cfg: ModelConfig, plan: MeshPlan) -> Dict:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    N = cfg.ssm_d_state
    nh = cfg.ssm_heads
    dc = cfg.ssm_d_conv
    ks = split_keys(key, 8)
    return {
        "w_x": dense_init(ks[0], (d, di)),
        "w_z": dense_init(ks[1], (d, di)),
        "w_bc": dense_init(ks[2], (d, 2 * G_GROUPS * N)),
        "w_dt": dense_init(ks[3], (d, nh)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "conv_x": dense_init(ks[4], (di, dc), scale=1.0),
        "conv_bc": dense_init(ks[5], (2 * G_GROUPS * N, dc), scale=1.0),
        "norm_w": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[6], (di, d)),
    }


def mamba_specs(cfg: ModelConfig, plan: MeshPlan) -> Dict:
    from jax.sharding import PartitionSpec as P

    mx = plan.spec_model_axis
    return {
        "w_x": P(None, mx), "w_z": P(None, mx), "w_bc": P(),
        "w_dt": P(None, mx), "dt_bias": P(mx), "A_log": P(mx), "D": P(mx),
        "conv_x": P(mx, None), "conv_bc": P(), "norm_w": P(mx),
        "out_proj": P(mx, None),
    }


def _causal_conv(x, w, prepend=None):
    """Depthwise causal conv along seq. x: (B, S, C); w: (C, K)."""
    B, S, C = x.shape
    K = w.shape[1]
    if prepend is None:
        prepend = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([prepend, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(K):
        # xp[:, i : i+S] is x shifted so that tap i sees x[t - (K-1) + i]
        out = out + xp[:, i:i + S] * w[:, i][None, None, :]
    return out


def mamba_forward(p, x, cfg: ModelConfig, plan: MeshPlan,
                  return_state: bool = False):
    """x: (B, S, d) replicated over model -> P(sum) partial output.

    If ``return_state``: also returns (ssm_state, conv_tail) for decoding.
    """
    B, S, d = x.shape
    tp = plan.tp
    nh_l = cfg.ssm_heads // tp
    P_hd = cfg.ssm_head_dim
    N = cfg.ssm_d_state
    dt_ = x.dtype

    xs = x @ p["w_x"].astype(dt_)                  # (B, S, di_l)
    z = x @ p["w_z"].astype(dt_)
    bc = x @ p["w_bc"].astype(dt_)                 # (B, S, 2GN) replicated
    dt_raw = x @ p["w_dt"].astype(dt_)             # (B, S, nh_l)

    # conv tails kept separately: xs is head-sharded, bc replicated (their
    # global layouts differ, so one concatenated cache array cannot be SBP'd)
    conv_tail = (xs[:, -(cfg.ssm_d_conv - 1):], bc[:, -(cfg.ssm_d_conv - 1):])
    xs = jax.nn.silu(_causal_conv(xs, p["conv_x"].astype(dt_)))
    bc = jax.nn.silu(_causal_conv(bc, p["conv_bc"].astype(dt_)))

    Bm = bc[..., :G_GROUPS * N].reshape(B, S, G_GROUPS, N)
    Cm = bc[..., G_GROUPS * N:].reshape(B, S, G_GROUPS, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xh = xs.reshape(B, S, nh_l, P_hd)
    y, hT = ssd_chunked_ref(xh, dt, A, Bm, Cm, p["D"].astype(jnp.float32),
                            chunk=cfg.ssm_chunk)
    y = y.reshape(B, S, nh_l * P_hd)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"].astype(dt_), cfg.norm_eps)
    out = y @ p["out_proj"].astype(dt_)            # P(sum) over model
    if return_state:
        return out, (hT.astype(jnp.float32), conv_tail)
    return out


def mamba_decode(p, x, state, cfg: ModelConfig, plan: MeshPlan):
    """Single-token step. x: (B, 1, d); state: (ssm_state, tail_x, tail_bc)
    with ssm_state (B, nh_l, P, N), tail_x (B, d_conv-1, di_l),
    tail_bc (B, d_conv-1, 2GN). Returns (P(sum) partial (B,1,d), new_state)."""
    B = x.shape[0]
    tp = plan.tp
    nh_l = cfg.ssm_heads // tp
    P_hd = cfg.ssm_head_dim
    N = cfg.ssm_d_state
    dt_ = x.dtype
    h, tail_x, tail_bc = state
    di_l = nh_l * P_hd

    xs = (x @ p["w_x"].astype(dt_))[:, 0]          # (B, di_l)
    z = (x @ p["w_z"].astype(dt_))[:, 0]
    bc = (x @ p["w_bc"].astype(dt_))[:, 0]
    dt_raw = (x @ p["w_dt"].astype(dt_))[:, 0]

    win_x = jnp.concatenate([tail_x.astype(dt_), xs[:, None]], axis=1)
    win_bc = jnp.concatenate([tail_bc.astype(dt_), bc[:, None]], axis=1)
    xs_c = jax.nn.silu(jnp.einsum("bkc,ck->bc", win_x, p["conv_x"].astype(dt_)))
    bc_c = jax.nn.silu(jnp.einsum("bkc,ck->bc", win_bc,
                                  p["conv_bc"].astype(dt_)))
    new_tail_x, new_tail_bc = win_x[:, 1:], win_bc[:, 1:]

    Bm = bc_c[..., :G_GROUPS * N].reshape(B, G_GROUPS, N)
    Cm = bc_c[..., G_GROUPS * N:].reshape(B, G_GROUPS, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_new = ssd_decode_step(xs_c.reshape(B, nh_l, P_hd), dt, A, Bm, Cm,
                               p["D"].astype(jnp.float32), h)
    y = y.reshape(B, di_l)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"].astype(dt_), cfg.norm_eps)
    out = (y @ p["out_proj"].astype(dt_))[:, None]
    return out, (h_new, new_tail_x, new_tail_bc)


def init_mamba_state(cfg: ModelConfig, plan: MeshPlan, batch: int,
                     dtype=jnp.bfloat16):
    nh_l = cfg.ssm_heads // plan.tp
    di_l = nh_l * cfg.ssm_head_dim
    h = jnp.zeros((batch, nh_l, cfg.ssm_head_dim, cfg.ssm_d_state), jnp.float32)
    tail_x = jnp.zeros((batch, cfg.ssm_d_conv - 1, di_l), dtype)
    tail_bc = jnp.zeros((batch, cfg.ssm_d_conv - 1,
                         2 * G_GROUPS * cfg.ssm_d_state), dtype)
    return h, tail_x, tail_bc
