"""Public model API: build any assigned architecture from its config.

Everything here operates on *local* shards (the functions are called inside
``shard_map``); batch sizes are per-device. ``launch/`` and ``train/`` wrap
these in the actual SPMD programs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models.attention import kv_heads_local
from repro.models.common import MeshPlan
from repro.models.mamba import G_GROUPS


@dataclasses.dataclass
class ModelBundle:
    cfg: ModelConfig
    plan: MeshPlan
    init: Callable                      # (key) -> params (global shapes)
    specs: Callable                     # () -> PartitionSpec pytree
    loss_fn: Callable                   # (params, batch) -> (loss, metrics)
    prefill: Callable
    decode_step: Callable
    init_caches: Callable               # (local_batch, cache_len) -> caches


def build_model(cfg: ModelConfig, plan: MeshPlan,
                sliding_window: int = 0) -> ModelBundle:
    def init(key):
        return T.init_model(key, cfg, plan)

    def specs():
        return T.model_specs(cfg, plan)

    def loss_fn(params, batch):
        return T.forward_loss(params, batch, cfg, plan)

    def prefill_fn(params, batch, cache_len):
        return T.prefill(params, batch, cfg, plan, cache_len,
                         sliding_window=sliding_window)

    def decode_fn(params, caches, tok, pos):
        return T.decode_step(params, caches, tok, pos, cfg, plan,
                             sliding_window=sliding_window)

    def init_caches(local_batch, cache_len):
        return make_decode_caches(cfg, plan, local_batch, cache_len)

    return ModelBundle(cfg, plan, init, specs, loss_fn, prefill_fn,
                       decode_fn, init_caches)


# ---------------------------------------------------------------------------
# decode cache construction (for dry-running serve_step without a prefill)
# ---------------------------------------------------------------------------

def _block_cache(cfg: ModelConfig, plan: MeshPlan, kind: str,
                 local_batch: int, cache_len: int, ring: bool = False) -> Dict:
    B = local_batch
    adt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    c: Dict[str, Any] = {}
    if kind == "attn":
        if cfg.use_mla:
            c["c"] = jnp.zeros((B, cache_len, cfg.kv_lora_rank), adt)
            c["kpe"] = jnp.zeros((B, cache_len, cfg.qk_rope_head_dim), adt)
        else:
            L_loc = cache_len // plan.tp
            c["k"] = jnp.zeros((B, L_loc, cfg.num_kv_heads, cfg.head_dim), adt)
            c["v"] = jnp.zeros((B, L_loc, cfg.num_kv_heads, cfg.head_dim), adt)
            if ring:   # sliding-window ring buffer: per-slot position table
                c["pos"] = jnp.full((B, L_loc), -1, jnp.int32)
    else:
        nh_l = cfg.ssm_heads // plan.tp
        di_l = nh_l * cfg.ssm_head_dim
        c["h"] = jnp.zeros((B, nh_l, cfg.ssm_head_dim, cfg.ssm_d_state),
                           jnp.float32)
        c["tail_x"] = jnp.zeros((B, cfg.ssm_d_conv - 1, di_l), adt)
        c["tail_bc"] = jnp.zeros(
            (B, cfg.ssm_d_conv - 1, 2 * G_GROUPS * cfg.ssm_d_state), adt)
    if cfg.encoder_decoder:
        n_kv = kv_heads_local(cfg, plan)
        c["xk"] = jnp.zeros((B, cfg.encoder_seq, n_kv, cfg.head_dim), adt)
        c["xv"] = jnp.zeros((B, cfg.encoder_seq, n_kv, cfg.head_dim), adt)
    return c


def make_decode_caches(cfg: ModelConfig, plan: MeshPlan, local_batch: int,
                       cache_len: int, ring: bool = False) -> Dict:
    lay = T.stack_layout(cfg)
    pro = [_block_cache(cfg, plan, k, local_batch, cache_len, ring)
           for (k, _) in lay.prologue]
    body = []
    for (kind, _) in lay.period_slots:
        one = _block_cache(cfg, plan, kind, local_batch, cache_len, ring)
        body.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (lay.n_periods,) + x.shape),
            one))
    return {"prologue": pro, "body": body}


def cache_specs(cfg: ModelConfig, plan: MeshPlan, batch_axes: Tuple[str, ...],
                ring: bool = False):
    """PartitionSpecs for decode caches: batch over data axes; GQA k/v are
    ALSO sequence-sharded over the model axis (dim 1 locally = seq chunk)."""
    from jax.sharding import PartitionSpec as P

    lay = T.stack_layout(cfg)
    ba = tuple(batch_axes)
    mx = plan.spec_model_axis

    def blk(kind: str, stacked: bool):
        lead = (None,) if stacked else ()
        c = {}
        if kind == "attn":
            if cfg.use_mla:
                c["c"] = P(*lead, ba)          # latent replicated over model
                c["kpe"] = P(*lead, ba)
            else:
                c["k"] = P(*lead, ba, mx)      # seq-sharded cache
                c["v"] = P(*lead, ba, mx)
                if ring:
                    c["pos"] = P(*lead, ba, mx)
        else:
            c["h"] = P(*lead, ba, mx)          # heads sharded
            c["tail_x"] = P(*lead, ba, None, mx)
            c["tail_bc"] = P(*lead, ba)        # replicated bc channels
        if cfg.encoder_decoder:
            c["xk"] = P(*lead, ba, None, mx)   # cross kv: heads sharded
            c["xv"] = P(*lead, ba, None, mx)
        return c

    pro = [blk(k, False) for (k, _) in lay.prologue]
    body = [blk(k, True) for (k, _) in lay.period_slots]
    return {"prologue": pro, "body": body}
