"""Attention layers (GQA + MLA), tensor-parallel inside shard_map.

SBP view (model axis):
  wq            S(1)   column-parallel (heads)
  wk, wv        B      replicated; each device *slices* its kv group, so the
                       kv projection is computed once per group, not per chip
  wo            S(0)   row-parallel -> output is P(sum), reduced by the caller
                       (deferred reduction, paper §3.3: residual-add happens
                       after a single psum that also covers the MLP branch
                       when profitable)

Decode uses a sequence-sharded KV cache (SBP S(seq) on the model axis): each
shard emits P(max)/P(sum) flash-decode partials combined with pmax/psum — the
paper's partial-value signature with a non-sum reduction.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention.ref import (flash_attention_ref,
                                               flash_attention_triangular)
from repro.kernels.flash_decode.ref import (combine_partials,
                                            flash_decode_partial_ref)
from repro.models.common import (MeshPlan, apply_rope, dense_init, rms_norm,
                                 split_keys)


# ---------------------------------------------------------------------------
# shard arithmetic
# ---------------------------------------------------------------------------

def q_heads_local(cfg: ModelConfig, plan: MeshPlan) -> int:
    return cfg.padded_heads(plan.tp) // plan.tp


def kv_heads_local(cfg: ModelConfig, plan: MeshPlan) -> int:
    tp, kv = plan.tp, cfg.num_kv_heads
    if kv >= tp:
        assert kv % tp == 0, (kv, tp)
        return kv // tp
    assert tp % kv == 0, (kv, tp)
    return 1


def _kv_slice(p_w, cfg, plan, hd):
    """Slice this device's kv-head columns out of the replicated kv weight."""
    tp, kv = plan.tp, cfg.num_kv_heads
    n_kv = kv_heads_local(cfg, plan)
    if tp == 1:
        return p_w, 0
    m = jax.lax.axis_index(plan.model_axis)
    start = (m * kv) // tp          # group-aligned for kv < tp
    w = jax.lax.dynamic_slice_in_dim(p_w, start * hd, n_kv * hd, axis=-1)
    return w, start


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ModelConfig, plan: MeshPlan, cross: bool = False) -> Dict:
    d, hd = cfg.d_model, cfg.head_dim
    Hp = cfg.padded_heads(plan.tp)
    KV = cfg.num_kv_heads
    ks = split_keys(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, Hp * hd)),
        "wk": dense_init(ks[1], (d, KV * hd)),
        "wv": dense_init(ks[2], (d, KV * hd)),
        "wo": dense_init(ks[3], (Hp * hd, d)),
    }
    if Hp != cfg.num_heads:  # zero the padded q heads and their wo rows
        real = cfg.num_heads * hd
        p["wq"] = p["wq"].at[:, real:].set(0.0)
        p["wo"] = p["wo"].at[real:, :].set(0.0)
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((Hp * hd,), jnp.float32)
        p["bk"] = jnp.zeros((KV * hd,), jnp.float32)
        p["bv"] = jnp.zeros((KV * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def gqa_specs(cfg: ModelConfig, plan: MeshPlan, cross: bool = False) -> Dict:
    from jax.sharding import PartitionSpec as P

    mx = plan.spec_model_axis
    p = {"wq": P(None, mx), "wk": P(), "wv": P(), "wo": P(mx, None)}
    if cfg.qkv_bias and not cross:
        p.update({"bq": P(mx), "bk": P(), "bv": P()})
    if cfg.qk_norm:
        p.update({"q_norm": P(), "k_norm": P()})
    return p


def _project_qkv(p, x, kv_src, cfg, plan, positions, kv_positions,
                 rope: bool = True):
    """q from x; k,v from kv_src (cross-attention passes encoder states)."""
    hd = cfg.head_dim
    qh = q_heads_local(cfg, plan)
    n_kv = kv_heads_local(cfg, plan)
    B, S = x.shape[0], x.shape[1]
    Skv = kv_src.shape[1]

    q = x @ p["wq"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    wk, _ = _kv_slice(p["wk"], cfg, plan, hd)
    wv, kv_start = _kv_slice(p["wv"], cfg, plan, hd)
    k = kv_src @ wk.astype(x.dtype)
    v = kv_src @ wv.astype(x.dtype)
    if "bk" in p:
        bk, _ = _kv_slice(p["bk"][None], cfg, plan, hd)
        bv, _ = _kv_slice(p["bv"][None], cfg, plan, hd)
        k = k + bk[0].astype(x.dtype)
        v = v + bv[0].astype(x.dtype)
    q = q.reshape(B, S, qh, hd)
    k = k.reshape(B, Skv, n_kv, hd)
    v = v.reshape(B, Skv, n_kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"].astype(x.dtype), cfg.norm_eps)
        k = rms_norm(k, p["k_norm"].astype(x.dtype), cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_fraction, cfg.rope_theta)
    return q, k, v


def gqa_forward(p, x, cfg: ModelConfig, plan: MeshPlan, positions,
                causal: bool = True, kv_src=None, kv_positions=None,
                sliding_window: int = 0):
    """Training/prefill attention. Returns (out_partial, (k, v)).

    ``out_partial`` is P(sum) over the model axis (row-parallel wo); caller
    reduces. (k, v) are this device's kv-head slice over the full sequence.
    """
    self_attn = kv_src is None
    kv_src = x if kv_src is None else kv_src
    kv_positions = positions if kv_positions is None else kv_positions
    q, k, v = _project_qkv(p, x, kv_src, cfg, plan, positions, kv_positions,
                           rope=not cfg.use_mla)
    if causal and self_attn:
        # triangular block-skipping path: half the attention FLOPs (§Perf #2)
        out = flash_attention_triangular(q, k, v,
                                         sliding_window=sliding_window)
    else:
        out = flash_attention_ref(q, k, v, causal=causal,
                                  sliding_window=sliding_window)
    B, S = x.shape[0], x.shape[1]
    out = out.reshape(B, S, -1)
    y_partial = out @ p["wo"].astype(x.dtype)     # P(sum) over model axis
    return y_partial, (k, v)


def kv_to_seq_sharded(k, v, cfg: ModelConfig, plan: MeshPlan, cache_len: int):
    """Boxing for the decode cache: S(head) -> S(seq) on the model axis.

    For kv >= tp this is the Table-2 ``S(i)->S(j)`` all_to_all; for kv < tp
    the heads are group-replicated, so the transition is the free ``B->S``
    slice (Table 2, zero cost) after a small intra-group exchange.
    Returns (B, cache_len/tp, KV, hd) local cache slices, zero-padded to
    ``cache_len`` total.
    """
    tp, KV = plan.tp, cfg.num_kv_heads
    B, S, n_kv, hd = k.shape
    L_loc = cache_len // tp

    def pad_to_cache(t):
        if S < cache_len:
            t = jnp.pad(t, ((0, 0), (0, cache_len - S), (0, 0), (0, 0)))
        return t

    if tp == 1:
        return pad_to_cache(k), pad_to_cache(v)
    ax = plan.model_axis

    if KV >= tp:
        # all_to_all: release head split, impose seq split
        def a2a(t):
            t = pad_to_cache(t)
            return jax.lax.all_to_all(t, ax, split_axis=1, concat_axis=2,
                                      tiled=True)
        return a2a(k), a2a(v)

    # kv < tp: heads are replicated within groups of tp/KV devices; gather
    # the KV distinct heads across the axis, then slice our seq chunk.
    def gather_slice(t):
        t = pad_to_cache(t)
        full = jax.lax.all_gather(t, ax, axis=2, tiled=True)  # (B, L, tp, hd)
        # deduplicate: group g of size tp/KV all computed kv head g
        group = tp // KV
        full = full.reshape(B, cache_len, KV, group, hd)[:, :, :, 0]
        m = jax.lax.axis_index(ax)
        return jax.lax.dynamic_slice_in_dim(full, m * L_loc, L_loc, axis=1)
    return gather_slice(k), gather_slice(v)


def gqa_decode(p, x, cache_k, cache_v, pos, cfg: ModelConfig, plan: MeshPlan,
               sliding_window: int = 0, cross: bool = False, enc_len: int = 0,
               cache_pos=None):
    """One-token decode over a sequence-sharded KV cache.

    x: (B, 1, d) replicated over model; cache_k/v: (B, L_loc, KV, hd);
    pos: (B,) current absolute position. ``cache_pos``: (B, L_loc) slot
    position table — when given, the cache is a RING buffer of length
    ``sliding_window`` (long-context decode) and writes go to pos % window.
    Returns (out_partial P(sum), new_cache_k, new_cache_v, new_cache_pos).
    """
    B = x.shape[0]
    hd = cfg.head_dim
    tp, KV = plan.tp, cfg.num_kv_heads
    Hp = cfg.padded_heads(tp)
    ax = plan.model_axis
    L_loc = cache_k.shape[1]

    # q for ALL heads on every device: local q heads + all_gather (tiny)
    q, k_new, v_new = _project_qkv(
        p, x, x, cfg, plan, pos[:, None], pos[:, None], rope=not cross)
    if tp > 1:
        q = jax.lax.all_gather(q, ax, axis=2, tiled=True)   # S(head)->B
    q = q[:, 0]                                             # (B, Hp, hd)

    if not cross:
        # write the new token's kv into the owning shard's slice.
        # k_new: (B, 1, n_kv, hd) is this device's kv-head group; for the
        # cache we need all KV heads — gather heads (tiny: one token).
        if tp > 1:
            kh = jax.lax.all_gather(k_new, ax, axis=2, tiled=True)
            vh = jax.lax.all_gather(v_new, ax, axis=2, tiled=True)
            if KV < tp:
                group = tp // KV
                kh = kh.reshape(B, 1, KV, group, hd)[:, :, :, 0]
                vh = vh.reshape(B, 1, KV, group, hd)[:, :, :, 0]
            else:
                kh = kh[:, :, :KV]   # heads arrive in order; groups exact
                vh = vh[:, :, :KV]
        else:
            kh, vh = k_new, v_new
        m = jax.lax.axis_index(ax) if tp > 1 else 0
        write_pos = jnp.mod(pos, sliding_window) if cache_pos is not None \
            else pos                                         # ring slot
        local_idx = write_pos - m * L_loc                    # (B,)
        owns = (local_idx >= 0) & (local_idx < L_loc)
        safe = jnp.clip(local_idx, 0, L_loc - 1)

        def write(cache, val):
            upd = jax.vmap(
                lambda c, i, u, o: jax.lax.dynamic_update_slice_in_dim(
                    c, jnp.where(o, u, jax.lax.dynamic_slice_in_dim(
                        c, i, 1, axis=0)), i, axis=0)
            )(cache, safe, val, owns)
            return upd
        cache_k = write(cache_k, kh.astype(cache_k.dtype))
        cache_v = write(cache_v, vh.astype(cache_v.dtype))
        if cache_pos is not None:
            cache_pos = write(cache_pos[..., None],
                              pos[:, None, None])[..., 0]

    # partial flash-decode over the local seq chunk
    m_idx = jax.lax.axis_index(ax) if tp > 1 else 0
    k_off = m_idx * L_loc
    mm, ll, acc = flash_decode_partial_ref(
        q, cache_k.astype(q.dtype), cache_v.astype(q.dtype),
        k_offset=k_off, cur_pos=pos if not cross else None,
        sliding_window=sliding_window,
        k_positions=cache_pos if cache_pos is not None else None)
    if cross and enc_len and enc_len < L_loc * max(tp, 1):
        pass  # cross caches are exactly enc_len; no masking needed
    if tp > 1:
        out = combine_partials(mm, ll, acc, axis_name=ax)    # P -> B
    else:
        out = combine_partials(mm[None], ll[None], acc[None])
    out = out.astype(x.dtype)                                # (B, Hp, hd)

    # row-parallel output projection: slice local heads from the combined out
    qh = Hp // tp
    if tp > 1:
        start = jax.lax.axis_index(ax) * qh
        out_loc = jax.lax.dynamic_slice_in_dim(out, start, qh, axis=1)
    else:
        out_loc = out
    y_partial = out_loc.reshape(B, 1, qh * hd) @ p["wo"].astype(x.dtype)
    return y_partial, cache_k, cache_v, cache_pos


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, plan: MeshPlan) -> Dict:
    d = cfg.d_model
    H = cfg.num_heads
    r, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = split_keys(key, 7)
    p = {}
    if qr:
        p["wq_a"] = dense_init(ks[0], (d, qr))
        p["q_norm"] = jnp.ones((qr,), jnp.float32)
        p["wq_b"] = dense_init(ks[1], (qr, H * (nope + rope)))
    else:
        p["wq"] = dense_init(ks[0], (d, H * (nope + rope)))
    p["wkv_a"] = dense_init(ks[2], (d, r + rope))
    p["kv_norm"] = jnp.ones((r,), jnp.float32)
    p["w_uk"] = dense_init(ks[3], (r, H * nope))
    p["w_uv"] = dense_init(ks[4], (r, H * vd))
    p["wo"] = dense_init(ks[5], (H * vd, d))
    return p


def mla_specs(cfg: ModelConfig, plan: MeshPlan) -> Dict:
    from jax.sharding import PartitionSpec as P

    mx = plan.spec_model_axis
    p = {"wkv_a": P(), "kv_norm": P(),
         "w_uk": P(None, mx), "w_uv": P(None, mx), "wo": P(mx, None)}
    if cfg.q_lora_rank:
        p.update({"wq_a": P(), "q_norm": P(), "wq_b": P(None, mx)})
    else:
        p["wq"] = P(None, mx)
    return p


def _mla_q(p, x, cfg, plan, positions):
    B, S = x.shape[:2]
    H_l = cfg.num_heads // plan.tp
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        cq = rms_norm(x @ p["wq_a"].astype(x.dtype),
                      p["q_norm"].astype(x.dtype), cfg.norm_eps)
        q = cq @ p["wq_b"].astype(x.dtype)
    else:
        q = x @ p["wq"].astype(x.dtype)
    q = q.reshape(B, S, H_l, nope + rope_d)
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    q_pe = apply_rope(q_pe, positions, 1.0, cfg.rope_theta)
    return q_nope, q_pe


def _mla_latent(p, x, cfg, positions):
    ckv = x @ p["wkv_a"].astype(x.dtype)                 # (B, S, r + rope)
    c = rms_norm(ckv[..., :cfg.kv_lora_rank],
                 p["kv_norm"].astype(x.dtype), cfg.norm_eps)
    k_pe = apply_rope(ckv[..., None, cfg.kv_lora_rank:], positions,
                      1.0, cfg.rope_theta)[..., 0, :]    # (B, S, rope)
    return c, k_pe


def mla_forward(p, x, cfg: ModelConfig, plan: MeshPlan, positions,
                sliding_window: int = 0):
    """Training/prefill MLA: materialize per-head k,v from the latent.
    Returns (out_partial P(sum), (c, k_pe)) — latent cache for decode."""
    B, S = x.shape[:2]
    H_l = cfg.num_heads // plan.tp
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_pe = _mla_q(p, x, cfg, plan, positions)
    c, k_pe = _mla_latent(p, x, cfg, positions)
    k_nope = (c @ p["w_uk"].astype(x.dtype)).reshape(B, S, H_l, nope)
    v = (c @ p["w_uv"].astype(x.dtype)).reshape(B, S, H_l, vd)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None], (B, S, H_l, rope_d))],
        axis=-1)
    out = flash_attention_triangular(q, k, v, sliding_window=sliding_window)
    y_partial = out.reshape(B, S, H_l * vd) @ p["wo"].astype(x.dtype)
    return y_partial, (c, k_pe)


def mla_decode(p, x, cache_c, cache_kpe, pos, cfg: ModelConfig, plan: MeshPlan,
               sliding_window: int = 0):
    """Absorbed-MLA decode: the latent cache is replicated over the model
    axis (SBP B — optimal per Table 2 since the latent is tiny), heads are
    sharded; scores are computed in latent space (absorption trick).

    x: (B, 1, d); cache_c: (B, L, r); cache_kpe: (B, L, rope).
    """
    B = x.shape[0]
    L = cache_c.shape[1]
    H_l = cfg.num_heads // plan.tp
    r = cfg.kv_lora_rank
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    q_nope, q_pe = _mla_q(p, x, cfg, plan, pos[:, None])
    c_new, kpe_new = _mla_latent(p, x, cfg, pos[:, None])
    # replicated cache write (every device writes the same values)
    upd = jax.vmap(lambda cc, i, u: jax.lax.dynamic_update_slice_in_dim(
        cc, u, i, axis=0))
    cache_c = upd(cache_c, pos, c_new.astype(cache_c.dtype))
    cache_kpe = upd(cache_kpe, pos, kpe_new.astype(cache_kpe.dtype))

    # absorbed scores: q' = q_nope @ W_uk  (per local head)
    w_uk = p["w_uk"].astype(x.dtype).reshape(r, H_l, nope)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], w_uk)   # (B, H_l, r)
    s_lat = jnp.einsum("bhr,blr->bhl", q_lat, cache_c.astype(x.dtype))
    s_pe = jnp.einsum("bhe,ble->bhl", q_pe[:, 0], cache_kpe.astype(x.dtype))
    scale = 1.0 / ((nope + rope_d) ** 0.5)
    s = (s_lat + s_pe).astype(jnp.float32) * scale
    kpos = jnp.arange(L)
    mask = kpos[None, :] <= pos[:, None]
    if sliding_window:
        mask &= kpos[None, :] > (pos[:, None] - sliding_window)
    s = jnp.where(mask[:, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out_lat = jnp.einsum("bhl,blr->bhr", pr, cache_c.astype(x.dtype))
    w_uv = p["w_uv"].astype(x.dtype).reshape(r, H_l, vd)
    out = jnp.einsum("bhr,rhv->bhv", out_lat, w_uv)          # (B, H_l, vd)
    y_partial = out.reshape(B, 1, H_l * vd) @ p["wo"].astype(x.dtype)
    return y_partial, cache_c, cache_kpe
