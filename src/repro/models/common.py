"""Shared model-building blocks + the SBP-annotated collective helper.

All model code runs *inside* ``shard_map`` over the production mesh; every
collective is written as an explicit SBP transition via :class:`Boxer`, so the
model source reads as OneFlow-style SBP annotations (the compiler-inserted
boxing ops of paper §3.2 appear literally in the code).
"""
from __future__ import annotations

import dataclasses
import math
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.compat import pvary
from repro.core.boxing import boxing_fn
from repro.core.sbp import Split, ndsbp


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """How the mesh axes are used by the model code."""

    axis_names: Tuple[str, ...]          # e.g. ("pod", "data", "model")
    axis_sizes: Tuple[int, ...]
    model_axis: str = "model"

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return tuple(n for n in self.axis_names if n != self.model_axis)

    @property
    def tp(self) -> int:
        if self.model_axis not in self.axis_names:
            return 1          # FSDP plan: every mesh axis is a data axis
        return self.axis_sizes[self.axis_names.index(self.model_axis)]

    @property
    def dp(self) -> int:
        return math.prod(s for n, s in zip(self.axis_names, self.axis_sizes)
                         if n != self.model_axis)

    def axis_size(self, name: str) -> int:
        return self.axis_sizes[self.axis_names.index(name)]

    @property
    def spec_model_axis(self):
        """model axis name for PartitionSpecs; None under the FSDP plan."""
        return self.model_axis if self.model_axis in self.axis_names else None

    @staticmethod
    def single_device() -> "MeshPlan":
        return MeshPlan(("data", "model"), (1, 1))


class Boxer:
    """SBP-transition helper bound to a mesh plan, usable inside shard_map.

    ``bx(x, "S(0),B,P", "S(0),B,B")`` emits exactly the collective the boxing
    cost model prices for that transition. The logical shape is derived from
    the local shard shape and the source signature.
    """

    def __init__(self, plan: MeshPlan):
        self.plan = plan

    def __call__(self, x, src, dst):
        src_n, dst_n = ndsbp(src), ndsbp(dst)
        logical = list(x.shape)
        for comp, size in zip(src_n, self.plan.axis_sizes):
            if isinstance(comp, Split):
                logical[comp.axis] *= size
        fn = boxing_fn(src_n, dst_n, self.plan.axis_names,
                       self.plan.axis_sizes, tuple(logical))
        return fn(x)

    # frequent shortcuts ------------------------------------------------------
    def psum_model(self, x):
        return jax.lax.psum(x, self.plan.model_axis)

    def psum_data(self, x):
        for ax in self.plan.data_axes:
            x = jax.lax.psum(x, ax)
        return x

    def pmean_data(self, x):
        return self.psum_data(x) / self.plan.dp

    def allgather_model(self, x, axis: int):
        return jax.lax.all_gather(x, self.plan.model_axis, axis=axis, tiled=True)


# ---------------------------------------------------------------------------
# Megatron's "f" operator: identity forward, psum backward.
#
# A replicated activation consumed by model-parallel branches (each device's
# branch sees only its head/expert/vocab slice) has DISJOINT per-device
# gradient contributions; the true dL/dx is their sum. Forward needs nothing
# (x is replicated); backward needs a psum. This is the conjugate of the
# forward psum ("g") whose backward is the identity.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def grad_sync(x, axis_name: str):
    return x


def _grad_sync_fwd(x, axis_name):
    return x, None


def _grad_sync_bwd(axis_name, _, g):
    return (jax.lax.psum(g, axis_name),)


grad_sync.defvjp(_grad_sync_fwd, _grad_sync_bwd)


def maybe_grad_sync(x, plan: "MeshPlan"):
    return grad_sync(x, plan.model_axis) if plan.tp > 1 else x


def bound_axes(axis_names):
    """Which of ``axis_names`` are live shard_map axes in this trace."""
    live = set(jax.core.unsafe_get_axis_names_DO_NOT_USE())
    return tuple(n for n in axis_names if n in live)


def force_vary(x, axis_names):
    """Make x's vma cover all live ``axis_names`` (scan carries must have
    a consistent vma across architectures; pvary is free). No-op outside
    shard_map."""
    names = bound_axes(axis_names)
    if not names:
        return x
    vma = getattr(jax.core.get_aval(x), "vma", frozenset()) or frozenset()
    missing = tuple(n for n in names if n not in vma)
    return pvary(x, missing) if missing else x


def certified_pmean(x, axis_name):
    """pmean that no-ops when ``axis_name`` is not a live shard_map axis
    (e.g. smoke tests calling model code outside shard_map)."""
    if not bound_axes((axis_name,)):
        return x
    return jax.lax.pmean(force_vary(x, (axis_name,)), axis_name)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * w


def rope_freqs(head_dim: int, rope_fraction: float, theta: float):
    rot = int(head_dim * rope_fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return rot, inv


def apply_rope(x, positions, rope_fraction: float = 1.0, theta: float = 1e4):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    rot, inv = rope_freqs(hd, rope_fraction, theta)
    if rot == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., seq, rot/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x_rot.shape).astype(x.dtype)
    return jnp.concatenate([out, x_pass], axis=-1) if rot < hd else out


def swiglu(gate, up):
    return jax.nn.silu(gate) * up


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32, scale=1.0):
    fan_in = shape[in_axis]
    std = scale / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))
