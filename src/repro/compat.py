"""JAX API compatibility shims.

``shard_map`` graduated from ``jax.experimental`` to the ``jax`` namespace
(and its ``check_rep`` kwarg became ``check_vma``) across jax versions; the
repo must run on both. Import :func:`shard_map` from here instead of jax.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
else:  # pragma: no cover - jax < 0.6
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check)
