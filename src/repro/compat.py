"""JAX API compatibility shims.

``shard_map`` graduated from ``jax.experimental`` to the ``jax`` namespace
(and its ``check_rep`` kwarg became ``check_vma``) across jax versions; the
repo must run on both. Import :func:`shard_map` from here instead of jax.

``jax.lax.pvary`` only exists on jax versions with varying-manual-axes (vma)
tracking; on older versions there is no vma to widen, so the identity is the
correct shim. Import :func:`pvary` from here instead of ``jax.lax``.
"""
from __future__ import annotations

import jax

if hasattr(jax.lax, "pvary"):
    pvary = jax.lax.pvary
else:  # pragma: no cover - exercised via reload in tests/test_compat.py
    def pvary(x, axis_names):
        return x

if hasattr(jax, "shard_map"):
    def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
else:  # pragma: no cover - jax < 0.6
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
        # pre-vma jax has no pvary to certify replication, so its check_rep
        # inference rejects valid programs (e.g. psum-synced optimizer
        # states); the check is advisory — disable it there.
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
