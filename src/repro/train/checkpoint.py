"""Sharded checkpointing: pytree -> directory of .npy leaves + manifest.

Layout:
    <dir>/manifest.json     {"leaves": {key: {"file", "shape", "dtype"}},
                             "step": int, "meta": {...}}
    <dir>/<key>.npy         one file per leaf (host-gathered)

Restore can re-shard onto any mesh via ``shardings`` (a matching pytree of
NamedSharding / PartitionSpec), so a checkpoint taken on one mesh restores
onto another — the paper's "naive global checkpointing" (§7) done properly.
"""
from __future__ import annotations

import json
import pathlib
import re
from typing import Any, Dict, Optional

import jax
import numpy as np


def _key_str(path) -> str:
    parts = []
    for p in path:
        name = getattr(p, "key", None)
        if name is None:
            name = getattr(p, "idx", None)
        if name is None:
            name = getattr(p, "name", str(p))
        parts.append(str(name))
    key = ".".join(parts)
    return re.sub(r"[^A-Za-z0-9_.\-]", "_", key)


def save_checkpoint(ckpt_dir: str, tree: Any, step: int = 0,
                    meta: Optional[Dict] = None) -> None:
    d = pathlib.Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    leaves = {}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        key = _key_str(path)
        arr = np.asarray(jax.device_get(leaf))
        fn = f"{key}.npy"
        np.save(d / fn, arr)
        leaves[key] = {"file": fn, "shape": list(arr.shape),
                       "dtype": str(arr.dtype)}
    (d / "manifest.json").write_text(json.dumps(
        {"leaves": leaves, "step": step, "meta": meta or {}}, indent=2))


def load_checkpoint(ckpt_dir: str, like: Any, shardings: Any = None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). Returns (tree, step)."""
    d = pathlib.Path(ckpt_dir)
    manifest = json.loads((d / "manifest.json").read_text())
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    out = []
    for (path, leaf), sh in zip(flat, shard_flat):
        key = _key_str(path)
        if key not in manifest["leaves"]:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(d / manifest["leaves"][key]["file"])
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]
