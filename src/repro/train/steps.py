"""SPMD train / serve step builders.

These wrap the (local-shard) model functions in ``shard_map`` over the
production mesh with explicit in/out shardings — the "physical graph" of the
paper, with every collective visible in the lowered HLO (which is what the
roofline analysis parses).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import pvary, shard_map
from repro.configs.base import ModelConfig
from repro.models.common import MeshPlan
from repro.models.model_zoo import build_model, cache_specs, make_decode_caches
from repro.optim.adamw import AdamWConfig, AdamWState
from repro.optim.zero import (
    combine_model_grads, gather_master_local, init_zero_state_local,
    local_shape_of, master_specs, model_combine_tree, plain_dp_adamw_update,
    shard_master_local, zero_adamw_update, zero_state_specs)


def plan_from_mesh(mesh) -> MeshPlan:
    return MeshPlan(tuple(mesh.axis_names), tuple(mesh.devices.shape))


def _dp_spec(plan: MeshPlan):
    axes = plan.data_axes
    return axes if len(axes) > 1 else axes[0]


def batch_specs(cfg: ModelConfig, plan: MeshPlan, kind: str):
    """PartitionSpecs for a batch dict (global arrays)."""
    dp = _dp_spec(plan)
    if kind == "train":
        if cfg.embed_frontend and not cfg.encoder_decoder:
            sp = {"embeds": P(dp), "labels": P(dp)}
        else:
            sp = {"tokens": P(dp)}
        if cfg.encoder_decoder:
            sp["enc_embeds"] = P(dp)
        return sp
    if kind == "prefill":
        if cfg.embed_frontend and not cfg.encoder_decoder:
            sp = {"embeds": P(dp)}
        else:
            sp = {"tokens": P(dp)}
        if cfg.encoder_decoder:
            sp["enc_embeds"] = P(dp)
        return sp
    raise ValueError(kind)


def _replication_tree(specs, plan: MeshPlan):
    """Per-leaf count of identical model-axis copies (for grad-norm math)."""
    mx = plan.model_axis

    def leaf(spec):
        flat = []
        for entry in spec:
            if isinstance(entry, tuple):
                flat.extend(entry)
            elif entry is not None:
                flat.append(entry)
        return 1 if mx in flat else plan.tp

    return jax.tree.map(leaf, specs, is_leaf=lambda s: isinstance(s, P))


# Model-replicated params whose per-device gradient contributions are
# DISJOINT (each device computes grads only through its kv-head / expert /
# B,C-group slice): these need a psum over the model axis before the update.
# Replicated params with IDENTICAL per-device grads (layer norms, wkv_a, ...)
# need none. Distinguished by leaf name.
_MODEL_GRAD_SUM_LEAVES = frozenset(
    {"wk", "wv", "bk", "bv", "q_norm", "k_norm", "w_bc", "conv_bc", "router"})


def _grad_sync_tree(specs, plan: MeshPlan):
    mx = plan.model_axis

    def mode(path, spec):
        flat = []
        for entry in spec:
            if isinstance(entry, tuple):
                flat.extend(entry)
            elif entry is not None:
                flat.append(entry)
        if mx in flat:
            return "none"                      # sharded: local grad is exact
        name = None
        for p in reversed(path):
            name = getattr(p, "key", None)
            if name is not None:
                break
        return "sum" if name in _MODEL_GRAD_SUM_LEAVES else "none"

    import jax.tree_util as jtu
    return jtu.tree_map_with_path(mode, specs,
                                  is_leaf=lambda s: isinstance(s, P))


def _sync_model_grads(grads, sync_tree, plan: MeshPlan):
    if plan.tp == 1:
        return grads

    def fix(g, mode):
        return jax.lax.psum(g, plan.model_axis) if mode == "sum" else g

    return jax.tree.map(fix, grads, sync_tree)


@dataclasses.dataclass
class TrainStep:
    step_fn: Any            # jitted: (params, opt_state, batch) -> (params, opt, metrics)
    param_specs: Any        # specs of the step's param argument (masters if zero)
    model_param_specs: Any  # specs of the unflattened model params
    opt_specs: Any
    batch_specs: Dict
    init_params: Any        # (key) -> global model params (small runs only)
    init_opt: Any           # (step-params) -> opt state (jitted, sharded)
    plan: MeshPlan
    zero: bool = True
    shard_params_fn: Any = None   # full model params -> flat masters (zero)
    gather_params_fn: Any = None  # flat masters -> full model params (zero)


def make_train_step(cfg: ModelConfig, mesh, optimizer: AdamWConfig = None,
                    zero: bool = True, remat: bool = True,
                    fsdp: bool = False) -> TrainStep:
    """``fsdp=True``: beyond-paper plan for small models — the model axis
    becomes extra data parallelism (pure ZeRO/FSDP over all 256/512 chips);
    the per-layer tensor-parallel boxing collectives disappear entirely."""
    optimizer = optimizer or AdamWConfig()
    plan = plan_from_mesh(mesh)
    if fsdp:
        plan = MeshPlan(plan.axis_names, plan.axis_sizes,
                        model_axis="__fsdp_none__")
    bundle = build_model(cfg, plan)
    pspecs = bundle.specs()
    bspecs = batch_specs(cfg, plan, "train")
    repl = _replication_tree(pspecs, plan)
    def is_spec(s):
        return isinstance(s, P)
    cdt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def certified_mean(v):
        vma = getattr(jax.core.get_aval(v), "vma", frozenset())
        missing = tuple(n for n in plan.axis_names if n not in vma)
        if missing:
            v = pvary(v, missing)
        return jax.lax.pmean(v, plan.axis_names)

    metric_names = {"lm_loss": 0, "aux_loss": 0, "loss": 0,
                    **({"mtp_loss": 0} if cfg.mtp else {}), "grad_norm": 0}
    mspecs_out = jax.tree.map(lambda *_: P(), metric_names)

    if zero:
        # ---- FSDP/ZeRO path: flat (DP, TP, chunk) master shards -------------
        arg_specs = master_specs(pspecs, plan)
        ospecs = zero_state_specs(pspecs, plan)
        combine = model_combine_tree(pspecs, plan)
        params_global_s = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
        local_shapes = jax.tree.map(
            lambda sds, spec: local_shape_of(sds.shape, spec, plan),
            params_global_s, pspecs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

        def gather_full_(masters):
            import jax.tree_util as jtu
            flat_m, treedef = jtu.tree_flatten(masters)
            flat_s = treedef.flatten_up_to(local_shapes)
            return treedef.unflatten([
                gather_master_local(m, tuple(s), cdt, plan)
                for m, s in zip(flat_m, flat_s)])

        def local_step(masters, opt_state, batch):
            def loss_fn(mf):
                return bundle.loss_fn(gather_full_(mf), batch)

            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(masters)
            # AD's all_gather transpose already reduce-scattered over data;
            # normalize the data-sum to a mean, then combine over model.
            grads = jax.tree.map(
                lambda g: g.astype(jnp.float32) / plan.dp, grads)
            grads = combine_model_grads(grads, combine, plan)
            new_m, new_opt, gnorm = zero_adamw_update(
                optimizer, masters, grads, opt_state, plan, repl)
            metrics["grad_norm"] = gnorm
            metrics = {k: certified_mean(v) for k, v in metrics.items()}
            return new_m, new_opt, metrics

        step_fn = jax.jit(
            shard_map(local_step, mesh=mesh,
                      in_specs=(arg_specs, ospecs, bspecs),
                      out_specs=(arg_specs, ospecs, mspecs_out),
                      check=True),
            donate_argnums=(0, 1))

        def init_opt(masters):
            fn = jax.jit(shard_map(
                lambda m: init_zero_state_local(m, plan), mesh=mesh,
                in_specs=(arg_specs,), out_specs=ospecs, check=False))
            return fn(masters)

        shard_params_fn = jax.jit(shard_map(
            lambda p: jax.tree.map(
                lambda l: shard_master_local(l, plan), p),
            mesh=mesh, in_specs=(pspecs,), out_specs=arg_specs,
            check=False))
        gather_params_fn = jax.jit(shard_map(
            gather_full_, mesh=mesh, in_specs=(arg_specs,),
            out_specs=pspecs, check=False))

        return TrainStep(step_fn, arg_specs, pspecs, ospecs, bspecs,
                         bundle.init, init_opt, plan, zero=True,
                         shard_params_fn=shard_params_fn,
                         gather_params_fn=gather_params_fn)

    # ---- plain data-parallel baseline (§6.2) --------------------------------
    ospecs = AdamWState(P(), jax.tree.map(lambda s: s, pspecs, is_leaf=is_spec),
                        jax.tree.map(lambda s: s, pspecs, is_leaf=is_spec))

    def local_step(params, opt_state, batch):
        def loss_fn(p):
            return bundle.loss_fn(p, batch)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_params, new_opt, gnorm = plain_dp_adamw_update(
            optimizer, params, grads, opt_state, plan, repl)
        metrics["grad_norm"] = gnorm
        metrics = {k: certified_mean(v) for k, v in metrics.items()}
        return new_params, new_opt, metrics

    step_fn = jax.jit(
        shard_map(local_step, mesh=mesh,
                  in_specs=(pspecs, ospecs, bspecs),
                  out_specs=(pspecs, ospecs, mspecs_out),
                  check=True),
        donate_argnums=(0, 1))

    def init_opt(params):
        from repro.optim.adamw import init_adamw
        fn = jax.jit(shard_map(init_adamw, mesh=mesh, in_specs=(pspecs,),
                               out_specs=ospecs, check=False))
        return fn(params)

    return TrainStep(step_fn, pspecs, pspecs, ospecs, bspecs, bundle.init,
                     init_opt, plan, zero=False)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeStep:
    prefill_fn: Any
    decode_fn: Any
    init_caches_fn: Any
    logits_fn: Any          # (params, h_last) -> logits, the decode head
    param_specs: Any
    cache_specs_: Any
    batch_specs: Dict
    plan: MeshPlan


def greedy_from_logits(logits, vocab_size: int):
    """Greedy token selection over a padded vocabulary.

    The unembedding is padded to ``cfg.padded_vocab()`` columns, so a bare
    argmax can emit padding ids >= ``vocab_size`` (junk the tokenizer cannot
    decode). Mask the padding columns to -inf first; the result is always a
    valid id < ``vocab_size``.
    """
    logits = jnp.asarray(logits)
    mask = jnp.arange(logits.shape[-1]) >= vocab_size
    return jnp.argmax(jnp.where(mask, -jnp.inf, logits),
                      axis=-1).astype(jnp.int32)


def make_serve_step(cfg: ModelConfig, mesh, cache_len: int,
                    sliding_window: int = 0, ring: bool = False,
                    shard_batch: bool = True) -> ServeStep:
    """``ring=True``: sliding-window ring-buffer cache (cache_len == window).
    ``shard_batch=False``: global batch < dp (long_500k) — batch replicated
    over the data axes, KV cache sharded over the model axis only."""
    plan = plan_from_mesh(mesh)
    bundle = build_model(cfg, plan, sliding_window=sliding_window)
    pspecs = bundle.specs()
    bspecs = batch_specs(cfg, plan, "prefill")
    batch_axes = plan.data_axes if shard_batch else ()
    cspecs = cache_specs(cfg, plan, batch_axes, ring=ring)
    dp = _dp_spec(plan) if shard_batch else None
    if not shard_batch:
        bspecs = jax.tree.map(lambda _: P(), bspecs,
                              is_leaf=lambda s: isinstance(s, P))

    def local_prefill(params, batch):
        return bundle.prefill(params, batch, cache_len)

    prefill_fn = jax.jit(
        shard_map(local_prefill, mesh=mesh, in_specs=(pspecs, bspecs),
                  out_specs=(P(dp), cspecs), check=False))

    def local_decode(params, caches, tok, pos):
        return bundle.decode_step(params, caches, tok, pos)

    decode_fn = jax.jit(
        shard_map(local_decode, mesh=mesh,
                  in_specs=(pspecs, cspecs, P(dp), P(dp)),
                  out_specs=(P(dp, plan.model_axis), cspecs),
                  check=False),
        donate_argnums=(1,))

    def local_init_caches(tok):
        B_l = tok.shape[0]
        return make_decode_caches(cfg, plan, B_l, cache_len, ring=ring)

    init_caches_fn = jax.jit(
        shard_map(local_init_caches, mesh=mesh, in_specs=(P(dp),),
                  out_specs=cspecs, check=False))

    def local_logits(params, h_last):
        # the decode-step head, bit for bit (decode_step's final matmul):
        # prefill's first-token logits must come from THIS program, not a
        # host-side h @ unembed that skips the shard_map and promotes dtypes
        return h_last[:, 0] @ params["unembed"].astype(h_last.dtype)

    logits_fn = jax.jit(
        shard_map(local_logits, mesh=mesh, in_specs=(pspecs, P(dp)),
                  out_specs=P(dp, plan.model_axis), check=False))

    return ServeStep(prefill_fn, decode_fn, init_caches_fn, logits_fn,
                     pspecs, cspecs, bspecs, plan)


# ---------------------------------------------------------------------------
# LogicalGraph training steps — DEPRECATED shims over repro.api.compile.
#
# The real machinery lives in repro.api: compile(graph, mode="train",
# backend="monolithic"|"actors") returns a Session with one uniform surface.
# These wrappers only preserve the historical calling conventions
# (per-call param threading for the monolithic step, a bare
# TrainPipelineExecutor for the pipelined one) for code written against
# PR 2/3; new code should call repro.api.compile directly.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GraphTrainStep:
    """Monolithic microbatched SPMD training step over a ``LogicalGraph``.

    ``step_fn(param_values, data) -> (loss, grads, new_params)``: runs every
    microbatch through one whole-graph jitted value-and-grad program,
    accumulates gradients in fp32, and applies the
    :class:`repro.core.lowering.OptimizerSpec` (default plain SGD) — with
    global-norm clipping and the lr schedule resolved exactly like the
    pipeline's optimizer actors, via the same
    :mod:`repro.optim.adamw` kernels in the same canonical param order. The
    objective is the sum of the loss sink over the whole batch; ``grads``
    are post-clip when clipping is on. This is the reference
    :func:`make_pipeline_train_step` is checked against, bit for bit.

    A stateful optimizer's :class:`repro.optim.adamw.AdamWState` persists on
    ``opt_state`` across :meth:`step` calls; ``step_count`` indexes the lr
    schedule; ``last_grad_norm`` is the pre-clip global norm (None when
    clipping is off).
    """

    step_fn: Any
    param_names: Tuple[str, ...]
    num_microbatches: int
    lr: float
    optimizer: Any = None
    opt_state: Any = None
    step_count: int = 0
    last_grad_norm: Any = None

    def step(self, param_values: Dict[str, Any], data: Dict[str, Any]):
        return self.step_fn(param_values, data)


def make_graph_train_step(graph, mesh, params, microbatch_inputs,
                          num_microbatches: int, lr: float = 1e-2,
                          loss=None, graph_plan=None,
                          optimizer=None) -> GraphTrainStep:
    """DEPRECATED: use ``repro.api.compile(graph, mode="train",
    backend="monolithic", ...)`` — this shim only adapts the old
    params-threaded-per-call convention onto the session it builds.

    ``params`` names the graph inputs to train; ``microbatch_inputs`` names
    the inputs split along axis 0 into ``num_microbatches`` chunks. The SBP
    plan is computed with :func:`repro.core.planner.plan` unless
    ``graph_plan`` is given. ``optimizer`` is an
    :class:`repro.core.lowering.OptimizerSpec` (default: SGD at ``lr``).
    """
    import warnings

    warnings.warn(
        "make_graph_train_step is deprecated; use repro.api.compile("
        "graph, mode='train', backend='monolithic', ...) instead",
        DeprecationWarning, stacklevel=2)

    from repro import api
    from repro.core.lowering import (OptimizerSpec, _resolve_loss,
                                     _resolve_params)

    param_names = tuple(getattr(t, "name", t) for t in params)
    # fail at build time like the old direct lowering did, not on first step
    _resolve_params(graph, param_names)
    _resolve_loss(graph, loss)
    opt = optimizer if optimizer is not None else OptimizerSpec.sgd(lr)
    ts = GraphTrainStep(step_fn=None, param_names=param_names,
                        num_microbatches=num_microbatches, lr=lr,
                        optimizer=opt)
    holder: Dict[str, Any] = {"session": None}

    def step_fn(param_values: Dict[str, Any], data: Dict[str, Any]):
        sess = holder["session"]
        missing = [n for n in param_names if n not in param_values]
        if missing:
            raise ValueError(f"missing params: {missing}")
        pvals = {n: param_values[n] for n in param_names}
        if sess is None:
            sess = holder["session"] = api.compile(
                graph, mode="train", backend="monolithic", plan=graph_plan,
                mesh=mesh, params=pvals,
                microbatch_inputs=list(microbatch_inputs),
                num_microbatches=num_microbatches, lr=lr, optimizer=opt,
                loss=loss)
        else:
            sess.load_params(pvals)
        res = sess.step(**{n: v for n, v in data.items()
                           if n not in pvals})
        ts.opt_state = sess.opt_state
        ts.step_count = sess.step_count
        ts.last_grad_norm = res.metrics["grad_norm"]
        return res.loss, res.grads, res.params

    ts.step_fn = step_fn
    return ts


def make_pipeline_train_step(graph, init_params: Dict[str, Any],
                             microbatch_inputs, num_microbatches: int,
                             num_stages: Optional[int] = None, mesh=None,
                             stage_meshes=None, lr: float = 1e-2,
                             regs=None, loss=None, graph_plan=None,
                             fn_wrap=None, optimizer=None):
    """DEPRECATED: use ``repro.api.compile(graph, mode="train",
    backend="actors", ...)`` — this shim compiles a session and returns its
    backing :class:`repro.runtime.pipeline.TrainPipelineExecutor` to
    preserve the historical return type.

    ``init_params`` maps each trainable graph input to its initial value;
    the executor owns the params (and any optimizer state) from then on.
    ``optimizer`` is an :class:`repro.core.lowering.OptimizerSpec` —
    AdamW runs with per-stage state actors and, with ``grad_clip`` > 0, a
    cross-stage ``norm`` actor for global-norm clipping (default: SGD at
    ``lr``).
    """
    import warnings

    warnings.warn(
        "make_pipeline_train_step is deprecated; use repro.api.compile("
        "graph, mode='train', backend='actors', ...) instead",
        DeprecationWarning, stacklevel=2)

    from repro import api

    sess = api.compile(
        graph, mode="train", backend="actors", plan=graph_plan,
        stages=num_stages, params=init_params,
        microbatch_inputs=list(microbatch_inputs),
        num_microbatches=num_microbatches, lr=lr,
        # preserve this shim's historical default schedule (1F1B) rather
        # than compile()'s simulated register planning
        regs=regs if regs is not None else "1f1b",
        loss=loss, mesh=mesh, stage_meshes=stage_meshes, fn_wrap=fn_wrap,
        optimizer=optimizer)
    return sess.executor
