"""Snapshot actors: async checkpointing as a register-stream consumer.

The PR-3 insight was that optimizer state is just *another register stream*
(`state{s}` -> `opt{s}`). Checkpointing rides the same pattern one hop
further: a ``snap{s}`` actor per parameterized stage subscribes to
``opt{s}``'s output stream — the one register that already carries the
post-update params *and* the fresh ``AdamWState`` — and serializes it to
disk from its **own** mailbox thread (``thread=1`` on the stage's node),
with its own out-register quota. The 1F1B schedule on thread 0 never waits
on serialization; under ``runtime="processes"`` each stage writes from its
own worker, in parallel across stages.

On-disk layout (all under the session's ``snapshot_dir``)::

    <dir>/step-00000003/stage0/           per-stage arrays + manifest.json
                        stage1/              (repro.train.checkpoint format:
                        ...                   params.<name>.npy,
                                              opt.mu.<name>.npy, opt.step.npy)
                        MANIFEST.json     written LAST, by the driver, only
                                          after every stage's write receipt
                                          arrived -> its presence marks the
                                          snapshot complete (atomic-enough:
                                          a kill mid-write leaves stage dirs
                                          without a MANIFEST, which restore
                                          ignores)

``step-N`` holds the state *after* N optimizer steps together with the
schedule state (the step counter the lr schedule is indexed by), so a
session restored from it replays step N+1 bit-identically.

:func:`load_snapshot` merges the per-stage trees back into the flat
``params`` / merged ``AdamWState`` form that ``Session.load_state`` takes —
deliberately partition-agnostic, so a snapshot taken on a 4-stage pipeline
restores onto a 2-stage (or monolithic) session.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import re
from typing import Any, Dict, List, Optional, Tuple

MANIFEST_NAME = "MANIFEST.json"
_STEP_DIR_RE = re.compile(r"^step-(\d+)$")


@dataclasses.dataclass(frozen=True)
class SnapshotSpec:
    """Picklable snapshot config carried by the train spec builders into
    worker processes (the directory is the only cross-process field; the
    per-epoch step/write decision travels through ``ctx``)."""

    dir: str


def step_dir(root: str, step: int) -> pathlib.Path:
    return pathlib.Path(root) / f"step-{step:08d}"


def stage_dir(root: str, step: int, stage: int) -> pathlib.Path:
    return step_dir(root, step) / f"stage{stage}"


def _sanitize(name: str) -> str:
    # mirror repro.train.checkpoint._key_str's per-segment sanitization
    return re.sub(r"[^A-Za-z0-9_.\-]", "_", name)


def write_stage_snapshot(root: str, step: int, stage: int,
                         params: Dict[str, Any], opt_state=None,
                         zero: Optional[Dict[str, Any]] = None) -> None:
    """One stage's slice of a snapshot, in the
    :mod:`repro.train.checkpoint` directory format. Runs inside the
    ``snap{s}`` actor — off the schedule's hot path.

    With ``zero`` set (``{"dp": int, "shapes": {name: [dims]}}``), the
    arrays being written are the opt actor's *flat* ``(dp, 1, chunk)`` fp32
    master/moment shards, persisted as-is — the zero metadata lets
    :func:`_load_stage` gather them back to full tensors on the host, so
    restore stays partition- and zero-agnostic."""
    from repro.train.checkpoint import save_checkpoint

    tree: Dict[str, Any] = {"params": dict(params)}
    if opt_state is not None:
        tree["opt"] = {"step": opt_state.step, "mu": dict(opt_state.mu),
                       "nu": dict(opt_state.nu)}
    meta: Dict[str, Any] = {"stage": stage,
                            "param_names": list(params),
                            "stateful": opt_state is not None}
    if zero is not None:
        meta["zero"] = True
        meta["zero_dp"] = int(zero["dp"])
        meta["zero_shapes"] = {n: [int(d) for d in s]
                               for n, s in zero["shapes"].items()}
    save_checkpoint(str(stage_dir(root, step, stage)), tree, step=step,
                    meta=meta)


def write_manifest(root: str, step: int, stages: List[int],
                   meta: Optional[Dict[str, Any]] = None) -> None:
    """Finalize a snapshot: written by the driver only after every stage's
    receipt, and renamed into place so a complete MANIFEST either exists or
    doesn't."""
    d = step_dir(root, step)
    d.mkdir(parents=True, exist_ok=True)
    body = json.dumps({"version": 1, "step": int(step),
                       "stages": sorted(int(s) for s in stages),
                       "meta": meta or {}}, indent=2)
    tmp = d / (MANIFEST_NAME + ".tmp")
    tmp.write_text(body)
    os.replace(tmp, d / MANIFEST_NAME)


def list_snapshots(root: str) -> List[int]:
    """Completed (manifest-bearing) snapshot steps under ``root``, sorted."""
    d = pathlib.Path(root)
    if not d.is_dir():
        return []
    steps = []
    for child in d.iterdir():
        m = _STEP_DIR_RE.match(child.name)
        if m and (child / MANIFEST_NAME).is_file():
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_snapshot(root: str) -> Optional[int]:
    """The newest completed snapshot step, or None (e.g. killed before the
    first snapshot landed -> the caller restarts from scratch)."""
    steps = list_snapshots(root)
    return steps[-1] if steps else None


def _load_stage(d: pathlib.Path):
    """Load one stage dir -> (params, mu, nu, opt_step or None).

    ZeRO stage dirs (``meta["zero"]``) hold flat ``(dp, 1, chunk)`` shards;
    they are gathered back to full tensors here, on the host, with the same
    reshape-then-truncate the jitted gather kernel performs — a pure layout
    operation, so the round-trip is bitwise. The caller never sees shards."""
    import numpy as np

    manifest = json.loads((d / "manifest.json").read_text())
    meta = manifest.get("meta") or {}
    names = meta.get("param_names", [])
    stateful = bool(meta.get("stateful"))
    leaves = manifest["leaves"]
    zero_shapes = meta.get("zero_shapes") if meta.get("zero") else None

    def load(key, shape=None):
        if key not in leaves:
            raise KeyError(f"stage snapshot {d} missing leaf {key!r}")
        arr = np.load(d / leaves[key]["file"])
        if shape is not None:
            n = int(np.prod(shape)) if shape else 1
            arr = arr.reshape(-1)[:n].reshape(shape)
        return arr

    def shape_of(n):
        if zero_shapes is None:
            return None
        return tuple(int(d) for d in zero_shapes[n])

    params = {n: load(f"params.{_sanitize(n)}", shape_of(n)) for n in names}
    if not stateful:
        return params, {}, {}, None
    mu = {n: load(f"opt.mu.{_sanitize(n)}", shape_of(n)) for n in names}
    nu = {n: load(f"opt.nu.{_sanitize(n)}", shape_of(n)) for n in names}
    return params, mu, nu, load("opt.step")


def load_snapshot(root: str, step: Optional[int] = None
                  ) -> Tuple[Dict[str, Any], Any, int, Dict[str, Any]]:
    """Load a completed snapshot -> ``(params, opt_state, step, meta)``.

    ``params`` is the flat name->array dict and ``opt_state`` the merged
    :class:`repro.optim.adamw.AdamWState` (or None for a stateless
    optimizer) — exactly what ``Session.load_state`` takes, independent of
    the stage partition the snapshot was written under. ``step=None`` loads
    the latest snapshot; a missing/incomplete snapshot raises
    ``FileNotFoundError``.
    """
    if step is None:
        step = latest_snapshot(root)
        if step is None:
            raise FileNotFoundError(
                f"no completed snapshot (step-*/{MANIFEST_NAME}) under "
                f"{root!r}")
    d = step_dir(root, step)
    mpath = d / MANIFEST_NAME
    if not mpath.is_file():
        raise FileNotFoundError(f"snapshot {d} has no {MANIFEST_NAME} "
                                "(incomplete write?)")
    manifest = json.loads(mpath.read_text())
    params: Dict[str, Any] = {}
    mu: Dict[str, Any] = {}
    nu: Dict[str, Any] = {}
    opt_steps = []
    for s in manifest["stages"]:
        p, m, v, ostep = _load_stage(d / f"stage{s}")
        params.update(p)
        mu.update(m)
        nu.update(v)
        if ostep is not None:
            opt_steps.append(ostep)
    opt_state = None
    if opt_steps:
        from repro.optim.adamw import AdamWState
        first = opt_steps[0]
        if any(o != first for o in opt_steps[1:]):
            raise ValueError(
                f"snapshot {d} has inconsistent per-stage optimizer steps: "
                f"{opt_steps}")
        opt_state = AdamWState(first, mu, nu)
    return params, opt_state, int(manifest["step"]), manifest.get("meta", {})
