from repro.runtime.actor import Actor, ActorSpec, build_actors
from repro.runtime.messages import Ack, Req, make_actor_id, parse_actor_id
from repro.runtime.pipeline import (ActorPipelineExecutor,
                                    TrainPipelineExecutor, analyze,
                                    pipeline_specs, plan_registers,
                                    stage_actor_specs,
                                    train_stage_actor_specs)
from repro.runtime.scheduler import CommModel, SimResult, Simulator, simulate
from repro.runtime.threaded import ThreadedRuntime
