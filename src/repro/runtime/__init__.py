from repro.runtime.actor import Actor, ActorSpec, build_actors
from repro.runtime.base import (RUNTIME_KINDS, Runtime, SpecBuilder,
                                WorkerError, encode_payload, make_runtime)
from repro.runtime.chaos import (DelayEdge, DropAck, DuplicateReq, FaultPlan,
                                 KillWorker, WorkerKilled)
from repro.runtime.messages import Ack, Req, make_actor_id, parse_actor_id
from repro.runtime.pipeline import (ActorPipelineExecutor, InferSpecBuilder,
                                    ServePipelineExecutor, ServeSpecBuilder,
                                    TrainPipelineExecutor, TrainSpecBuilder,
                                    analyze, pipeline_specs, plan_registers,
                                    serve_stage_actor_specs, stage_actor_specs,
                                    train_stage_actor_specs)
from repro.runtime.process import ProcessRuntime
from repro.runtime.recipes import (InferRecipe, MeshSpec, ServeRecipe,
                                   TrainRecipe)
from repro.runtime.scheduler import CommModel, SimResult, Simulator, simulate
from repro.runtime.snapshot import (SnapshotSpec, latest_snapshot,
                                    list_snapshots, load_snapshot)
from repro.runtime.threaded import ThreadedRuntime
