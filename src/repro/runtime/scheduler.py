"""Discrete-event simulator for the actor runtime (paper §4/§5).

Faithful to the paper's execution rules:

* actions fire only when all in counters > 0 and the out counter > 0;
* `ack`s are sent when the consumer has *finished using* the data (action end);
* `req`s are delivered to consumers at action end (+ routing latency);
* actors bound to the same OS thread / hardware queue serialize (Fig 7);
* cross-node messages pay CommNet latency + bandwidth (Fig 7 case 3).

The simulator is what the framework uses for compile-time *resource planning*
(picking register quotas = pipeline depth) before lowering the real program,
and it doubles as the evaluation harness for Figs 2/6 and the pipeline
benchmarks.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.runtime.actor import Actor, ActorSpec, build_actors
from repro.runtime.messages import Ack, Req, node_of, thread_of


@dataclasses.dataclass
class CommModel:
    """Message routing cost (Fig 7): local queue, same-node, cross-node."""

    same_thread: float = 0.0
    same_node: float = 1e-3
    cross_node_latency: float = 5e-3
    cross_node_gbps: float = 12.5       # 100 Gbps RoCE, as in the paper

    def latency(self, src_id: int, dst_id: int, nbytes: int) -> float:
        if node_of(src_id) != node_of(dst_id):
            return self.cross_node_latency + nbytes / (self.cross_node_gbps * 1e9)
        if thread_of(src_id) != thread_of(dst_id):
            return self.same_node
        return self.same_thread


@dataclasses.dataclass
class SimResult:
    makespan: float
    history: Dict[str, List[Tuple[float, float]]]    # actor -> action intervals
    peak_regs: Dict[str, int]
    fires: Dict[str, int]
    outputs: List[Any]
    deadlocked: bool = False
    pending_at_deadlock: int = 0

    def utilization(self, actor: str) -> float:
        busy = sum(e - s for s, e in self.history[actor])
        return busy / self.makespan if self.makespan else 0.0


class Simulator:
    def __init__(self, specs: Sequence[ActorSpec], comm: Optional[CommModel] = None,
                 collect_outputs_of: Optional[str] = None):
        self.by_name, self.by_id = build_actors(specs)
        self.comm = comm or CommModel()
        self.collect = collect_outputs_of
        self._seq = itertools.count()
        self.heap: List[Tuple[float, int, str, Any]] = []
        self.thread_free: Dict[Tuple[int, int], float] = {}
        self.busy: Dict[str, bool] = {n: False for n in self.by_name}
        self.outputs: List[Any] = []

    def _push(self, t: float, kind: str, data: Any) -> None:
        heapq.heappush(self.heap, (t, next(self._seq), kind, data))

    def _duration(self, actor: Actor) -> float:
        d = actor.spec.duration
        return d(actor.version) if callable(d) else float(d)

    def _try_fire(self, actor: Actor, now: float) -> None:
        if self.busy[actor.spec.name] or not actor.ready():
            return
        key = (actor.spec.node, actor.spec.thread)
        start = max(now, self.thread_free.get(key, 0.0))
        dur = self._duration(actor)
        end = start + dur
        self.thread_free[key] = end
        self.busy[actor.spec.name] = True
        out, acks, reg_id = actor.fire()
        version = actor.version - 1
        actor.history.append((start, end))
        if self.collect == actor.spec.name and actor.emitted_last_fire:
            self.outputs.append(out)
        self._push(end, "action_end",
                   (actor.spec.name, out, acks, reg_id, version))

    def run(self, max_events: int = 10_000_000) -> SimResult:
        now = 0.0
        for a in self.by_name.values():
            self._try_fire(a, 0.0)
        events = 0
        while self.heap:
            events += 1
            if events > max_events:
                raise RuntimeError("simulator exceeded max_events")
            now, _, kind, data = heapq.heappop(self.heap)
            if kind == "action_end":
                name, out, acks, reg_id, version = data
                actor = self.by_name[name]
                self.busy[name] = False
                for ack in acks:
                    lat = self.comm.latency(ack.src, ack.dst, 64)
                    self._push(now + lat, "deliver_ack", ack)
                if reg_id != -1:
                    for req in actor.emit_reqs(out, reg_id, version):
                        lat = self.comm.latency(req.src, req.dst, req.nbytes)
                        self._push(now + lat, "deliver_req", req)
                self._try_fire(actor, now)
            elif kind == "deliver_req":
                req: Req = data
                actor = self.by_id[req.dst]
                actor.on_req(req)
                self._try_fire(actor, now)
            elif kind == "deliver_ack":
                ack: Ack = data
                actor = self.by_id[ack.dst]
                actor.on_ack(ack)
                self._try_fire(actor, now)

        # detect deadlock / starvation: any actor with pending input that never ran
        pending = sum(
            sum(len(q) for q in a.in_queues.values()) for a in self.by_name.values())
        not_done = [a for a in self.by_name.values()
                    if not a.exhausted and a.spec.max_fires is not None]
        deadlocked = pending > 0 or bool(not_done)
        return SimResult(
            makespan=now,
            history={n: a.history for n, a in self.by_name.items()},
            peak_regs={n: a.peak_regs_in_use for n, a in self.by_name.items()},
            fires={n: a.fired for n, a in self.by_name.items()},
            outputs=self.outputs,
            deadlocked=deadlocked,
            pending_at_deadlock=pending,
        )


def simulate(specs: Sequence[ActorSpec], **kw) -> SimResult:
    return Simulator(specs, **kw).run()
