"""Actor messages and hierarchical 64-bit addressing (paper §5, Fig 7/8).

Every actor gets a 64-bit ID encoding (node, thread, hardware queue, actor
index). IDs of the device/thread/node an actor resides on can be parsed back
out of the ID, which is all the message bus needs to route a message — the
receiver's ID *is* the route (paper: "attaching the receiver actor's ID with
the message suffices").
"""
from __future__ import annotations

import dataclasses
from typing import Any

# Field widths (bits). Fig 8 shows node|thread|queue|actor; widths here are
# chosen so the whole address packs into 64 bits with room at every level.
NODE_BITS, THREAD_BITS, QUEUE_BITS, ACTOR_BITS = 12, 12, 8, 32
assert NODE_BITS + THREAD_BITS + QUEUE_BITS + ACTOR_BITS == 64


def make_actor_id(node: int, thread: int, queue: int, index: int) -> int:
    for v, bits, name in ((node, NODE_BITS, "node"), (thread, THREAD_BITS, "thread"),
                          (queue, QUEUE_BITS, "queue"), (index, ACTOR_BITS, "actor")):
        if not 0 <= v < (1 << bits):
            raise ValueError(f"{name} id {v} out of range for {bits} bits")
    return (((node << THREAD_BITS | thread) << QUEUE_BITS | queue)
            << ACTOR_BITS | index)


def parse_actor_id(actor_id: int):
    index = actor_id & ((1 << ACTOR_BITS) - 1)
    rest = actor_id >> ACTOR_BITS
    queue = rest & ((1 << QUEUE_BITS) - 1)
    rest >>= QUEUE_BITS
    thread = rest & ((1 << THREAD_BITS) - 1)
    node = rest >> THREAD_BITS
    return node, thread, queue, index


def node_of(actor_id: int) -> int:
    return parse_actor_id(actor_id)[0]


def thread_of(actor_id: int) -> int:
    return parse_actor_id(actor_id)[1]


def payload_nbytes(payload: Any) -> int:
    """Best-effort byte count of a register payload: array leaves summed
    recursively through dicts/sequences/dataclasses. Non-array leaves
    (closures, ints, None) count as zero — the number feeds instrumentation
    (``Req.nbytes``, per-edge traffic), not allocation."""
    if payload is None:
        return 0
    nb = getattr(payload, "nbytes", None)
    if nb is not None and not callable(nb):
        return int(nb)
    if isinstance(payload, dict):
        return sum(payload_nbytes(v) for v in payload.values())
    if isinstance(payload, (list, tuple)):
        return sum(payload_nbytes(v) for v in payload)
    if dataclasses.is_dataclass(payload) and not isinstance(payload, type):
        return sum(payload_nbytes(getattr(payload, f.name))
                   for f in dataclasses.fields(payload))
    return 0


@dataclasses.dataclass
class Req:
    """Producer -> consumer: a register holds a newly produced tensor."""

    src: int                 # producer actor id
    dst: int                 # consumer actor id
    reg_id: int              # out-register instance being shared
    channel: str             # consumer's input channel name
    payload: Any             # the tensor (by reference: zero-copy on-node)
    version: int             # microbatch / iteration index
    nbytes: int = 0


@dataclasses.dataclass
class Ack:
    """Consumer -> producer: the register is no longer referenced."""

    src: int                 # consumer actor id
    dst: int                 # producer actor id
    reg_id: int
    version: int
