"""Picklable lowering recipes — how a worker process rebuilds its stages.

``runtime="processes"`` ships each executor's spec builder to one worker per
node id (:mod:`repro.runtime.process`). A lowered program cannot make that
trip: jitted callables, vjp closures and ``jax.sharding.Mesh`` objects are
process-local. What *can* travel is the recipe the driver lowered from — the
logical graph, the SBP plan, the stage partition and a device-id description
of the mesh — so each worker re-runs the same deterministic lowering against
its own XLA client and jit-compiles only the stages it actually fires.

:class:`MeshSpec` is the wire form of a mesh: axis names + shape + flat
device ids, rebuilt against the worker's device table (workers inherit the
driver's ``XLA_FLAGS`` via :mod:`repro.launch.xla_env`, so the tables match).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """A ``jax.sharding.Mesh`` as data: rebuildable in any process that sees
    the same device table."""

    axis_names: Tuple[str, ...]
    shape: Tuple[int, ...]
    device_ids: Tuple[int, ...]

    @classmethod
    def capture(cls, mesh) -> Optional["MeshSpec"]:
        if mesh is None:
            return None
        import numpy as np

        devs = np.asarray(mesh.devices)
        return cls(tuple(mesh.axis_names), tuple(devs.shape),
                   tuple(int(d.id) for d in devs.ravel()))

    def to_mesh(self):
        import jax
        import numpy as np

        table = {d.id: d for d in jax.devices()}
        missing = [i for i in self.device_ids if i not in table]
        if missing:
            raise RuntimeError(
                f"mesh device id(s) {missing} absent in this process "
                f"({len(table)} devices visible); runtime='processes' "
                "workers must see the driver's device table — check "
                "XLA_FLAGS=--xla_force_host_platform_device_count")
        arr = np.array([table[i] for i in self.device_ids],
                       dtype=object).reshape(self.shape)
        return jax.sharding.Mesh(arr, self.axis_names)


def _resolve_meshes(graph, mesh: Optional[MeshSpec],
                    stage_meshes: Optional[Tuple[MeshSpec, ...]]):
    """Mirror ``repro.api.compile``'s mesh defaulting: an explicit mesh spec
    wins, else the graph placement's mesh — unless per-stage meshes are
    given, in which case the shared mesh stays None."""
    if mesh is not None:
        shared = mesh.to_mesh()
    elif stage_meshes is None:
        shared = graph.placement.to_mesh()
    else:
        shared = None
    per_stage = ([m.to_mesh() for m in stage_meshes]
                 if stage_meshes is not None else None)
    return shared, per_stage


@dataclasses.dataclass
class InferRecipe:
    """Everything :func:`repro.core.lowering.lower_stages` needs, as data."""

    graph: Any
    plan: Any
    partition: Any
    mesh: Optional[MeshSpec] = None
    stage_meshes: Optional[Tuple[MeshSpec, ...]] = None

    def lower(self):
        from repro.core.lowering import lower_stages

        shared, per_stage = _resolve_meshes(self.graph, self.mesh,
                                            self.stage_meshes)
        return lower_stages(self.graph, self.plan, self.partition,
                            mesh=shared, stage_meshes=per_stage)


@dataclasses.dataclass
class TrainRecipe:
    """Everything :func:`repro.core.lowering.lower_train_stages` needs, as
    data. ``loss`` is a tensor name (or LTensor); the optimizer's ``lr``
    must be a float or module-level callable to survive pickling."""

    graph: Any
    plan: Any
    partition: Any
    param_names: List[str]
    loss: Any = None
    mesh: Optional[MeshSpec] = None
    stage_meshes: Optional[Tuple[MeshSpec, ...]] = None
    optimizer: Any = None

    def lower(self):
        from repro.core.lowering import lower_train_stages

        shared, per_stage = _resolve_meshes(self.graph, self.mesh,
                                            self.stage_meshes)
        return lower_train_stages(self.graph, self.plan, self.partition,
                                  list(self.param_names), loss=self.loss,
                                  mesh=shared, stage_meshes=per_stage,
                                  optimizer=self.optimizer)


@dataclasses.dataclass
class ServeRecipe:
    """Everything :func:`repro.core.lowering.lower_serve_stages` needs, as
    data. ``params`` are host (numpy) copies of the model params."""

    cfg: Any
    params: Dict[str, Any]
    num_stages: int
    cache_len: int
    max_prompt_len: int
    group_size: int
    mesh: Optional[MeshSpec] = None

    def lower(self):
        import jax

        from repro.core.lowering import lower_serve_stages

        mesh = (self.mesh.to_mesh() if self.mesh is not None
                else jax.make_mesh((1, 1), ("data", "model")))
        return lower_serve_stages(self.cfg, mesh, self.params,
                                  num_stages=self.num_stages,
                                  cache_len=self.cache_len,
                                  max_prompt_len=self.max_prompt_len,
                                  group_size=self.group_size)
