"""The actor protocol (paper §4): registers, counters, req/ack state machine.

This module is *driver-agnostic*: the same :class:`Actor` logic is advanced by
the discrete-event simulator (:mod:`repro.runtime.scheduler`) and by the real
threaded runtime (:mod:`repro.runtime.threaded`). Drivers deliver messages and
ask ``actor.try_fire()``; the actor owns all counter bookkeeping:

* ``in counter``   — per input channel: tensors ready to consume.
* ``out counter``  — free out-register quota (pre-allocated memory budget).
* ``reference counter`` — per out-register instance: active consumers.

An action fires only when every in counter is non-zero AND the out counter is
non-zero — resource availability is an explicit dependency (paper §4.2),
which is what prevents the Fig. 2 OOM/deadlock and gives back-pressure/
pipelining for free (§4.3).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.runtime.messages import Ack, Req, make_actor_id, payload_nbytes


@dataclasses.dataclass
class ActorSpec:
    """Static description of one actor (one physical op)."""

    name: str
    fn: Callable[..., Any]                  # action body (real or dummy)
    inputs: Tuple[str, ...] = ()            # producer actor names
    out_regs: int = 2                       # out-register quota (memory budget)
    node: int = 0
    thread: int = 0
    queue: int = 0
    duration: Any = 1.0                     # sim-mode cost (float or fn(version))
    max_fires: Optional[int] = None         # e.g. #batches for source actors
    out_nbytes: int = 0                     # for comm cost in sim mode
    wants_version: bool = False             # fn also receives version= kwarg
    emit_every: int = 1                     # emit output every k-th fire only
    on_epoch: Optional[Callable[[Any], None]] = None
    # ^ per-epoch context hook: a persistent runtime calls it with this
    #   actor's slice of the run() ctx before any fire of the new epoch
    #   (None when the epoch carries nothing for this actor)


_reg_counter = itertools.count(1)


class Actor:
    """Protocol state machine for one actor."""

    def __init__(self, spec: ActorSpec, actor_id: int,
                 consumers: Sequence[Tuple[int, str]]):
        self.spec = spec
        self.actor_id = actor_id
        # consumers: list of (consumer_actor_id, channel_name)
        self.consumers = list(consumers)
        self.consumer_names: Dict[int, str] = {}    # filled by build_actors
        # in-register state: channel -> FIFO of Req (holding payload refs)
        self.in_queues: Dict[str, collections.deque] = {
            ch: collections.deque() for ch in spec.inputs}
        # per-channel resequencer: a producer with emit_every=k emits
        # versions k-1, 2k-1, ... — `in_stride`/`in_next` track the next
        # expected version so duplicated or reordered Req deliveries (a
        # lossy transport, or chaos injection) are deduplicated/reordered
        # here instead of corrupting the FIFO. build_actors fills the real
        # strides from the producers' specs.
        self.in_stride: Dict[str, int] = {ch: 1 for ch in spec.inputs}
        self.in_next: Dict[str, int] = {ch: 0 for ch in spec.inputs}
        self.in_pending: Dict[str, Dict[int, Req]] = {
            ch: {} for ch in spec.inputs}
        # out-register state
        self.out_counter = spec.out_regs
        self.refcount: Dict[int, int] = {}          # reg instance -> refs
        self.reg_payload: Dict[int, Any] = {}
        self.fired = 0
        self.version = 0
        self.epoch = 0
        self.max_fires = spec.max_fires             # per-epoch override target
        self.last_nbytes = 0                        # bytes of the last payload
        # instrumentation
        self.peak_regs_in_use = 0
        self.history: List[Tuple[float, float]] = []   # (start, end) of actions
        self.edge_bytes: Dict[str, int] = {}        # consumer name -> bytes sent

    def reset(self, max_fires: Optional[int] = None) -> None:
        """Start a new epoch: fire/version counters, in-flight registers and
        instrumentation are cleared so a persistent runtime can reuse the
        actor across runs. ``max_fires`` overrides the spec's bound for this
        epoch only (serve rounds vary their work count)."""
        self.in_queues = {ch: collections.deque() for ch in self.spec.inputs}
        self.in_next = {ch: s - 1 for ch, s in self.in_stride.items()}
        self.in_pending = {ch: {} for ch in self.spec.inputs}
        self.out_counter = self.spec.out_regs
        self.refcount.clear()
        self.reg_payload.clear()
        self.fired = 0
        self.version = 0
        self.epoch += 1
        self.max_fires = (self.spec.max_fires if max_fires is None
                          else max_fires)
        self.last_nbytes = 0
        self.peak_regs_in_use = 0
        self.history = []
        self.edge_bytes = {}

    # -- message handling -------------------------------------------------------
    def on_req(self, msg: Req) -> None:
        """Accept a produced register: dedup + resequence per channel.

        A duplicate delivery (version already consumed or already pending)
        is dropped *without* an ack — the first copy acks exactly once when
        consumed, so the producer's reference counter stays consistent. An
        early delivery (a later version overtaking an in-flight one) is
        buffered until the versions before it arrive, preserving the
        in-order FIFO the fire path consumes. In-order delivery — every
        non-chaotic transport — hits the buffer-and-drain path with an
        empty buffer.
        """
        ch = msg.channel
        nxt = self.in_next.get(ch)
        if nxt is None:                      # undeclared channel: legacy FIFO
            self.in_queues[ch].append(msg)
            return
        pend = self.in_pending[ch]
        if msg.version < nxt or msg.version in pend:
            return
        pend[msg.version] = msg
        stride = self.in_stride[ch]
        while nxt in pend:
            self.in_queues[ch].append(pend.pop(nxt))
            nxt += stride
        self.in_next[ch] = nxt

    def on_ack(self, msg: Ack) -> bool:
        """Returns True when the ack recycled the register (last reference)."""
        self.refcount[msg.reg_id] -= 1
        if self.refcount[msg.reg_id] == 0:
            # register recycled: memory quota returns (paper: out counter += 1)
            del self.refcount[msg.reg_id]
            del self.reg_payload[msg.reg_id]
            self.out_counter += 1
            return True
        return False

    # -- firing -------------------------------------------------------------------
    @property
    def exhausted(self) -> bool:
        return self.max_fires is not None and self.fired >= self.max_fires

    @property
    def emitted_last_fire(self) -> bool:
        """Whether the most recent fire emitted its output — false for the
        fires an ``emit_every`` accumulation actor suppressed. Drivers use
        this for output collection (``reg_id == -1`` can't distinguish
        'suppressed' from 'no consumers')."""
        return self.fired % max(1, self.spec.emit_every) == 0

    def ready(self) -> bool:
        if self.exhausted or self.out_counter <= 0:
            return False
        return all(q for q in self.in_queues.values())

    def fire(self) -> Tuple[Any, List[Ack], int]:
        """Execute the action. Returns (output_payload, acks_to_send, reg_id).

        The driver is responsible for sending the returned acks and the reqs
        built by :meth:`emit_reqs`, and for timing/thread serialization.
        """
        assert self.ready()
        ins = []
        acks = []
        for ch in self.spec.inputs:
            req = self.in_queues[ch].popleft()
            ins.append(req.payload)
            acks.append(Ack(src=self.actor_id, dst=req.src,
                            reg_id=req.reg_id, version=req.version))
        if self.spec.wants_version:
            # microbatch-indexed actions (e.g. a pipeline source emitting
            # microbatch k) need to know which firing this is
            out = self.spec.fn(*ins, version=self.version)
        else:
            out = self.spec.fn(*ins)
        self.fired += 1
        # allocate an out register instance
        self.out_counter -= 1
        reg_id = next(_reg_counter)
        nrefs = len(self.consumers)
        # OneFlow-style accumulation actor (`acc`): consumes every firing but
        # emits only each emit_every-th output (e.g. the summed gradient of a
        # whole step). Non-emitting fires recycle their register immediately.
        if not self.emitted_last_fire:
            nrefs = 0
        if nrefs == 0:
            # no consumer: recycle immediately
            self.out_counter += 1
        else:
            self.refcount[reg_id] = nrefs
            self.reg_payload[reg_id] = out
        # real payload size when measurable, the spec's static estimate
        # otherwise (the simulator's dummy payloads carry no arrays)
        self.last_nbytes = payload_nbytes(out) or self.spec.out_nbytes
        in_use = self.spec.out_regs - self.out_counter
        self.peak_regs_in_use = max(self.peak_regs_in_use, in_use)
        v = self.version
        self.version += 1
        return out, acks, reg_id if nrefs else -1

    def emit_reqs(self, out: Any, reg_id: int, version: int) -> List[Req]:
        nbytes = self.last_nbytes
        for cid, _ in self.consumers:
            name = self.consumer_names.get(cid, str(cid))
            self.edge_bytes[name] = self.edge_bytes.get(name, 0) + nbytes
        return [Req(src=self.actor_id, dst=cid, reg_id=reg_id, channel=ch,
                    payload=out, version=version, nbytes=nbytes)
                for cid, ch in self.consumers]


def build_actors(specs: Sequence[ActorSpec]):
    """Wire a graph of ActorSpecs into Actor instances with assigned IDs.

    Returns (actors_by_name, actors_by_id).
    """
    per_key_index: Dict[Tuple[int, int, int], int] = collections.defaultdict(int)
    ids: Dict[str, int] = {}
    for s in specs:
        key = (s.node, s.thread, s.queue)
        idx = per_key_index[key]
        per_key_index[key] += 1
        ids[s.name] = make_actor_id(s.node, s.thread, s.queue, idx)
    # consumer lists: actor A consumes channel named after producer
    consumers: Dict[str, List[Tuple[int, str]]] = collections.defaultdict(list)
    for s in specs:
        for producer_name in s.inputs:
            if producer_name not in ids:
                raise ValueError(f"{s.name} consumes unknown actor {producer_name}")
            consumers[producer_name].append((ids[s.name], producer_name))
    names_by_id = {aid: name for name, aid in ids.items()}
    by_name, by_id = {}, {}
    for s in specs:
        a = Actor(s, ids[s.name], consumers.get(s.name, ()))
        a.consumer_names = {cid: names_by_id[cid] for cid, _ in a.consumers}
        by_name[s.name] = a
        by_id[a.actor_id] = a
    # resequencer strides: a producer with emit_every=k emits versions
    # k-1, 2k-1, ... on its channel
    for s in specs:
        a = by_name[s.name]
        for producer_name in s.inputs:
            stride = max(1, by_name[producer_name].spec.emit_every)
            a.in_stride[producer_name] = stride
            a.in_next[producer_name] = stride - 1
    return by_name, by_id
