"""The Runtime seam: one protocol over the threaded and process runtimes.

The executors in :mod:`repro.runtime.pipeline` never name a concrete runtime
class; they hold a *spec builder* (a callable returning ``(specs,
collect_outputs_of)``) and ask :func:`make_runtime` for a :class:`Runtime`.
A runtime is built ONCE per executor and reused across steps/rounds — actors
are resettable state machines (:meth:`repro.runtime.actor.Actor.reset`), so
each :meth:`Runtime.run` starts a fresh *epoch* over the same actor graph:

* per-epoch inputs arrive through ``ctx`` (``{actor name: value}``), applied
  by each actor's ``ActorSpec.on_epoch`` hook before any fire;
* per-epoch fire bounds arrive through ``fires`` (``{actor name: count}``,
  e.g. a serve round's work count), overriding ``ActorSpec.max_fires``;
* persistent per-stage state (placed params, optimizer state, serve caches)
  lives in the actor closures — resident wherever the actor runs, never
  round-tripping through the driver.

For ``kind="processes"`` the builder must be picklable: it is shipped to one
worker process per node id (paper Fig 7/8 — the node field of the 64-bit
actor address becomes a real OS process) and re-lowers its stages there.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

RUNTIME_KINDS = ("threads", "processes")

#: builder protocol: () -> (List[ActorSpec], collect_outputs_of)
SpecBuilder = Callable[[], Tuple[List[Any], Any]]


class WorkerError(RuntimeError):
    """A worker process died or raised; carries the remote traceback text."""

    def __init__(self, message: str, node: Optional[int] = None,
                 remote_traceback: Optional[str] = None):
        super().__init__(message)
        self.node = node
        self.remote_traceback = remote_traceback


class RemoteTraceback(Exception):
    """Re-raised as the __cause__ of a WorkerError so the worker-side frames
    appear chained under the driver-side raise."""

    def __str__(self):
        return "\n" + self.args[0] if self.args else ""


def encode_payload(payload: Any) -> Any:
    """Prepare a register payload for crossing a node (process) boundary:
    device arrays become host numpy arrays, containers are rebuilt, and
    private top-level dict keys (``"__"``-prefixed, e.g. the stashed vjp
    closure a forward actor shares with its same-node backward actor) are
    stripped — they are same-node contracts, never wire format."""
    if isinstance(payload, dict):
        return {k: _encode(v) for k, v in payload.items()
                if not (isinstance(k, str) and k.startswith("__"))}
    return _encode(payload)


def _encode(v: Any) -> Any:
    import numpy as np

    if isinstance(v, dict):
        return {k: _encode(x) for k, x in v.items()}
    if isinstance(v, tuple):
        if hasattr(v, "_fields"):        # NamedTuple (e.g. AdamWState)
            return type(v)(*(_encode(x) for x in v))
        return tuple(_encode(x) for x in v)
    if isinstance(v, list):
        return [_encode(x) for x in v]
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return dataclasses.replace(v, **{
            f.name: _encode(getattr(v, f.name))
            for f in dataclasses.fields(v)})
    try:
        import jax
        if isinstance(v, jax.Array):
            return np.asarray(v)
    except ImportError:        # pragma: no cover - jax is always present here
        pass
    return v


class Runtime:
    """What the executors program against (duck-typed base; the concrete
    runtimes are :class:`repro.runtime.threaded.ThreadedRuntime` and
    :class:`repro.runtime.process.ProcessRuntime`).

    ``run(ctx=, fires=, timeout=)`` executes one epoch and returns the
    collected outputs (a flat list for a single collected actor, else
    ``{name: [outputs...]}``). After each run the instrumentation of the
    epoch is available as ``last_history`` (per-actor action intervals),
    ``last_peak_regs`` (per-actor peak out-registers in use),
    ``last_edge_bytes`` (``{(producer, consumer): bytes}`` traffic) and
    ``last_fired`` (per-actor fire counts). ``close()`` releases workers.
    """

    last_history: Dict[str, List[Tuple[float, float]]]
    last_peak_regs: Dict[str, int]
    last_edge_bytes: Dict[Tuple[str, str], int]
    last_fired: Dict[str, int]

    def run(self, ctx: Optional[Dict[str, Any]] = None,
            fires: Optional[Dict[str, int]] = None,
            timeout: float = 120.0):
        raise NotImplementedError

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def _check_epoch_names(specs, ctx, fires) -> None:
    known = {s.name for s in specs}
    for what, d in (("ctx", ctx), ("fires", fires)):
        for name in (d or {}):
            if name not in known:
                raise ValueError(
                    f"{what} names unknown actor {name!r}; "
                    f"actors: {sorted(known)}")


def make_runtime(kind: str, builder: SpecBuilder,
                 collect_outputs_of=None, faults=None,
                 trace=None) -> Runtime:
    """Build a runtime of ``kind`` over the actor graph ``builder`` yields.

    ``"threads"`` calls the builder in-process and drives every actor on OS
    threads; ``"processes"`` ships the (picklable) builder to one worker
    process per node id. ``collect_outputs_of`` overrides the builder's own
    collect choice when given. ``faults`` is an optional
    :class:`repro.runtime.chaos.FaultPlan` injected deterministically into
    the engines (kill-at-fire, delayed/duplicated Reqs, dropped Acks).
    ``trace`` is an optional :class:`repro.analysis.trace.TraceRecorder`
    capturing every Req delivery (and applied fault) for the trace
    sanitizer — threads runtime only, since the recorder is shared mutable
    state the worker processes could not see.
    """
    if kind not in RUNTIME_KINDS:
        raise ValueError(
            f"unknown runtime {kind!r}; expected one of {RUNTIME_KINDS}")
    if kind == "threads":
        from repro.runtime.threaded import ThreadedRuntime
        specs, collect = builder()
        if collect_outputs_of is not None:
            collect = collect_outputs_of
        return ThreadedRuntime(specs, collect_outputs_of=collect,
                               faults=faults, trace=trace)
    if trace is not None:
        raise ValueError(
            "trace= requires runtime='threads' (deliveries happen inside "
            "worker processes the recorder cannot observe)")
    from repro.runtime.process import ProcessRuntime
    return ProcessRuntime(builder, collect_outputs_of=collect_outputs_of,
                          faults=faults)
