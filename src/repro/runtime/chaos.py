"""Deterministic fault injection on the Runtime seam (elastic-training gate).

The paper's actor model claims the register/counter protocol — not timing
luck — carries correctness: every dependency (data, resources, movement) is
an explicit counter, so a delayed, duplicated or reordered message must
never change *what* is computed, only *when*. This module turns that claim
into exercised code: a picklable :class:`FaultPlan` rides into either
runtime through ``make_runtime(kind, builder, faults=...)`` and a
:class:`FaultInjector` applies the faults deterministically:

* :class:`KillWorker` — raise :class:`WorkerKilled` (threads) or hard-exit
  the worker process (processes, ``os._exit``) immediately before the named
  actor's Nth fire. Exercises the PR-6 ``WorkerError``/dead-worker/Mattern
  machinery and the snapshot-restore path end to end.
* :class:`DelayEdge` — deliver one ``Req`` on a named edge late. Sound by
  construction: the producer's register stays referenced until the consumer
  acks, so the epoch cannot conclude under a delayed message (the Mattern
  probe sees ``live > 0`` / unbalanced counters).
* :class:`DuplicateReq` — deliver one ``Req`` twice. The consumer-side
  per-channel resequencer (:meth:`repro.runtime.actor.Actor.on_req`) drops
  the second copy *without* acking it, so the producer's refcount stays
  consistent.
* :class:`DropAck` — swallow one ``Ack``. The producer's register is never
  recycled, so a quota-bound producer wedges and the epoch surfaces as the
  runtime's ``TimeoutError`` naming the stuck actor — a *detected* fault,
  never silent corruption.

Faults are one-shot: each entry triggers at most once per injector (per
worker process under ``runtime="processes"`` — routing happens only at the
sending engine, so a fault still applies exactly once per edge).

Delayed delivery runs on a daemon ``threading.Timer``. A timer that
outlives its epoch (possible only after the epoch was already abandoned by
timeout/error) drops its message instead of poisoning the next epoch: the
timer captures the epoch counter and the epoch's own mailbox table.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple, Union

from repro.runtime.base import WorkerError
from repro.runtime.messages import Req

#: Exit code a process worker dies with under :class:`KillWorker` — the
#: driver's liveness probe reports it in the ``WorkerError`` message.
KILL_EXIT_CODE = 57


class WorkerKilled(WorkerError):
    """A :class:`KillWorker` fault fired under ``runtime="threads"``.

    Subclasses :class:`WorkerError` so kill-and-resume callers catch one
    exception type for both runtimes (process workers die for real and
    surface as the ordinary dead-worker ``WorkerError``).
    """


@dataclasses.dataclass(frozen=True)
class KillWorker:
    """Kill the worker hosting ``actor`` immediately before its Nth fire
    (``fire`` is 1-based and cumulative across epochs/steps)."""

    actor: str
    fire: int = 1


@dataclasses.dataclass(frozen=True)
class DelayEdge:
    """Hold the ``Req`` for ``version`` on edge ``src -> dst`` for
    ``seconds`` before delivering it (``version=None``: the first Req seen
    on the edge)."""

    src: str
    dst: str
    seconds: float = 0.05
    version: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class DuplicateReq:
    """Deliver the ``Req`` for ``version`` on edge ``src -> dst`` twice."""

    src: str
    dst: str
    version: int = 0


@dataclasses.dataclass(frozen=True)
class DropAck:
    """Swallow the ``Ack`` for ``version`` on edge ``src -> dst`` (``src``
    is the consumer sending the ack, ``dst`` the producer awaiting it)."""

    src: str
    dst: str
    version: int = 0


Fault = Union[KillWorker, DelayEdge, DuplicateReq, DropAck]


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic, picklable set of faults to inject into one run."""

    faults: Tuple[Fault, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))
        kinds = (KillWorker, DelayEdge, DuplicateReq, DropAck)
        for f in self.faults:
            if not isinstance(f, kinds):
                raise ValueError(f"unknown fault type: {f!r}")

    @property
    def kills(self) -> Tuple[KillWorker, ...]:
        return tuple(f for f in self.faults if isinstance(f, KillWorker))


class FaultInjector:
    """Applies a :class:`FaultPlan` inside one ``_LocalEngine``.

    The engine calls :meth:`before_fire` under the firing actor's thread and
    :meth:`route` for every outgoing message; both are cheap no-ops once
    every fault has triggered. One injector per engine — under
    ``runtime="processes"`` each worker builds its own from the shipped
    plan, and a fault naming a remote actor/edge simply never matches
    there.
    """

    def __init__(self, plan: FaultPlan, process_mode: bool = False):
        self.plan = plan
        self.process_mode = process_mode
        self._fired = {}        # actor name -> cumulative fire attempts
        self._done = set()      # indices of consumed (one-shot) faults
        self._armed = len(plan.faults) > 0
        # optional repro.analysis.trace.TraceRecorder: applied faults are
        # logged so the trace sanitizer can report what the run absorbed
        self.recorder = None

    def _record(self, fault, msg) -> None:
        if self.recorder is not None:
            self.recorder.record_fault(
                type(fault).__name__, fault.src, fault.dst,
                getattr(msg, "version", None))

    # -- fire-path faults --------------------------------------------------------
    def before_fire(self, name: str) -> None:
        """Called immediately before actor ``name`` fires; may not return."""
        if not self._armed:
            return
        n = self._fired.get(name, 0) + 1
        self._fired[name] = n
        for i, f in enumerate(self.plan.faults):
            if i in self._done or not isinstance(f, KillWorker):
                continue
            if f.actor == name and f.fire == n:
                self._done.add(i)
                if self.process_mode:
                    # a real worker death: no unwind, no goodbye — the
                    # driver's liveness probe must catch it
                    os._exit(KILL_EXIT_CODE)
                raise WorkerKilled(
                    f"fault injection: killed worker at {name} fire {n}",
                    node=None)

    # -- message-path faults -----------------------------------------------------
    def route(self, msg, src_name: str, dst_name: str):
        """Map one outgoing message to ``[(message, delay_seconds), ...]``
        (empty list: dropped). Called at the *sending* engine only."""
        out = [(msg, 0.0)]
        if not self._armed:
            return out
        is_req = isinstance(msg, Req)
        for i, f in enumerate(self.plan.faults):
            if i in self._done:
                continue
            if isinstance(f, DelayEdge) and is_req:
                if (f.src == src_name and f.dst == dst_name
                        and (f.version is None or f.version == msg.version)):
                    self._done.add(i)
                    self._record(f, msg)
                    out = [(m, d + f.seconds) for m, d in out]
            elif isinstance(f, DuplicateReq) and is_req:
                if (f.src == src_name and f.dst == dst_name
                        and f.version == msg.version):
                    self._done.add(i)
                    self._record(f, msg)
                    out = out + [(msg, 0.0)]
            elif isinstance(f, DropAck) and not is_req:
                # Ack direction: consumer (src) -> producer (dst)
                if (f.src == src_name and f.dst == dst_name
                        and f.version == msg.version):
                    self._done.add(i)
                    self._record(f, msg)
                    out = []
        return out
