"""Process-backed actor runtime — one worker process per node id (paper §5).

The 64-bit actor address (:mod:`repro.runtime.messages`) encodes a *node*
field; here it stops being notation: :class:`ProcessRuntime` spawns one
worker process per distinct node id in the spec graph, each running a
:class:`repro.runtime.threaded._LocalEngine` over its own (node, thread)
keys. Same-node reqs keep their zero-copy in-process ``payload``; a req
crossing nodes has its payload serialized as host arrays
(:func:`repro.runtime.base.encode_payload`) and travels a real transport
(multiprocessing queues). The actor protocol itself is byte-for-byte the one
the threaded runtime speaks — workers coordinate purely by req/ack, with no
central scheduler (§5's "no middleman" claim).

Spec graphs are shipped as a *picklable builder* (called once in the parent
for metadata, once in each worker), so closures holding jax arrays or
traced functions never cross the process boundary — each worker lowers and
jit-compiles only the stages that actually fire on its node.

Distributed termination detection: each worker reports local quiescence
*transitions* (``pending == 0 and live == 0``, see the counter discipline in
:mod:`repro.runtime.threaded`) on its FIFO channel to the driver. The driver
concludes an epoch when every node's latest report is quiescent and every
collected actor delivered its expected output count. This is sound because a
req in flight to node B implies its sender still holds a live (unacked)
register, so the *sender's* latest report is non-quiescent — the driver can
never conclude while protocol messages are outstanding.

Epoch hygiene: every protocol message is epoch-tagged. Workers buffer
messages that race ahead of the driver's epoch broadcast and drop stale
ones, so a timed-out epoch cannot poison the next.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import pickle
import queue
import time
import traceback
from typing import Any, Dict, List, Optional, Tuple

from repro.runtime.base import (RemoteTraceback, Runtime, SpecBuilder,
                                WorkerError, _check_epoch_names, _encode,
                                encode_payload)
from repro.runtime.messages import Req, node_of


def _worker_main(node: int, builder, collect_names, inbox, driver_q,
                 peer_queues, faults=None) -> None:
    """Entry point of one node's worker process (module-level: spawn pickles
    the function by reference)."""
    state = {"epoch": 0, "sent": 0, "recv": 0}
    try:
        import threading

        from repro.launch.xla_env import apply_worker_env
        apply_worker_env(node)
        from repro.runtime.threaded import _LocalEngine

        specs, _ = builder()
        local_keys = sorted({(s.node, s.thread) for s in specs
                             if s.node == node})
        engine = _LocalEngine(specs, local_keys=local_keys)
        engine.collect_names = set(collect_names)
        if faults is not None:
            from repro.runtime.chaos import FaultInjector
            # each worker routes only the messages its own actors originate,
            # so every fault still applies exactly once graph-wide; a
            # KillWorker here hard-exits this process (os._exit) and the
            # driver's liveness probe turns that into a WorkerError
            engine.fault_injector = FaultInjector(faults, process_mode=True)
        sent_lock = threading.Lock()

        def send_remote(msg):
            if isinstance(msg, Req):
                msg = dataclasses.replace(
                    msg, payload=encode_payload(msg.payload))
            # count BEFORE the message can possibly be received: the probe
            # sums (see ProcessRuntime) rely on sent >= recv at all times
            with sent_lock:
                state["sent"] += 1
            peer_queues[node_of(msg.dst)].put(("msg", state["epoch"], msg))

        def on_output(name, value, version):
            driver_q.put(("out", state["epoch"], node, name,
                          encode_payload(value), version))

        def on_quiescence(flag):
            driver_q.put(("q", state["epoch"], node, flag))

        def on_error(exc, key):
            driver_q.put(("error", state["epoch"], node,
                          type(exc).__name__, str(exc),
                          "".join(traceback.format_exception(exc))))

        engine.send_remote = send_remote
        engine.on_output = on_output
        engine.on_quiescence = on_quiescence
        engine.on_error = on_error
        driver_q.put(("ready", node))

        held: List[Tuple[int, Any]] = []  # msgs that raced the epoch bcast
        while True:
            item = inbox.get()
            kind = item[0]
            if kind == "stop":
                engine.stop_workers()
                return
            if kind == "epoch":
                _, e, ctx, fires = item
                engine.stop_workers()
                engine.join_workers(1.0)
                state["epoch"] = e
                with sent_lock:
                    state["sent"] = 0
                state["recv"] = 0
                engine.start_epoch(ctx, fires)
                replay = [m for ee, m in held if ee == e]
                held = [(ee, m) for ee, m in held if ee > e]
                for m in replay:
                    state["recv"] += 1
                    engine.deliver(m)
            elif kind == "msg":
                _, e, m = item
                if e == state["epoch"]:
                    state["recv"] += 1
                    # deliver, not post: the message was already fault-routed
                    # at the sending worker's engine
                    engine.deliver(m)
                elif e > state["epoch"]:
                    held.append((e, m))
                # e < epoch: stale message from an abandoned epoch — drop
            elif kind == "probe":
                _, e, k = item
                if e == state["epoch"]:
                    with sent_lock:
                        s = state["sent"]
                    driver_q.put(("probe_ack", e, k, node,
                                  engine.quiescent, s, state["recv"]))
            elif kind == "stats":
                _, e = item
                if e == state["epoch"]:
                    engine.stop_workers()
                    engine.join_workers(1.0)
                    driver_q.put(("stats", e, node, engine.snapshot()))
                else:
                    driver_q.put(("stats", e, node, ({}, {}, {}, {})))
    except BaseException as exc:  # noqa: BLE001 — ship everything to driver
        try:
            driver_q.put(("error", state["epoch"], node,
                          type(exc).__name__, str(exc),
                          "".join(traceback.format_exception(exc))))
        except Exception:
            pass


class ProcessRuntime(Runtime):
    """Drive an actor graph across one worker process per node id.

    ``builder`` is a picklable callable returning ``(specs,
    collect_outputs_of)``; ``collect_outputs_of`` here overrides the
    builder's choice. Workers are spawned once in ``__init__`` and reused
    across :meth:`run` epochs; :meth:`close` (or context-manager exit)
    tears them down.
    """

    def __init__(self, builder: SpecBuilder, collect_outputs_of=None,
                 start_timeout: float = 180.0, faults=None):
        try:
            pickle.dumps(builder)
        except Exception as exc:
            raise ValueError(
                "runtime='processes' requires a picklable spec builder (it "
                "is shipped to one worker process per node); pickling "
                f"failed with: {exc!r}") from exc
        specs, default_collect = builder()
        collect = (default_collect if collect_outputs_of is None
                   else collect_outputs_of)
        self._collect_single = collect is None or isinstance(collect, str)
        names = [collect] if self._collect_single else list(collect)
        self._collect_names = [n for n in names if n is not None]
        self._specs = list(specs)
        self._spec_by_name = {s.name: s for s in self._specs}
        for n in self._collect_names:
            if n not in self._spec_by_name:
                raise ValueError(f"collect_outputs_of names unknown actor {n!r}")
        self.nodes = sorted({s.node for s in self._specs})
        ctx = mp.get_context("spawn")
        self._driver_q = ctx.Queue()
        self._node_qs = {n: ctx.Queue() for n in self.nodes}
        self._procs: Dict[int, mp.Process] = {}
        self._epoch = 0
        self._closed = False
        self.last_history: Dict[str, List[Tuple[float, float]]] = {}
        self.last_peak_regs: Dict[str, int] = {}
        self.last_edge_bytes: Dict[Tuple[str, str], int] = {}
        self.last_fired: Dict[str, int] = {}
        from repro.launch.xla_env import worker_env
        for n in self.nodes:
            p = ctx.Process(
                target=_worker_main,
                args=(n, builder, tuple(self._collect_names),
                      self._node_qs[n], self._driver_q, self._node_qs,
                      faults),
                daemon=True)
            # spawn snapshots os.environ at start(): inject the per-worker
            # XLA setup here, before the child's first (jax) import
            overrides = worker_env(n)
            saved = {k: os.environ.get(k) for k in overrides}
            os.environ.update(overrides)
            try:
                p.start()
            finally:
                for k, v in saved.items():
                    if v is None:
                        os.environ.pop(k, None)
                    else:
                        os.environ[k] = v
            self._procs[n] = p
        self._await_ready(start_timeout)

    # -- startup -----------------------------------------------------------------
    def _await_ready(self, timeout: float) -> None:
        ready = set()
        deadline = time.monotonic() + timeout
        while len(ready) < len(self.nodes):
            try:
                item = self._driver_q.get(timeout=0.2)
            except queue.Empty:
                self._check_alive()
                if time.monotonic() > deadline:
                    self.close()
                    raise TimeoutError(
                        "process runtime workers failed to start; missing "
                        f"nodes: {sorted(set(self.nodes) - ready)}")
                continue
            if item[0] == "ready":
                ready.add(item[1])
            elif item[0] == "error":
                self._raise_worker_error(item)

    # -- epoch execution ---------------------------------------------------------
    def run(self, ctx: Optional[Dict[str, Any]] = None,
            fires: Optional[Dict[str, int]] = None,
            timeout: float = 120.0):
        if self._closed:
            raise RuntimeError("process runtime is closed")
        _check_epoch_names(self._specs, ctx, fires)
        ctx = ctx or {}
        fires = fires or {}
        effective = {s.name: fires.get(s.name, s.max_fires)
                     for s in self._specs}
        if not any(v is not None for v in effective.values()):
            raise ValueError("process runtime needs at least one bounded actor")
        self._epoch += 1
        e = self._epoch
        node_of_name = {s.name: s.node for s in self._specs}
        for n in self.nodes:
            ctx_n = {k: _encode(v) for k, v in ctx.items()
                     if node_of_name[k] == n}
            fires_n = {k: v for k, v in fires.items()
                       if node_of_name[k] == n}
            self._node_qs[n].put(("epoch", e, ctx_n, fires_n))
        outputs: Dict[str, List[Any]] = {n: [] for n in self._collect_names}
        qstate: Dict[int, bool] = {}
        stats: Dict[int, Any] = {}
        deadline = time.monotonic() + timeout
        # Termination detection (Mattern four-counter / double-wave method):
        # quiescence-transition reports are only a cheap *trigger*. When the
        # latest report from every node is quiescent, the driver probes all
        # workers; each replies with its current (quiescent, sent, recv)
        # transport counters. The epoch concludes after TWO consecutive
        # probe waves that are all-quiescent with equal and unchanged
        # sum(sent) == sum(recv) — monotone counters make that condition
        # sticky-correct even though per-node replies are not simultaneous.
        # Once concluded, per-process FIFO ordering of the driver queue
        # guarantees every collected output has already been delivered
        # (outputs are enqueued before the fire's counter bump, hence
        # before any later probe reply of that worker).
        probe_k = 0
        awaiting: Optional[int] = None
        acks: Dict[int, Tuple[bool, int, int]] = {}
        prev_sums: Optional[Tuple[int, int]] = None
        done = False
        while not done:
            if (awaiting is None and len(qstate) == len(self.nodes)
                    and all(qstate.values())):
                probe_k += 1
                awaiting = probe_k
                acks = {}
                for n in self.nodes:
                    self._node_qs[n].put(("probe", e, probe_k))
            item = self._poll(e, outputs, qstate, stats, deadline, effective)
            if item is None or item[0] != "probe_ack":
                continue
            _, ee, k, node, quiescent, sent, recv = item
            if ee != e or k != awaiting:
                continue  # stale probe reply
            acks[node] = (quiescent, sent, recv)
            if len(acks) < len(self.nodes):
                continue
            awaiting = None
            if all(a[0] for a in acks.values()):
                s_sum = sum(a[1] for a in acks.values())
                r_sum = sum(a[2] for a in acks.values())
                if s_sum == r_sum and prev_sums == (s_sum, r_sum):
                    done = True
                else:
                    prev_sums = (s_sum, r_sum) if s_sum == r_sum else None
            else:
                prev_sums = None
        for n in self.nodes:
            self._node_qs[n].put(("stats", e))
        while len(stats) < len(self.nodes):
            self._poll(e, outputs, qstate, stats, deadline, effective)
        hist: Dict[str, Any] = {}
        peaks: Dict[str, int] = {}
        edges: Dict[Tuple[str, str], int] = {}
        fired: Dict[str, int] = {}
        for _, (h, p, ed, f) in sorted(stats.items()):
            hist.update(h)
            peaks.update(p)
            edges.update(ed)
            fired.update(f)
        self.last_history, self.last_peak_regs = hist, peaks
        self.last_edge_bytes, self.last_fired = edges, fired
        if self._collect_single:
            return outputs[self._collect_names[0]] if self._collect_names else []
        return outputs

    def _poll(self, e, outputs, qstate, stats, deadline, effective):
        """Handle one driver-queue item; returns it for kinds the caller
        dispatches on itself (probe_ack), None on an empty slice."""
        try:
            item = self._driver_q.get(timeout=0.2)
        except queue.Empty:
            self._check_alive()
            if time.monotonic() > deadline:
                self._raise_timeout(e, effective)
            return None
        kind = item[0]
        if kind == "q":
            _, ee, node, flag = item
            if ee == e:
                qstate[node] = flag
        elif kind == "out":
            _, ee, node, name, value, version = item
            if ee == e:
                outputs[name].append(value)
        elif kind == "stats":
            _, ee, node, snap = item
            if ee == e:
                stats[node] = snap
        elif kind == "error":
            self._raise_worker_error(item)
        return item

    def _raise_worker_error(self, item) -> None:
        _, _, node, tname, msg, tb = item
        self.close()  # the distributed graph state is poisoned — tear down
        raise WorkerError(
            f"worker for node {node} failed: {tname}: {msg}",
            node=node, remote_traceback=tb) from RemoteTraceback(tb)

    def _check_alive(self) -> None:
        dead = [(n, p.exitcode) for n, p in self._procs.items()
                if not p.is_alive()]
        if not dead:
            return
        # a posted error message beats a bare exit code
        try:
            while True:
                item = self._driver_q.get_nowait()
                if item[0] == "error":
                    self._raise_worker_error(item)
        except queue.Empty:
            pass
        n, code = dead[0]
        self.close()
        raise WorkerError(
            f"worker for node {n} died (exit code {code})", node=n)

    def _raise_timeout(self, e, effective) -> None:
        # best-effort fire counts so the error names every unfired actor
        for n in self.nodes:
            self._node_qs[n].put(("stats", e))
        fired: Dict[str, int] = {}
        t_end = time.monotonic() + 3.0
        got = 0
        while got < len(self.nodes) and time.monotonic() < t_end:
            try:
                item = self._driver_q.get(timeout=0.2)
            except queue.Empty:
                continue
            if item[0] == "stats" and item[1] == e:
                got += 1
                fired.update(item[3][3])
        unfired = [f"{name}={fired.get(name, '?')}/{eff}"
                   for name, eff in effective.items()
                   if eff is not None and fired.get(name, -1) != eff]
        raise TimeoutError(
            "process actor runtime did not complete: " + ", ".join(unfired))

    # -- teardown ----------------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for q_ in self._node_qs.values():
            try:
                q_.put(("stop",))
            except Exception:
                pass
        for p in self._procs.values():
            p.join(timeout=2.0)
        for p in self._procs.values():
            if p.is_alive():
                p.terminate()
        for p in self._procs.values():
            p.join(timeout=1.0)
        # SIGKILL stragglers: a worker wedged in native code (or mid-error)
        # can survive terminate(), and an errored runtime must never leak
        # processes past close()
        for p in self._procs.values():
            if p.is_alive():
                p.kill()
                p.join(timeout=1.0)

    def __del__(self):  # best-effort; daemon workers die with the parent anyway
        try:
            self.close()
        except Exception:
            pass
