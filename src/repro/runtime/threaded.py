"""Real threaded actor runtime — actors on OS threads with FIFO mailboxes.

This is the paper's Fig 7 implementation for the *host side* of the JAX
program: data loading, preprocessing, host-to-device staging and step issue
run as actors on dedicated OS threads (one per "hardware queue"), with the
same req/ack + register-quota protocol as the simulator. Because the quota is
enforced, a fast producer (data loader) is back-pressured instead of buffering
unboundedly (§4.3) — this is what `repro.data.pipeline` builds on.

Two pieces live here:

* :class:`_LocalEngine` — drives the *local subset* of an actor graph on OS
  threads. With every key local it IS the threaded runtime's engine; each
  :class:`repro.runtime.process.ProcessRuntime` worker runs one over its own
  node's keys, with cross-node messages diverted through ``send_remote``.
* :class:`ThreadedRuntime` — the :class:`repro.runtime.base.Runtime`
  implementation executors use in-process. Persistent: one instance runs
  many epochs (steps/rounds); actors reset at the *start* of each run so
  their counters stay inspectable afterwards.

Completion is event-driven, not polled. Each engine keeps two lock-protected
counters: ``pending`` (remaining fires of local bounded actors) and ``live``
(local out-register instances not yet fully acked). Both are updated
*before* any ack/req from a fire is posted, so "both zero" (quiescence) can
never be observed while a local actor still owes the graph a message: an
unsent ack means the producer's register is still refcounted, which keeps
the producer's ``live`` non-zero. When every key is local, quiescence is
exactly completion; across processes it feeds the termination protocol in
:mod:`repro.runtime.process`.
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.runtime.actor import Actor, ActorSpec, build_actors
from repro.runtime.base import Runtime, _check_epoch_names
from repro.runtime.messages import Req, node_of, thread_of


def _no_remote(msg) -> None:
    raise RuntimeError(
        f"message for non-local actor {msg.dst:#x} but no remote transport "
        "is attached (send_remote hook unset)")


class _LocalEngine:
    """Drive the local (node, thread) keys of an actor graph on OS threads.

    All actors are *built* (IDs and consumer wiring need the whole graph)
    but only those on ``local_keys`` are run; a message addressed off-node
    goes through the ``send_remote`` hook. Owners attach:

    * ``send_remote(msg)`` — deliver a Req/Ack to a non-local key
    * ``on_output(name, value, version)`` — a collected actor emitted
    * ``on_quiescence(flag)`` — local quiescence changed (called under the
      counter lock, so reports are emitted in transition order)
    * ``on_error(exc, key)`` — a worker thread raised
    """

    def __init__(self, specs: Sequence[ActorSpec],
                 local_keys: Optional[Sequence[Tuple[int, int]]] = None):
        self.specs = list(specs)
        self.by_name, self.by_id = build_actors(self.specs)
        all_keys = sorted({(s.node, s.thread) for s in self.specs})
        if local_keys is None:
            self.local_keys = all_keys
        else:
            wanted = set(local_keys)
            self.local_keys = [k for k in all_keys if k in wanted]
        local = set(self.local_keys)
        self.local_actors: List[Actor] = [
            a for a in self.by_name.values()
            if (a.spec.node, a.spec.thread) in local]
        self.actors_on: Dict[Tuple[int, int], List[Actor]] = \
            collections.defaultdict(list)
        for a in self.local_actors:
            self.actors_on[(a.spec.node, a.spec.thread)].append(a)
        # hooks
        self.send_remote: Callable[[Any], None] = _no_remote
        self.on_output: Optional[Callable[[str, Any, int], None]] = None
        self.on_quiescence: Optional[Callable[[bool], None]] = None
        self.on_error: Optional[Callable[[BaseException, Tuple[int, int]], None]] = None
        self.collect_names: Set[str] = set()
        # optional chaos layer (repro.runtime.chaos.FaultInjector): consulted
        # before every local fire and for every outgoing message
        self.fault_injector = None
        # optional repro.analysis.trace.TraceRecorder: records every Req
        # delivery (version + what the resequencer released) so the static
        # trace sanitizer can certify the run restored canonical order
        self.trace_recorder = None
        # epoch state
        self._epoch = 0
        self._mailboxes: Dict[Tuple[int, int], queue.Queue] = {}
        self._threads: List[threading.Thread] = []
        self._lock = threading.Lock()
        self._pending = 0
        self._live = 0
        self._quiescent = True
        self._stopping = False
        self._t0 = time.perf_counter()

    # -- epoch lifecycle ---------------------------------------------------------
    def start_epoch(self, ctx: Optional[Dict[str, Any]] = None,
                    fires: Optional[Dict[str, int]] = None) -> None:
        """Reset local actors and launch one worker thread per local key.

        ``fires`` overrides per-actor fire bounds for this epoch only;
        ``ctx`` is routed to each actor's ``on_epoch`` hook (hooks with no
        entry still run with ``None`` so per-epoch state resets happen)."""
        ctx = ctx or {}
        fires = fires or {}
        self._epoch += 1
        if self.trace_recorder is not None:
            # resequencer state resets per epoch; the trace sanitizer
            # checks canonical order per (epoch, consumer, channel)
            self.trace_recorder.current_epoch = self._epoch
        self._stopping = False
        for a in self.local_actors:
            a.reset(max_fires=fires.get(a.spec.name))
        # hooks run after every reset: an on_epoch that seeds an upstream
        # cell must not race a half-reset consumer
        for a in self.local_actors:
            if a.spec.on_epoch is not None:
                a.spec.on_epoch(ctx.get(a.spec.name))
        pending = sum(a.max_fires - a.fired for a in self.local_actors
                      if a.max_fires is not None)
        # fresh mailboxes per epoch: anything a previous (timed-out) epoch
        # left queued is unreachable garbage, not a poisoned message
        self._mailboxes = {k: queue.Queue() for k in self.local_keys}
        self._t0 = time.perf_counter()
        with self._lock:
            self._pending = pending
            self._live = 0
            self._quiescent = (pending == 0)
            if self.on_quiescence is not None:
                self.on_quiescence(self._quiescent)
        self._threads = []
        epoch = self._epoch
        for key in self.local_keys:
            t = threading.Thread(target=self._worker, args=(key, epoch),
                                 daemon=True)
            t.start()
            self._threads.append(t)

    def stop_workers(self) -> None:
        self._stopping = True
        for box in self._mailboxes.values():
            box.put(None)

    def join_workers(self, timeout: float = 2.0) -> None:
        for t in self._threads:
            t.join(timeout=timeout)

    def snapshot(self):
        """(history, peak_regs, edge_bytes, fired) of the local actors."""
        hist = {a.spec.name: list(a.history) for a in self.local_actors}
        peaks = {a.spec.name: a.peak_regs_in_use for a in self.local_actors}
        edges = {(a.spec.name, cname): n for a in self.local_actors
                 for cname, n in a.edge_bytes.items()}
        fired = {a.spec.name: a.fired for a in self.local_actors}
        return hist, peaks, edges, fired

    # -- message routing ---------------------------------------------------------
    def post(self, msg) -> None:
        """Route an *originating* message (the sending engine's side).

        With a fault injector attached the message may be delayed,
        duplicated or dropped here; :meth:`deliver` is the fault-free path
        used for messages arriving from another process (already routed at
        their sender)."""
        inj = self.fault_injector
        if inj is None:
            self.deliver(msg)
            return
        src = self.by_id[msg.src].spec.name
        dst = self.by_id[msg.dst].spec.name
        epoch, boxes = self._epoch, self._mailboxes
        for m, delay in inj.route(msg, src, dst):
            if delay > 0:
                t = threading.Timer(delay, self._deliver_late,
                                    args=(m, epoch, boxes))
                t.daemon = True
                t.start()
            else:
                self.deliver(m)

    def deliver(self, msg) -> None:
        box = self._mailboxes.get((node_of(msg.dst), thread_of(msg.dst)))
        if box is not None:
            box.put(msg)
        else:
            self.send_remote(msg)

    def _deliver_late(self, msg, epoch: int, boxes) -> None:
        """Timer callback for a delayed message. A pending delayed Req/Ack
        keeps its producer's register referenced, so the epoch cannot
        conclude before delivery; if the epoch was nevertheless abandoned
        (timeout/error), deliver into the *captured* mailbox table — a
        stale epoch's boxes are unreachable garbage, never poison."""
        if self._epoch != epoch or self._stopping:
            return
        box = boxes.get((node_of(msg.dst), thread_of(msg.dst)))
        if box is not None:
            box.put(msg)
        else:
            self.send_remote(msg)

    # -- counters ----------------------------------------------------------------
    def _bump(self, dpending: int, dlive: int) -> None:
        with self._lock:
            self._pending += dpending
            self._live += dlive
            q = (self._pending == 0 and self._live == 0)
            if q != self._quiescent:
                self._quiescent = q
                if self.on_quiescence is not None:
                    self.on_quiescence(q)

    @property
    def quiescent(self) -> bool:
        with self._lock:
            return self._quiescent

    # -- worker loop -------------------------------------------------------------
    def _worker(self, key: Tuple[int, int], epoch: int) -> None:
        box = self._mailboxes[key]
        try:
            self._fire_ready(key, epoch)
            while True:
                msg = box.get()
                if msg is None or self._epoch != epoch:
                    return
                actor = self.by_id[msg.dst]
                if isinstance(msg, Req):
                    rec = self.trace_recorder
                    if rec is None:
                        actor.on_req(msg)
                    else:
                        self._traced_on_req(rec, actor, msg)
                else:
                    if actor.on_ack(msg):
                        self._bump(0, -1)
                self._fire_ready(key, epoch)
        except BaseException as e:  # surface worker crashes to the owner
            self._stopping = True
            if self.on_error is not None:
                self.on_error(e, key)
            self.stop_workers()

    @staticmethod
    def _traced_on_req(rec, actor: Actor, msg: Req) -> None:
        """Deliver a Req through the resequencer while recording what it
        did: the versions released to the FIFO (empty for a buffered early
        arrival) and whether the message was accepted at all (duplicates
        are dropped without an ack)."""
        ch = msg.channel
        before = actor.in_next.get(ch)
        if before is None:                      # undeclared channel: FIFO
            actor.on_req(msg)
            rec.record_delivery(actor.spec.name, ch, msg.version,
                                (msg.version,), 1)
            return
        pend_before = len(actor.in_pending[ch])
        actor.on_req(msg)
        stride = actor.in_stride[ch]
        released = tuple(range(before, actor.in_next[ch], stride))
        accepted = bool(released) or len(actor.in_pending[ch]) > pend_before
        rec.record_delivery(actor.spec.name, ch, msg.version, released,
                            stride, accepted)

    def _fire_ready(self, key: Tuple[int, int], epoch: int) -> None:
        progressed = True
        while progressed and not self._stopping:
            progressed = False
            for actor in self.actors_on[key]:
                while (actor.ready() and not self._stopping
                       and self._epoch == epoch):
                    if self.fault_injector is not None:
                        # may raise WorkerKilled (threads) or hard-exit the
                        # process (a KillWorker fault)
                        self.fault_injector.before_fire(actor.spec.name)
                    start = time.perf_counter() - self._t0
                    out, acks, reg_id = actor.fire()
                    # wall-clock action history mirrors the simulator's, so
                    # pipeline overlap can be observed on real threads too
                    actor.history.append((start, time.perf_counter() - self._t0))
                    version = actor.version - 1
                    # collect only fires the protocol emitted (emit_every
                    # suppresses all but each k-th output of an acc actor).
                    # Outputs report BEFORE the counter bump: on a shared
                    # FIFO channel the epoch's last output then provably
                    # precedes the quiescent-transition report.
                    if (actor.spec.name in self.collect_names
                            and actor.emitted_last_fire
                            and self.on_output is not None):
                        self.on_output(actor.spec.name, out, version)
                    # counters move BEFORE the fire's messages go out —
                    # completion must be unobservable while acks are unsent
                    self._bump(-1 if actor.max_fires is not None else 0,
                               1 if reg_id != -1 else 0)
                    for ack in acks:
                        self.post(ack)
                    if reg_id != -1:
                        for req in actor.emit_reqs(out, reg_id, version):
                            self.post(req)
                    progressed = True


class ThreadedRuntime(Runtime):
    """Drive a graph of :class:`ActorSpec`s on OS threads, in-process.

    ``collect_outputs_of`` names the actor(s) whose outputs :meth:`run`
    returns: a single name yields a flat list (fire order), a sequence of
    names yields ``{name: [outputs...]}`` — the training pipeline collects
    the loss stream and every optimizer actor at once.

    Persistent: one instance serves many :meth:`run` epochs. Actors reset at
    the *start* of the next run, so ``by_name`` counters (fired, out_counter,
    peak_regs_in_use) remain inspectable after a run — the zero-consumer and
    data-pipeline tests rely on that.
    """

    def __init__(self, specs: Sequence[ActorSpec],
                 collect_outputs_of=None, faults=None, trace=None):
        self._engine = _LocalEngine(specs)
        if faults is not None:
            from repro.runtime.chaos import FaultInjector
            self._engine.fault_injector = FaultInjector(faults)
        if trace is not None:
            # a repro.analysis.trace.TraceRecorder; the injector (if any)
            # also reports which faults it actually applied
            self._engine.trace_recorder = trace
            if self._engine.fault_injector is not None:
                self._engine.fault_injector.recorder = trace
        self.by_name = self._engine.by_name
        self.by_id = self._engine.by_id
        self._collect_single = (collect_outputs_of is None
                                or isinstance(collect_outputs_of, str))
        names = ([collect_outputs_of] if self._collect_single else
                 list(collect_outputs_of))
        self._collect_names = {n for n in names if n is not None}
        self._engine.collect_names = self._collect_names
        self._engine.on_output = self._on_output
        self._engine.on_quiescence = self._on_quiescence
        self._engine.on_error = self._on_error
        self.outputs: List[Any] = []
        self.outputs_by_name: Dict[str, List[Any]] = {
            n: [] for n in self._collect_names}
        self._outputs_lock = threading.Lock()
        self._wake = threading.Event()
        self._errors: List[Tuple[BaseException, Tuple[int, int]]] = []
        self.last_history: Dict[str, List[Tuple[float, float]]] = {}
        self.last_peak_regs: Dict[str, int] = {}
        self.last_edge_bytes: Dict[Tuple[str, str], int] = {}
        self.last_fired: Dict[str, int] = {}

    # -- engine hooks ------------------------------------------------------------
    def _on_output(self, name: str, value: Any, version: int) -> None:
        with self._outputs_lock:
            self.outputs_by_name[name].append(value)
            if self._collect_single:
                self.outputs.append(value)

    def _on_quiescence(self, q: bool) -> None:
        if q:
            self._wake.set()

    def _on_error(self, exc: BaseException, key: Tuple[int, int]) -> None:
        self._errors.append((exc, key))
        self._wake.set()

    # -- public API --------------------------------------------------------------
    def run(self, ctx: Optional[Dict[str, Any]] = None,
            fires: Optional[Dict[str, int]] = None,
            timeout: float = 120.0):
        """Run one epoch until every bounded actor has exhausted its fires.

        ``ctx`` feeds per-actor ``on_epoch`` hooks (per-step batches, params
        to load, a serve round's work list); ``fires`` overrides fire bounds
        for this epoch. Returns the collected outputs: a flat list when a
        single actor name was given, else ``{name: [outputs...]}``.
        """
        _check_epoch_names(self._engine.specs, ctx, fires)
        fires = fires or {}
        effective = {s.name: fires.get(s.name, s.max_fires)
                     for s in self._engine.specs}
        if not any(v is not None for v in effective.values()):
            raise ValueError("threaded runtime needs at least one bounded actor")
        self.outputs = []
        self.outputs_by_name = {n: [] for n in self._collect_names}
        self._errors = []
        self._wake.clear()
        self._engine.start_epoch(ctx, fires)
        deadline = time.monotonic() + timeout
        while True:
            if self._errors or self._engine.quiescent:
                break
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            self._wake.wait(remaining)
            self._wake.clear()
        self._engine.stop_workers()
        self._engine.join_workers(2.0)
        (self.last_history, self.last_peak_regs,
         self.last_edge_bytes, self.last_fired) = self._engine.snapshot()
        if self._errors:
            exc, key = self._errors[0]
            if hasattr(exc, "add_note"):  # py3.11+
                exc.add_note(f"raised in actor worker thread "
                             f"(node={key[0]}, thread={key[1]})")
            # re-raise with the worker thread's original traceback attached
            raise exc
        bounded = [a for a in self._engine.local_actors
                   if a.max_fires is not None]
        if not all(a.exhausted for a in bounded):
            raise TimeoutError(
                "threaded actor runtime did not complete: "
                + ", ".join(f"{a.spec.name}={a.fired}/{a.max_fires}"
                            for a in bounded if not a.exhausted))
        return self.outputs if self._collect_single else self.outputs_by_name

    def close(self) -> None:
        self._engine.stop_workers()
        self._engine.join_workers(0.5)
