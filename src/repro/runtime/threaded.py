"""Real threaded actor runtime — actors on OS threads with FIFO mailboxes.

This is the paper's Fig 7 implementation for the *host side* of the JAX
program: data loading, preprocessing, host-to-device staging and step issue
run as actors on dedicated OS threads (one per "hardware queue"), with the
same req/ack + register-quota protocol as the simulator. Because the quota is
enforced, a fast producer (data loader) is back-pressured instead of buffering
unboundedly (§4.3) — this is what `repro.data.pipeline` builds on.
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.runtime.actor import Actor, ActorSpec, build_actors
from repro.runtime.messages import Ack, Req, thread_of, node_of


class ThreadedRuntime:
    """Drive a graph of :class:`ActorSpec`s on OS threads.

    ``collect_outputs_of`` names the actor(s) whose outputs :meth:`run`
    returns: a single name yields a flat list (fire order), a sequence of
    names yields ``{name: [outputs...]}`` — the training pipeline collects
    the loss stream and every optimizer actor at once.
    """

    def __init__(self, specs: Sequence[ActorSpec],
                 collect_outputs_of=None):
        self.by_name, self.by_id = build_actors(specs)
        self._collect_single = (collect_outputs_of is None
                                or isinstance(collect_outputs_of, str))
        names = ([collect_outputs_of] if self._collect_single else
                 list(collect_outputs_of))
        self._collect_names = {n for n in names if n is not None}
        self.outputs: List[Any] = []
        self.outputs_by_name: Dict[str, List[Any]] = {
            n: [] for n in self._collect_names}
        self._outputs_lock = threading.Lock()
        # one mailbox + worker per (node, thread)
        keys = sorted({(s.node, s.thread) for s in (a.spec for a in self.by_name.values())})
        self.mailboxes: Dict[Tuple[int, int], queue.Queue] = {
            k: queue.Queue() for k in keys}
        self.actors_on: Dict[Tuple[int, int], List[Actor]] = collections.defaultdict(list)
        for a in self.by_name.values():
            self.actors_on[(a.spec.node, a.spec.thread)].append(a)
        self._done = threading.Event()
        self._threads: List[threading.Thread] = []
        self._errors: List[BaseException] = []
        self._t0 = time.perf_counter()
        self._consumed = False

    @property
    def consumed(self) -> bool:
        """True once :meth:`run` has been called — the actors are spent and
        this instance cannot run again (callers rebuild instead)."""
        return self._consumed

    def _key_of(self, actor_id: int) -> Tuple[int, int]:
        return (node_of(actor_id), thread_of(actor_id))

    def _post(self, msg) -> None:
        self.mailboxes[self._key_of(msg.dst)].put(msg)

    def _fire_ready(self, key) -> None:
        progressed = True
        while progressed and not self._done.is_set():
            progressed = False
            for actor in self.actors_on[key]:
                while actor.ready():
                    start = time.perf_counter() - self._t0
                    out, acks, reg_id = actor.fire()
                    # wall-clock action history mirrors the simulator's, so
                    # pipeline overlap can be observed on real threads too
                    actor.history.append((start, time.perf_counter() - self._t0))
                    version = actor.version - 1
                    # collect only fires the protocol emitted (emit_every
                    # suppresses all but each k-th output of an acc actor)
                    if (actor.spec.name in self._collect_names
                            and actor.emitted_last_fire):
                        with self._outputs_lock:
                            self.outputs_by_name[actor.spec.name].append(out)
                            if self._collect_single:
                                self.outputs.append(out)
                    for ack in acks:
                        self._post(ack)
                    if reg_id != -1:
                        for req in actor.emit_reqs(out, reg_id, version):
                            self._post(req)
                    progressed = True

    def _worker(self, key) -> None:
        box = self.mailboxes[key]
        try:
            self._fire_ready(key)
            while not self._done.is_set():
                try:
                    msg = box.get(timeout=0.05)
                except queue.Empty:
                    continue
                if msg is None:
                    return
                actor = self.by_id[msg.dst]
                if isinstance(msg, Req):
                    actor.on_req(msg)
                else:
                    actor.on_ack(msg)
                self._fire_ready(key)
        except BaseException as e:  # surface worker crashes to the caller
            self._errors.append(e)
            self._done.set()

    def run(self, timeout: float = 120.0):
        """Run until every bounded actor has exhausted its fires.

        Returns the collected outputs: a flat list when a single actor name
        was given, else ``{name: [outputs...]}``.

        Single-use: actors are consumable state machines (their fire counts
        and register refcounts are spent by the run), so a second ``run()``
        on the same instance raises — build a fresh :class:`ThreadedRuntime`
        per run, as the per-step executors do.
        """
        if self._consumed:
            raise RuntimeError(
                "runtime already consumed: ThreadedRuntime.run() is "
                "single-use (actors are spent state machines); build a new "
                "ThreadedRuntime per run")
        self._consumed = True
        bounded = [a for a in self.by_name.values() if a.spec.max_fires is not None]
        if not bounded:
            raise ValueError("threaded runtime needs at least one bounded actor")
        self._t0 = time.perf_counter()
        for key in self.mailboxes:
            t = threading.Thread(target=self._worker, args=(key,), daemon=True)
            t.start()
            self._threads.append(t)
        deadline = time.time() + timeout
        while time.time() < deadline:
            if self._errors:
                break
            if all(a.exhausted for a in bounded) and all(
                    not a.refcount for a in self.by_name.values()):
                break
            time.sleep(0.002)
        self._done.set()
        for t in self._threads:
            t.join(timeout=2.0)
        if self._errors:
            raise self._errors[0]
        if not all(a.exhausted for a in bounded):
            raise TimeoutError(
                "threaded actor runtime did not complete: "
                + ", ".join(f"{a.spec.name}={a.fired}/{a.spec.max_fires}"
                            for a in bounded if not a.exhausted))
        return self.outputs if self._collect_single else self.outputs_by_name
