"""Pipeline-parallel schedules from register quotas (paper §4.3, §6.5).

The paper's key observation: a synchronous pipeline schedule is not a special
scheduler — it *emerges* from out-register quotas. A stage's forward actor
output register is referenced by BOTH the next stage's forward AND this
stage's backward (the stashed activation); it is recycled only when both have
acked. Capping the quota at ``R`` bounds in-flight microbatches to ``R``:

* ``R = num_microbatches``  -> GPipe-style all-forward-then-backward memory;
* ``R = num_stages - stage``-> 1F1B steady state (Megatron's schedule);
* ``R = 1``                 -> fully serialized (no pipelining).

:func:`pipeline_specs` builds the actor graph; :func:`plan_registers` is the
compile-time resource planner: it simulates quotas and picks the smallest one
within ``tolerance`` of the best makespan — this is the "resource planning at
compile time" the paper argues for (§2.3), done with the actor model itself.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.runtime.actor import ActorSpec
from repro.runtime.scheduler import CommModel, SimResult, simulate
from repro.runtime.threaded import ThreadedRuntime


def pipeline_specs(num_stages: int, num_microbatches: int,
                   fwd_time: float = 1.0, bwd_time: float = 2.0,
                   regs: Optional[Sequence[int]] = None,
                   act_nbytes: int = 1 << 20) -> List[ActorSpec]:
    """Actor graph for a synchronous fwd/bwd pipeline over ``num_stages``
    devices. ``regs[s]`` is stage s's activation register quota."""
    if regs is None:
        regs = [num_stages - s for s in range(num_stages)]  # 1F1B default
    specs: List[ActorSpec] = []
    specs.append(ActorSpec(
        name="data", fn=lambda *a: 0, inputs=(), out_regs=2,
        node=0, thread=0, duration=fwd_time * 0.1,
        max_fires=num_microbatches, out_nbytes=act_nbytes))
    for s in range(num_stages):
        fwd_in = "data" if s == 0 else f"f{s-1}"
        # forward actor on device/thread s
        specs.append(ActorSpec(
            name=f"f{s}", fn=lambda *a: 0, inputs=(fwd_in,),
            out_regs=max(1, regs[s]), node=0, thread=s + 1,
            duration=fwd_time, max_fires=num_microbatches,
            out_nbytes=act_nbytes))
    for s in reversed(range(num_stages)):
        # backward actor: consumes stashed activation f{s} and upstream grad
        ins = (f"f{s}",) if s == num_stages - 1 else (f"f{s}", f"b{s+1}")
        specs.append(ActorSpec(
            name=f"b{s}", fn=lambda *a: 0, inputs=ins,
            out_regs=2, node=0, thread=s + 1,
            duration=bwd_time, max_fires=num_microbatches,
            out_nbytes=act_nbytes))
    # optimizer actor per stage consuming the gradient stream
    for s in range(num_stages):
        specs.append(ActorSpec(
            name=f"opt{s}", fn=lambda *a: 0, inputs=(f"b{s}",),
            out_regs=1, node=0, thread=s + 1, duration=0.01,
            max_fires=num_microbatches))
    return specs


@dataclasses.dataclass
class PipelinePlan:
    regs: List[int]
    makespan: float
    peak_activation_regs: Dict[str, int]
    bubble_fraction: float


def analyze(num_stages: int, num_microbatches: int, regs: Sequence[int],
            fwd_time: float = 1.0, bwd_time: float = 2.0) -> PipelinePlan:
    specs = pipeline_specs(num_stages, num_microbatches, fwd_time, bwd_time,
                           list(regs))
    res = simulate(specs, comm=CommModel(same_node=0.0, cross_node_latency=0.0))
    if res.deadlocked:
        raise RuntimeError(f"pipeline deadlocked with regs={list(regs)}")
    ideal = num_microbatches * (fwd_time + bwd_time)
    bubble = 1.0 - ideal / res.makespan if res.makespan > 0 else 0.0
    return PipelinePlan(
        regs=list(regs), makespan=res.makespan,
        peak_activation_regs={f"f{s}": res.peak_regs[f"f{s}"]
                              for s in range(num_stages)},
        bubble_fraction=max(0.0, bubble))


def plan_registers(num_stages: int, num_microbatches: int,
                   fwd_time: float = 1.0, bwd_time: float = 2.0,
                   tolerance: float = 0.02) -> PipelinePlan:
    """Compile-time resource planning: smallest uniform quota whose makespan
    is within ``tolerance`` of the best observed — memory saved for free."""
    best: Optional[PipelinePlan] = None
    plans = []
    for r in range(1, num_microbatches + 1):
        p = analyze(num_stages, num_microbatches, [r] * num_stages,
                    fwd_time, bwd_time)
        plans.append(p)
        if best is None or p.makespan < best.makespan:
            best = p
        if r >= num_stages and p.makespan <= best.makespan * (1 + 1e-9):
            break  # saturated: more registers cannot help
    target = best.makespan * (1 + tolerance)
    for p in plans:
        if p.makespan <= target:
            return p
    return best


# ---------------------------------------------------------------------------
# Actor-driven execution of lowered stage programs (compiler ∘ runtime).
#
# This is the seam the paper argues for: the compiler's per-stage jitted
# callables (repro.core.lowering.lower_stages) become real ActorSpec.fn
# bodies. One actor per stage, on its own OS thread; microbatch payloads flow
# through Req.payload as {tensor name: value} dicts along the stage chain;
# out-register quotas alone bound in-flight microbatches, so 1F1B-style
# overlap *emerges* (§4.3) instead of being scheduled explicitly.
# ---------------------------------------------------------------------------

def stage_actor_specs(staged, inputs: Dict[str, Any],
                      microbatch_inputs: Sequence[str],
                      num_microbatches: int,
                      regs: Optional[Sequence[int]] = None,
                      fn_wrap: Optional[Callable[[int, Callable], Callable]] = None,
                      ) -> Tuple[List[ActorSpec], str]:
    """Build the actor graph executing ``staged`` over microbatches.

    ``staged`` is a :class:`repro.core.lowering.StagedProgram`. ``inputs``
    maps every graph-input name to its value; names in ``microbatch_inputs``
    are split into ``num_microbatches`` equal chunks along axis 0 and streamed
    by a source actor, the rest (weights) are bound to their stages at build
    time. ``regs[s]`` is stage s's out-register quota (default: 1F1B,
    ``num_stages - s``). ``fn_wrap(stage_index, fn)`` optionally decorates
    each stage body (benchmarks use it to emulate device latency).

    Returns ``(specs, final_stage_name)`` — collect the final stage's outputs
    to reassemble the sinks.
    """
    import numpy as np

    S = staged.num_stages
    if regs is None:
        regs = [max(1, S - s) for s in range(S)]
    if len(regs) != S:
        raise ValueError(f"need {S} register quotas, got {len(regs)}")
    missing = [n for n in staged.input_names if n not in inputs]
    if missing:
        raise ValueError(f"missing graph inputs: {missing}")
    mb_names = list(microbatch_inputs)
    for n in mb_names:
        if n not in staged.input_names:
            raise ValueError(f"{n} is not a graph input")
        if inputs[n].shape[0] % num_microbatches:
            raise ValueError(
                f"input {n} axis 0 ({inputs[n].shape[0]}) not divisible by "
                f"num_microbatches={num_microbatches}")

    # pre-split the streamed inputs: source actor emits payload dict k
    payloads = [dict() for _ in range(num_microbatches)]
    for n in mb_names:
        for k, chunk in enumerate(np.split(np.asarray(inputs[n]),
                                           num_microbatches, axis=0)):
            payloads[k][n] = chunk

    # which payload entries each stage must forward to later consumers: any
    # tensor needed by a stage after s still travels the chain at s's output
    graph_inputs = set(staged.input_names)
    needed_after: List[set] = [set() for _ in range(S + 1)]
    sink_names = {t.name for t in staged.sinks}
    for s in reversed(range(S)):
        payload_borne = {n for n in staged.stages[s].input_names
                         if n in mb_names or n not in graph_inputs}
        needed_after[s] = needed_after[s + 1] | payload_borne

    specs: List[ActorSpec] = []
    specs.append(ActorSpec(
        name="data", fn=lambda version: payloads[version], inputs=(),
        out_regs=2, node=0, thread=0, max_fires=num_microbatches,
        wants_version=True))

    def make_stage_fn(stage, bound):
        def run_stage(payload):
            incoming = stage.place_inputs(
                [bound[n] if n in bound else payload[n]
                 for n in stage.input_names])
            outs = stage.fn(*incoming)
            import jax
            outs = jax.block_until_ready(outs)
            carried = {n: v for n, v in payload.items()
                       if n in needed_after[stage.index + 1] or n in sink_names}
            carried.update(zip(stage.output_names, outs))
            return carried
        return run_stage

    for s, stage in enumerate(staged.stages):
        # weights and other non-streamed graph inputs are bound at build time;
        # everything else arrives in the payload dict (microbatch chunks and
        # boundary tensors from earlier stages)
        bound = {n: inputs[n] for n in stage.input_names
                 if n in graph_inputs and n not in mb_names}
        fn = make_stage_fn(stage, bound)
        if fn_wrap is not None:
            fn = fn_wrap(s, fn)
        specs.append(ActorSpec(
            name=f"stage{s}", fn=fn,
            inputs=("data",) if s == 0 else (f"stage{s-1}",),
            out_regs=max(1, regs[s]), node=0, thread=s + 1,
            max_fires=num_microbatches))
    return specs, f"stage{S - 1}"


class ActorPipelineExecutor:
    """Run a :class:`StagedProgram` on the threaded actor runtime.

    Each call builds a fresh actor graph (actors are single-use state
    machines), streams ``num_microbatches`` chunks through it, and
    reassembles the graph sinks by concatenating per-microbatch results along
    axis 0. ``last_makespan`` / ``last_history`` expose the wall-clock
    schedule of the most recent run.
    """

    def __init__(self, staged, microbatch_inputs: Sequence[str],
                 num_microbatches: int, regs: Optional[Sequence[int]] = None,
                 fn_wrap: Optional[Callable] = None):
        self.staged = staged
        self.microbatch_inputs = list(microbatch_inputs)
        self.num_microbatches = num_microbatches
        self.regs = regs
        self.fn_wrap = fn_wrap
        self.last_makespan: Optional[float] = None
        self.last_history: Dict[str, List[Tuple[float, float]]] = {}
        self.last_peak_regs: Dict[str, int] = {}

    def run(self, inputs: Dict[str, Any], timeout: float = 300.0) -> Tuple:
        import numpy as np

        specs, final = stage_actor_specs(
            self.staged, inputs, self.microbatch_inputs,
            self.num_microbatches, regs=self.regs, fn_wrap=self.fn_wrap)
        rt = ThreadedRuntime(specs, collect_outputs_of=final)
        t0 = time.perf_counter()
        outs = rt.run(timeout=timeout)
        self.last_makespan = time.perf_counter() - t0
        self.last_history = {name: list(a.history)
                             for name, a in rt.by_name.items()}
        self.last_peak_regs = {name: a.peak_regs_in_use
                               for name, a in rt.by_name.items()}
        if len(outs) != self.num_microbatches:
            raise RuntimeError(
                f"collected {len(outs)} microbatch results, expected "
                f"{self.num_microbatches}")
        # the final stage fires in version order on one thread, so ``outs``
        # is already microbatch-ordered. Sinks downstream of a microbatched
        # input are per-chunk slices -> concatenate along the batch axis;
        # anything else (e.g. a weights-only sink) is recomputed identically
        # every firing -> take one copy.
        mb_dependent = set(self.microbatch_inputs)
        for op in self.staged.graph.topo_ops():
            if any(t.name in mb_dependent for t in op.inputs):
                mb_dependent.add(op.output.name)
        results = []
        for t in self.staged.sinks:
            if t.name in mb_dependent:
                results.append(np.concatenate(
                    [np.asarray(d[t.name]) for d in outs], axis=0))
            else:
                results.append(np.asarray(outs[0][t.name]))
        return tuple(results)
