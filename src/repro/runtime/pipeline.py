"""Pipeline-parallel schedules from register quotas (paper §4.3, §6.5).

The paper's key observation: a synchronous pipeline schedule is not a special
scheduler — it *emerges* from out-register quotas. A stage's forward actor
output register is referenced by BOTH the next stage's forward AND this
stage's backward (the stashed activation); it is recycled only when both have
acked. Capping the quota at ``R`` bounds in-flight microbatches to ``R``:

* ``R = num_microbatches``  -> GPipe-style all-forward-then-backward memory;
* ``R = num_stages - stage``-> 1F1B steady state (Megatron's schedule);
* ``R = 1``                 -> fully serialized (no pipelining).

:func:`pipeline_specs` builds the actor graph; :func:`plan_registers` is the
compile-time resource planner: it simulates quotas and picks the smallest one
within ``tolerance`` of the best makespan — this is the "resource planning at
compile time" the paper argues for (§2.3), done with the actor model itself.

Three executors then run *real compiled programs* under that protocol:

* :func:`stage_actor_specs` / :class:`ActorPipelineExecutor` — forward-only
  pipelines over the per-stage jitted programs of
  :func:`repro.core.lowering.lower_stages` (inference / PR 1).
* :func:`train_stage_actor_specs` / :class:`TrainPipelineExecutor` — full
  training pipelines over :func:`repro.core.lowering.lower_train_stages`:
  forward actors stash their vjp closure (residuals/activations) in the out
  register that the *backward* actor also references, backward actors flow
  cotangents up the chain, accumulation actors (``emit_every`` — OneFlow's
  `acc` op) sum per-microbatch gradients, and optimizer actors fire once per
  step. The 1F1B schedule is never written down: it emerges from the forward
  quota ``R[s] = num_stages - s`` alone (§4.3, §6.5).
* :func:`serve_stage_actor_specs` / :class:`ServePipelineExecutor` —
  continuous-batching decode with per-stage caches as actor-local state.

Every executor builds its actor graph ONCE (a picklable *spec builder*) and
drives it through the :class:`repro.runtime.base.Runtime` seam: actors are
resettable state machines, each run/step/round is one *epoch* over the same
graph, with per-epoch inputs delivered via ``ctx`` (routed to
``ActorSpec.on_epoch`` hooks) and per-epoch fire bounds via ``fires``.
Persistent per-stage state — placed params, optimizer state, serve caches —
lives in the actor closures, resident wherever the actor runs. Stage ``s``
is addressed at node ``s + 1`` (data/admit/norm at node 0), so under
``runtime="processes"`` each stage owns a real worker process and payloads
cross stages as serialized host arrays (:func:`repro.runtime.base
.encode_payload`) while same-node registers stay zero-copy.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.runtime.actor import ActorSpec
from repro.runtime.base import RUNTIME_KINDS, make_runtime
from repro.runtime.scheduler import CommModel, simulate


def _validate_regs(regs: Sequence[int], num_stages: int,
                   num_microbatches: Optional[int] = None) -> List[int]:
    """Reject bad quota lists up front: a zero/negative quota would deadlock
    (or be silently rewritten), so fail fast naming the offending stage and
    the analyzer's minimal feasible quota vector."""
    regs = list(regs)
    if len(regs) != num_stages:
        raise ValueError(f"need {num_stages} register quotas, got {len(regs)}")
    for s, r in enumerate(regs):
        if r < 1:
            from repro.analysis.deadlock import min_feasible_stage_regs
            feasible = min_feasible_stage_regs(num_stages, num_microbatches)
            raise ValueError(
                f"stage {s} register quota must be >= 1, got {r} "
                f"(regs={regs}); minimal feasible quotas for "
                f"{num_stages} stages: {feasible}")
    return regs


def pipeline_specs(num_stages: int, num_microbatches: int,
                   fwd_time: float = 1.0, bwd_time: float = 2.0,
                   regs: Optional[Sequence[int]] = None,
                   act_nbytes: int = 1 << 20) -> List[ActorSpec]:
    """Actor graph for a synchronous fwd/bwd pipeline over ``num_stages``
    devices. ``regs[s]`` is stage s's activation register quota."""
    if regs is None:
        regs = [num_stages - s for s in range(num_stages)]  # 1F1B default
    regs = _validate_regs(regs, num_stages, num_microbatches)
    specs: List[ActorSpec] = []
    specs.append(ActorSpec(
        name="data", fn=lambda *a: 0, inputs=(), out_regs=2,
        node=0, thread=0, duration=fwd_time * 0.1,
        max_fires=num_microbatches, out_nbytes=act_nbytes))
    for s in range(num_stages):
        fwd_in = "data" if s == 0 else f"f{s-1}"
        # forward actor on device/thread s
        specs.append(ActorSpec(
            name=f"f{s}", fn=lambda *a: 0, inputs=(fwd_in,),
            out_regs=regs[s], node=0, thread=s + 1,
            duration=fwd_time, max_fires=num_microbatches,
            out_nbytes=act_nbytes))
    for s in reversed(range(num_stages)):
        # backward actor: consumes stashed activation f{s} and upstream grad
        ins = (f"f{s}",) if s == num_stages - 1 else (f"f{s}", f"b{s+1}")
        specs.append(ActorSpec(
            name=f"b{s}", fn=lambda *a: 0, inputs=ins,
            out_regs=2, node=0, thread=s + 1,
            duration=bwd_time, max_fires=num_microbatches,
            out_nbytes=act_nbytes))
    # optimizer actor per stage consuming the gradient stream
    for s in range(num_stages):
        specs.append(ActorSpec(
            name=f"opt{s}", fn=lambda *a: 0, inputs=(f"b{s}",),
            out_regs=1, node=0, thread=s + 1, duration=0.01,
            max_fires=num_microbatches))
    return specs


@dataclasses.dataclass
class PipelinePlan:
    """Result of simulating one register-quota choice: the quota itself, the
    simulated makespan, per-stage peak activation registers actually used,
    and the pipeline-bubble fraction (idle time vs the ideal makespan)."""

    regs: List[int]
    makespan: float
    peak_activation_regs: Dict[str, int]
    bubble_fraction: float


def analyze(num_stages: int, num_microbatches: int, regs: Sequence[int],
            fwd_time: float = 1.0, bwd_time: float = 2.0) -> PipelinePlan:
    """Simulate the fwd/bwd pipeline under quota ``regs`` and summarize it
    as a :class:`PipelinePlan`. Raises if the quota deadlocks the graph."""
    specs = pipeline_specs(num_stages, num_microbatches, fwd_time, bwd_time,
                           list(regs))
    res = simulate(specs, comm=CommModel(same_node=0.0, cross_node_latency=0.0))
    if res.deadlocked:
        raise RuntimeError(f"pipeline deadlocked with regs={list(regs)}")
    ideal = num_microbatches * (fwd_time + bwd_time)
    bubble = 1.0 - ideal / res.makespan if res.makespan > 0 else 0.0
    return PipelinePlan(
        regs=list(regs), makespan=res.makespan,
        peak_activation_regs={f"f{s}": res.peak_regs[f"f{s}"]
                              for s in range(num_stages)},
        bubble_fraction=max(0.0, bubble))


def plan_registers(num_stages: int, num_microbatches: int,
                   fwd_time: float = 1.0, bwd_time: float = 2.0,
                   tolerance: float = 0.02) -> PipelinePlan:
    """Compile-time resource planning: smallest uniform quota whose makespan
    is within ``tolerance`` of the best observed — memory saved for free."""
    best: Optional[PipelinePlan] = None
    plans = []
    for r in range(1, num_microbatches + 1):
        p = analyze(num_stages, num_microbatches, [r] * num_stages,
                    fwd_time, bwd_time)
        plans.append(p)
        if best is None or p.makespan < best.makespan:
            best = p
        if r >= num_stages and p.makespan <= best.makespan * (1 + 1e-9):
            break  # saturated: more registers cannot help
    target = best.makespan * (1 + tolerance)
    for p in plans:
        if p.makespan <= target:
            return p
    return best


# ---------------------------------------------------------------------------
# Actor-driven execution of lowered stage programs (compiler ∘ runtime).
#
# This is the seam the paper argues for: the compiler's per-stage jitted
# callables (repro.core.lowering.lower_stages) become real ActorSpec.fn
# bodies. One actor per stage, owned by node s+1 of the runtime; microbatch
# payloads flow through Req.payload as {tensor name: value} dicts along the
# stage chain; out-register quotas alone bound in-flight microbatches, so
# 1F1B-style overlap *emerges* (§4.3) instead of being scheduled explicitly.
# ---------------------------------------------------------------------------

def check_run_inputs(provided, expected, what: str = "input",
                     owned: Sequence[str] = ()) -> None:
    """Fail fast with the offending key when a run/step input dict has
    unknown or missing names, instead of failing deep inside an actor body.

    ``expected`` are the names the caller must provide; ``owned`` are names
    the executor itself supplies (trainable params) — passing one of those is
    reported as such rather than as merely "unknown".
    """
    expected = set(expected)
    owned = set(owned)
    provided = set(provided)
    shadowed = sorted(provided & owned)
    if shadowed:
        raise ValueError(
            f"{what} {shadowed[0]!r} is a trainable param owned by the "
            f"executor; pass only data inputs (expected: {sorted(expected)})")
    unknown = sorted(provided - expected)
    if unknown:
        more = f" (+{len(unknown) - 1} more)" if len(unknown) > 1 else ""
        raise ValueError(
            f"unknown {what} {unknown[0]!r}{more}; "
            f"expected {what}s: {sorted(expected)}")
    missing = sorted(expected - provided)
    if missing:
        more = f" (+{len(missing) - 1} more)" if len(missing) > 1 else ""
        raise ValueError(
            f"missing {what} {missing[0]!r}{more}; "
            f"expected {what}s: {sorted(expected)}")


class _SpecBuilderBase:
    """Base of the picklable spec builders the executors hand to
    :func:`repro.runtime.base.make_runtime`.

    Carries either an already-lowered program (``staged``, process-local —
    what ``runtime="threads"`` uses directly) or a lowering recipe
    (:mod:`repro.runtime.recipes`, pure data). Pickling for a worker process
    drops the lowered program and ships the recipe; the worker re-lowers on
    arrival and jit-compiles only the stages it fires.
    """

    def __init__(self, staged=None, recipe=None):
        if staged is None and recipe is None:
            raise ValueError("spec builder needs a lowered program or a "
                             "lowering recipe")
        self._staged = staged
        self.recipe = recipe

    @property
    def staged(self):
        if self._staged is None:
            self._staged = self.recipe.lower()
        return self._staged

    def __getstate__(self):
        if self.recipe is None:
            raise ValueError(
                "this spec builder carries only a process-local lowered "
                "program; runtime='processes' needs a lowering recipe "
                "(repro.runtime.recipes) — compile through repro.api")
        state = dict(self.__dict__)
        state["_staged"] = None      # workers re-lower from the recipe
        return state


class _StagedExecutorBase:
    """Shared machinery of the stage-pipeline executors.

    Construction-time validation (microbatch count, register-quota length,
    microbatch input names, runtime kind), run-time input validation
    (:func:`check_run_inputs`), and the persistent runtime underneath: the
    executor builds ONE :class:`repro.runtime.base.Runtime` from its spec
    builder on first use and re-runs it per step/round (one epoch each),
    with per-epoch values delivered through ``ctx``/``fires``. Per-run
    instrumentation (``last_makespan``, ``last_history``, ``last_peak_regs``,
    ``last_edge_bytes``) snapshots the most recent epoch.
    """

    def __init__(self, program, microbatch_inputs: Sequence[str],
                 num_microbatches: int, regs: Optional[Sequence[int]],
                 fn_wrap: Optional[Callable] = None,
                 runtime: str = "threads", recipe=None, faults=None):
        if num_microbatches < 1:
            raise ValueError(
                f"num_microbatches must be >= 1, got {num_microbatches}")
        if regs is not None:
            regs = _validate_regs(regs, program.num_stages, num_microbatches)
        for n in microbatch_inputs:
            if n not in program.input_names:
                raise ValueError(f"{n} is not a graph input")
        if runtime not in RUNTIME_KINDS:
            raise ValueError(
                f"unknown runtime {runtime!r}; expected one of "
                f"{RUNTIME_KINDS}")
        if runtime == "processes" and recipe is None:
            raise ValueError(
                "runtime='processes' needs a picklable lowering recipe "
                "(repro.runtime.recipes) — compile through repro.api, or "
                "pass recipe=")
        self.microbatch_inputs = list(microbatch_inputs)
        self.num_microbatches = num_microbatches
        self.regs = regs
        self.fn_wrap = fn_wrap
        self.runtime_kind = runtime
        self.recipe = recipe
        self.faults = faults          # optional chaos FaultPlan (tests/CI)
        # optional repro.analysis.trace.TraceRecorder — set before the first
        # run; the threads runtime logs every Req delivery into it so
        # repro.analysis.trace.check_trace can certify the resequencer
        self.trace = None
        self._rt = None
        self.last_makespan: Optional[float] = None
        self.last_history: Dict[str, List[Tuple[float, float]]] = {}
        self.last_peak_regs: Dict[str, int] = {}
        self.last_edge_bytes: Dict[Tuple[str, str], int] = {}

    def _make_builder(self):
        raise NotImplementedError

    @property
    def runtime(self):
        """The persistent :class:`repro.runtime.base.Runtime` underneath
        (built on first use)."""
        if self._rt is None:
            self._rt = make_runtime(self.runtime_kind, self._make_builder(),
                                    faults=self.faults, trace=self.trace)
        return self._rt

    def _run_rt(self, ctx, fires, timeout: float):
        """Run one epoch over the persistent runtime, snapshotting
        wall-clock makespan, per-actor action history, peak out-registers,
        and per-edge payload traffic."""
        rt = self.runtime
        t0 = time.perf_counter()
        outs = rt.run(ctx=ctx, fires=fires, timeout=timeout)
        self.last_makespan = time.perf_counter() - t0
        self.last_history = dict(rt.last_history)
        self.last_peak_regs = dict(rt.last_peak_regs)
        self.last_edge_bytes = dict(rt.last_edge_bytes)
        return outs

    def close(self) -> None:
        """Release the runtime's workers (threads or processes). The
        executor rebuilds it lazily if used again."""
        if self._rt is not None:
            self._rt.close()
            self._rt = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def _bind_placed(stage, bound: Dict[str, Any]):
    """Pre-place the epoch-bound inputs (weights) on the stage's mesh once
    per rebind — they are constant for the whole run, so transferring them
    per microbatch fire would be pure waste. Returns the placed ``bound``
    plus a name->sharding map for per-fire placement of streamed payload
    entries (both empty no-ops when all stages share one mesh)."""
    if stage.in_shardings is None:
        return bound, {}
    import jax

    shard_of = dict(zip(stage.input_names, stage.in_shardings))
    return {n: jax.device_put(v, shard_of[n])
            for n, v in bound.items()}, shard_of


def _place_incoming(input_names, bound: Dict[str, Any],
                    shard_of: Dict[str, Any], payload: Dict[str, Any]):
    """Assemble a stage's positional inputs: pre-placed bound values as-is,
    streamed payload entries transferred onto the stage mesh when stages own
    distinct meshes. Shared by the forward-only and training pipelines."""
    import jax

    return [bound[n] if n in bound else
            (jax.device_put(payload[n], shard_of[n]) if n in shard_of
             else payload[n])
            for n in input_names]


def _stage_binding(stage):
    """Persistent bound-input state for one stage actor: a ``bound`` dict
    the closures read at fire time and an ``on_epoch`` hook that (re)binds
    the values the driver sent in ``ctx`` — placed on the stage's mesh in
    the worker that OWNS the stage, so weights live device-resident where
    they are used and never round-trip through the driver between epochs."""
    bound: Dict[str, Any] = {}
    shard_of = ({} if stage.in_shardings is None
                else dict(zip(stage.input_names, stage.in_shardings)))

    def on_epoch(raw):
        if not raw:
            return
        import jax

        for n, v in raw.items():
            bound[n] = jax.device_put(v, shard_of[n]) if n in shard_of else v
    return bound, shard_of, on_epoch


def _payload_source_spec(name: str, max_fires: int) -> ActorSpec:
    """The streaming source actor: emits one pre-split payload dict per
    version. The payload list is per-epoch state, delivered via ``ctx``."""
    cell: Dict[str, Any] = {"payloads": []}

    def on_epoch(v):
        if v is not None:
            cell["payloads"] = list(v)

    return ActorSpec(
        name=name, fn=lambda version: cell["payloads"][version], inputs=(),
        out_regs=2, node=0, thread=0, max_fires=max_fires,
        wants_version=True, on_epoch=on_epoch)


def stage_actor_specs(staged, microbatch_inputs: Sequence[str],
                      num_microbatches: int,
                      regs: Optional[Sequence[int]] = None,
                      fn_wrap: Optional[Callable[[int, Callable], Callable]] = None,
                      ) -> Tuple[List[ActorSpec], str]:
    """Build the persistent actor graph executing ``staged`` over
    microbatches.

    ``staged`` is a :class:`repro.core.lowering.StagedProgram`. The graph is
    built once and re-run per epoch: each run's inputs arrive via ``ctx`` —
    ``ctx["data"]`` is the pre-split microbatch payload list (one dict per
    version, :func:`repro.core.lowering.split_microbatches`), and
    ``ctx[f"stage{s}"]`` the stage's non-streamed graph inputs (weights),
    which the owning worker places on the stage mesh at epoch start.
    ``regs[s]`` is stage s's out-register quota (default: 1F1B,
    ``num_stages - s``). ``fn_wrap(stage_index, fn)`` optionally decorates
    each stage body (benchmarks use it to emulate device latency).

    Stage ``s`` lives at node ``s + 1`` (the data source at node 0), so the
    process runtime gives each stage its own worker.

    Returns ``(specs, final_stage_name)`` — collect the final stage's
    outputs to reassemble the sinks.
    """
    S = staged.num_stages
    if regs is None:
        regs = [max(1, S - s) for s in range(S)]
    regs = _validate_regs(regs, S, num_microbatches)
    mb_names = list(microbatch_inputs)
    for n in mb_names:
        if n not in staged.input_names:
            raise ValueError(f"{n} is not a graph input")

    # which payload entries each stage must forward to later consumers: any
    # tensor needed by a stage after s still travels the chain at s's output
    graph_inputs = set(staged.input_names)
    needed_after: List[set] = [set() for _ in range(S + 1)]
    sink_names = {t.name for t in staged.sinks}
    for s in reversed(range(S)):
        payload_borne = {n for n in staged.stages[s].input_names
                         if n in mb_names or n not in graph_inputs}
        needed_after[s] = needed_after[s + 1] | payload_borne

    specs: List[ActorSpec] = [_payload_source_spec("data", num_microbatches)]

    def make_stage_fn(stage):
        bound, shard_of, on_epoch = _stage_binding(stage)

        def run_stage(payload):
            import jax

            incoming = _place_incoming(stage.input_names, bound, shard_of,
                                       payload)
            outs = stage.fn(*incoming)
            outs = jax.block_until_ready(outs)
            carried = {n: v for n, v in payload.items()
                       if n in needed_after[stage.index + 1] or n in sink_names}
            carried.update(zip(stage.output_names, outs))
            return carried
        return run_stage, on_epoch

    for s, stage in enumerate(staged.stages):
        fn, on_epoch = make_stage_fn(stage)
        if fn_wrap is not None:
            fn = fn_wrap(s, fn)
        specs.append(ActorSpec(
            name=f"stage{s}", fn=fn,
            inputs=("data",) if s == 0 else (f"stage{s-1}",),
            out_regs=regs[s], node=s + 1, thread=0,
            max_fires=num_microbatches, on_epoch=on_epoch))
    return specs, f"stage{S - 1}"


class InferSpecBuilder(_SpecBuilderBase):
    """Picklable builder of the forward-pipeline actor graph."""

    def __init__(self, microbatch_inputs: Sequence[str],
                 num_microbatches: int, regs=None, fn_wrap=None,
                 staged=None, recipe=None):
        super().__init__(staged=staged, recipe=recipe)
        self.microbatch_inputs = list(microbatch_inputs)
        self.num_microbatches = num_microbatches
        self.regs = None if regs is None else list(regs)
        self.fn_wrap = fn_wrap

    def __call__(self):
        return stage_actor_specs(self.staged, self.microbatch_inputs,
                                 self.num_microbatches, regs=self.regs,
                                 fn_wrap=self.fn_wrap)


class ActorPipelineExecutor(_StagedExecutorBase):
    """Run a :class:`StagedProgram` on the actor runtime.

    The actor graph is built once; each :meth:`run` is one epoch over it:
    the pre-split microbatch payloads and the per-stage bound inputs
    (weights) travel in ``ctx``, ``num_microbatches`` chunks stream through
    the stage chain, and the graph sinks are reassembled by concatenating
    per-microbatch results along axis 0. ``last_makespan`` /
    ``last_history`` expose the wall-clock schedule of the most recent run.
    """

    def __init__(self, staged, microbatch_inputs: Sequence[str],
                 num_microbatches: int, regs: Optional[Sequence[int]] = None,
                 fn_wrap: Optional[Callable] = None,
                 runtime: str = "threads", recipe=None):
        super().__init__(staged, microbatch_inputs, num_microbatches, regs,
                         fn_wrap, runtime=runtime, recipe=recipe)
        self.staged = staged

    def _make_builder(self):
        return InferSpecBuilder(self.microbatch_inputs, self.num_microbatches,
                                regs=self.regs, fn_wrap=self.fn_wrap,
                                staged=self.staged, recipe=self.recipe)

    def run(self, inputs: Dict[str, Any], timeout: float = 300.0) -> Tuple:
        check_run_inputs(inputs, self.staged.input_names)
        from repro.core.lowering import reassemble_sinks, split_microbatches

        graph_inputs = set(self.staged.input_names)
        mb = set(self.microbatch_inputs)
        ctx: Dict[str, Any] = {
            "data": split_microbatches(inputs, self.microbatch_inputs,
                                       self.num_microbatches)}
        for stage in self.staged.stages:
            ctx[f"stage{stage.index}"] = {
                n: inputs[n] for n in stage.input_names
                if n in graph_inputs and n not in mb}
        outs = self._run_rt(ctx, None, timeout)
        if len(outs) != self.num_microbatches:
            raise RuntimeError(
                f"collected {len(outs)} microbatch results, expected "
                f"{self.num_microbatches}")
        # the final stage fires in version order in one worker, so ``outs``
        # is already microbatch-ordered
        return reassemble_sinks(self.staged.graph, self.staged.sinks,
                                self.microbatch_inputs, outs)


# ---------------------------------------------------------------------------
# Training pipelines: backward + optimizer actors (the tentpole of PR 2).
#
# One microbatch's journey: data -> f0 -> f1 -> ... -> f{S-1} -> b{S-1} ->
# ... -> b0, with acc{s} summing each stage's per-microbatch gradients
# (OneFlow's `acc` op, via ActorSpec.emit_every) and opt{s} firing exactly
# once per step on the summed gradient. Stage s's forward out register holds
# BOTH the boundary activations for f{s+1} AND the vjp closure (residuals)
# for b{s}; it is recycled only when both have acked — capping that quota at
# R[s] = S - s is all it takes for the 1F1B schedule to emerge.
#
# Stage s's actors (f, b, acc, opt, state) all live at node s+1, one worker
# mailbox — so the stage's params, optimizer state and gradient accumulator
# are node-local closure state, updated in place by the opt actor and never
# shipped between steps. "__"-prefixed payload keys (the vjp closure, the
# grad stream) are same-node contracts: repro.runtime.base.encode_payload
# strips them at node boundaries.
# ---------------------------------------------------------------------------

_VJP_KEY = "__vjp__"
_GRADS_KEY = "__grads__"


def _train_collect_names(tstaged, snapshot: bool = False,
                         dynamic: bool = False) -> List[str]:
    """The collect list shared by the builder and the executor: the
    loss-bearing backward actor first, then every ``opt{s}``, then (with
    snapshotting on) every ``snap{s}`` — the write receipts the driver
    needs before it finalizes a snapshot's MANIFEST — then (with dynamic
    loss scaling) the ``scale`` actor, whose decision the driver mirrors."""
    produced_at = {n: st.index for st in tstaged.stages
                   for n in st.output_names}
    loss_stage = produced_at[tstaged.loss_name]
    param_stages = [st.index for st in tstaged.stages if st.param_names]
    names = [f"b{loss_stage}"] + [f"opt{s}" for s in param_stages]
    if snapshot:
        names += [f"snap{s}" for s in param_stages]
    if dynamic and param_stages:
        names.append("scale")
    return names


def train_stage_actor_specs(tstaged, microbatch_inputs: Sequence[str],
                            num_microbatches: int, lr: float = 1e-2,
                            regs: Optional[Sequence[int]] = None,
                            fn_wrap: Optional[Callable] = None,
                            optimizer=None, snapshot=None,
                            ) -> Tuple[List[ActorSpec], List[str]]:
    """Build the persistent fwd/bwd/opt actor graph for training steps.

    ``tstaged`` is a :class:`repro.core.lowering.TrainStagedProgram`. The
    graph is built once and re-run per step (one epoch each); per-step
    values arrive via ``ctx``:

    * ``ctx["data"]`` — the pre-split microbatch payload list;
    * ``ctx[f"f{s}"]`` — values to (re)bind on stage s: its non-microbatch
      data inputs every step, plus its params on the first step (or after a
      ``load_params``). The owning worker places them on the stage mesh;
      afterwards ``opt{s}`` updates the same bound dict in place, so params
      stay device-resident in the worker across steps.
    * ``ctx[f"opt{s}"]`` — the step index (resolves the lr schedule), as a
      plain int or as ``{"step": int, "load_state": AdamWState-or-None}``
      after a ``load_state`` restore (the restored moments replace the
      worker-resident state before the epoch's first fire);
    * ``ctx[f"snap{s}"]`` — with ``snapshot`` set, ``{"step": int,
      "write": bool}`` controlling this epoch's checkpoint write.

    ``regs[s]`` is forward stage s's out-register quota (default 1F1B,
    ``num_stages - s``); backward/acc/opt actors need no tuning.
    ``fn_wrap(kind, stage_index, fn)`` with kind in ``{"fwd", "bwd"}``
    optionally decorates the stage bodies.

    The optimizer subsystem (paper §3.3 partial-value + §4.3 actors):

    * ``optimizer`` is a :class:`repro.core.lowering.OptimizerSpec` (falls
      back to ``tstaged.optimizer``, then plain SGD at ``lr``).
    * With ``optimizer.grad_clip`` > 0, every ``acc{s}`` emits its
      stage-local squared-norm partials alongside the summed gradients, and
      a ``norm`` actor — OneFlow's P→B boxing expressed as an actor — sums
      the partials in canonical param order and broadcasts the clip scale
      sideways to every ``opt{s}``.
    * With a stateful optimizer (AdamW), a ``state{s}`` source actor emits
      the stage's current optimizer state as a register that ``opt{s}``
      consumes — the second register stream. The state lives in the stage's
      worker across steps (initialized on the first step); the updated copy
      also rides the opt actor's output payload so the driver can mirror it.
    * With ``snapshot`` (a :class:`repro.runtime.snapshot.SnapshotSpec`), a
      ``snap{s}`` actor per parameterized stage consumes ``opt{s}``'s
      output register — the stream already carrying the post-update params
      and fresh optimizer state — and serializes the stage's slice to disk
      from its own mailbox thread (``thread=1``) with its own register
      quota, so checkpoint writes never sit on the schedule's thread. It
      emits a write receipt the driver collects before finalizing the
      snapshot manifest.

    Gradients are accumulated in fp32 regardless of the backward dtype
    (matching the optimizer kernels' fp32 math); the accumulator is reset
    at every epoch start by its ``on_epoch`` hook.

    Returns ``(specs, collect_names)``: ``collect_names[0]`` is the backward
    actor of the loss-producing stage (the per-microbatch loss stream), the
    rest are the ``opt{s}`` actors (each stage's post-clip gradients,
    updated params, and new optimizer state).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.lowering import OptimizerSpec, loss_scale_update
    from repro.optim.adamw import (clip_scale, global_norm_from_partials,
                                   scale_grad, sqnorm_partials)

    S = tstaged.num_stages
    if regs is None:
        regs = [max(1, S - s) for s in range(S)]
    regs = _validate_regs(regs, S, num_microbatches)
    mb_names = list(microbatch_inputs)
    for n in mb_names:
        if n not in tstaged.input_names:
            raise ValueError(f"{n} is not a graph input")

    opt = optimizer if optimizer is not None else (
        tstaged.optimizer if tstaged.optimizer is not None
        else OptimizerSpec.sgd(lr))
    clip = bool(opt.grad_clip)
    mp = opt.mixed_precision           # fp32 masters live in the opt actor
    compute_dtype = opt.compute_dtype  # what fwd/bwd see (None: keep as-is)
    scaling = opt.loss_scaling is not None
    dynamic = opt.dynamic_scaling
    need_norm = clip or dynamic        # dynamic scaling needs the finiteness
    param_order = tstaged.param_names  # check even with clipping off
    param_stages = [st.index for st in tstaged.stages if st.param_names]

    graph_inputs = set(tstaged.input_names)
    loss_name = tstaged.loss_name

    # forward carry: tensors a stage must forward for later stages' use
    needed_after: List[set] = [set() for _ in range(S + 1)]
    for s in reversed(range(S)):
        payload_borne = {n for n in tstaged.stages[s].input_names
                         if n in mb_names or n not in graph_inputs}
        needed_after[s] = needed_after[s + 1] | payload_borne

    # backward carry: which cotangents b{s} must emit to b{s-1}. A boundary
    # activation produced at stage p collects contributions from every
    # consuming stage >= s on the way down and is consumed as b{p}'s seed.
    produced_at = {n: st.index for st in tstaged.stages
                   for n in st.output_names}
    # the loss stream is collected at the backward actor of the stage that
    # produces the loss sink (usually, but not necessarily, the last stage)
    loss_stage = produced_at[loss_name]
    diff_boundary = {n for st in tstaged.stages
                     for n in st.diff_input_names if n not in st.param_names}
    out_cot_names: List[set] = [set() for _ in range(S)]
    for n in diff_boundary:
        consumers = {st.index for st in tstaged.stages
                     if n in st.diff_input_names}
        for s in range(produced_at[n] + 1, S):
            if any(c >= s for c in consumers):
                out_cot_names[s].add(n)

    specs: List[ActorSpec] = [_payload_source_spec("data", num_microbatches)]

    def make_fwd_fn(stage):
        bound, shard_of, base_on_epoch = _stage_binding(stage)
        # mixed precision: driver-sent params are fp32; the worker stashes
        # them for the opt actor (to (re)build its fp32 masters) and binds
        # the compute-dtype copy — the paper's Fig-14 ``cast`` op, applied
        # once per (re)bind at the forward-stage boundary
        raw_cell: Dict[str, Any] = {}
        pset = set(stage.param_names)

        def on_epoch(raw):
            base_on_epoch(raw)
            if not (mp and raw):
                return
            cdt = jnp.dtype(compute_dtype)
            for n in raw:
                if n in pset:
                    raw_cell[n] = bound[n]
                    bound[n] = bound[n].astype(cdt)

        def run_fwd(payload):
            incoming = _place_incoming(stage.input_names, bound, shard_of,
                                       payload)
            outs, vjp = stage.fwd(*incoming)
            outs = jax.block_until_ready(outs)
            carried = {n: v for n, v in payload.items()
                       if n in needed_after[stage.index + 1]}
            carried.update(zip(stage.output_names, outs))
            carried[_VJP_KEY] = vjp
            return carried
        return run_fwd, bound, raw_cell, on_epoch

    def make_bwd_fn(stage):
        # the loss stage's backward seed: 1 normally, the loss scale when
        # scaling is on (driver-sent via ctx so the worker and the driver
        # mirror never disagree on the step's scale)
        seed_cell = {"scale": None}

        def on_epoch(v):
            if v is not None:
                seed_cell["scale"] = float(v["loss_seed"])

        def run_bwd(f_payload, b_payload=None):
            incoming = {} if b_payload is None else b_payload["cots"]
            grads, contrib = {}, {}
            if stage.bwd is not None:
                seeds = stage.output_cotangents(f_payload, incoming,
                                                loss_name,
                                                loss_seed=seed_cell["scale"])
                in_cots = stage.bwd(f_payload[_VJP_KEY], seeds)
                in_cots = jax.block_until_ready(in_cots)
                for n, c in zip(stage.diff_input_names, in_cots):
                    if n in stage.param_names:
                        grads[n] = c
                    else:
                        contrib[n] = c
            out_cots = {}
            for n in out_cot_names[stage.index]:
                c = incoming.get(n)
                if n in contrib:
                    c = contrib[n] if c is None else c + contrib[n]
                out_cots[n] = c
            # the per-stage grad stream rides a same-node private key: only
            # acc{s} (same worker) reads it, so it never crosses to b{s-1}
            out = {"cots": out_cots, _GRADS_KEY: grads}
            if stage.index == loss_stage:
                # reduce to a scalar HERE, on the stage's own mesh: summing
                # driver-side would re-partition the reduction after the
                # tensor crossed a process boundary as a gathered numpy
                # array, changing the f32 rounding vs the threaded path
                out["loss"] = jnp.sum(f_payload[loss_name])
            return out
        return run_bwd, on_epoch

    def make_acc_fn():
        # per-microbatch gradients accumulate in fp32 (the optimizer kernels'
        # math dtype) no matter what dtype the backward emits (e.g. bf16);
        # the accumulator is epoch-local state, reset by on_epoch. With loss
        # scaling on, the driver sends ``1/scale`` and the accumulator
        # unscales ONCE on its final fire — before the squared-norm partials,
        # so the norm (and the finiteness check behind dynamic scaling) is of
        # the true gradients.
        state: Dict[str, Any] = {}
        meta = {"fires": 0, "inv": None}

        def on_epoch(v):
            state.clear()
            meta["fires"] = 0
            meta["inv"] = None if v is None else v.get("inv_scale")

        def run_acc(b_payload):
            meta["fires"] += 1
            for n, g in b_payload[_GRADS_KEY].items():
                g32 = g.astype(jnp.float32)
                state[n] = state[n] + g32 if n in state else g32
            final = meta["fires"] == num_microbatches
            if final and meta["inv"] is not None:
                for n in state:
                    state[n] = scale_grad(state[n], meta["inv"])
            out = {_GRADS_KEY: dict(state)}
            if need_norm and final:
                # the stage-local P contribution to the global grad norm
                out["sqnorms"] = sqnorm_partials(state)
            return out
        return run_acc, on_epoch

    def make_opt_fn(stage, bound, raw_cell, state_cell):
        pnames = stage.param_names
        meta = {"step": 0}

        def on_epoch(v):
            if v is None:
                return
            if isinstance(v, dict):
                meta["step"] = int(v["step"])
                if "load_state" in v:
                    # restore seam: replace the worker-resident optimizer
                    # state before this epoch's state{s} fire emits it
                    state_cell["state"] = v["load_state"]
            else:
                meta["step"] = int(v)

        def refresh_masters():
            # (re)build the fp32 masters from the fp32 params the driver
            # just sent (first step, load_params, or a snapshot restore) —
            # sharded flat for ZeRO, dense fp32 otherwise. The register
            # stream the opt actor owns from here on.
            raw = {n: raw_cell[n] for n in pnames}
            if opt.zero:
                masters = opt.shard_masters(raw)
            else:
                masters = {n: v.astype(jnp.float32) for n, v in raw.items()}
            state_cell["masters"] = masters
            state_cell["shapes"] = {n: tuple(v.shape) for n, v in raw.items()}
            raw_cell.clear()

        def run_opt(acc_payload, *rest):
            idx = 0
            norm_payload = scale_payload = None
            state = None
            if need_norm:
                norm_payload = rest[idx]
                idx += 1
            if dynamic:
                scale_payload = rest[idx]
                idx += 1
            if opt.stateful:
                state = rest[idx]["state"]
                idx += 1
            if mp and raw_cell:
                refresh_masters()
            if scale_payload is not None and scale_payload["skip"]:
                # non-finite grads under dynamic scaling: no update, no step
                # advance — the register stream (masters/moments/bound
                # params) is left exactly as it was
                out = {"skipped": True,
                       "scale": scale_payload["scale"],
                       "next_scale": scale_payload["next_scale"],
                       "good_steps": scale_payload["good_steps"]}
                if norm_payload is not None:
                    out["norm"] = norm_payload["norm"]
                return out
            grads = acc_payload[_GRADS_KEY]
            if norm_payload is not None:
                grads = {n: scale_grad(grads[n], norm_payload["scale"])
                         for n in pnames}
            else:
                grads = {n: grads[n] for n in pnames}
            if mp:
                params = state_cell["masters"]
            else:
                params = {n: bound[n] for n in pnames}
            if opt.stateful and state is None:
                # first step in this worker: fresh (zeroed) state — the
                # same values the driver-side mirror starts from
                state = opt.init_state(params)
            lr_now = opt.lr_at(meta["step"])
            meta["step"] += 1
            new_params, new_state = opt.update(params, grads, state, lr_now)
            new_params = jax.block_until_ready(new_params)
            # the stage's persistent state advances IN the worker: the next
            # epoch's forward reads the updated bound params, state{s} emits
            # the updated optimizer state
            if mp:
                shapes = state_cell["shapes"]
                state_cell["masters"] = new_params
                if opt.zero:
                    # gather for next step's forward at compute width (the
                    # Fig-14 cast BEFORE the gather: half the wire bytes)
                    bound.update(opt.gather_params(
                        new_params, dtype=compute_dtype, shapes=shapes))
                    full = opt.gather_params(new_params, shapes=shapes)
                else:
                    bound.update({n: v.astype(jnp.dtype(compute_dtype))
                                  for n, v in new_params.items()})
                    full = new_params
            else:
                bound.update(new_params)
                full = new_params
            if opt.stateful:
                state_cell["state"] = new_state
            # the driver mirror always sees full fp32 params; snap{s} (same
            # node) additionally sees the raw shards via a private key
            out = {"params": full, "grads": grads}
            if opt.stateful:
                out["state"] = new_state
            if opt.zero:
                out["__zero__"] = {"masters": new_params, "state": new_state,
                                   "shapes": state_cell["shapes"],
                                   "dp": opt.zero_dp}
            if scale_payload is not None:
                out["scale"] = scale_payload["scale"]
                out["next_scale"] = scale_payload["next_scale"]
                out["good_steps"] = scale_payload["good_steps"]
            if norm_payload is not None:
                out["norm"] = norm_payload["norm"]
            return out
        return run_opt, on_epoch

    def make_snap_fn(stage):
        # the snapshot actor's per-epoch control cell: which step this
        # epoch's write belongs to, and whether to write at all
        cell = {"step": 0, "write": False}

        def on_epoch(v):
            if v is not None:
                cell["step"] = int(v["step"])
                cell["write"] = bool(v["write"])

        def run_snap(opt_payload):
            from repro.runtime.snapshot import write_stage_snapshot

            write = cell["write"] and not opt_payload.get("skipped")
            if write:
                zero_meta = opt_payload.get("__zero__")
                if zero_meta is not None:
                    # ZeRO: persist the flat master/moment *shards* (the
                    # "__zero__" key is a same-node contract — snap{s} lives
                    # on the opt actor's node, so it sees the raw stream);
                    # load_snapshot gathers them partition-agnostically
                    write_stage_snapshot(
                        snapshot.dir, cell["step"], stage.index,
                        dict(zero_meta["masters"]),
                        opt_state=zero_meta["state"],
                        zero={"dp": zero_meta["dp"],
                              "shapes": {n: list(s) for n, s in
                                         zero_meta["shapes"].items()}})
                else:
                    write_stage_snapshot(
                        snapshot.dir, cell["step"], stage.index,
                        {n: opt_payload["params"][n]
                         for n in stage.param_names},
                        opt_state=opt_payload.get("state"))
            return {"stage": stage.index, "step": cell["step"],
                    "written": write}
        return run_snap, on_epoch

    collect = _train_collect_names(tstaged, snapshot=snapshot is not None,
                                   dynamic=dynamic)
    for s, stage in enumerate(tstaged.stages):
        fwd_fn, bound, raw_cell, fwd_on_epoch = make_fwd_fn(stage)
        bwd_fn, bwd_on_epoch = make_bwd_fn(stage)
        if fn_wrap is not None:
            fwd_fn = fn_wrap("fwd", s, fwd_fn)
            bwd_fn = fn_wrap("bwd", s, bwd_fn)
        specs.append(ActorSpec(
            name=f"f{s}", fn=fwd_fn,
            inputs=("data",) if s == 0 else (f"f{s-1}",),
            out_regs=regs[s], node=s + 1, thread=0,
            max_fires=num_microbatches, on_epoch=fwd_on_epoch))
        specs.append(ActorSpec(
            name=f"b{s}", fn=bwd_fn,
            inputs=(f"f{s}",) if s == S - 1 else (f"f{s}", f"b{s+1}"),
            out_regs=2, node=s + 1, thread=0,
            max_fires=num_microbatches,
            on_epoch=bwd_on_epoch if (scaling and s == loss_stage)
            else None))
        if stage.param_names:
            acc_fn, acc_on_epoch = make_acc_fn()
            specs.append(ActorSpec(
                name=f"acc{s}", fn=acc_fn, inputs=(f"b{s}",),
                out_regs=1, node=s + 1, thread=0,
                max_fires=num_microbatches, emit_every=num_microbatches,
                on_epoch=acc_on_epoch))
            opt_inputs = (f"acc{s}",)
            if need_norm:
                opt_inputs += ("norm",)
            if dynamic:
                opt_inputs += ("scale",)
            state_cell: Dict[str, Any] = {"state": None, "masters": None,
                                          "shapes": None}
            if opt.stateful:
                # the optimizer-state register stream: a source actor emits
                # the worker-resident AdamWState (flat ZeroState shards when
                # zero=True); opt{s} consumes it next to the summed
                # gradients and the broadcast clip scale
                specs.append(ActorSpec(
                    name=f"state{s}",
                    fn=lambda _c=state_cell: {"state": _c["state"]},
                    inputs=(), out_regs=1, node=s + 1, thread=0,
                    max_fires=1))
                opt_inputs += (f"state{s}",)
            opt_fn, opt_on_epoch = make_opt_fn(stage, bound, raw_cell,
                                               state_cell)
            specs.append(ActorSpec(
                name=f"opt{s}", fn=opt_fn,
                inputs=opt_inputs, out_regs=1, node=s + 1, thread=0,
                max_fires=1, on_epoch=opt_on_epoch))
            if snapshot is not None:
                # async checkpointing as one more register-stream consumer:
                # snap{s} subscribes to opt{s}'s output (post-update params
                # + fresh optimizer state) on the stage node's thread 1 —
                # its own mailbox, OS thread and register quota, so
                # serialization never blocks the schedule on thread 0
                snap_fn, snap_on_epoch = make_snap_fn(stage)
                specs.append(ActorSpec(
                    name=f"snap{s}", fn=snap_fn, inputs=(f"opt{s}",),
                    out_regs=1, node=s + 1, thread=1,
                    max_fires=1, on_epoch=snap_on_epoch))

    if need_norm and param_stages:
        # cross-stage *sideways* communication on the actor protocol: sum the
        # per-stage squared-norm partials (P→B boxing as an actor) and
        # broadcast the clip scale to every opt{s} (1.0 when clipping is off
        # and the norm only feeds the dynamic-scaling finiteness check)
        def run_norm(*acc_payloads):
            partials = {}
            for pl in acc_payloads:
                partials.update(pl["sqnorms"])
            norm = global_norm_from_partials(partials, param_order)
            return {"norm": norm, "scale": clip_scale(norm, opt.grad_clip)}

        specs.append(ActorSpec(
            name="norm", fn=run_norm,
            inputs=tuple(f"acc{s}" for s in param_stages),
            out_regs=1, node=0, thread=0, max_fires=1))

    if dynamic and param_stages:
        # dynamic loss scaling rides the norm actor's sideways P→B edge: one
        # more actor inspects the true-gradient norm for finiteness and
        # broadcasts the skip/backoff/growth decision to every opt{s}. The
        # driver re-seeds the cell each step via ctx["scale"], so kills and
        # restores never fork the scale trajectory.
        sc_cell = {"scale": float(opt.initial_scale()), "good": 0}

        def sc_on_epoch(v):
            if v is not None:
                sc_cell["scale"] = float(v["scale"])
                sc_cell["good"] = int(v["good_steps"])

        def run_scale(norm_payload):
            finite = bool(np.isfinite(np.float32(norm_payload["norm"])))
            skip, nxt, good = loss_scale_update(
                opt.precision, sc_cell["scale"], sc_cell["good"], finite)
            out = {"skip": skip, "scale": sc_cell["scale"],
                   "next_scale": nxt, "good_steps": good}
            sc_cell["scale"], sc_cell["good"] = nxt, good
            return out

        specs.append(ActorSpec(
            name="scale", fn=run_scale, inputs=("norm",),
            out_regs=1, node=0, thread=0, max_fires=1,
            on_epoch=sc_on_epoch))

    return specs, collect


class TrainSpecBuilder(_SpecBuilderBase):
    """Picklable builder of the fwd/bwd/opt training actor graph."""

    def __init__(self, microbatch_inputs: Sequence[str],
                 num_microbatches: int, lr: float = 1e-2, regs=None,
                 fn_wrap=None, optimizer=None, staged=None, recipe=None,
                 snapshot=None):
        super().__init__(staged=staged, recipe=recipe)
        self.microbatch_inputs = list(microbatch_inputs)
        self.num_microbatches = num_microbatches
        self.lr = lr
        self.regs = None if regs is None else list(regs)
        self.fn_wrap = fn_wrap
        self.optimizer = optimizer
        self.snapshot = snapshot      # SnapshotSpec (plain data — picklable)

    def __call__(self):
        return train_stage_actor_specs(self.staged, self.microbatch_inputs,
                                       self.num_microbatches, lr=self.lr,
                                       regs=self.regs, fn_wrap=self.fn_wrap,
                                       optimizer=self.optimizer,
                                       snapshot=self.snapshot)


class TrainPipelineExecutor(_StagedExecutorBase):
    """Run a :class:`TrainStagedProgram` as a 1F1B training pipeline.

    The fwd/bwd/opt actor graph is built once; each :meth:`step` is one
    epoch over it. Per-stage persistent state — placed params, the AdamW
    state, the fp32 gradient accumulator — lives in the stage's actor
    closures, resident in whichever worker owns the stage (its OS thread
    under ``runtime="threads"``, its worker process under
    ``runtime="processes"``): the opt actor updates the stage's bound
    params and optimizer state in place, so nothing round-trips through the
    driver between steps. The driver keeps a mirror (``params``,
    ``opt_states``) refreshed from the opt actors' collected outputs, and
    returns ``(loss, grads, params)`` bit-identical to the monolithic
    reference (:func:`repro.train.steps.make_graph_train_step` with the
    same :class:`repro.core.lowering.OptimizerSpec`; the objective is the
    *sum* of the loss tensor over the batch, ``grads`` are post-clip when
    global-norm clipping is on).

    ``opt_state`` merges the per-stage states; ``last_grad_norm`` is the
    global gradient norm the ``norm`` actor computed (None when clipping is
    off). Instrumentation mirrors :class:`ActorPipelineExecutor`:
    ``last_makespan`` (wall-clock seconds), ``last_history`` (per-actor
    action intervals), ``last_peak_regs`` (per-actor peak out-registers in
    use — ``f{s}`` entries are the in-flight activation counts the 1F1B
    quota bounds), ``last_edge_bytes`` (per-edge payload traffic).
    """

    def __init__(self, tstaged, params: Dict[str, Any],
                 microbatch_inputs: Sequence[str], num_microbatches: int,
                 lr: float = 1e-2, regs: Optional[Sequence[int]] = None,
                 fn_wrap: Optional[Callable] = None, optimizer=None,
                 runtime: str = "threads", recipe=None,
                 snapshot_dir: Optional[str] = None, snapshot_every: int = 1,
                 faults=None):
        from repro.core.lowering import OptimizerSpec

        super().__init__(tstaged, microbatch_inputs, num_microbatches, regs,
                         fn_wrap, runtime=runtime, recipe=recipe,
                         faults=faults)
        self.tstaged = tstaged
        self.lr = lr
        self.optimizer = optimizer if optimizer is not None else (
            tstaged.optimizer if tstaged.optimizer is not None
            else OptimizerSpec.sgd(lr))
        if snapshot_every < 1:
            raise ValueError(
                f"snapshot_every must be >= 1, got {snapshot_every}")
        self._snapshot = None
        if snapshot_dir is not None:
            from repro.runtime.snapshot import SnapshotSpec
            self._snapshot = SnapshotSpec(str(snapshot_dir))
        self.snapshot_every = snapshot_every
        self._state_dirty = False
        self.params: Dict[str, Any] = {}
        self.load_params(params)
        # driver-side mirror of the per-stage optimizer state (None entries
        # for SGD; flat ZeroState shards when zero=True); the workers
        # initialize their own identical (zeroed) copy on the first step and
        # send each update back on the opt payload
        self.opt_states: Dict[int, Any] = {
            st.index: self._fresh_state(st)
            for st in tstaged.stages if st.param_names}
        self.step_count = 0
        self.last_grad_norm = None
        # loss-scaling mirror: the driver owns the scale authority — it
        # seeds the backward pass and the unscale factor via ctx every step
        self._scaling = self.optimizer.loss_scaling is not None
        self.loss_scale = (self.optimizer.initial_scale()
                           if self._scaling else None)
        self.scale_good_steps = 0
        self.last_skipped = False
        self.last_scale = None      # the scale the last step ran under
        self._loss_stage = next(
            st.index for st in tstaged.stages
            if tstaged.loss_name in st.output_names)

    def _fresh_state(self, st):
        """A zeroed optimizer state for stage ``st`` — sharded flat when the
        optimizer runs ZeRO, matching what the stage's worker builds."""
        p = {n: self.params[n] for n in st.param_names}
        if self.optimizer.zero:
            p = self.optimizer.shard_masters(p)
        return self.optimizer.init_state(p)

    def _make_builder(self):
        return TrainSpecBuilder(self.microbatch_inputs, self.num_microbatches,
                                lr=self.lr, regs=self.regs,
                                fn_wrap=self.fn_wrap,
                                optimizer=self.optimizer,
                                staged=self.tstaged, recipe=self.recipe,
                                snapshot=self._snapshot)

    def load_params(self, params: Dict[str, Any]) -> None:
        """Replace the executor-owned params (e.g. a checkpoint restore).

        The new values ride the next step's ``ctx`` into each stage's
        worker, which places them on its mesh; afterwards the opt actors
        keep them device-resident. Optimizer state is untouched — reset
        ``opt_states`` separately if the new params are unrelated to the
        old trajectory.
        """
        missing = [n for n in self.tstaged.param_names if n not in params]
        if missing:
            raise ValueError(f"missing params: {missing}")
        self.params = {n: params[n] for n in self.tstaged.param_names}
        self._params_dirty = True

    def load_state(self, params: Optional[Dict[str, Any]] = None,
                   opt_state=None, step: Optional[int] = None) -> None:
        """Restore a full training state (the kill-and-resume seam).

        Extends :meth:`load_params` with the two pieces a restart must not
        lose: ``opt_state`` — a *merged* :class:`repro.optim.adamw
        .AdamWState` over all params (e.g. from
        :func:`repro.runtime.snapshot.load_snapshot`), split per stage by
        THIS executor's partition, so a snapshot restores onto a different
        stage cut — and ``step``, the optimizer-step counter the lr
        schedule is indexed by. The restored moments ride the next step's
        ``ctx`` into each stage's worker, replacing the worker-resident
        state before its ``state{s}`` actor fires.
        """
        if params is not None:
            self.load_params(params)
        if opt_state is not None:
            if not self.optimizer.stateful:
                raise ValueError(
                    "opt_state given but the optimizer is stateless "
                    f"({self.optimizer.kind})")
            self.opt_states = self.optimizer.split_state(
                opt_state, {st.index: st.param_names
                            for st in self.tstaged.stages if st.param_names})
            self._state_dirty = True
        if step is not None:
            self.step_count = int(step)

    @property
    def peak_inflight_activations(self) -> int:
        """Peak forward registers in use across stages in the last step —
        the in-flight microbatch count the quota back-pressures. Zero
        before the first step (or for a zero-stage program)."""
        return max((self.last_peak_regs.get(f"f{s}", 0)
                    for s in range(self.tstaged.num_stages)), default=0)

    @property
    def opt_state(self):
        """The per-stage optimizer states merged into one
        :class:`repro.optim.adamw.AdamWState` over all params (None for a
        stateless optimizer)."""
        return self.optimizer.merge_states(
            [self.opt_states[s] for s in sorted(self.opt_states)])

    def step(self, data_inputs: Dict[str, Any], timeout: float = 300.0):
        """Run one training step over the current params.

        ``data_inputs`` maps non-param graph inputs to values (the
        microbatched ones are split along axis 0). Updates ``self.params``,
        ``self.opt_states`` and the step counter in place and returns
        ``(loss, grads, params)``.
        """
        import jax.numpy as jnp
        import numpy as np

        from repro.core.lowering import split_microbatches

        check_run_inputs(
            data_inputs,
            [n for n in self.tstaged.input_names if n not in self.params],
            owned=self.tstaged.param_names)
        graph_inputs = set(self.tstaged.input_names)
        mb = set(self.microbatch_inputs)
        ctx: Dict[str, Any] = {
            "data": split_microbatches(data_inputs, self.microbatch_inputs,
                                       self.num_microbatches)}
        snap_step = self.step_count + 1   # the state after THIS step lands
        write = (self._snapshot is not None
                 and snap_step % self.snapshot_every == 0)
        if self._scaling:
            # seed the loss stage's backward with the scale, the acc actors
            # with 1/scale (exact for power-of-two scales), and re-anchor
            # the scale actor's cell at the driver's authoritative mirror
            inv = np.float32(np.float32(1.0) / np.float32(self.loss_scale))
            self.last_scale = self.loss_scale
            ctx[f"b{self._loss_stage}"] = {"loss_seed": float(self.loss_scale)}
            if self.optimizer.dynamic_scaling:
                ctx["scale"] = {"scale": self.loss_scale,
                                "good_steps": self.scale_good_steps}
        for st in self.tstaged.stages:
            bound = {n: data_inputs[n] for n in st.input_names
                     if n in graph_inputs and n not in mb
                     and n not in self.params}
            if self._params_dirty:
                bound.update({n: self.params[n] for n in st.param_names})
            ctx[f"f{st.index}"] = bound
            if st.param_names:
                if self._scaling:
                    ctx[f"acc{st.index}"] = {"inv_scale": inv}
                if self._state_dirty:
                    ctx[f"opt{st.index}"] = {
                        "step": self.step_count,
                        "load_state": self.opt_states[st.index]}
                else:
                    ctx[f"opt{st.index}"] = self.step_count
                if self._snapshot is not None:
                    ctx[f"snap{st.index}"] = {"step": snap_step,
                                              "write": write}
        outs = self._run_rt(ctx, None, timeout)
        self._params_dirty = False
        self._state_dirty = False

        collect = _train_collect_names(
            self.tstaged, snapshot=self._snapshot is not None,
            dynamic=self.optimizer.dynamic_scaling)
        # the loss-bearing backward actor fires in version order in one
        # worker, so the collected loss stream is microbatch-ordered
        loss_payloads = outs[collect[0]]
        if len(loss_payloads) != self.num_microbatches:
            raise RuntimeError(
                f"collected {len(loss_payloads)} loss chunks, expected "
                f"{self.num_microbatches}")
        loss = None
        for pl in loss_payloads:
            ls = jnp.sum(pl["loss"])
            loss = ls if loss is None else loss + ls

        grads: Dict[str, Any] = {}
        norm = None
        skipped = False
        for name in collect[1:]:
            if not name.startswith("opt"):
                continue
            (opt_out,) = outs[name]        # optimizer fired exactly once
            s = int(name[len("opt"):])
            if "norm" in opt_out:
                norm = opt_out["norm"]
            if opt_out.get("skipped"):
                skipped = True
                continue
            grads.update(opt_out["grads"])
            self.params.update(opt_out["params"])
            if "state" in opt_out:
                self.opt_states[s] = opt_out["state"]
        self.last_grad_norm = norm
        if self.optimizer.dynamic_scaling:
            (sc,) = outs["scale"]
            skipped = bool(sc["skip"])
            self.loss_scale = float(sc["next_scale"])
            self.scale_good_steps = int(sc["good_steps"])
        self.last_skipped = skipped
        if write and not skipped:
            self._finalize_snapshot(outs, snap_step)
        if not skipped:
            self.step_count += 1
        return loss, grads, dict(self.params)

    def _finalize_snapshot(self, outs, snap_step: int) -> None:
        """Write the snapshot MANIFEST — only after every stage's snap actor
        delivered a write receipt for this step. The MANIFEST is the
        completeness marker: a step killed mid-write leaves stage dirs
        without one, and restore ignores them."""
        from repro.runtime.snapshot import write_manifest

        receipts = []
        for st in self.tstaged.stages:
            if not st.param_names:
                continue
            (r,) = outs[f"snap{st.index}"]
            if not r["written"] or int(r["step"]) != snap_step:
                raise RuntimeError(
                    f"snapshot receipt mismatch from stage {st.index}: {r} "
                    f"(expected written step {snap_step})")
            receipts.append(int(r["stage"]))
        meta = {"param_names": list(self.tstaged.param_names),
                "stateful": self.optimizer.stateful,
                "optimizer": self.optimizer.kind,
                "num_stages": self.tstaged.num_stages,
                "zero": bool(self.optimizer.zero)}
        if self._scaling:
            # the scale to RESUME with (already advanced past this step)
            meta["loss_scale"] = float(self.loss_scale)
            meta["scale_good_steps"] = int(self.scale_good_steps)
        write_manifest(self._snapshot.dir, snap_step, receipts, meta=meta)

    def opt_state_bytes(self) -> Dict[int, int]:
        """Per-stage bytes of worker-resident optimizer-held fp32 state.

        With a mixed-precision optimizer this is masters + moments (3x the
        fp32 param bytes dense, 3x/DP per device under ZeRO); for plain
        AdamW it is the two moment tensors (the params themselves are the
        model, not optimizer state). The DP-fold memory saving the ZeRO
        stream buys is visible here without a profiler."""
        import numpy as np

        out: Dict[int, int] = {}
        zero_dp = self.optimizer.zero_dp if self.optimizer.zero else 1
        for st in self.tstaged.stages:
            if not st.param_names:
                continue
            total = 0
            state = self.opt_states.get(st.index)
            if state is not None:
                for tree in (state.mu, state.nu):
                    total += sum(int(np.asarray(v).nbytes)
                                 for v in tree.values())
            if self.optimizer.mixed_precision:
                # fp32 masters, flat-sharded under ZeRO
                for n in st.param_names:
                    nelem = int(np.asarray(self.params[n]).size)
                    chunk = -(-nelem // zero_dp) * zero_dp
                    total += chunk * 4
            out[st.index] = total // zero_dp   # per-device share
        return out


# ---------------------------------------------------------------------------
# Serving pipelines: continuous-batching decode on the actor protocol.
#
# Stage = contiguous model shard (repro.core.lowering.lower_serve_stages);
# microbatch = request group. Each round streams one work item per live group
# through the stage chain: a DecodeWork advances every slot of the group by
# one token, a PrefillWork runs one freshly admitted request's prompt and
# scatters its caches into the group cache. The stage's KV/SSM caches never
# ride the payload — they are persistent stage-local state in the stage
# actor's closure (the same pattern as the AdamW state stream in training),
# resident in whichever worker owns the stage, so the only tensors crossing
# stages are the (B, 1, d) hidden and the final logits. Overlap across
# groups emerges from the stage out-register quotas alone (§4.3): while
# stage 1 decodes group 0, stage 0 already decodes group 1.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PrefillWork:
    """Admit one request: run its prompt, build its slot caches.

    ``tokens`` is (1, prompt_len) int32 (batch-replicated); ``last_index``
    is the prompt's final position — the first generated token's logits are
    gathered there through the decode head. Under ``cache="paged"``,
    ``sid`` is the request's slot id in the page pool and ``row`` its
    *write* page-table row (shared-prefix entries masked to ``-1``)."""

    group: int
    slot: int
    tokens: Any
    last_index: int
    sid: int = -1
    row: Any = None


@dataclasses.dataclass
class DecodeWork:
    """Advance every slot of ``group`` by one token. ``tok``/``pos`` are
    (group_size,) int32; retired slots are parked (see ServeSession).
    Under ``cache="paged"``, ``sids``/``rows`` carry each slot's pool id
    and page-table row (``-1`` rows for parked or mid-chunk slots)."""

    group: int
    tok: Any
    pos: Any
    sids: Any = None
    rows: Any = None


@dataclasses.dataclass
class PrefillChunkWork:
    """One bounded chunked-prefill step for slot ``(group, slot)``
    (``cache="paged"`` only): the stage's scan-of-decode chunk program over
    ``toks`` (chunk_len, group_size), slot ``b`` visiting positions
    ``pos0[b] + t * adv[b]``. Non-owner columns are parked no-ops
    (``adv == 0``, table row ``-1``) so the group program keeps one fixed
    shape per chunk length. ``sids_in`` gates the state-row gather (``-1``
    on the first chunk: recurrent state starts from exact zeros),
    ``sids_out`` the state-row scatter. ``final`` marks the chunk whose
    last-position logits produce the request's first token."""

    group: int
    slot: int
    toks: Any
    pos0: Any
    adv: Any
    rows: Any
    sids_in: Any
    sids_out: Any
    final: bool


def _work_input(work):
    """The first stage's input tensor for a work item: prompt ids for a
    prefill, the chunk token matrix for a chunk, last tokens for a decode."""
    if isinstance(work, PrefillWork):
        return work.tokens
    if isinstance(work, PrefillChunkWork):
        return work.toks
    return work.tok


class DenseStageCache:
    """The dense per-group cache dict behind a stage-cache interface: one
    ``(group_size, cache_len, ...)`` block per slot group, allocated lazily
    the first time the group reaches the stage. This is the PR-5 semantics,
    bit for bit — the reference the paged cache is checked against."""

    def __init__(self, stage, group_size: int):
        self.stage = stage
        self.group_size = group_size
        self.caches: Dict[int, Any] = {}

    def _ensure(self, group: int) -> None:
        if group not in self.caches:
            import jax.numpy as jnp

            tok = jnp.zeros((self.group_size,), jnp.int32)
            self.caches[group] = self.stage.init_caches(tok)

    def write_prefill(self, work, slot_caches) -> None:
        self._ensure(work.group)
        self.caches[work.group] = self.stage.write_slot(
            self.caches[work.group], slot_caches, work.slot)

    def run_decode(self, work, xin):
        import jax

        self._ensure(work.group)
        xout, new_caches = self.stage.decode(
            self.stage.params, self.caches[work.group], xin, work.pos)
        xout = jax.block_until_ready(xout)
        self.caches[work.group] = new_caches
        return xout

    def run_chunk(self, work, xin):
        raise RuntimeError(
            "chunked prefill (PrefillChunkWork) requires cache='paged'; the "
            "dense cache admits whole prompts only")


def make_stage_cache(stage, group_size: int, cache_len: int, spec=None):
    """One stage's serving cache: dense per-group blocks, or the paged
    slab pool when a :class:`repro.serve.paged_cache.PagedCacheSpec` is
    given."""
    if spec is None:
        return DenseStageCache(stage, group_size)
    from repro.serve.paged_cache import PagedStageCache

    return PagedStageCache(stage, group_size, cache_len, spec)


def serve_stage_apply(stage, cache, work, xin):
    """Run one work item through one serve stage, updating the stage's
    persistent cache in place. ``cache`` is a :class:`DenseStageCache` /
    ``PagedStageCache`` (or the bare dense per-group dict, accepted for
    compatibility). Returns the stage's output tensor (the hidden
    mid-pipeline, the logits on the last stage). Shared by the actor
    executor and the monolithic serve engine so their math is identical."""
    import jax
    import jax.numpy as jnp

    if isinstance(cache, dict):
        dense = DenseStageCache(stage, 0)
        dense.caches = cache
        dense._ensure = lambda group: None      # caller pre-allocated
        cache = dense
    if isinstance(work, PrefillWork):
        li = jnp.full((work.tokens.shape[0],), work.last_index, jnp.int32)
        xout, slot_caches = stage.prefill(stage.params, xin, li)
        xout = jax.block_until_ready(xout)
        cache.write_prefill(work, slot_caches)
        return xout
    if isinstance(work, PrefillChunkWork):
        return cache.run_chunk(work, xin)
    return cache.run_decode(work, xin)


class _ServeEngineBase:
    """Shared state of the inline serving engine: one persistent stage
    cache per stage (dense per-group blocks or the paged slab pool — the
    register stream that outlives every round), the optional sampler
    stream, and round instrumentation."""

    def _init_serve_state(self, sstaged, cache_spec=None,
                          sampling=None) -> None:
        self.sstaged = sstaged
        self.cache_spec = cache_spec
        self.sampling = sampling
        self.stage_caches = [
            make_stage_cache(stage, sstaged.group_size, sstaged.cache_len,
                             cache_spec)
            for stage in sstaged.stages]
        self.sampler = None
        if sampling is not None:
            from repro.serve.sampler import SamplerStream

            self.sampler = SamplerStream(sampling, sstaged.cfg.vocab_size)
        self.rounds = 0
        self.total_makespan = 0.0

    def _count_round(self) -> None:
        self.rounds += 1
        self.total_makespan += self.last_makespan


def _finish_round_item(sampler, work, logits):
    """Shape one round result. Without a sampler the result is the raw
    logits (the PR-5 protocol, untouched). With one, it is
    ``{"logits", "tokens"}`` — the sampler key advances once per
    token-producing item (never for a non-final chunk), in work order, so
    every backend/runtime consumes the key stream identically."""
    if sampler is None:
        return logits
    if isinstance(work, PrefillChunkWork):
        if not work.final:
            return {"logits": logits, "tokens": None}
        return {"logits": logits, "tokens": sampler.sample(logits[-1])}
    return {"logits": logits, "tokens": sampler.sample(logits)}


class InlineServeEngine(_ServeEngineBase):
    """``backend="monolithic"`` serving: the same round protocol as the
    actor executor, run inline (no actors) over a whole-stack
    ``lower_serve_stages(num_stages=1)`` program — the reference the
    pipelined engine is checked against, token for token."""

    def __init__(self, sstaged, cache_spec=None, sampling=None):
        self._init_serve_state(sstaged, cache_spec, sampling)
        self.last_makespan: Optional[float] = None

    def run_round(self, work: Sequence, timeout: float = 300.0) -> List:
        t0 = time.perf_counter()
        results = []
        for w in work:
            xin = _work_input(w)
            for cache in self.stage_caches:
                xin = serve_stage_apply(cache.stage, cache, w, xin)
            results.append(_finish_round_item(self.sampler, w, xin))
        self.last_makespan = time.perf_counter() - t0
        self._count_round()
        return results


def serve_stage_actor_specs(sstaged, regs: Optional[Sequence[int]] = None,
                            fn_wrap: Optional[Callable] = None,
                            cache_spec=None, sampling=None,
                            ) -> Tuple[List[ActorSpec], str]:
    """Build the persistent serve actor graph: an ``admit`` source emitting
    the round's work items (delivered via ``ctx["admit"]``, with ``fires``
    set to the round's work count) and one ``stage{s}`` actor per model
    shard at node ``s + 1``, each owning its per-group KV/SSM caches as
    closure state — allocated lazily the first time a group reaches the
    stage, resident in the owning worker across rounds.

    Returns ``(specs, final_stage_name)``."""
    S = sstaged.num_stages
    if regs is None:
        regs = [max(1, S - s) for s in range(S)]
    regs = _validate_regs(regs, S)

    cell: Dict[str, Any] = {"work": []}

    def on_epoch(v):
        if v is not None:
            cell["work"] = list(v)

    specs: List[ActorSpec] = [ActorSpec(
        name="admit", fn=lambda version: {"work": cell["work"][version]},
        inputs=(), out_regs=2, node=0, thread=0, max_fires=0,
        wants_version=True, on_epoch=on_epoch)]

    def make_stage_fn(stage):
        cache = make_stage_cache(stage, sstaged.group_size,
                                 sstaged.cache_len, cache_spec)
        sampler = None
        if stage.last and sampling is not None:
            from repro.serve.sampler import SamplerStream

            # the sampler key stream is closure state of the LAST stage
            # actor (resident in that stage's worker), advanced once per
            # token-producing fire — fires are FIFO in submission order,
            # so the stream is identical across runtimes and backends
            sampler = SamplerStream(sampling, sstaged.cfg.vocab_size)

        def run_stage(payload):
            work = payload["work"]
            xin = payload.get("x")
            if xin is None:                       # first stage: token ids in
                xin = _work_input(work)
            xout = serve_stage_apply(stage, cache, work, xin)
            if stage.last:
                return {"work": work,
                        "result": _finish_round_item(sampler, work, xout)}
            return {"work": work, "x": xout}
        return run_stage

    for s, stage in enumerate(sstaged.stages):
        fn = make_stage_fn(stage)
        if fn_wrap is not None:
            fn = fn_wrap(s, fn)
        specs.append(ActorSpec(
            name=f"stage{s}", fn=fn,
            inputs=("admit",) if s == 0 else (f"stage{s-1}",),
            out_regs=regs[s], node=s + 1, thread=0, max_fires=0))
    return specs, f"stage{S - 1}"


class ServeSpecBuilder(_SpecBuilderBase):
    """Picklable builder of the continuous-batching serve actor graph.
    ``cache_spec``/``sampling`` are frozen dataclasses, so the paged-pool
    geometry and the sampler seed ride the pickle into process workers."""

    def __init__(self, regs=None, fn_wrap=None, staged=None, recipe=None,
                 cache_spec=None, sampling=None):
        super().__init__(staged=staged, recipe=recipe)
        self.regs = None if regs is None else list(regs)
        self.fn_wrap = fn_wrap
        self.cache_spec = cache_spec
        self.sampling = sampling

    def __call__(self):
        return serve_stage_actor_specs(self.staged, regs=self.regs,
                                       fn_wrap=self.fn_wrap,
                                       cache_spec=self.cache_spec,
                                       sampling=self.sampling)


class ServePipelineExecutor(_StagedExecutorBase):
    """Run a :class:`repro.core.lowering.ServeStagedProgram` as a pipelined
    continuous-batching decode engine.

    The actor graph persists across rounds; per-stage, per-group caches are
    closure state inside each ``stage{s}`` actor, resident in the worker
    that owns the stage (under ``runtime="processes"``, a real process —
    the caches never cross a process boundary). Each :meth:`run_round` is
    one epoch: the round's work items travel in ``ctx``, the per-actor fire
    bound is the round's work count, and the last stage's logits are
    collected in emission order. ``regs[s]`` is stage s's out-register
    quota (default ``max(1, S - s)``, the forward-pipeline schedule);
    quota back-pressure alone bounds how many groups are in flight.

    Instrumentation mirrors the other executors (``last_makespan``,
    ``last_history``, ``last_peak_regs``, ``last_edge_bytes``) plus
    ``rounds`` and ``total_makespan`` accumulated over the session.
    """

    def __init__(self, sstaged, regs: Optional[Sequence[int]] = None,
                 fn_wrap: Optional[Callable] = None,
                 runtime: str = "threads", recipe=None,
                 cache_spec=None, sampling=None):
        super().__init__(sstaged, [], 1, regs, fn_wrap,
                         runtime=runtime, recipe=recipe)
        self.sstaged = sstaged
        self.cache_spec = cache_spec
        self.sampling = sampling
        self.rounds = 0
        self.total_makespan = 0.0

    def _make_builder(self):
        return ServeSpecBuilder(regs=self.regs, fn_wrap=self.fn_wrap,
                                staged=self.sstaged, recipe=self.recipe,
                                cache_spec=self.cache_spec,
                                sampling=self.sampling)

    def run_round(self, work: Sequence, timeout: float = 300.0) -> List:
        """Stream ``work`` (PrefillWork/PrefillChunkWork/DecodeWork items)
        through the stage actors; returns one entry per item in submission
        order — the last stage's logits, or ``{"logits", "tokens"}`` dicts
        when sampling is on."""
        if not work:
            return []
        work = list(work)
        n = len(work)
        S = self.sstaged.num_stages
        fires = {"admit": n}
        fires.update({f"stage{s}": n for s in range(S)})
        outs = self._run_rt({"admit": work}, fires, timeout)
        if len(outs) != n:
            raise RuntimeError(f"collected {len(outs)} round results, "
                               f"expected {n}")
        self.rounds += 1
        self.total_makespan += self.last_makespan
        # the final stage fires in FIFO submission order in one worker
        return [o["result"] for o in outs]
