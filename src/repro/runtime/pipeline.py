"""Pipeline-parallel schedules from register quotas (paper §4.3, §6.5).

The paper's key observation: a synchronous pipeline schedule is not a special
scheduler — it *emerges* from out-register quotas. A stage's forward actor
output register is referenced by BOTH the next stage's forward AND this
stage's backward (the stashed activation); it is recycled only when both have
acked. Capping the quota at ``R`` bounds in-flight microbatches to ``R``:

* ``R = num_microbatches``  -> GPipe-style all-forward-then-backward memory;
* ``R = num_stages - stage``-> 1F1B steady state (Megatron's schedule);
* ``R = 1``                 -> fully serialized (no pipelining).

:func:`pipeline_specs` builds the actor graph; :func:`plan_registers` is the
compile-time resource planner: it simulates quotas and picks the smallest one
within ``tolerance`` of the best makespan — this is the "resource planning at
compile time" the paper argues for (§2.3), done with the actor model itself.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.runtime.actor import ActorSpec
from repro.runtime.scheduler import CommModel, SimResult, simulate


def pipeline_specs(num_stages: int, num_microbatches: int,
                   fwd_time: float = 1.0, bwd_time: float = 2.0,
                   regs: Optional[Sequence[int]] = None,
                   act_nbytes: int = 1 << 20) -> List[ActorSpec]:
    """Actor graph for a synchronous fwd/bwd pipeline over ``num_stages``
    devices. ``regs[s]`` is stage s's activation register quota."""
    if regs is None:
        regs = [num_stages - s for s in range(num_stages)]  # 1F1B default
    specs: List[ActorSpec] = []
    specs.append(ActorSpec(
        name="data", fn=lambda *a: 0, inputs=(), out_regs=2,
        node=0, thread=0, duration=fwd_time * 0.1,
        max_fires=num_microbatches, out_nbytes=act_nbytes))
    for s in range(num_stages):
        fwd_in = "data" if s == 0 else f"f{s-1}"
        # forward actor on device/thread s
        specs.append(ActorSpec(
            name=f"f{s}", fn=lambda *a: 0, inputs=(fwd_in,),
            out_regs=max(1, regs[s]), node=0, thread=s + 1,
            duration=fwd_time, max_fires=num_microbatches,
            out_nbytes=act_nbytes))
    for s in reversed(range(num_stages)):
        # backward actor: consumes stashed activation f{s} and upstream grad
        ins = (f"f{s}",) if s == num_stages - 1 else (f"f{s}", f"b{s+1}")
        specs.append(ActorSpec(
            name=f"b{s}", fn=lambda *a: 0, inputs=ins,
            out_regs=2, node=0, thread=s + 1,
            duration=bwd_time, max_fires=num_microbatches,
            out_nbytes=act_nbytes))
    # optimizer actor per stage consuming the gradient stream
    for s in range(num_stages):
        specs.append(ActorSpec(
            name=f"opt{s}", fn=lambda *a: 0, inputs=(f"b{s}",),
            out_regs=1, node=0, thread=s + 1, duration=0.01,
            max_fires=num_microbatches))
    return specs


@dataclasses.dataclass
class PipelinePlan:
    regs: List[int]
    makespan: float
    peak_activation_regs: Dict[str, int]
    bubble_fraction: float


def analyze(num_stages: int, num_microbatches: int, regs: Sequence[int],
            fwd_time: float = 1.0, bwd_time: float = 2.0) -> PipelinePlan:
    specs = pipeline_specs(num_stages, num_microbatches, fwd_time, bwd_time,
                           list(regs))
    res = simulate(specs, comm=CommModel(same_node=0.0, cross_node_latency=0.0))
    if res.deadlocked:
        raise RuntimeError(f"pipeline deadlocked with regs={list(regs)}")
    ideal = num_microbatches * (fwd_time + bwd_time)
    bubble = 1.0 - ideal / res.makespan if res.makespan > 0 else 0.0
    return PipelinePlan(
        regs=list(regs), makespan=res.makespan,
        peak_activation_regs={f"f{s}": res.peak_regs[f"f{s}"]
                              for s in range(num_stages)},
        bubble_fraction=max(0.0, bubble))


def plan_registers(num_stages: int, num_microbatches: int,
                   fwd_time: float = 1.0, bwd_time: float = 2.0,
                   tolerance: float = 0.02) -> PipelinePlan:
    """Compile-time resource planning: smallest uniform quota whose makespan
    is within ``tolerance`` of the best observed — memory saved for free."""
    best: Optional[PipelinePlan] = None
    plans = []
    for r in range(1, num_microbatches + 1):
        p = analyze(num_stages, num_microbatches, [r] * num_stages,
                    fwd_time, bwd_time)
        plans.append(p)
        if best is None or p.makespan < best.makespan:
            best = p
        if r >= num_stages and p.makespan <= best.makespan * (1 + 1e-9):
            break  # saturated: more registers cannot help
    target = best.makespan * (1 + tolerance)
    for p in plans:
        if p.makespan <= target:
            return p
    return best
