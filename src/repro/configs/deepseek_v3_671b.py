"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.
[arXiv:2412.19437]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,                 # leading dense layers
    vocab_size=129280,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    head_dim=192,
    num_experts=256,
    num_shared_experts=1,
    top_k=8,
    moe_d_ff=2048,
    first_dense_layers=3,
    mtp=True,
    source="arXiv:2412.19437 (DeepSeek-V3)",
)
