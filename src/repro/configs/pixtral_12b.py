"""pixtral-12b [vlm] — pixtral-ViT frontend (stub) + mistral-nemo decoder.
[hf:mistralai/Pixtral-12B-2409]

The vision encoder + projector are STUBBED per the task spec: ``input_specs``
provides precomputed patch embeddings of shape (batch, seq, d_model); this
config describes the language decoder that consumes them.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1e6,
    embed_frontend=True,
    source="hf:mistralai/Pixtral-12B-2409",
)
