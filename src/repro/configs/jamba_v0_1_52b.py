"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887]

Adaptation note: Jamba v0.1 uses Mamba-1 blocks; this framework implements the
SSD (Mamba-2) formulation for all SSM blocks — same state-space family,
MXU-friendlier scan (see DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    # 1 attention layer per 8, offset 4 (as in the released model)
    attn_every=8,
    attn_offset=4,
    # MoE on every second layer: 16 experts, top-2
    num_experts=16,
    top_k=2,
    moe_d_ff=14336,
    moe_every=2,
    # SSD block dims (adapted from Jamba's mamba config)
    ssm_d_state=16,
    ssm_d_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    source="arXiv:2403.19887 (Jamba)",
)
