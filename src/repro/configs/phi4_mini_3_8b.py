"""phi4-mini-3.8b [dense] — RoPE (partial rotary), SwiGLU, GQA kv=8.
[arXiv:2412.08905]

NOTE: 24 q heads do not divide the 16-way model axis; the framework pads q
heads to 32 (zero-weight heads). See DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    rope_fraction=0.75,
    rope_theta=1e4,
    source="arXiv:2412.08905 (Phi-4 technical report; mini dims)",
)
