"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 2 shared + 64 routed top-6.
[arXiv:2405.04434]

NOTE: the assignment line lists both "64e top-6" and "160 routed"; 160 routed
is the full V2 — V2-*Lite* has 64 routed experts (top-6) and 2 shared, which
is what we implement. moe_d_ff = 1408 as assigned.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10944,                 # the single leading dense layer
    vocab_size=102400,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=0,              # lite: no q compression
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    head_dim=192,               # nope + rope
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    source="arXiv:2405.04434 (DeepSeek-V2; Lite dims)",
)
