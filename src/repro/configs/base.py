"""Model / run configuration system.

Every assigned architecture gets one file in this package defining a
:class:`ModelConfig` with the exact published dimensions (source cited in the
docstring). ``reduced()`` derives the CPU smoke-test variant (2 layers,
d_model <= 512, <= 4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass
class ModelConfig:
    name: str
    family: str                      # dense | ssm | hybrid | moe | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # attention flavor
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    rope_fraction: float = 1.0       # phi4: partial rotary
    sliding_window: int = 0          # >0: sliding-window attention (long decode)

    # MLA (deepseek)
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0             # 0 = no q compression
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    num_experts: int = 0             # routed experts (0 = dense MLP)
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0      # deepseek: leading dense layers
    moe_every: int = 1               # jamba: MoE layer every k-th layer
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # SSM (mamba2-style SSD)
    ssm_d_state: int = 0
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    attn_every: int = 0              # hybrid: 1 attention layer per k (jamba 8)
    attn_offset: int = 0             # position of attn layer within the period

    # encoder-decoder (whisper)
    encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 0             # frames after the conv frontend stub

    # modality frontend stub (vlm/audio): inputs are embeddings, not ids
    embed_frontend: bool = False

    # MTP (deepseek v3)
    mtp: bool = False
    mtp_weight: float = 0.3

    # numerics
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    tie_embeddings: bool = False
    use_pallas: bool = False         # TPU deployment path: Pallas kernels

    source: str = ""                 # citation for the dimensions

    # ---- derived -------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim is None and self.num_heads:
            self.head_dim = self.d_model // self.num_heads

    def padded_vocab(self, multiple: int = 128) -> int:
        return _pad_to(self.vocab_size, multiple)

    def padded_heads(self, tp: int) -> int:
        """q heads padded up to a multiple of the tensor-parallel degree."""
        return _pad_to(self.num_heads, tp)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind: 'attn' | 'ssm', used by hybrid archs."""
        kinds = []
        for i in range(self.num_layers):
            if self.family == "ssm":
                kinds.append("ssm")
            elif self.attn_every:
                kinds.append("attn" if i % self.attn_every == self.attn_offset
                             else "ssm")
            else:
                kinds.append("attn")
        return tuple(kinds)

    def mlp_kinds(self) -> Tuple[str, ...]:
        """Per-layer MLP kind: 'dense' | 'moe' | 'none' (pure ssm layer)."""
        kinds = []
        for i in range(self.num_layers):
            if self.family == "ssm":
                kinds.append("none")      # mamba2 blocks have no separate MLP
            elif self.num_experts and i >= self.first_dense_layers \
                    and (i % self.moe_every == (self.moe_every - 1)
                         if self.moe_every > 1 else True):
                kinds.append("moe")
            else:
                kinds.append("dense")
        return tuple(kinds)

    # ---- parameter count (for MODEL_FLOPS = 6 N D) ----------------------------
    def param_count(self, active_only: bool = False) -> int:
        V, d = self.padded_vocab(), self.d_model
        n = V * d            # embedding
        if not self.tie_embeddings:
            n += V * d       # unembedding
        for kind, mlp in zip(self.layer_kinds(), self.mlp_kinds()):
            n += 2 * d       # rms norms
            if kind == "attn":
                if self.use_mla:
                    qd = self.qk_nope_head_dim + self.qk_rope_head_dim
                    if self.q_lora_rank:
                        n += d * self.q_lora_rank + self.q_lora_rank * self.num_heads * qd
                    else:
                        n += d * self.num_heads * qd
                    n += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                    n += self.kv_lora_rank * self.num_heads * (
                        self.qk_nope_head_dim + self.v_head_dim)
                    n += self.num_heads * self.v_head_dim * d
                else:
                    hd = self.head_dim
                    n += d * self.num_heads * hd          # q
                    n += 2 * d * self.num_kv_heads * hd   # k, v
                    n += self.num_heads * hd * d          # o
            else:  # ssm
                di, ns, nh = self.ssm_d_inner, self.ssm_d_state, self.ssm_heads
                n += d * (2 * di + 2 * ns + nh)  # in_proj (x,z) + B,C + dt
                n += di * self.ssm_d_conv + 2 * nh  # conv + A + D
                n += di * d                      # out_proj
            if mlp == "dense":
                n += 3 * d * self.d_ff
            elif mlp == "moe":
                e_all = self.num_experts
                e_act = self.top_k
                e = e_act if active_only else e_all
                n += 3 * d * self.moe_d_ff * e
                n += 3 * d * self.moe_d_ff * self.num_shared_experts
                n += d * self.num_experts      # router
        if self.encoder_decoder:
            # encoder layers: self-attn + dense mlp; decoder adds cross-attn
            hd = self.head_dim
            per_enc = (d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                       + self.num_heads * hd * d + 3 * d * self.d_ff + 2 * d)
            n += self.num_encoder_layers * per_enc
            n += self.num_layers * (d * self.num_heads * hd
                                    + 2 * d * self.num_kv_heads * hd
                                    + self.num_heads * hd * d + d)  # cross-attn
        return n

    # ---- reduced variant for CPU smoke tests -----------------------------------
    def reduced(self) -> "ModelConfig":
        r = dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=2,
            d_model=min(self.d_model, 256),
            num_heads=4,
            num_kv_heads=min(max(1, self.num_kv_heads), 2),
            head_dim=64,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 1024),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=min(self.moe_d_ff, 256) if self.moe_d_ff else 0,
            first_dense_layers=min(self.first_dense_layers, 1),
            capacity_factor=8.0,   # no token drops: keeps decode == prefill

            kv_lora_rank=min(self.kv_lora_rank, 64) if self.kv_lora_rank else 0,
            q_lora_rank=min(self.q_lora_rank, 64) if self.q_lora_rank else 0,
            qk_nope_head_dim=64 if self.qk_nope_head_dim else 0,
            qk_rope_head_dim=32 if self.qk_rope_head_dim else 0,
            v_head_dim=64 if self.v_head_dim else 0,
            ssm_d_state=min(self.ssm_d_state, 32) if self.ssm_d_state else 0,
            ssm_head_dim=32 if self.ssm_d_state else 64,
            ssm_chunk=32,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            attn_offset=min(self.attn_offset, 1),
            moe_every=min(self.moe_every, 2),
            num_encoder_layers=2 if self.encoder_decoder else 0,
            encoder_seq=min(self.encoder_seq, 64) if self.encoder_seq else 0,
            dtype="float32",
        )
        return r


# ---------------------------------------------------------------------------
# Input shapes (assigned).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str     # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
