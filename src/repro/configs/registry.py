"""--arch registry: name -> ModelConfig."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.configs import (deepseek_v2_lite_16b, deepseek_v3_671b,
                           jamba_v0_1_52b, llama3_8b, mamba2_370m,
                           phi4_mini_3_8b, pixtral_12b, qwen2_5_3b,
                           qwen3_1_7b, whisper_medium)

ARCHITECTURES: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (qwen2_5_3b, llama3_8b, mamba2_370m, phi4_mini_3_8b,
              jamba_v0_1_52b, deepseek_v2_lite_16b, pixtral_12b,
              deepseek_v3_671b, qwen3_1_7b, whisper_medium)
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHITECTURES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHITECTURES)}")
    return ARCHITECTURES[name]


def get_shape(name: str) -> InputShape:
    if name not in INPUT_SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[name]


def supports_shape(cfg: ModelConfig, shape: InputShape) -> bool:
    """Documented skips (DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k":
        # full-attention enc-dec out of family; dense archs use the
        # sliding-window variant (enabled by the launcher), SSM/hybrid native.
        if cfg.family == "audio":
            return False
    return True
