"""whisper-medium [audio] — encoder-decoder, conv frontend STUB.
[arXiv:2212.04356]

The mel-spectrogram + conv feature extractor is stubbed per the task spec:
``input_specs`` provides precomputed frame embeddings (batch, 1500, d_model)
for the encoder. This config describes the transformer backbone.

long_500k is SKIPPED for this arch (full-attention enc-dec, 448-token decoder
context by design) — see DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    encoder_decoder=True,
    num_encoder_layers=24,
    encoder_seq=1500,
    embed_frontend=True,
    source="arXiv:2212.04356 (Whisper)",
)
