"""repro — an OneFlow-style distributed deep-learning stack on jax.

The public surface is :mod:`repro.api`: build a
:class:`~repro.core.graph.LogicalGraph` with placement + SBP annotations,
then ``api.compile(graph, ...)`` returns a :class:`~repro.api.Session`
whatever the mode (infer/train) or backend (actors/monolithic)::

    from repro import api
    sess = api.compile(g, mode="train", params=init_params,
                       num_microbatches=8)
    res = sess.step(**batch)

Everything else (``repro.core``, ``repro.runtime``, ``repro.train``, ...)
is the machinery underneath — importable, but :mod:`repro.api` is the entry
point new features hang options off.
"""
from repro import api
from repro.api import (ServeRequest, ServeSession, Session, StepResult,
                       assert_sessions_match, compile)
from repro.core.graph import LogicalGraph, partition_stages
from repro.core.lowering import OptimizerSpec
from repro.core.placement import Placement

__all__ = [
    "api", "Session", "StepResult", "assert_sessions_match", "compile",
    "ServeRequest", "ServeSession",
    "LogicalGraph", "partition_stages", "OptimizerSpec", "Placement",
]
