"""Result types for the static plan verifier.

Every pass returns ``Violation`` records; the orchestrator folds them into a
``StaticReport`` that ``api.compile(..., check="static")`` attaches to the
session and renders inside ``Session.describe()``.  A FAIL verdict is raised
as ``AnalysisError`` at compile time, before any actor fires.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Violation:
    """One static-analysis finding.

    ``pass_name`` identifies the pass ("deadlock", "sbp", "memory", "trace"),
    ``subject`` the offending object (a cycle, an edge, a tensor), and
    ``message`` the human-readable explanation.
    """

    pass_name: str
    subject: str
    message: str

    def describe(self) -> str:
        return f"[{self.pass_name}] {self.subject}: {self.message}"


@dataclasses.dataclass
class StaticReport:
    """Aggregate outcome of the static passes over one compiled plan."""

    verdict: str  # "PASS" | "FAIL" | "SKIPPED"
    violations: Tuple[Violation, ...] = ()
    checked_edges: int = 0
    checked_channels: int = 0
    peak_bytes_per_device: Dict[str, int] = dataclasses.field(default_factory=dict)
    min_feasible_regs: Optional[Dict[str, int]] = None
    passes: Tuple[str, ...] = ()

    def describe(self) -> str:
        lines: List[str] = []
        if self.verdict == "SKIPPED":
            lines.append("static analysis: skipped")
            return "\n".join(lines)
        ran = ", ".join(self.passes) if self.passes else "none"
        lines.append(
            f"static analysis: {self.verdict} "
            f"(passes: {ran}; {self.checked_edges} edges, "
            f"{self.checked_channels} channels checked)"
        )
        for name, nbytes in sorted(self.peak_bytes_per_device.items()):
            lines.append(f"  static peak bytes [{name}]: {nbytes}")
        for v in self.violations:
            lines.append(f"  {v.describe()}")
        if self.min_feasible_regs is not None:
            pretty = ", ".join(
                f"{k}={q}" for k, q in sorted(self.min_feasible_regs.items())
            )
            lines.append(f"  minimal feasible quotas: {pretty}")
        return "\n".join(lines)


class AnalysisError(ValueError):
    """Raised by ``api.compile`` when a static pass rejects the plan."""

    def __init__(self, report: StaticReport) -> None:
        self.report = report
        detail = "; ".join(v.describe() for v in report.violations[:4])
        more = len(report.violations) - 4
        if more > 0:
            detail += f"; (+{more} more)"
        super().__init__(f"static analysis rejected the plan: {detail}")
