"""repro.analysis — the static plan verifier (compile-time oracle).

Four passes over the compiled artifacts — LogicalGraph + SBP plan + stage
partition + ActorSpec graph + register quotas — none of which execute a
single stage program:

* :mod:`repro.analysis.deadlock` — abstract token-flow saturation of the
  actor network (actors = transitions, out registers = places with capacity
  = quota, ``emit_every``-aware rates); rejects quota-starved cycles and
  rate-mismatched sideways edges, and reports the minimal feasible quota
  vector.
* :mod:`repro.analysis.sbp_check` — every edge's (producer SBP, consumer
  SBP, mesh shape) must be priced by the Table-2 cost model, split axes must
  divide the logical shape, and partial values must not leak past combiners
  or materialization points.
* :mod:`repro.analysis.membound` — static peak in-flight bytes per device
  from quotas × per-device payload bytes (activations, optimizer state
  streams, serve cache slabs).
* :mod:`repro.analysis.trace` — a vector-clock happens-before sanitizer over
  recorded Req delivery traces (chaos harness integration), certifying the
  per-channel resequencer restores canonical order.

``api.compile(..., check="static")`` (the default) runs the first three and
raises :class:`AnalysisError` on FAIL; ``python -m repro.analysis`` runs them
from the command line over a config-zoo model.  The ``plan="search"``
roadmap item consumes :func:`run_static_checks` as its feasibility oracle.
"""
from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

from repro.analysis import membound
from repro.analysis.deadlock import (DeadlockResult, check_deadlock,
                                     deadlock_violations, min_feasible_regs,
                                     min_feasible_stage_regs)
from repro.analysis.report import AnalysisError, StaticReport, Violation
from repro.analysis.sbp_check import check_sbp
from repro.analysis.skeleton import (infer_spec_skeleton, serve_spec_skeleton,
                                     train_spec_skeleton)
from repro.analysis.trace import TraceRecorder, TraceStats, check_trace
from repro.runtime.actor import ActorSpec

__all__ = [
    "AnalysisError", "StaticReport", "Violation", "DeadlockResult",
    "TraceRecorder", "TraceStats", "check_deadlock", "check_sbp",
    "check_trace", "deadlock_violations", "min_feasible_regs",
    "min_feasible_stage_regs", "infer_spec_skeleton", "serve_spec_skeleton",
    "train_spec_skeleton", "run_static_checks", "run_session_checks",
    "membound",
]


def run_static_checks(
    *,
    specs: Optional[Sequence[ActorSpec]] = None,
    fires: Optional[Mapping[str, int]] = None,
    graph: Any = None,
    plan: Any = None,
    partition: Any = None,
    boundary_sbp: Optional[Dict[str, Any]] = None,
    memory: Optional[Dict[str, int]] = None,
    find_min_regs: bool = True,
) -> StaticReport:
    """Run every applicable pass and fold the findings into one report.

    Passes run on whatever artifacts are provided: the deadlock pass needs
    ``specs`` (+ optional ``fires`` overrides), the SBP pass needs ``graph``
    and ``plan`` (+ optional ``partition``/``boundary_sbp``), and ``memory``
    is a precomputed per-device byte bound to surface.  This is the oracle
    ``plan="search"`` will call per candidate plan.
    """
    violations: Tuple[Violation, ...] = ()
    passes: Tuple[str, ...] = ()
    checked_edges = 0
    checked_channels = 0
    min_regs: Optional[Dict[str, int]] = None

    if specs is not None:
        result = check_deadlock(specs, fires=fires)
        violations += tuple(deadlock_violations(result))
        checked_channels += result.channels
        passes += ("deadlock",)
        if not result.ok and find_min_regs:
            min_regs = min_feasible_regs(specs, fires=fires)
    if graph is not None and plan is not None:
        sbp_violations, n_edges = check_sbp(
            graph, plan, partition, boundary_sbp=boundary_sbp)
        violations += tuple(sbp_violations)
        checked_edges += n_edges
        passes += ("sbp",)
    if memory is not None:
        passes += ("memory",)

    verdict = "FAIL" if violations else "PASS"
    return StaticReport(
        verdict=verdict,
        violations=violations,
        checked_edges=checked_edges,
        checked_channels=checked_channels,
        peak_bytes_per_device=dict(memory or {}),
        min_feasible_regs=min_regs,
        passes=passes,
    )


def _default_regs(num_stages: int) -> list:
    return [max(1, num_stages - s) for s in range(num_stages)]


def run_session_checks(sess: Any) -> StaticReport:
    """Run the static passes over a compiled ``api`` session (duck-typed:
    works on :class:`repro.api.Session` and :class:`repro.api.ServeSession`
    across every mode × backend × runtime)."""
    if getattr(sess, "mode", None) == "serve":
        return _serve_session_checks(sess)
    return _graph_session_checks(sess)


def _graph_session_checks(sess: Any) -> StaticReport:
    specs = None
    boundary_sbp = None
    memory: Optional[Dict[str, int]] = None
    if sess.backend == "actors":
        specs, _ = sess._engine._make_builder()()
        staged = getattr(sess._engine, "tstaged",
                         getattr(sess._engine, "staged", None))
        if staged is not None:
            boundary_sbp = staged.boundary_sbp
            num_stages = staged.num_stages
            regs = sess.regs if sess.regs is not None \
                else _default_regs(num_stages)
            if sess.mode == "train":
                memory = membound.train_memory_bound(
                    staged, regs, sess.num_microbatches,
                    optimizer=sess.optimizer)
            else:
                memory = membound.infer_memory_bound(
                    staged, regs, sess.num_microbatches)
    else:
        memory = membound.monolithic_memory_bound(sess.graph, sess.plan)
    return run_static_checks(
        specs=specs, graph=sess.graph, plan=sess.plan,
        partition=sess.partition, boundary_sbp=boundary_sbp, memory=memory)


def _serve_session_checks(sess: Any) -> StaticReport:
    specs = None
    fires = None
    num_stages = sess.sstaged.num_stages
    regs = sess.regs if sess.regs is not None else _default_regs(num_stages)
    if sess.backend == "actors":
        specs, _ = sess._engine._make_builder()()
        # serve specs are open-ended (max_fires=0, bounded per round); the
        # static pass analyzes one representative full round instead
        round_items = max(1, int(sess.num_groups))
        fires = {s.name: round_items for s in specs}
    memory = membound.serve_memory_bound(
        sess.sstaged, regs, sess.num_groups,
        cache=sess.cache, cache_spec=sess.cache_spec)
    return run_static_checks(specs=specs, fires=fires, memory=memory)
