"""Static deadlock detection over ``ActorSpec`` graphs (paper §4).

The actor protocol is a bounded-buffer dataflow network: each actor's out
register pool is a place with capacity ``out_regs``; every fire consumes one
token per input channel and (subject to ``emit_every``) produces one token
into the pool, which is recycled only once *every* consumer has acked it.
Because firing an actor only ever adds tokens downstream and releases
registers upstream, the enabling relation is monotone: greedy saturation is
confluent and reaches a unique quiescent marking.  The plan deadlocks iff
some bounded actor has not exhausted its fires at quiescence.

Nothing here ever calls ``spec.fn`` — only the counters move.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Set, Tuple

from repro.analysis.report import Violation
from repro.runtime.actor import ActorSpec

# Safety fuse so a malformed graph can never loop the analyzer forever.
_MAX_TOTAL_FIRES = 1_000_000


@dataclasses.dataclass
class DeadlockResult:
    """Outcome of one abstract saturation run."""

    ok: bool
    fired: Dict[str, int]
    required: Dict[str, Optional[int]]
    stuck: Tuple[str, ...]
    cycle: Tuple[str, ...]
    reasons: Tuple[str, ...]
    channels: int


class _Node:
    __slots__ = ("spec", "limit", "fired", "consumers", "consumed")

    def __init__(self, spec: ActorSpec, limit: Optional[int]) -> None:
        self.spec = spec
        self.limit = limit
        self.fired = 0
        self.consumers: List[str] = []
        # tokens each consumer has taken from this actor's output channel
        self.consumed: Dict[str, int] = {}

    @property
    def emit_every(self) -> int:
        return max(1, self.spec.emit_every)

    @property
    def emitted(self) -> int:
        return self.fired // self.emit_every

    def regs_in_use(self) -> int:
        if not self.consumers:
            return 0
        return self.emitted - min(self.consumed[c] for c in self.consumers)

    def out_free(self) -> int:
        return self.spec.out_regs - self.regs_in_use()

    def exhausted(self) -> bool:
        return self.limit is not None and self.fired >= self.limit


def _build_nodes(
    specs: Sequence[ActorSpec], fires: Optional[Mapping[str, int]]
) -> Dict[str, _Node]:
    nodes: Dict[str, _Node] = {}
    for spec in specs:
        limit = spec.max_fires
        if fires is not None and spec.name in fires:
            limit = fires[spec.name]
        nodes[spec.name] = _Node(spec, limit)
    for spec in specs:
        for src in spec.inputs:
            if src not in nodes:
                raise ValueError(
                    f"actor {spec.name!r} consumes unknown producer {src!r}"
                )
            nodes[src].consumers.append(spec.name)
            nodes[src].consumed[spec.name] = 0
    return nodes


def _ready(nodes: Dict[str, _Node], node: _Node) -> bool:
    if node.exhausted():
        return False
    if node.out_free() < 1:
        return False
    for src in node.spec.inputs:
        prod = nodes[src]
        if prod.emitted - prod.consumed[node.spec.name] < 1:
            return False
    return True


def _fire(nodes: Dict[str, _Node], node: _Node) -> None:
    for src in node.spec.inputs:
        nodes[src].consumed[node.spec.name] += 1
    node.fired += 1


def _saturate(nodes: Dict[str, _Node]) -> int:
    """Greedy confluent saturation; returns total fires."""
    total = 0
    pending: List[str] = list(nodes)
    queued: Set[str] = set(pending)
    while pending:
        name = pending.pop()
        queued.discard(name)
        node = nodes[name]
        progressed = False
        while _ready(nodes, node):
            _fire(nodes, node)
            progressed = True
            total += 1
            if total > _MAX_TOTAL_FIRES:
                return total
        if progressed:
            for nxt in node.consumers + list(node.spec.inputs) + [name]:
                if nxt not in queued:
                    queued.add(nxt)
                    pending.append(nxt)
    return total


def _wait_edges(
    nodes: Dict[str, _Node], name: str
) -> List[Tuple[str, str]]:
    """Who is ``name`` waiting on right now?  Returns (target, reason)."""
    node = nodes[name]
    edges: List[Tuple[str, str]] = []
    for src in node.spec.inputs:
        prod = nodes[src]
        if prod.emitted - prod.consumed[name] < 1:
            if prod.exhausted():
                edges.append(
                    (src, f"starved: {src} exhausted after {prod.fired} fires")
                )
            else:
                edges.append((src, f"awaits a token from {src}"))
    if node.out_free() < 1:
        for c in node.consumers:
            if node.consumed[c] < node.emitted:
                edges.append((c, f"awaits an ack from {c}"))
    return edges


def _find_cycle(
    nodes: Dict[str, _Node], roots: Sequence[str]
) -> Tuple[str, ...]:
    """DFS over the waits-for graph; returns the first cycle found."""
    graph = {
        name: [t for t, _ in _wait_edges(nodes, name)]
        for name in nodes
        if not nodes[name].exhausted()
    }
    color: Dict[str, int] = {}
    stack: List[str] = []

    def visit(name: str) -> Optional[Tuple[str, ...]]:
        color[name] = 1
        stack.append(name)
        for nxt in graph.get(name, ()):
            state = color.get(nxt, 0)
            if state == 1:
                i = stack.index(nxt)
                return tuple(stack[i:])
            if state == 0:
                found = visit(nxt)
                if found is not None:
                    return found
        stack.pop()
        color[name] = 2
        return None

    for root in roots:
        if color.get(root, 0) == 0:
            found = visit(root)
            if found is not None:
                return found
    return ()


def check_deadlock(
    specs: Sequence[ActorSpec],
    *,
    fires: Optional[Mapping[str, int]] = None,
) -> DeadlockResult:
    """Run the abstract token-flow simulation to quiescence.

    ``fires`` overrides ``max_fires`` per actor name — used for serve plans
    whose specs carry ``max_fires=0`` (open-ended) to analyze one
    representative round instead.
    """
    nodes = _build_nodes(specs, fires)
    unbounded_sources = [
        n for n, node in nodes.items()
        if node.limit is None and not node.spec.inputs
    ]
    if unbounded_sources:
        raise ValueError(
            "cannot analyze unbounded source actor(s) "
            f"{unbounded_sources}: pass fires= to bound them"
        )
    _saturate(nodes)
    stuck = tuple(
        sorted(n for n, node in nodes.items() if not node.exhausted()
               and node.limit is not None)
    )
    fired = {n: node.fired for n, node in nodes.items()}
    required = {n: node.limit for n, node in nodes.items()}
    channels = sum(len(node.spec.inputs) for node in nodes.values())
    if not stuck:
        return DeadlockResult(True, fired, required, (), (), (), channels)
    cycle = _find_cycle(nodes, stuck)
    reasons = []
    for name in stuck:
        for _, why in _wait_edges(nodes, name):
            reasons.append(f"{name} {why}")
    return DeadlockResult(
        False, fired, required, stuck, cycle, tuple(reasons), channels
    )


def deadlock_violations(result: DeadlockResult) -> List[Violation]:
    if result.ok:
        return []
    if result.cycle:
        subject = " -> ".join(result.cycle + (result.cycle[0],))
        kind = "quota-starved cycle"
    else:
        subject = ", ".join(result.stuck)
        kind = "starvation"
    progress = "; ".join(
        f"{n} fired {result.fired[n]}/{result.required[n]}"
        for n in result.stuck
    )
    detail = "; ".join(result.reasons[:6])
    return [
        Violation(
            "deadlock",
            subject,
            f"{kind}: plan quiesces with unfinished actors ({progress}); "
            f"{detail}",
        )
    ]


def min_feasible_regs(
    specs: Sequence[ActorSpec],
    *,
    fires: Optional[Mapping[str, int]] = None,
    tunable: Optional[Sequence[str]] = None,
    cap: int = 64,
) -> Optional[Dict[str, int]]:
    """Search the smallest per-actor quota vector that makes the plan live.

    Starts every tunable quota at 1, bumps quotas implicated in the failure
    until the abstract simulation completes, then coordinate-descends each
    quota back down.  Returns ``None`` when no quota assignment up to ``cap``
    fixes the plan (a rate mismatch, not a buffering problem).
    """
    by_name = {s.name: s for s in specs}
    has_consumer = {src for s in specs for src in s.inputs}
    if tunable is None:
        names = [s.name for s in specs if s.name in has_consumer]
    else:
        names = [n for n in tunable if n in by_name]
    if not names:
        return None
    quotas = {n: 1 for n in names}

    def attempt() -> DeadlockResult:
        trial = [
            dataclasses.replace(s, out_regs=quotas[s.name])
            if s.name in quotas else s
            for s in specs
        ]
        return check_deadlock(trial, fires=fires)

    result = attempt()
    rounds = 0
    while not result.ok and rounds < cap * len(names):
        rounds += 1
        blamed = set(result.stuck) | set(result.cycle)
        for name in result.stuck:
            # producers of a stuck actor may be the ones short on registers
            blamed.update(by_name[name].inputs)
        bumpable = [n for n in names if n in blamed and quotas[n] < cap]
        if not bumpable:
            return None
        for n in bumpable:
            quotas[n] += 1
        result = attempt()
    if not result.ok:
        return None
    # shrink back down, one coordinate at a time
    for n in sorted(names):
        while quotas[n] > 1:
            quotas[n] -= 1
            if not attempt().ok:
                quotas[n] += 1
                break
    return dict(quotas)


def min_feasible_stage_regs(
    num_stages: int, num_microbatches: Optional[int] = None
) -> List[int]:
    """Minimal per-stage forward quotas for the canonical train pipeline.

    Used by ``runtime.pipeline`` quota-validation errors to tell the caller
    what *would* work instead of merely rejecting what they passed.
    """
    from repro.analysis.skeleton import train_spec_skeleton

    nmb = num_microbatches if num_microbatches is not None else 2
    regs = [1] * num_stages
    specs = train_spec_skeleton(num_stages, nmb, regs)
    found = min_feasible_regs(
        specs, tunable=[f"f{s}" for s in range(num_stages)]
    )
    if found is None:
        # the canonical pipeline is always live at quota 1; be conservative
        return [1] * num_stages
    return [found.get(f"f{s}", 1) for s in range(num_stages)]
