"""Trace sanitizer: certify the per-channel resequencer restores causal order.

``Actor.on_req`` dedups and reorders Req deliveries per channel (the
resequencer).  Under chaos faults (``DelayEdge`` reordering a version past
its successor, ``DuplicateReq`` re-delivering one), "the run still produced
bitwise-identical output" is an *observed* outcome; this pass turns it into a
*checked invariant*.  The threaded runtime records every Req delivery — the
version delivered and the versions the resequencer released to the FIFO — and
``check_trace`` verifies:

1. per (consumer, channel), the concatenated released versions are exactly
   the canonical stride sequence ``stride-1, 2*stride-1, ...`` with no gap,
   duplicate, or reorder;
2. a vector-clock happens-before check: fire ``k`` of an actor carries clock
   ``VC(A,k) = join(VC(P, v_k(P)) for each input P) ∪ {A: k+1}``; for every
   observed fire the joined input clocks must not claim a causal *future* of
   the actor itself (no released version can depend on a fire that has not
   happened yet).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.report import Violation
from repro.runtime.actor import ActorSpec


@dataclasses.dataclass(frozen=True)
class DeliveryEvent:
    """One Req delivery at a consumer's mailbox."""

    seq: int
    dst: str
    channel: str
    version: int
    released: Tuple[int, ...]  # versions the resequencer moved to the FIFO
    stride: int
    accepted: bool = True      # False: duplicate, dropped without an ack
    epoch: int = 0             # resequencer state resets at epoch start


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One chaos fault the injector actually applied."""

    seq: int
    kind: str
    src: str
    dst: str
    version: Optional[int]
    epoch: int = 0


class TraceRecorder:
    """Thread-safe sink for delivery/fault events (one per runtime run)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._seq = 0
        # the engine stamps this at every start_epoch so events land in the
        # epoch whose resequencer state they belong to
        self.current_epoch = 0
        self.deliveries: List[DeliveryEvent] = []
        self.faults: List[FaultEvent] = []

    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def record_delivery(self, dst: str, channel: str, version: int,
                        released: Sequence[int], stride: int,
                        accepted: bool = True) -> None:
        with self._lock:
            self.deliveries.append(DeliveryEvent(
                self._next_seq(), dst, channel, version,
                tuple(released), stride, accepted, self.current_epoch))

    def record_fault(self, kind: str, src: str, dst: str,
                     version: Optional[int]) -> None:
        with self._lock:
            self.faults.append(FaultEvent(
                self._next_seq(), kind, src, dst, version,
                self.current_epoch))

    def clear(self) -> None:
        with self._lock:
            self.deliveries = []
            self.faults = []
            self._seq = 0
            self.current_epoch = 0


@dataclasses.dataclass
class TraceStats:
    """What the resequencer actually absorbed during the run."""

    deliveries: int
    duplicates_dropped: int
    reorders_buffered: int
    faults: int
    channels: int


def _canonical_prefix(stride: int, n: int) -> List[int]:
    return [(i + 1) * stride - 1 for i in range(n)]


def check_trace(
    recorder: TraceRecorder,
    specs: Sequence[ActorSpec],
) -> Tuple[List[Violation], TraceStats]:
    """Verify a recorded run; returns (violations, stats)."""
    by_name = {s.name: s for s in specs}
    stride_of = {name: max(1, s.emit_every) for name, s in by_name.items()}

    consumed: Dict[Tuple[int, str, str], List[int]] = {}
    duplicates = 0
    reorders = 0
    for ev in recorder.deliveries:
        key = (ev.epoch, ev.dst, ev.channel)
        seq = consumed.setdefault(key, [])
        if not ev.accepted:
            duplicates += 1
        elif not ev.released or len(ev.released) > 1 \
                or ev.released[0] != ev.version:
            reorders += 1
        seq.extend(ev.released)

    violations: List[Violation] = []
    for (epoch, dst, ch), seq in sorted(consumed.items()):
        stride = stride_of.get(ch, 1)
        want = _canonical_prefix(stride, len(seq))
        if seq != want:
            violations.append(Violation(
                "trace", f"{ch} -> {dst}",
                f"epoch {epoch}: resequencer released {seq[:12]} but the "
                f"canonical stride-{stride} order is {want[:12]}"))

    # vector-clock happens-before over the canonical consumption pattern
    clocks: Dict[Tuple[str, int], Dict[str, int]] = {}

    def fire_clock(name: str, k: int) -> Dict[str, int]:
        key = (name, k)
        got = clocks.get(key)
        if got is not None:
            return got
        vc: Dict[str, int] = {}
        if k > 0:
            vc.update(fire_clock(name, k - 1))
        for ch in by_name[name].inputs:
            stride = stride_of.get(ch, 1)
            version = (k + 1) * stride - 1
            # version v is produced by the producer's fire v
            for n2, c2 in fire_clock(ch, version).items():
                if c2 > vc.get(n2, 0):
                    vc[n2] = c2
        vc[name] = k + 1
        clocks[key] = vc
        return vc

    fires_observed: Dict[str, int] = {}
    for (epoch, dst, ch), seq in consumed.items():
        n = len(seq)
        cur = fires_observed.get(dst)
        fires_observed[dst] = n if cur is None else min(cur, n)
    for name, fires in sorted(fires_observed.items()):
        if name not in by_name:
            continue
        for k in range(fires):
            joined = 0
            for ch in by_name[name].inputs:
                stride = stride_of.get(ch, 1)
                version = (k + 1) * stride - 1
                joined = max(joined,
                             fire_clock(ch, version).get(name, 0))
            if joined > k:
                violations.append(Violation(
                    "trace", name,
                    f"fire {k} of {name} consumes a token that causally "
                    f"depends on its own fire {joined - 1} — the "
                    f"resequencer released a future version"))
                break

    stats = TraceStats(
        deliveries=len(recorder.deliveries),
        duplicates_dropped=duplicates,
        reorders_buffered=reorders,
        faults=len(recorder.faults),
        channels=len(consumed),
    )
    return violations, stats
