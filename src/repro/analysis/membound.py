"""Static per-device memory bounds from register quotas and SBP signatures.

The actor protocol's only buffering is the out-register pools, so a plan's
peak in-flight bytes per device is bounded *statically*: quota × the
per-device payload bytes of each register stream (activations via
``NdSbp.bytes_per_device`` on the stage boundary signatures, optimizer
moments/masters via the same ZeRO sharding math as
``TrainPipelineExecutor.opt_state_bytes``, serve cache slabs via the
``cache_bytes`` eval_shape math).  The bound is informational — it is
surfaced in ``Session.describe()`` next to the *measured*
``peak_inflight_activations`` so existing instrumentation cross-checks it.
"""
from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Sequence

from repro.core.graph import LogicalGraph
from repro.core.sbp import NdSbp


def _per_device_bytes(
    graph: LogicalGraph,
    name: str,
    sbp_of: Mapping[str, NdSbp],
    itemsize: Optional[int] = None,
) -> int:
    tensors = {t.name: t for t in graph.tensors}
    t = tensors.get(name)
    if t is None:
        return 0
    sig = sbp_of.get(name)
    size = t.itemsize if itemsize is None else itemsize
    if sig is None:
        nelem = 1
        for d in t.shape:
            nelem *= int(d)
        return nelem * size
    mesh_shape = tuple(graph.placement.mesh_shape())
    return int(sig.bytes_per_device(t.shape, mesh_shape, size))


def infer_memory_bound(
    staged: Any, regs: Sequence[int], num_microbatches: int
) -> Dict[str, int]:
    """Per-stage bound for the forward pipeline: quota × boundary payload."""
    graph = staged.graph
    sbp_of = dict(staged.plan.tensor_sbp)
    sbp_of.update(staged.boundary_sbp)
    mb = max(1, num_microbatches)
    out: Dict[str, int] = {}
    for s, stage in enumerate(staged.stages):
        payload = sum(_per_device_bytes(graph, n, sbp_of)
                      for n in stage.output_names)
        out[f"stage{s}"] = regs[s] * -(-payload // mb)
    return out


def train_memory_bound(
    tstaged: Any,
    regs: Sequence[int],
    num_microbatches: int,
    optimizer: Any = None,
) -> Dict[str, int]:
    """Per-stage bound for the 1F1B pipeline.

    Counts the forward activation stream (quota × boundary bytes per
    microbatch — the registers the 1F1B quota actually caps), the backward
    cotangent stream (quota 2), the fp32 gradient accumulator, and the
    optimizer state streams (AdamW moments, fp32 masters under mixed
    precision), sharded by ``zero_dp`` when ZeRO is on — the same math as
    ``TrainPipelineExecutor.opt_state_bytes``.
    """
    graph = tstaged.graph
    sbp_of = dict(tstaged.plan.tensor_sbp)
    sbp_of.update(tstaged.boundary_sbp)
    opt = optimizer if optimizer is not None else tstaged.optimizer
    stateful = bool(opt is not None and getattr(opt, "stateful", False))
    mp = bool(opt is not None and getattr(opt, "mixed_precision", False))
    zero_dp = 1
    if opt is not None and getattr(opt, "zero", False):
        zero_dp = max(1, int(getattr(opt, "zero_dp", 1)))
    mb = max(1, num_microbatches)
    out: Dict[str, int] = {}
    for s, stage in enumerate(tstaged.stages):
        fwd_payload = sum(_per_device_bytes(graph, n, sbp_of)
                          for n in stage.output_names)
        cot_payload = sum(
            _per_device_bytes(graph, n, sbp_of)
            for n in stage.diff_input_names if n not in stage.param_names)
        total = regs[s] * -(-fwd_payload // mb)
        total += 2 * -(-cot_payload // mb)
        if stage.param_names:
            # element count per device = bytes_per_device at itemsize 1
            nelem = sum(_per_device_bytes(graph, n, sbp_of, itemsize=1)
                        for n in stage.param_names)
            total += 4 * nelem                      # fp32 grad accumulator
            state = 0
            if stateful:
                state += 2 * 4 * nelem              # AdamW m + v, fp32
            if mp:
                state += 4 * nelem                  # fp32 masters
            total += state // zero_dp
        out[f"stage{s}"] = total
    return out


def stage_boundary_bound(
    graph: LogicalGraph,
    plan: Any,
    partition: Any,
    regs: Sequence[int],
    num_microbatches: int,
) -> Dict[str, int]:
    """Per-stage bound straight from (graph, plan, partition) — no lowering.

    A stage's register payload is its boundary tensors: produced at stage
    ``s`` and consumed at a later stage (or a graph sink at the last stage).
    Used by the CLI and the plan-search oracle, where no staged program
    exists yet.
    """
    stage_of_tensor = {op.output.name: partition.stage_of[op.name]
                       for op in graph.ops}
    mb = max(1, num_microbatches)
    boundary: Dict[int, int] = {s: 0 for s in range(partition.num_stages)}
    sinks = {t.name for t in graph.sinks()}
    for op in graph.ops:
        t = op.output
        src = stage_of_tensor[t.name]
        crosses = t.name in sinks and src == partition.num_stages - 1
        for consumer in graph.consumers(t):
            if partition.stage_of[consumer.name] > src:
                crosses = True
        if crosses:
            boundary[src] += _per_device_bytes(graph, t.name, plan.tensor_sbp)
    return {f"stage{s}": regs[s] * -(-boundary[s] // mb)
            for s in range(partition.num_stages)}


def monolithic_memory_bound(graph: LogicalGraph, plan: Any) -> Dict[str, int]:
    """Whole-graph bound: every planned tensor resident at once."""
    total = sum(_per_device_bytes(graph, t.name, plan.tensor_sbp)
                for t in graph.tensors)
    return {"whole-graph": total}


def serve_memory_bound(
    sstaged: Any,
    regs: Sequence[int],
    num_groups: int,
    cache: str = "dense",
    cache_spec: Any = None,
) -> Dict[str, int]:
    """Per-stage bound for the serve pipeline: quota × hidden payload plus
    the persistent per-stage cache reservation (paged slab or dense)."""
    import jax
    import jax.numpy as jnp

    from repro.serve.paged_cache import dense_bytes, slab_bytes

    cfg = sstaged.cfg
    hidden = sstaged.group_size * cfg.d_model * 4
    logits = sstaged.group_size * cfg.padded_vocab() * 4
    tok = jax.ShapeDtypeStruct((sstaged.group_size,), jnp.int32)
    out: Dict[str, int] = {}
    for s, stage in enumerate(sstaged.stages):
        template = jax.eval_shape(stage.init_caches, tok)
        if cache == "paged":
            cache_b = slab_bytes(template, cache_spec)
        else:
            cache_b = dense_bytes(template, num_groups)
        payload = logits if stage.last else hidden
        out[f"stage{s}"] = regs[s] * payload + cache_b
    return out
