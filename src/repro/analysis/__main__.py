"""CLI for the static plan verifier.

Analyze a config-zoo model's pipeline plan without lowering or executing
anything::

    python -m repro.analysis deepseek_v3_671b --stages 8 --regs 1f1b
    python -m repro.analysis qwen3_1_7b --stages 4 --regs 2,2,1,1 --mode train

Builds the model's layer-stack logical graph (one matmul block per layer,
cut into ``--stages`` contiguous stages), plans SBP signatures, mirrors the
executor's actor topology as a dummy-fn skeleton, and runs the deadlock,
SBP-legality and memory-bound passes.  Exit code 1 on a FAIL verdict.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List, Optional

from repro.analysis import membound, run_static_checks
from repro.analysis.skeleton import infer_spec_skeleton, train_spec_skeleton
from repro.core.graph import LogicalGraph, partition_stages
from repro.core.placement import Placement
from repro.core.planner import plan as plan_sbp


def build_stack_graph(num_layers: int, d_model: int, num_stages: int,
                      batch: int = 8) -> LogicalGraph:
    """A synthetic per-layer matmul stack pinned to contiguous stages — the
    same shape/stage structure the real lowered models have, cheap enough
    to plan at 671B scale."""
    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    x = g.input("x", (batch, d_model), sbp="B")
    h = x
    for i in range(num_layers):
        w = g.input(f"w{i}", (d_model, d_model))
        stage = min(i * num_stages // num_layers, num_stages - 1)
        with g.stage(stage):
            h = g.matmul(h, w, name=f"layer{i}")
    return g


def parse_regs(text: str, num_stages: int, num_microbatches: int) -> List[int]:
    if text == "1f1b":
        return [max(1, num_stages - s) for s in range(num_stages)]
    if text == "gpipe":
        return [num_microbatches] * num_stages
    if text == "serial":
        return [1] * num_stages
    return [int(part) for part in text.split(",")]


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static plan verifier over a config-zoo model")
    parser.add_argument("config", help="config name (repro.configs registry)")
    parser.add_argument("--stages", type=int, default=4)
    parser.add_argument("--regs", default="1f1b",
                        help="'1f1b' | 'gpipe' | 'serial' | comma list")
    parser.add_argument("--microbatches", type=int, default=8)
    parser.add_argument("--mode", choices=("infer", "train"),
                        default="train")
    args = parser.parse_args(argv)

    from repro.configs.registry import get_config

    cfg = get_config(args.config)
    regs = parse_regs(args.regs, args.stages, args.microbatches)
    if len(regs) != args.stages:
        print(f"need {args.stages} quotas, got {len(regs)}", file=sys.stderr)
        return 2

    t0 = time.perf_counter()
    graph = build_stack_graph(cfg.num_layers, cfg.d_model, args.stages)
    plan = plan_sbp(graph)
    partition = partition_stages(graph)
    if args.mode == "train":
        specs = train_spec_skeleton(args.stages, args.microbatches, regs)
    else:
        specs = infer_spec_skeleton(args.stages, args.microbatches, regs)
    memory = membound.stage_boundary_bound(graph, plan, partition, regs,
                                          args.microbatches)
    report = run_static_checks(specs=specs, graph=graph, plan=plan,
                               partition=partition, memory=memory)
    elapsed = time.perf_counter() - t0

    print(f"model: {cfg.name} ({cfg.num_layers} layers, "
          f"d_model={cfg.d_model})")
    print(f"plan: {args.stages} stages, regs={regs}, "
          f"microbatches={args.microbatches}, mode={args.mode}")
    print(report.describe())
    print(f"analyzer wall time: {elapsed * 1e3:.1f} ms")
    return 0 if report.verdict == "PASS" else 1


if __name__ == "__main__":
    sys.exit(main())
