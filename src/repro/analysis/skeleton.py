"""Dummy-fn actor-graph skeletons mirroring the real executor topologies.

The deadlock pass only reads the *wiring* of an ``ActorSpec`` graph — names,
inputs, quotas, fire bounds, emit rates — never the stage bodies.  These
builders reproduce the exact topologies of
:func:`repro.runtime.pipeline.stage_actor_specs`,
:func:`repro.runtime.pipeline.train_stage_actor_specs` and
:func:`repro.runtime.pipeline.serve_stage_actor_specs` with trivial fns, so
the CLI and benchmarks can analyze a plan without lowering any jax program,
and ``min_feasible_stage_regs`` can search quota vectors cheaply.  A parity
test pins these skeletons against the real builders field by field.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.runtime.actor import ActorSpec


def _noop(*args: object) -> int:
    return 0


def _default_regs(num_stages: int) -> List[int]:
    return [max(1, num_stages - s) for s in range(num_stages)]


def infer_spec_skeleton(
    num_stages: int,
    num_microbatches: int,
    regs: Optional[Sequence[int]] = None,
) -> List[ActorSpec]:
    """Topology of the forward pipeline: data -> stage0 -> ... -> stage{S-1}."""
    regs = _default_regs(num_stages) if regs is None else list(regs)
    specs = [ActorSpec(name="data", fn=_noop, inputs=(), out_regs=2,
                       node=0, thread=0, max_fires=num_microbatches)]
    for s in range(num_stages):
        specs.append(ActorSpec(
            name=f"stage{s}", fn=_noop,
            inputs=("data",) if s == 0 else (f"stage{s-1}",),
            out_regs=regs[s], node=s + 1, thread=0,
            max_fires=num_microbatches))
    return specs


def train_spec_skeleton(
    num_stages: int,
    num_microbatches: int,
    regs: Optional[Sequence[int]] = None,
    *,
    param_stages: Optional[Sequence[int]] = None,
    loss_stage: Optional[int] = None,
    clip: bool = False,
    dynamic: bool = False,
    stateful: bool = False,
    snapshot: bool = False,
) -> List[ActorSpec]:
    """Topology of the 1F1B training pipeline, including the sideways
    ``norm``/``scale`` edges and the ``state{s}``/``snap{s}`` streams."""
    S = num_stages
    M = num_microbatches
    regs = _default_regs(S) if regs is None else list(regs)
    pstages = list(range(S)) if param_stages is None else list(param_stages)
    need_norm = clip or dynamic

    specs = [ActorSpec(name="data", fn=_noop, inputs=(), out_regs=2,
                       node=0, thread=0, max_fires=M)]
    for s in range(S):
        specs.append(ActorSpec(
            name=f"f{s}", fn=_noop,
            inputs=("data",) if s == 0 else (f"f{s-1}",),
            out_regs=regs[s], node=s + 1, thread=0, max_fires=M))
        specs.append(ActorSpec(
            name=f"b{s}", fn=_noop,
            inputs=(f"f{s}",) if s == S - 1 else (f"f{s}", f"b{s+1}"),
            out_regs=2, node=s + 1, thread=0, max_fires=M))
        if s in pstages:
            specs.append(ActorSpec(
                name=f"acc{s}", fn=_noop, inputs=(f"b{s}",),
                out_regs=1, node=s + 1, thread=0,
                max_fires=M, emit_every=M))
            opt_inputs: Tuple[str, ...] = (f"acc{s}",)
            if need_norm:
                opt_inputs += ("norm",)
            if dynamic:
                opt_inputs += ("scale",)
            if stateful:
                specs.append(ActorSpec(
                    name=f"state{s}", fn=_noop, inputs=(),
                    out_regs=1, node=s + 1, thread=0, max_fires=1))
                opt_inputs += (f"state{s}",)
            specs.append(ActorSpec(
                name=f"opt{s}", fn=_noop, inputs=opt_inputs,
                out_regs=1, node=s + 1, thread=0, max_fires=1))
            if snapshot:
                specs.append(ActorSpec(
                    name=f"snap{s}", fn=_noop, inputs=(f"opt{s}",),
                    out_regs=1, node=s + 1, thread=1, max_fires=1))
    if need_norm and pstages:
        specs.append(ActorSpec(
            name="norm", fn=_noop,
            inputs=tuple(f"acc{s}" for s in pstages),
            out_regs=1, node=0, thread=0, max_fires=1))
    if dynamic and pstages:
        specs.append(ActorSpec(
            name="scale", fn=_noop, inputs=("norm",),
            out_regs=1, node=0, thread=0, max_fires=1))
    return specs


def serve_spec_skeleton(
    num_stages: int,
    regs: Optional[Sequence[int]] = None,
    *,
    round_items: int = 1,
) -> List[ActorSpec]:
    """Topology of one serve round: admit -> stage0 -> ... -> stage{S-1}.

    The real specs carry ``max_fires=0`` (open-ended, bounded per round via
    ``fires``); the skeleton bounds every actor at ``round_items`` so the
    deadlock pass analyzes one representative round directly.
    """
    regs = _default_regs(num_stages) if regs is None else list(regs)
    specs = [ActorSpec(name="admit", fn=_noop, inputs=(), out_regs=2,
                       node=0, thread=0, max_fires=round_items)]
    for s in range(num_stages):
        specs.append(ActorSpec(
            name=f"stage{s}", fn=_noop,
            inputs=("admit",) if s == 0 else (f"stage{s-1}",),
            out_regs=regs[s], node=s + 1, thread=0, max_fires=round_items))
    return specs
