"""SBP legality pass: every plan edge must be expressible and well-shaped.

Three invariants over a (LogicalGraph, Plan) pair, checked without placing a
single tensor:

1. every stored / required signature validates against the tensor's logical
   shape (split axes in range and dividing the dimension);
2. every producer→consumer edge's (have, need) transition is priced by the
   Table-2 cost model (:func:`repro.core.boxing.nd_transition_cost`) — an
   unpriceable transition means no boxing primitive realizes the edge;
3. partial-sum values never leak: a P signature may feed further ops (the
   planner prices the P→B combine), but it must not escape through a graph
   sink without an epilogue materialization, nor cross a stage boundary
   unmaterialized — at the actor level only the ``norm``-style combiners may
   consume partials sideways.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.analysis.report import Violation
from repro.core.boxing import nd_transition_cost
from repro.core.graph import LogicalGraph, StagePartition
from repro.core.planner import Plan
from repro.core.sbp import NdSbp


def check_sbp(
    graph: LogicalGraph,
    plan: Plan,
    partition: Optional[StagePartition] = None,
    boundary_sbp: Optional[Dict[str, NdSbp]] = None,
) -> Tuple[List[Violation], int]:
    """Return (violations, checked_edge_count)."""
    mesh_shape = tuple(graph.placement.mesh_shape())
    tensors = {t.name: t for t in graph.tensors}
    violations: List[Violation] = []
    checked = 0

    for name, sig in plan.tensor_sbp.items():
        t = tensors.get(name)
        if t is None:
            continue
        try:
            sig.validate_for_shape(t.shape, mesh_shape)
        except ValueError as e:
            violations.append(Violation(
                "sbp", name,
                f"signature {sig} is illegal for shape {tuple(t.shape)} "
                f"on mesh {mesh_shape}: {e}"))

    producer_stage: Dict[str, int] = {}
    if partition is not None:
        for op in graph.ops:
            producer_stage[op.output.name] = partition.stage_of[op.name]

    for op in graph.ops:
        need_sigs = plan.op_in_sbp.get(op.name)
        for i, t in enumerate(op.inputs):
            have = plan.tensor_sbp.get(t.name)
            need = need_sigs[i] if need_sigs is not None else None
            if have is None or need is None:
                continue
            checked += 1
            edge = f"{t.name} -> {op.name}"
            try:
                need.validate_for_shape(t.shape, mesh_shape)
            except ValueError as e:
                violations.append(Violation(
                    "sbp", edge,
                    f"required signature {need} is illegal for shape "
                    f"{tuple(t.shape)} on mesh {mesh_shape}: {e}"))
                continue
            try:
                nd_transition_cost(have, need, float(t.nbytes), mesh_shape)
            except (ValueError, TypeError) as e:
                violations.append(Violation(
                    "sbp", edge,
                    f"transition {have} -> {need} (shape {tuple(t.shape)}, "
                    f"mesh {mesh_shape}) is not expressible by any boxing "
                    f"primitive: {e}"))
            if partition is not None:
                src_stage = producer_stage.get(t.name)
                dst_stage = partition.stage_of[op.name]
                if src_stage is not None and dst_stage > src_stage:
                    boundary = (boundary_sbp or {}).get(t.name, have)
                    if boundary.has_partial:
                        violations.append(Violation(
                            "sbp", edge,
                            f"partial value {t.name} ({boundary}) crosses the "
                            f"stage {src_stage} -> {dst_stage} boundary "
                            f"unmaterialized; partials may only reach P->B "
                            f"combiners or an explicit materialization"))

    materialized_sinks = {tname for tname, opname, _, _, _ in plan.boxings
                          if opname == "__epilogue__"}
    for t in graph.sinks():
        sig = plan.tensor_sbp.get(t.name)
        if sig is None:
            continue
        checked += 1
        if sig.has_partial and t.name not in materialized_sinks:
            violations.append(Violation(
                "sbp", t.name,
                f"partial value {t.name} ({sig}, shape {tuple(t.shape)}) "
                f"leaks through a graph sink without a P->B combiner or "
                f"epilogue materialization"))
    return violations, checked
