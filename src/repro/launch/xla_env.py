"""Per-worker XLA environment setup for the process-backed actor runtime.

Each :class:`repro.runtime.process.ProcessRuntime` worker is a fresh spawned
interpreter, so it gets its own XLA client — the one chance to set
compile-time flags per *stage* rather than per job. This module must stay
importable **before** jax (no jax import at module level): the worker calls
:func:`apply_worker_env` first thing in ``_worker_main``, then the spec
builder's first jax touch picks the flags up.

The GPU flag set follows the standard latency-hiding recipe (async
collectives + latency-hiding scheduler + priority async stream) so that a
stage's cross-node sends overlap its compute; on CPU hosts the flags are
omitted — the CPU client rejects GPU-only options.
"""
from __future__ import annotations

import os
from typing import Dict, Optional

# flags that let a pipeline stage overlap collective communication with
# compute (see jax gpu_performance_tips); applied only when the worker is
# actually going to use the gpu client
GPU_ASYNC_FLAGS = (
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


def _wants_gpu(env: Dict[str, str]) -> bool:
    plats = env.get("JAX_PLATFORMS", env.get("JAX_PLATFORM_NAME", ""))
    return "cuda" in plats or "gpu" in plats or "rocm" in plats


def worker_env(node: int, base: Optional[Dict[str, str]] = None) -> Dict[str, str]:
    """Environment overrides for the worker owning ``node``.

    The parent's ``XLA_FLAGS`` are inherited verbatim (this is how
    ``--xla_force_host_platform_device_count=N`` reaches every worker so a
    stage sees the same device table the driver planned against); GPU
    workers additionally get the async-collective flags appended.
    """
    base = dict(os.environ if base is None else base)
    flags = base.get("XLA_FLAGS", "").split()
    if _wants_gpu(base):
        for f in GPU_ASYNC_FLAGS:
            if f not in flags:
                flags.append(f)
    env: Dict[str, str] = {}
    if flags:
        env["XLA_FLAGS"] = " ".join(flags)
    # workers share one host: don't let each grab the whole accelerator pool
    env.setdefault("XLA_PYTHON_CLIENT_PREALLOCATE", "false")
    env["REPRO_WORKER_NODE"] = str(node)
    return env


def apply_worker_env(node: int) -> None:
    """Install the per-worker environment. Must run before jax is imported
    in the worker process — XLA reads these at client construction."""
    if "jax" in __import__("sys").modules:  # pragma: no cover - guard only
        # too late for XLA_FLAGS to matter; don't silently pretend otherwise
        os.environ["REPRO_WORKER_NODE"] = str(node)
        return
    os.environ.update(worker_env(node))
