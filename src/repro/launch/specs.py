"""ShapeDtypeStruct stand-ins for every model input (dry-run, no allocation)."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig


def _adt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def train_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.embed_frontend and not cfg.encoder_decoder:
        batch = {"embeds": sds((B, S, cfg.d_model), _adt(cfg)),
                 "labels": sds((B, S), jnp.int32)}
    else:
        batch = {"tokens": sds((B, S + 1), jnp.int32)}
    if cfg.encoder_decoder:
        batch["enc_embeds"] = sds((B, cfg.encoder_seq, cfg.d_model), _adt(cfg))
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.embed_frontend and not cfg.encoder_decoder:
        batch = {"embeds": sds((B, S, cfg.d_model), _adt(cfg))}
    else:
        batch = {"tokens": sds((B, S), jnp.int32)}
    if cfg.encoder_decoder:
        batch["enc_embeds"] = sds((B, cfg.encoder_seq, cfg.d_model), _adt(cfg))
    return batch


def decode_io_specs(cfg: ModelConfig, shape: InputShape) -> Tuple:
    B = shape.global_batch
    sds = jax.ShapeDtypeStruct
    return sds((B,), jnp.int32), sds((B,), jnp.int32)   # (tok, pos)


def serve_plan_for(cfg: ModelConfig, shape: InputShape) -> Dict:
    """Cache/window policy per (arch family, input shape) — DESIGN.md §5."""
    assert shape.kind == "decode"
    long_ctx = shape.seq_len > 100_000
    plan = {"cache_len": shape.seq_len, "sliding_window": 0, "ring": False,
            "shard_batch": shape.global_batch >= 16}
    if long_ctx:
        if cfg.use_mla or cfg.family in ("ssm", "hybrid"):
            # latent cache / recurrent state / 1:7 hybrid: native long context
            pass
        else:
            # dense GQA: sliding-window ring cache (the sub-quadratic variant)
            plan.update({"cache_len": 8192, "sliding_window": 8192,
                         "ring": True})
    return plan
