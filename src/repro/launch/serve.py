"""Batched serving driver: prefill a batch of prompts, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=0)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.registry import get_config
    from repro.train.steps import make_serve_step

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    d_, m_ = (int(v) for v in args.mesh.split("x"))
    mesh = jax.make_mesh((d_, m_), ("data", "model"))
    cache_len = args.cache_len or (args.prompt_len + args.gen + 8)
    cache_len = ((cache_len + m_ - 1) // m_) * m_

    ss = make_serve_step(cfg, mesh, cache_len=cache_len)
    from repro.models.model_zoo import build_model
    from repro.train.steps import plan_from_mesh

    bundle = build_model(cfg, plan_from_mesh(mesh))
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    batch = {}
    if cfg.embed_frontend and not cfg.encoder_decoder:
        batch["embeds"] = jnp.asarray(rng.normal(
            size=(args.batch, args.prompt_len, cfg.d_model)).astype(np.float32))
    else:
        batch["tokens"] = jnp.asarray(rng.integers(
            0, cfg.vocab_size, size=(args.batch, args.prompt_len)), jnp.int32)
    if cfg.encoder_decoder:
        batch["enc_embeds"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32))

    t0 = time.time()
    h_last, caches = ss.prefill_fn(params, batch)
    h_last.block_until_ready()
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s")

    # greedy decode from the last prefill hidden
    logits0 = h_last[:, 0] @ params["unembed"]
    tok = jnp.argmax(logits0, axis=-1).astype(jnp.int32)
    generated = [np.asarray(tok)]
    pos = jnp.full((args.batch,), args.prompt_len, jnp.int32)
    t0 = time.time()
    for i in range(args.gen):
        logits, caches = ss.decode_fn(params, caches, tok, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        generated.append(np.asarray(tok))
        pos = pos + 1
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"decode {args.gen} steps: {dt:.2f}s "
          f"({args.gen*args.batch/dt:.1f} tok/s)")
    import numpy as _np
    gen = _np.stack(generated, axis=1)
    print("generated ids (first row):", gen[0][:16])
    assert gen.shape == (args.batch, args.gen + 1)
    assert (gen >= 0).all() and (gen < cfg.padded_vocab()).all()
    print("serve ok")


if __name__ == "__main__":
    main()
