"""Serving driver: continuous-batching pipelined decode on the actor runtime.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --requests 6 --prompt-len 32 --gen 16 --backend actors --stages 2

Token-frontend archs go through ``repro.api.compile(cfg, mode="serve")``:
requests with differing generation lengths are packed into decode slots,
finished requests retire and queued ones are admitted mid-flight, and the
stage actors overlap across request groups. Embed-frontend / encoder-decoder
archs (pixtral, whisper) fall back to the classic monolithic batched loop
(``--classic`` forces it for any arch).
"""
from __future__ import annotations

import argparse
import time


def classic_loop(cfg, args, mesh):
    """The pre-pipeline serve loop: one batched prefill + greedy decode.

    First-token logits go through ``ServeStep.logits_fn`` — the same
    jitted/shard-mapped head as the decode step — and greedy selection masks
    the padded vocab columns, so emitted ids are always < cfg.vocab_size.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models.model_zoo import build_model
    from repro.train.steps import (greedy_from_logits, make_serve_step,
                                   plan_from_mesh)

    m_ = mesh.devices.shape[1]
    cache_len = args.cache_len or (args.prompt_len + args.gen + 8)
    cache_len = ((cache_len + m_ - 1) // m_) * m_

    ss = make_serve_step(cfg, mesh, cache_len=cache_len)
    bundle = build_model(cfg, plan_from_mesh(mesh))
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    batch = {}
    if cfg.embed_frontend and not cfg.encoder_decoder:
        batch["embeds"] = jnp.asarray(rng.normal(
            size=(args.batch, args.prompt_len, cfg.d_model)).astype(np.float32))
    else:
        batch["tokens"] = jnp.asarray(rng.integers(
            0, cfg.vocab_size, size=(args.batch, args.prompt_len)), jnp.int32)
    if cfg.encoder_decoder:
        batch["enc_embeds"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32))

    t0 = time.time()
    h_last, caches = ss.prefill_fn(params, batch)
    h_last.block_until_ready()
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s")

    # greedy decode from the last prefill hidden, through the decode head
    tok = greedy_from_logits(ss.logits_fn(params, h_last), cfg.vocab_size)
    generated = [np.asarray(tok)]
    pos = jnp.full((args.batch,), args.prompt_len, jnp.int32)
    t0 = time.time()
    for _ in range(args.gen):
        logits, caches = ss.decode_fn(params, caches, tok, pos)
        tok = greedy_from_logits(logits, cfg.vocab_size)
        generated.append(np.asarray(tok))
        pos = pos + 1
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"decode {args.gen} steps: {dt:.2f}s "
          f"({args.gen*args.batch/dt:.1f} tok/s)")
    gen = np.stack(generated, axis=1)
    print("generated ids (first row):", gen[0][:16])
    assert gen.shape == (args.batch, args.gen + 1)
    assert (gen >= 0).all() and (gen < cfg.vocab_size).all()
    print("serve ok (classic loop)")


def continuous_batching(cfg, args, mesh):
    import numpy as np

    from repro import api

    sess = api.compile(cfg, mode="serve", backend=args.backend,
                       stages=args.stages, mesh=mesh,
                       num_groups=args.groups, group_size=args.slots,
                       max_prompt_len=args.prompt_len,
                       max_new_tokens=args.gen,
                       cache_len=args.cache_len or None)
    print(sess.describe())

    rng = np.random.default_rng(0)
    requests = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, (args.prompt_len,))
        gen = max(1, args.gen - (i % max(1, args.gen // 2)))  # unequal lengths
        requests.append((prompt.astype(np.int32), gen))

    outs = sess.generate(requests)
    stats = sess.last_stats
    print(f"{args.requests} requests, {stats['tokens']} tokens in "
          f"{stats['rounds']} rounds / {stats['wall_s']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s, "
          f"{stats['admitted_mid_flight']} admitted mid-flight)")
    print("generated ids (first request):", outs[0][:16])
    assert all(len(o) == g for o, (_, g) in zip(outs, requests))
    assert all((o >= 0).all() and (o < cfg.vocab_size).all() for o in outs)
    print("serve ok (continuous batching)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--backend", default="actors",
                    choices=("actors", "monolithic"))
    ap.add_argument("--classic", action="store_true",
                    help="force the monolithic batched prefill+decode loop")
    ap.add_argument("--stages", type=int, default=None)
    ap.add_argument("--groups", type=int, default=2)
    ap.add_argument("--slots", type=int, default=2,
                    help="decode slots per request group")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=4,
                    help="batch size of the classic loop")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=0)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args()

    import jax

    from repro.configs.registry import get_config

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    d_, m_ = (int(v) for v in args.mesh.split("x"))
    mesh = jax.make_mesh((d_, m_), ("data", "model"))

    if args.classic or cfg.embed_frontend or cfg.encoder_decoder:
        classic_loop(cfg, args, mesh)
    else:
        continuous_batching(cfg, args, mesh)


if __name__ == "__main__":
    main()
