"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state. ``make_production_mesh`` builds the single-pod 16x16
(data, model) mesh or the 2-pod (pod, data, model) = 512-chip mesh.
"""
from __future__ import annotations


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 4):
    """Small CPU mesh for the distributed test suites."""
    import jax

    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis (§Roofline).
PEAK_BF16_FLOPS = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per chip, one direction)
HBM_BYTES = 16 * 1024**3        # 16 GiB per chip
