"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 50 --batch 8 --seq 128

``--smoke`` uses the reduced config (CPU-scale); without it, the full config
is used (real cluster). The data pipeline is the actor-runtime prefetcher
(paper §6.1); checkpointing every ``--ckpt-every`` steps.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config for CPU")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--zero", action="store_true", default=True)
    ap.add_argument("--no-zero", dest="zero", action="store_false")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="checkpoints/run")
    ap.add_argument("--data-buffers", type=int, default=2)
    ap.add_argument("--mesh", default="1x1",
                    help="data x model, e.g. 2x4 (needs that many devices)")
    args = ap.parse_args()

    import jax

    from repro.configs.registry import get_config
    from repro.data.pipeline import ActorDataPipeline, SyntheticLM
    from repro.optim.adamw import AdamWConfig
    from repro.train.checkpoint import save_checkpoint
    from repro.train.steps import make_train_step

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    d_, m_ = (int(v) for v in args.mesh.split("x"))
    mesh = jax.make_mesh((d_, m_), ("data", "model"))

    ts = make_train_step(cfg, mesh, optimizer=AdamWConfig(lr=args.lr),
                         zero=args.zero)
    params = ts.init_params(jax.random.PRNGKey(0))
    # place params according to their (model) specs
    params = jax.tree.map(
        lambda p, s: jax.device_put(p, jax.sharding.NamedSharding(mesh, s)),
        params, ts.model_param_specs,
        is_leaf=lambda x: not isinstance(x, dict) and not isinstance(x, list))
    if ts.zero:
        params = ts.shard_params_fn(params)   # flat fp32 master shards
    opt_state = ts.init_opt(params)

    src = SyntheticLM(cfg.vocab_size, args.batch, args.seq)
    pipe = ActorDataPipeline(src, num_batches=args.steps,
                             buffers=args.data_buffers)

    t0 = time.time()
    losses = []
    for step, tokens in enumerate(pipe):
        batch = {"tokens": tokens}
        params, opt_state, metrics = ts.step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % 10 == 0 or step == args.steps - 1:
            dt = time.time() - t0
            tok_s = (step + 1) * args.batch * args.seq / dt
            print(f"step {step:4d}  loss {loss:.4f}  "
                  f"grad_norm {float(metrics['grad_norm']):.3f}  "
                  f"{tok_s:,.0f} tok/s")
        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            full = ts.gather_params_fn(params) if ts.zero else params
            save_checkpoint(args.ckpt_dir, {"params": full}, step=step + 1,
                            meta={"arch": cfg.name})
            print(f"  checkpoint @ step {step + 1} -> {args.ckpt_dir}")
    print(f"first loss {losses[0]:.4f} -> last {losses[-1]:.4f} "
          f"({'improved' if losses[-1] < losses[0] else 'NOT improved'})")
    assert losses[-1] < losses[0], "training did not reduce the loss"


if __name__ == "__main__":
    main()
