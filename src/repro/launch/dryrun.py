import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST be the first two lines: jax locks the device count on first init.
#    512 placeholder host devices back the production meshes below.

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination, print memory/cost analysis, and extract the roofline terms.

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --multi-pod
    python -m repro.launch.dryrun --all --out results/dryrun

Outputs one JSON per combination with:
    flops, bytes_accessed (cost_analysis), per-device memory (analytic +
    memory_analysis when the backend provides it), per-collective wire bytes
    (parsed from the lowered StableHLO, scan-body trip counts applied), and
    the three roofline terms per DESIGN/EXPERIMENTS.
"""
import argparse
import json
import math
import pathlib
import re
import sys
import time


def _build(arch: str, shape_name: str, multi_pod: bool, fsdp: bool = False):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.registry import get_config, get_shape, supports_shape
    from repro.launch import specs as S
    from repro.launch.mesh import make_production_mesh
    from repro.train.steps import (make_serve_step, make_train_step,
                                   plan_from_mesh)
    from repro.optim.zero import master_shapes, zero_state_shapes

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if not supports_shape(cfg, shape):
        return None  # documented skip
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = plan_from_mesh(mesh)

    def shard(tree_structs, tree_specs):
        return jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
            tree_structs, tree_specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    if shape.kind == "train":
        ts = make_train_step(cfg, mesh, zero=True, fsdp=fsdp)
        full_s = jax.eval_shape(ts.init_params, jax.random.PRNGKey(0))
        masters_s = shard(master_shapes(full_s, ts.model_param_specs,
                                        ts.plan), ts.param_specs)
        opt_s = shard(zero_state_shapes(full_s, ts.model_param_specs,
                                        ts.plan), ts.opt_specs)
        batch_s = shard(S.train_batch_specs(cfg, shape), ts.batch_specs)
        lowered = ts.step_fn.lower(masters_s, opt_s, batch_s)
        aux = {"params": masters_s, "opt": opt_s}
        return lowered, mesh, cfg, shape, aux

    if shape.kind == "prefill":
        ss = make_serve_step(cfg, mesh, cache_len=shape.seq_len)
        params_s = jax.eval_shape(
            lambda k: __import__("repro.models.model_zoo", fromlist=["x"])
            .build_model(cfg, plan).init(k), jax.random.PRNGKey(0))
        params_s = shard(params_s, ss.param_specs)
        batch_s = shard(S.prefill_batch_specs(cfg, shape), ss.batch_specs)
        lowered = ss.prefill_fn.lower(params_s, batch_s)
        return lowered, mesh, cfg, shape, {"params": params_s}

    # decode
    from repro.launch.specs import serve_plan_for
    from repro.models.model_zoo import build_model, make_decode_caches

    sp = serve_plan_for(cfg, shape)
    ss = make_serve_step(cfg, mesh, cache_len=sp["cache_len"],
                         sliding_window=sp["sliding_window"],
                         ring=sp["ring"], shard_batch=sp["shard_batch"])
    params_s = jax.eval_shape(
        lambda k: build_model(cfg, plan).init(k), jax.random.PRNGKey(0))
    params_s = shard(params_s, ss.param_specs)
    B = shape.global_batch
    B_l = B // plan.dp if sp["shard_batch"] else B
    caches_s = jax.eval_shape(
        lambda: make_decode_caches(cfg, plan, B_l, sp["cache_len"],
                                   ring=sp["ring"]))
    # caches eval_shape gives LOCAL shapes; lift to global per cache spec
    def lift(sds, spec):
        shp = list(sds.shape)
        for dim, entry in enumerate(spec):
            names = entry if isinstance(entry, tuple) else (entry,)
            for n in names:
                if n is None:
                    continue
                shp[dim] *= plan.axis_size(n)
        return jax.ShapeDtypeStruct(
            tuple(shp), sds.dtype, sharding=NamedSharding(mesh, spec))
    caches_s = jax.tree.map(lift, caches_s, ss.cache_specs_,
                            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    tok_s, pos_s = S.decode_io_specs(cfg, shape)
    dspec = (P(plan.data_axes if len(plan.data_axes) > 1 else plan.data_axes[0])
             if sp["shard_batch"] else P())
    tok_s = jax.ShapeDtypeStruct(tok_s.shape, tok_s.dtype,
                                 sharding=NamedSharding(mesh, dspec))
    pos_s = jax.ShapeDtypeStruct(pos_s.shape, pos_s.dtype,
                                 sharding=NamedSharding(mesh, dspec))
    lowered = ss.decode_fn.lower(params_s, caches_s, tok_s, pos_s)
    return lowered, mesh, cfg, shape, {"params": params_s, "caches": caches_s}


# ---------------------------------------------------------------------------
# collective parsing (StableHLO text, scan trip counts applied)
# ---------------------------------------------------------------------------

_TY = re.compile(r"tensor<([0-9x]*?)x?(f32|f64|f16|bf16|i32|i64|i8|ui32|ui8|i1)>")
_DTSIZE = {"f32": 4, "f64": 8, "f16": 2, "bf16": 2, "i32": 4, "i64": 8,
           "i8": 1, "ui32": 4, "ui8": 1, "i1": 1}

_COLL = re.compile(
    r'"?stablehlo\.(all_reduce|all_gather|reduce_scatter|all_to_all|'
    r'collective_permute|collective_broadcast)"?')


def _dims_bytes(dims: str, dt: str):
    n = 1
    for d in dims.split("x"):
        if d:
            n *= int(d)
    return n, n * _DTSIZE[dt]


def _sig_tensors(ln: str):
    """Parse the trailing ``: (tensor<..>, ...) -> tensor<..>`` signature."""
    m = re.search(r":\s*\(([^)]*)\)\s*->\s*(.*)$", ln)
    if not m:
        return [], []
    ins = [_dims_bytes(d, t) for d, t in _TY.findall(m.group(1))]
    outs = [_dims_bytes(d, t) for d, t in _TY.findall(m.group(2))]
    return ins, outs


class _HloTextParser:
    """Walk the lowered StableHLO, tracking while-loop trip counts AND the
    call graph (scan bodies under jax.checkpoint become ``func.call``s),
    collecting collectives + dot_generals with full multipliers.

    XLA's HloCostAnalysis counts a while body ONCE, so for scanned-layer
    models both FLOPs and collective bytes must be re-derived from the text
    with trip counts applied — that is what this parser is for.
    """

    def __init__(self, text: str):
        # per-function records: name -> {"dots", "colls", "calls"}
        self.funcs = {}
        self._parse(text)
        self.collectives = []
        self.dots = []
        self._resolve("main", 1, frozenset())

    def _resolve(self, fname, mult, stack):
        f = self.funcs.get(fname)
        if f is None or fname in stack:
            return
        stack = stack | {fname}
        for d in f["dots"]:
            self.dots.append({**d, "trip": d["trip"] * mult})
        for c in f["colls"]:
            self.collectives.append({**c, "trip": c["trip"] * mult})
        for callee, trip in f["calls"]:
            self._resolve(callee, mult * trip, stack)

    def _parse(self, text: str):
        cur = None
        const = {}
        depth_stack = []  # [entry_depth, trip_or_None, armed]
        brace_depth = 0
        pending = None

        for ln in text.splitlines():
            mfn = re.search(r"func\.func\s+(?:\w+\s+)?@([\w.\-]+)\s*\(", ln)
            if mfn:
                cur = mfn.group(1)
                self.funcs[cur] = {"dots": [], "colls": [], "calls": []}
                const = {}
                depth_stack = []
                brace_depth = 0
                pending = None
            if cur is None:
                continue
            f = self.funcs[cur]

            mconst = re.search(
                r"(%[\w#]+)\s*=\s*stablehlo\.constant dense<(\d+)>\s*:\s*"
                r"tensor<i(?:32|64)>", ln)
            if mconst:
                const[mconst.group(1)] = int(mconst.group(2))

            if "stablehlo.while" in ln:
                depth_stack.append([brace_depth, None, False])
            mcmp = re.search(
                r"compare\s+LT,\s*%iterArg[\w#]*\s*,\s*([%][\w#]+)", ln)
            if mcmp and depth_stack and depth_stack[-1][1] is None:
                depth_stack[-1][1] = const.get(mcmp.group(1), 1)

            trip = 1
            for _, t, _armed in depth_stack:
                trip *= (t or 1)

            if pending is not None:
                ins, outs = _sig_tensors(ln)
                if ins:
                    pending["operand_bytes"] = ins[0][1]
                    pending["out_bytes"] = outs[0][1] if outs else 0
                    f["colls"].append(pending)
                    pending = None

            mcall = re.search(r"(?:func\.call|call)\s+@([\w.\-]+)\s*\(", ln)
            if mcall:
                f["calls"].append((mcall.group(1), trip))

            mcoll = _COLL.search(ln)
            if mcoll:
                g = re.search(r"tensor<(\d+)x(\d+)xi64>", ln)
                gs = int(g.group(2)) if g else 1
                rec = {"kind": mcoll.group(1), "group_size": gs, "trip": trip,
                       "operand_bytes": 0, "out_bytes": 0}
                ins, outs = _sig_tensors(ln)
                if ins:     # signature on the same line (all_gather etc.)
                    rec["operand_bytes"] = ins[0][1]
                    rec["out_bytes"] = outs[0][1] if outs else 0
                    f["colls"].append(rec)
                else:       # region op (all_reduce/reduce_scatter): sig later
                    pending = rec

            if "stablehlo.dot_general" in ln or "stablehlo.dot " in ln:
                ins, outs = _sig_tensors(ln)
                if ins and outs:
                    lhs_n, lhs_b = ins[0]
                    out_n, out_b = outs[0]
                    rhs_b = ins[1][1] if len(ins) > 1 else 0
                    mctr = re.search(
                        r"contracting_dims\s*=\s*\[([\d,\s]*)\]", ln)
                    contract = 1
                    if mctr and mctr.group(1).strip():
                        idxs = [int(v) for v in mctr.group(1).split(",")]
                        msig = re.search(r":\s*\(([^)]*)\)\s*->", ln)
                        mlhs = _TY.search(msig.group(1)) if msig else None
                        if mlhs:
                            lhs_dims = [int(d) for d in
                                        mlhs.group(1).split("x") if d]
                            for i in idxs:
                                contract *= lhs_dims[i]
                    f["dots"].append({
                        "flops": 2.0 * out_n * contract,
                        "bytes": lhs_b + rhs_b + out_b,
                        "trip": trip})

            if depth_stack and not depth_stack[-1][2] and "{" in ln:
                depth_stack[-1][2] = True      # region opened
            brace_depth += ln.count("{") - ln.count("}")
            while depth_stack and depth_stack[-1][2] \
                    and brace_depth <= depth_stack[-1][0]:
                depth_stack.pop()

    @property
    def dot_flops(self):
        return sum(d["flops"] * d["trip"] for d in self.dots)

    @property
    def dot_bytes(self):
        return sum(d["bytes"] * d["trip"] for d in self.dots)


def parse_collectives(text: str):
    return _HloTextParser(text).collectives


def wire_bytes(coll) -> float:
    """Per-device bytes on the wire for one collective execution."""
    b, p = coll["operand_bytes"], max(coll["group_size"], 1)
    k = coll["kind"]
    if p == 1:
        return 0.0
    if k == "all_reduce":
        return 2 * (p - 1) / p * b
    if k == "all_gather":
        return (p - 1) * b          # operand is the local shard
    if k == "reduce_scatter":
        return (p - 1) / p * b
    if k == "all_to_all":
        return (p - 1) / p * b
    if k in ("collective_permute", "collective_broadcast"):
        return b
    return b


def analyze(lowered, mesh, cfg, shape, aux, t_compile_start=None):
    import jax

    n_dev = mesh.devices.size
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:  # pragma: no cover
        cost = {"error": str(e)}

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:
        mem = {"error": str(e)}

    # analytic per-device bytes for the inputs (params + opt + caches + batch)
    def tree_bytes_per_device(tree):
        total = 0
        for leaf in jax.tree.leaves(tree):
            n = math.prod(leaf.shape) * leaf.dtype.itemsize
            spec = leaf.sharding.spec
            denom = 1
            for entry in spec:
                names = entry if isinstance(entry, tuple) else (entry,)
                for nm in names:
                    if nm is not None:
                        denom *= dict(zip(mesh.axis_names,
                                          mesh.devices.shape))[nm]
            total += n / denom
        return total

    analytic = {k: tree_bytes_per_device(v) for k, v in aux.items()}

    text = lowered.as_text()
    parser = _HloTextParser(text)
    colls = parser.collectives
    total_wire = sum(wire_bytes(c) * c["trip"] for c in colls)
    by_kind = {}
    for c in colls:
        by_kind.setdefault(c["kind"], 0.0)
        by_kind[c["kind"]] += wire_bytes(c) * c["trip"]

    from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_BF16_FLOPS

    # NOTE: XLA's HloCostAnalysis counts while (scan) bodies ONCE, so for
    # scanned-layer models the honest per-device numbers come from the text
    # parse with loop trip counts applied. We record both.
    flops_total = max(cost.get("flops", 0.0), parser.dot_flops)
    bytes_total = max(cost.get("bytes accessed", 0.0), parser.dot_bytes)
    compute_s = flops_total / PEAK_BF16_FLOPS
    memory_s = bytes_total / HBM_BW
    coll_s = total_wire / ICI_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", coll_s)), key=lambda kv: kv[1])[0]

    n_active = cfg.param_count(active_only=True)
    n_total = cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2 * n_active * tokens
    hlo_flops_all_devices = flops_total * n_dev
    useful = model_flops / hlo_flops_all_devices if hlo_flops_all_devices else 0.0

    return {
        "arch": cfg.name, "shape": shape.name, "mesh": list(mesh.devices.shape),
        "axis_names": list(mesh.axis_names), "n_devices": n_dev,
        "compile_seconds": compile_s,
        "cost_analysis": cost,
        "memory_analysis": mem,
        "analytic_bytes_per_device": analytic,
        "collectives": {"total_wire_bytes_per_device": total_wire,
                        "by_kind": by_kind,
                        "count": len(colls)},
        "roofline": {"compute_s": compute_s, "memory_s": memory_s,
                     "collective_s": coll_s, "dominant": dominant},
        "model_flops": model_flops,
        "params_total": n_total, "params_active": n_active,
        "useful_flops_ratio": useful,
    }


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            verbose: bool = True, fsdp: bool = False):
    out_path = pathlib.Path(out_dir)
    out_path.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}"
    if fsdp:
        tag += "__fsdp"
    fn = out_path / f"{tag}.json"
    if fn.exists():
        print(f"[skip] {tag} (exists)")
        return json.loads(fn.read_text())
    t0 = time.time()
    built = _build(arch, shape_name, multi_pod, fsdp=fsdp)
    if built is None:
        rec = {"arch": arch, "shape": shape_name, "skipped": True,
               "reason": "documented skip (DESIGN.md §Arch-applicability)"}
        fn.write_text(json.dumps(rec, indent=2))
        print(f"[SKIP] {tag}")
        return rec
    lowered, mesh, cfg, shape, aux = built
    trace_s = time.time() - t0
    rec = analyze(lowered, mesh, cfg, shape, aux)
    rec["trace_seconds"] = trace_s
    fn.write_text(json.dumps(rec, indent=2))
    if verbose:
        r = rec["roofline"]
        print(f"[ok] {tag}: trace {trace_s:.0f}s compile "
              f"{rec['compile_seconds']:.0f}s | compute {r['compute_s']*1e3:.2f}ms "
              f"memory {r['memory_s']*1e3:.2f}ms coll {r['collective_s']*1e3:.2f}ms "
              f"-> {r['dominant']} | useful {rec['useful_flops_ratio']:.2f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    from repro.configs.base import INPUT_SHAPES
    from repro.configs.registry import ARCHITECTURES

    if args.all:
        combos = [(a, s, mp)
                  for a in sorted(ARCHITECTURES)
                  for s in INPUT_SHAPES
                  for mp in (False, True)]
    else:
        combos = [(args.arch, args.shape, args.multi_pod)]
    failures = []
    for a, s, mp in combos:
        try:
            run_one(a, s, mp, args.out, fsdp=args.fsdp)
        except Exception as e:
            failures.append((a, s, mp, repr(e)[:500]))
            print(f"[FAIL] {a} {s} {'multi' if mp else 'single'}: {e!r}",
                  file=sys.stderr)
    if failures:
        print(f"{len(failures)} failures", file=sys.stderr)
        sys.exit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
