"""Data pipeline built ON the actor runtime (paper §6.1, Fig 9).

The paper's claim: OneFlow needs no DALI-style plugin — pipelining falls out
of giving the data-loading actors 2 out-registers each. We reproduce that
literally: loader -> preprocess -> stage(H2D) actors on separate OS threads
with register quotas, feeding the training loop through the req/ack protocol
(back-pressure included: a slow consumer stalls the loader instead of
unbounded buffering).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import numpy as np

from repro.runtime.actor import ActorSpec
from repro.runtime.threaded import ThreadedRuntime


class SyntheticLM:
    """Synthetic token stream: deterministic, seeded, zipf-ish marginals."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0):
        self.vocab, self.batch, self.seq = vocab_size, batch, seq_len
        self.rng = np.random.default_rng(seed)

    def __call__(self, index: int) -> np.ndarray:
        # zipf-flavored ids, clipped to the vocab (cheap but non-uniform)
        z = self.rng.zipf(1.3, size=(self.batch, self.seq + 1))
        return (z % self.vocab).astype(np.int32)


def _augment(tokens: np.ndarray) -> np.ndarray:
    """Stand-in preprocessing (shift/copy) with real CPU cost."""
    return np.ascontiguousarray(tokens)


class ActorDataPipeline:
    """loader -> preprocess -> stage actor chain with register quotas.

    Iterating yields ready batches; the chain runs ahead by exactly
    ``buffers`` batches (the out-register quota), overlapping data work with
    the consumer's compute — Fig 6/Fig 9 behavior on real OS threads.
    """

    def __init__(self, source: Callable[[int], np.ndarray], num_batches: int,
                 buffers: int = 2, preprocess: Callable = _augment):
        self.source = source
        self.num_batches = num_batches
        self.buffers = buffers
        self.preprocess = preprocess
        self._thread: Optional[threading.Thread] = None
        self._build()

    def _build(self) -> None:
        """Persistent actor chain: built once, re-run per epoch. Actors reset
        at the start of each run; the loader's ``on_epoch`` hook rewinds the
        batch counter so every epoch replays the same stream."""
        self.out_q: "queue.Queue" = queue.Queue(maxsize=max(1, self.buffers))
        self._counter = [0]

        def load():
            i = self._counter[0]
            self._counter[0] += 1
            return self.source(i)

        def sink(x):
            self.out_q.put(x)  # bounded queue: blocking = back-pressure
            return 0

        def rewind(_ctx):
            self._counter[0] = 0

        specs = [
            ActorSpec("loader", load, (), out_regs=self.buffers, thread=0,
                      max_fires=self.num_batches, on_epoch=rewind),
            ActorSpec("preprocess", self.preprocess, ("loader",),
                      out_regs=self.buffers, thread=1),
            ActorSpec("stage", sink, ("preprocess",), out_regs=1, thread=2),
        ]
        self.rt = ThreadedRuntime(specs)

    def __iter__(self) -> Iterator[np.ndarray]:
        # a fresh output queue per epoch (sink reads the attribute at call
        # time), so an abandoned iteration can't leak stale batches
        self.out_q = queue.Queue(maxsize=max(1, self.buffers))
        self._thread = threading.Thread(
            target=lambda rt=self.rt: rt.run(timeout=3600), daemon=True)
        self._thread.start()
        for _ in range(self.num_batches):
            yield self.out_q.get()
        self._thread.join(timeout=10.0)

    @property
    def peak_buffered(self) -> int:
        return max(a.peak_regs_in_use for a in self.rt.by_name.values())


class SyncDataPipeline:
    """Baseline without actor prefetch (load+preprocess inline)."""

    def __init__(self, source, num_batches: int, preprocess=_augment):
        self.source, self.n, self.pre = source, num_batches, preprocess

    def __iter__(self):
        for i in range(self.n):
            yield self.pre(self.source(i))
