"""Stateful AdamW optimizer actors + cross-stage global-norm clipping (PR 3).

The tentpole's acceptance criteria, pinned down:

(a) pipeline AdamW — with global-norm clipping and a step-indexed lr
    schedule — is *bit-identical* to the monolithic AdamW reference over
    multiple steps: loss, post-clip gradients, AdamWState (step/mu/nu) and
    params;
(b) optimizer state demonstrably persists across
    ``TrainPipelineExecutor.step`` calls: the step counter advances, mu/nu
    become nonzero, and each step's ``state{s}`` actors feed the previous
    step's state back into the actor graph;
(c) the ``norm`` actor (OneFlow's P→B boxing as an actor — the first
    *sideways* cross-stage edge) fires exactly once per step and its clip
    scale reaches every ``opt{s}``;
(d) gradient accumulation is fp32 even when the backward emits bf16, pinned
    by a bf16 bit-identity test;
(e) executors validate their configuration at construction and
    ``peak_inflight_activations`` is safe before the first step.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.graph import LogicalGraph, partition_stages
from repro.core.lowering import OptimizerSpec, lower_train_stages
from repro.core.placement import Placement
from repro.core.planner import plan
from repro.train.steps import make_graph_train_step, make_pipeline_train_step

B, W, DEPTH = 16, 32, 4


def _train_graph(depth=DEPTH, batch=B, width=W):
    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (batch, width))
    labels = g.input("labels", (batch,), dtype="int32")
    for i in range(depth):
        w = g.input(f"w{i}", (width, width))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < depth - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")
    return g


def _params_and_data(g, seed=0, w_scale=0.1):
    rng = np.random.default_rng(seed)
    params, data = {}, {}
    for t in g.inputs:
        if t.name.startswith("w"):
            params[t.name] = (rng.normal(size=t.shape) * w_scale
                              ).astype(np.float32)
        elif t.dtype == "int32":
            data[t.name] = rng.integers(0, W, size=t.shape).astype(np.int32)
        else:
            data[t.name] = rng.normal(size=t.shape).astype(np.float32)
    return params, data


def _assert_states_equal(ms, ps, params):
    assert int(ms.step) == int(ps.step)
    for n in params:
        assert bool(jnp.all(ms.mu[n] == ps.mu[n])), f"mu[{n}]"
        assert bool(jnp.all(ms.nu[n] == ps.nu[n])), f"nu[{n}]"


class TestAdamWBitIdentical:
    def test_adamw_clip_schedule_matches_monolithic_over_three_steps(self):
        """Criterion (a): loss, clipped grads, AdamWState and params agree
        bitwise for three consecutive steps, with clipping active and a
        decaying lr schedule."""
        g = _train_graph()
        # w_scale=0.5 makes the global grad norm far exceed grad_clip, so
        # the clip scale is genuinely < 1 in every step of this test
        params, data = _params_and_data(g, w_scale=0.5)
        mesh = g.placement.to_mesh()
        opt = OptimizerSpec.adamw(lr=lambda step: 1e-3 * (0.5 ** step),
                                  grad_clip=0.5)
        mono = make_graph_train_step(g, mesh, list(params), ["x", "labels"],
                                     num_microbatches=4, optimizer=opt)
        pipe = make_pipeline_train_step(g, dict(params), ["x", "labels"],
                                        num_microbatches=4, num_stages=4,
                                        mesh=mesh, optimizer=opt)
        mono_params = dict(params)
        for step in range(3):
            ml, mg, mono_params = mono.step(mono_params, data)
            pl, pg, pipe_params = pipe.step(data)
            assert bool(ml == pl), f"loss diverged at step {step}"
            for n in params:
                assert bool(jnp.all(mg[n] == pg[n])), \
                    f"clipped grad {n} diverged at step {step}"
                assert bool(jnp.all(mono_params[n] == pipe_params[n])), \
                    f"param {n} diverged at step {step}"
            _assert_states_equal(mono.opt_state, pipe.opt_state, params)
            # the norm actor's P->B combine equals the monolithic norm and
            # clipping was actually engaged (scale < 1)
            assert float(pipe.last_grad_norm) == float(mono.last_grad_norm)
            assert float(pipe.last_grad_norm) > opt.grad_clip

    def test_sgd_with_global_norm_clipping(self):
        """The norm actor is optimizer-agnostic: SGD + clipping matches the
        monolithic reference bitwise too."""
        g = _train_graph()
        params, data = _params_and_data(g, w_scale=0.5)
        mesh = g.placement.to_mesh()
        opt = OptimizerSpec.sgd(lr=1e-2, grad_clip=1.0)
        mono = make_graph_train_step(g, mesh, list(params), ["x", "labels"],
                                     num_microbatches=4, optimizer=opt)
        pipe = make_pipeline_train_step(g, dict(params), ["x", "labels"],
                                        num_microbatches=4, num_stages=4,
                                        mesh=mesh, optimizer=opt)
        ml, mg, mp = mono.step(dict(params), data)
        pl, pg, pp = pipe.step(data)
        assert bool(ml == pl)
        for n in params:
            assert bool(jnp.all(mg[n] == pg[n]))
            assert bool(jnp.all(mp[n] == pp[n]))
        assert pipe.opt_state is None and mono.opt_state is None

    def test_adamw_unclipped_has_no_norm_actor(self):
        """grad_clip=0 keeps the actor graph free of the sideways edge but
        still trains stateful AdamW bit-identically."""
        g = _train_graph()
        params, data = _params_and_data(g)
        mesh = g.placement.to_mesh()
        opt = OptimizerSpec.adamw(lr=1e-3, grad_clip=0.0)
        mono = make_graph_train_step(g, mesh, list(params), ["x", "labels"],
                                     num_microbatches=4, optimizer=opt)
        pipe = make_pipeline_train_step(g, dict(params), ["x", "labels"],
                                        num_microbatches=4, num_stages=4,
                                        mesh=mesh, optimizer=opt)
        for _ in range(2):
            ml, mg, mp = mono.step(dict(pipe.params), data)
            pl, pg, pp = pipe.step(data)
            assert bool(ml == pl)
            for n in params:
                assert bool(jnp.all(mg[n] == pg[n]))
                assert bool(jnp.all(mp[n] == pp[n]))
        assert "norm" not in pipe.last_history
        assert pipe.last_grad_norm is None

    def test_reference_step_adamw_matches_monolithic(self):
        """The sequential staged reference honors the program's
        OptimizerSpec and agrees bitwise with the monolithic step."""
        g = _train_graph()
        params, data = _params_and_data(g, w_scale=0.5)
        mesh = g.placement.to_mesh()
        opt = OptimizerSpec.adamw(lr=1e-3, grad_clip=0.5)
        p = plan(g)
        part = partition_stages(g, num_stages=4)
        ts = lower_train_stages(g, p, part, list(params), mesh=mesh,
                                optimizer=opt)
        mono = make_graph_train_step(g, mesh, list(params), ["x", "labels"],
                                     num_microbatches=4, optimizer=opt)
        state = None
        mono_params = dict(params)
        ref_params = dict(params)
        for _ in range(2):
            ml, mg, mono_params = mono.step(mono_params, data)
            rl, rg, ref_params, state = ts.reference_step(
                {**ref_params, **data}, ["x", "labels"],
                num_microbatches=4, opt_state=state)
            assert bool(rl == ml)
            for n in params:
                assert bool(jnp.all(rg[n] == mg[n]))
                assert bool(jnp.all(ref_params[n] == mono_params[n]))
        _assert_states_equal(mono.opt_state, state, params)


class TestStatePersistence:
    def test_state_survives_across_step_calls(self):
        """Criterion (b): the executor's per-stage AdamWState advances its
        step counter and accumulates nonzero moments across steps."""
        g = _train_graph()
        params, data = _params_and_data(g)
        mesh = g.placement.to_mesh()
        pipe = make_pipeline_train_step(
            g, dict(params), ["x", "labels"], num_microbatches=4,
            num_stages=4, mesh=mesh, optimizer=OptimizerSpec.adamw(lr=1e-3))
        assert int(pipe.opt_state.step) == 0
        for expected in (1, 2, 3):
            pipe.step(data)
            st = pipe.opt_state
            assert int(st.step) == expected
            assert pipe.step_count == expected
            for n in params:
                assert float(jnp.sum(jnp.abs(st.mu[n]))) > 0
                assert float(jnp.sum(jnp.abs(st.nu[n]))) > 0

    def test_state_actors_in_graph_and_training_progresses(self):
        """Each step's actor graph contains one state{s} source per param
        stage (the second register stream) and the loss decreases."""
        g = _train_graph()
        params, data = _params_and_data(g)
        mesh = g.placement.to_mesh()
        pipe = make_pipeline_train_step(
            g, dict(params), ["x", "labels"], num_microbatches=4,
            num_stages=4, mesh=mesh,
            optimizer=OptimizerSpec.adamw(lr=1e-2, grad_clip=1.0))
        losses = []
        for _ in range(4):
            loss, _, _ = pipe.step(data)
            losses.append(float(loss))
            for s in range(4):
                assert len(pipe.last_history[f"state{s}"]) == 1
                assert len(pipe.last_history[f"opt{s}"]) == 1
        assert losses[-1] < losses[0]

    def test_reference_step_sgd_schedule_uses_step_index(self):
        """Stateless SGD has no opt_state to carry the step count, so the
        caller-provided step_index must drive the lr schedule."""
        g = _train_graph()
        params, data = _params_and_data(g)
        mesh = g.placement.to_mesh()
        opt = OptimizerSpec.sgd(lr=lambda s: 1e-2 if s == 0 else 0.0)
        p = plan(g)
        part = partition_stages(g, num_stages=4)
        ts = lower_train_stages(g, p, part, list(params), mesh=mesh,
                                optimizer=opt)
        _, _, after0, _ = ts.reference_step({**params, **data},
                                            ["x", "labels"],
                                            num_microbatches=4, step_index=0)
        assert any(not np.array_equal(np.asarray(after0[n]), params[n])
                   for n in params)
        _, _, after1, _ = ts.reference_step({**after0, **data},
                                            ["x", "labels"],
                                            num_microbatches=4, step_index=1)
        for n in params:    # lr(1) == 0 -> params frozen
            assert np.array_equal(np.asarray(after1[n]),
                                  np.asarray(after0[n]))

    def test_lr_schedule_is_step_indexed(self):
        """A schedule that zeroes the lr after step 0 freezes params from
        step 1 on — proof the executor resolves lr at its step counter."""
        g = _train_graph()
        params, data = _params_and_data(g)
        mesh = g.placement.to_mesh()
        pipe = make_pipeline_train_step(
            g, dict(params), ["x", "labels"], num_microbatches=4,
            num_stages=4, mesh=mesh,
            optimizer=OptimizerSpec.sgd(lr=lambda s: 1e-2 if s == 0 else 0.0))
        _, _, after0 = pipe.step(data)
        assert any(not np.array_equal(np.asarray(after0[n]), params[n])
                   for n in params)
        _, _, after1 = pipe.step(data)
        for n in params:
            assert np.array_equal(np.asarray(after1[n]),
                                  np.asarray(after0[n]))


class TestNormActor:
    def test_norm_actor_fires_once_and_broadcasts(self):
        """Criterion (c): one norm firing per step, consuming every acc{s}
        partial; every opt actor still fires exactly once."""
        g = _train_graph()
        params, data = _params_and_data(g, w_scale=0.5)
        mesh = g.placement.to_mesh()
        M, S = 8, 4
        pipe = make_pipeline_train_step(
            g, dict(params), ["x", "labels"], num_microbatches=M,
            num_stages=S, mesh=mesh,
            optimizer=OptimizerSpec.adamw(lr=1e-3, grad_clip=0.5))
        for _ in range(2):
            pipe.step(data)
            assert len(pipe.last_history["norm"]) == 1
            for s in range(S):
                assert len(pipe.last_history[f"acc{s}"]) == M
                assert len(pipe.last_history[f"opt{s}"]) == 1
            assert float(pipe.last_grad_norm) > 0

    def test_quota_still_bounds_inflight_with_optimizer_actors(self):
        """The sideways norm edge must not break the 1F1B back-pressure."""
        g = _train_graph()
        params, data = _params_and_data(g)
        mesh = g.placement.to_mesh()
        S, M = 4, 8
        for regs in ([1] * S, [S - s for s in range(S)]):
            pipe = make_pipeline_train_step(
                g, dict(params), ["x", "labels"], num_microbatches=M,
                num_stages=S, mesh=mesh, regs=regs,
                optimizer=OptimizerSpec.adamw(lr=1e-3, grad_clip=1.0))
            pipe.step(data)
            for s in range(S):
                assert pipe.last_peak_regs[f"f{s}"] <= regs[s]


class TestFp32Accumulation:
    def _bf16_graph(self):
        placement = Placement(("d",), (1,), device_kind="cpu")
        g = LogicalGraph(placement)
        x = g.input("x", (8, 16))
        labels = g.input("labels", (8,), dtype="int32")
        w0 = g.input("w0", (16, 16), dtype="bfloat16")
        w1 = g.input("w1", (16, 16), dtype="bfloat16")
        with g.stage(0):
            h = g.unary(g.matmul(x, w0, name="mm0"), "relu", name="relu0")
        with g.stage(1):
            h = g.matmul(h, w1, name="mm1")
            g.softmax_xent(h, labels, name="loss")
        return g

    @pytest.mark.parametrize("opt", [
        OptimizerSpec.sgd(lr=1e-2, grad_clip=1.0),
        OptimizerSpec.adamw(lr=1e-3, grad_clip=1.0),
    ], ids=["sgd", "adamw"])
    def test_bf16_grads_accumulate_in_fp32_bit_identical(self, opt):
        """Criterion (d): with bf16 params (hence bf16 per-microbatch
        gradients from the backward) the acc actors accumulate in fp32 and
        the whole step stays bit-identical to the monolithic reference."""
        g = self._bf16_graph()
        rng = np.random.default_rng(1)
        params = {n: jnp.asarray(rng.normal(size=(16, 16)) * 0.1,
                                 jnp.bfloat16) for n in ("w0", "w1")}
        data = {"x": rng.normal(size=(8, 16)).astype(np.float32),
                "labels": rng.integers(0, 16, size=(8,)).astype(np.int32)}
        mesh = g.placement.to_mesh()
        mono = make_graph_train_step(g, mesh, list(params), ["x", "labels"],
                                     num_microbatches=4, optimizer=opt)
        pipe = make_pipeline_train_step(g, dict(params), ["x", "labels"],
                                        num_microbatches=4, mesh=mesh,
                                        optimizer=opt)
        mp = dict(params)
        for step in range(2):
            ml, mg, mp = mono.step(mp, data)
            pl, pg, pp = pipe.step(data)
            assert bool(ml == pl), f"step {step}"
            for n in params:
                # fp32 accumulation is the contract, not just a detail
                assert pg[n].dtype == jnp.float32
                assert pp[n].dtype == jnp.bfloat16
                assert bool(jnp.all(mg[n] == pg[n])), f"{n} step {step}"
                assert bool(jnp.all(mp[n] == pp[n])), f"{n} step {step}"


class TestExecutorValidation:
    def test_peak_inflight_is_zero_before_first_step(self):
        """Criterion (e): no KeyError/ValueError on an executor that has
        not run yet."""
        g = _train_graph()
        params, _ = _params_and_data(g)
        pipe = make_pipeline_train_step(g, dict(params), ["x", "labels"],
                                        num_microbatches=4, num_stages=4,
                                        mesh=g.placement.to_mesh())
        assert pipe.peak_inflight_activations == 0

    def test_invalid_num_microbatches_rejected_at_construction(self):
        g = _train_graph()
        params, _ = _params_and_data(g)
        with pytest.raises(ValueError, match="num_microbatches"):
            make_pipeline_train_step(g, dict(params), ["x", "labels"],
                                     num_microbatches=0, num_stages=4,
                                     mesh=g.placement.to_mesh())

    def test_wrong_regs_length_rejected_at_construction(self):
        g = _train_graph()
        params, _ = _params_and_data(g)
        with pytest.raises(ValueError, match="register quotas"):
            make_pipeline_train_step(g, dict(params), ["x", "labels"],
                                     num_microbatches=4, num_stages=4,
                                     mesh=g.placement.to_mesh(),
                                     regs=[1, 1])

    def test_unknown_microbatch_input_rejected_at_construction(self):
        g = _train_graph()
        params, _ = _params_and_data(g)
        with pytest.raises(ValueError, match="not a graph input"):
            make_pipeline_train_step(g, dict(params), ["nope"],
                                     num_microbatches=4, num_stages=4,
                                     mesh=g.placement.to_mesh())

    def test_unknown_optimizer_kind_rejected(self):
        with pytest.raises(ValueError, match="optimizer kind"):
            OptimizerSpec(kind="rmsprop")
