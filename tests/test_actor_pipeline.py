"""Stage-partitioned actor executor tests (paper §4.3 made executable).

The compiler cuts the logical graph into pipeline stages, lowers each stage
to its own jitted program, and the actor runtime drives them with register
quotas — these tests pin down that the whole path is *numerically identical*
to the monolithic ``lower_plan`` execution.
"""
import numpy as np
import pytest

from repro.core.graph import LogicalGraph, partition_stages
from repro.core.lowering import lower_plan, lower_stages
from repro.core.placement import Placement
from repro.core.planner import plan
from repro.runtime import ActorPipelineExecutor, ActorSpec, ThreadedRuntime


def _mlp_graph(depth=4, batch=32, width=64):
    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (batch, width))
    for i in range(depth):
        w = g.input(f"w{i}", (width, width))
        h = g.matmul(h, w, name=f"mm{i}")
        h = g.unary(h, "relu", name=f"relu{i}")
    return g


def _inputs_for(g, seed=0):
    rng = np.random.default_rng(seed)
    return {t.name: rng.normal(size=t.shape).astype(np.float32)
            for t in g.inputs}


class TestStagePartition:
    def test_balanced_partition_is_contiguous_and_monotone(self):
        g = _mlp_graph(depth=6)
        part = partition_stages(g, num_stages=3)
        assert part.num_stages == 3
        # contiguous in topo order -> stage ids nondecreasing
        stages = [part.stage_of[op.name] for op in g.topo_ops()]
        assert stages == sorted(stages)
        assert set(stages) == {0, 1, 2}
        # every edge goes forward
        for op in g.ops:
            for t in op.inputs:
                if t.producer is not None:
                    assert part.stage_of[t.producer.name] <= part.stage_of[op.name]

    def test_balanced_partition_splits_cost(self):
        from repro.core.graph import op_cost
        g = _mlp_graph(depth=8)
        part = partition_stages(g, num_stages=4)
        costs = [sum(op_cost(op) for op in part.ops_in(g, s)) for s in range(4)]
        assert max(costs) <= 2.0 * min(costs)  # near-balanced

    def test_balanced_partition_backloaded_costs(self):
        """One huge op at the end must not swallow every stage: the cut is
        forced so each trailing stage stays non-empty."""
        placement = Placement(("d",), (1,), device_kind="cpu")
        g = LogicalGraph(placement)
        x = g.input("x", (4, 4))
        h = g.unary(x, "relu", name="cheap0")       # tiny
        h = g.unary(h, "relu", name="cheap1")       # tiny
        w = g.input("w", (4, 4096))
        g.matmul(h, w, name="huge")                 # dominates cost
        part = partition_stages(g, num_stages=3)
        assert part.stage_of == {"cheap0": 0, "cheap1": 1, "huge": 2}

    def test_user_annotations_respected(self):
        placement = Placement(("d",), (1,), device_kind="cpu")
        g = LogicalGraph(placement)
        x = g.input("x", (8, 16))
        w0 = g.input("w0", (16, 16))
        w1 = g.input("w1", (16, 16))
        with g.stage(0):
            h = g.matmul(x, w0, name="a")
        with g.stage(1):
            y = g.matmul(h, w1, name="b")
        part = partition_stages(g)
        assert part.stage_of == {"a": 0, "b": 1}

    def test_non_monotone_annotation_rejected(self):
        placement = Placement(("d",), (1,), device_kind="cpu")
        g = LogicalGraph(placement)
        x = g.input("x", (8, 16))
        w0 = g.input("w0", (16, 16))
        w1 = g.input("w1", (16, 16))
        with g.stage(1):
            h = g.matmul(x, w0, name="a")
        with g.stage(0):
            g.matmul(h, w1, name="b")
        with pytest.raises(ValueError, match="non-monotone"):
            partition_stages(g)

    def test_mixed_annotation_rejected(self):
        placement = Placement(("d",), (1,), device_kind="cpu")
        g = LogicalGraph(placement)
        x = g.input("x", (8, 16))
        w0 = g.input("w0", (16, 16))
        w1 = g.input("w1", (16, 16))
        with g.stage(0):
            h = g.matmul(x, w0, name="a")
        g.matmul(h, w1, name="b")  # unannotated
        with pytest.raises(ValueError, match="mixed stage annotation"):
            partition_stages(g)


class TestStagedLowering:
    def test_staged_equals_monolithic_bitwise(self):
        g = _mlp_graph(depth=4)
        p = plan(g)
        mesh = g.placement.to_mesh()
        part = partition_stages(g, num_stages=4)
        mono = lower_plan(g, p, mesh)
        staged = lower_stages(g, p, part, mesh=mesh)
        inputs = _inputs_for(g)
        args = [inputs[t.name] for t in g.inputs]
        a, b = mono(*args), staged(*args)
        assert len(a) == len(b) == 1
        assert np.array_equal(np.asarray(a[0]), np.asarray(b[0]))

    def test_physical_program_always_returns_tuple(self):
        g = _mlp_graph(depth=2)
        p = plan(g)
        prog = lower_plan(g, p, g.placement.to_mesh())
        out = prog(*[_inputs_for(g)[t.name] for t in g.inputs])
        assert isinstance(out, tuple) and len(out) == 1


class TestActorPipelineExecutor:
    def test_actor_execution_bitwise_equals_monolithic(self):
        """The acceptance criterion: actor-driven stage execution over
        microbatches reproduces direct lower_plan execution exactly."""
        g = _mlp_graph(depth=4, batch=32)
        p = plan(g)
        mesh = g.placement.to_mesh()
        part = partition_stages(g, num_stages=4)
        mono = lower_plan(g, p, mesh)
        staged = lower_stages(g, p, part, mesh=mesh)
        inputs = _inputs_for(g)

        ex = ActorPipelineExecutor(staged, ["x"], num_microbatches=4)
        got = ex.run(inputs)
        ref = mono(*(inputs[t.name] for t in g.inputs))
        assert np.array_equal(got[0], np.asarray(ref[0]))
        # every stage actor fired once per microbatch
        assert all(len(h) == 4 for h in ex.last_history.values())

    def test_register_quota_bounds_in_flight_microbatches(self):
        g = _mlp_graph(depth=4, batch=32)
        p = plan(g)
        part = partition_stages(g, num_stages=4)
        staged = lower_stages(g, p, part, mesh=g.placement.to_mesh())
        inputs = _inputs_for(g)
        for quota in (1, 2):
            ex = ActorPipelineExecutor(staged, ["x"], num_microbatches=8,
                                       regs=[quota] * 4)
            ex.run(inputs)
            assert all(ex.last_peak_regs[f"stage{s}"] <= quota
                       for s in range(4))

    def test_annotated_stages_with_mid_graph_sink(self):
        """Sinks produced before the last stage are carried through the chain
        and reassembled correctly."""
        placement = Placement(("d",), (1,), device_kind="cpu")
        g = LogicalGraph(placement)
        x = g.input("x", (16, 32))
        w0 = g.input("w0", (32, 32))
        w1 = g.input("w1", (32, 32))
        with g.stage(0):
            h = g.matmul(x, w0, name="mm0")
        with g.stage(1):
            early = g.unary(h, "relu", name="early_sink")  # sink at stage 1
        with g.stage(1):
            h2 = g.matmul(h, w1, name="mm1")
        with g.stage(2):
            g.unary(h2, "tanh", name="late_sink")
        p = plan(g)
        mesh = placement.to_mesh()
        part = partition_stages(g)
        mono = lower_plan(g, p, mesh)
        staged = lower_stages(g, p, part, mesh=mesh)
        inputs = _inputs_for(g)
        ex = ActorPipelineExecutor(staged, ["x"], num_microbatches=2)
        got = ex.run(inputs)
        ref = mono(*(inputs[t.name] for t in g.inputs))
        assert len(got) == len(ref) == 2
        for gv, rv in zip(got, ref):
            assert np.array_equal(gv, np.asarray(rv))

    def test_weights_only_sink_not_concatenated(self):
        """A sink independent of the microbatched input is recomputed
        identically per microbatch; the executor must return one copy with
        the reference shape, not M stacked copies."""
        placement = Placement(("d",), (1,), device_kind="cpu")
        g = LogicalGraph(placement)
        x = g.input("x", (16, 32))
        w0 = g.input("w0", (32, 32))
        with g.stage(0):
            h = g.matmul(x, w0, name="mm0")
        with g.stage(1):
            g.unary(h, "relu", name="act_sink")
            g.unary(w0, "tanh", name="w_sink")      # weights-only sink
        p = plan(g)
        mesh = placement.to_mesh()
        part = partition_stages(g)
        mono = lower_plan(g, p, mesh)
        staged = lower_stages(g, p, part, mesh=mesh)
        inputs = _inputs_for(g)
        got = ActorPipelineExecutor(staged, ["x"], num_microbatches=4).run(inputs)
        ref = mono(*(inputs[t.name] for t in g.inputs))
        for gv, rv in zip(got, ref):
            assert gv.shape == np.asarray(rv).shape
            assert np.array_equal(gv, np.asarray(rv))


class TestThreadedZeroConsumer:
    def test_zero_consumer_actor_recycles_immediately(self):
        """nrefs == 0 branch of Actor.fire on the real threaded runtime: a
        bounded producer with no consumers completes and its quota is fully
        restored after every fire."""
        specs = [ActorSpec("lonely", lambda version: version, (), out_regs=2,
                           max_fires=5, thread=0, wants_version=True)]
        rt = ThreadedRuntime(specs, collect_outputs_of="lonely")
        outs = rt.run(timeout=10.0)
        a = rt.by_name["lonely"]
        assert a.fired == 5
        assert outs == [0, 1, 2, 3, 4]
        assert a.out_counter == 2          # quota fully restored
        assert not a.refcount              # nothing left referenced
        assert a.peak_regs_in_use == 0     # recycled before the peak sample
