"""First unit tests for repro.optim.zero (paper §6.4: ZeRO from SBP).

Everything here runs eagerly on one device: the flat-shard layout helpers
are pure metadata, and with ``dp=1``/``tp=1`` the shard/gather/update paths
contain no collectives, so the ZeRO update can be checked bit-for-bit
against the plain replicated-DP baseline it must agree with.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import MeshPlan
from repro.optim.adamw import AdamWConfig, AdamWState
from repro.optim.zero import (ZeroState, _chunk_size, combine_model_grads,
                              gather_master_local, init_zero_state_local,
                              local_shape_of, master_shapes,
                              model_combine_tree, plain_dp_adamw_update,
                              shard_master_local, zero_adamw_update,
                              zero_state_shapes)


class TestFlatShardLayout:
    def test_chunk_size_is_ceil_division(self):
        assert _chunk_size(8, 2) == 4
        assert _chunk_size(7, 2) == 4      # padded, not truncated
        assert _chunk_size(1, 4) == 1
        assert _chunk_size(12, 1) == 12

    def test_local_shape_of_divides_sharded_dims(self):
        plan = MeshPlan(("data", "model"), (2, 4))
        assert local_shape_of((8, 12), ("data", None), plan) == (4, 12)
        assert local_shape_of((8, 12), (None, "model"), plan) == (8, 3)
        assert local_shape_of((16, 5), (("data", "model"), None),
                              plan) == (2, 5)
        assert local_shape_of((8, 12), (None, None), plan) == (8, 12)

    def test_master_shapes_are_dp_tp_chunk(self):
        plan = MeshPlan(("data", "model"), (2, 1))
        params = {"w": jax.ShapeDtypeStruct((7, 1), jnp.bfloat16)}
        shapes = master_shapes(params, {"w": (None, None)}, plan)
        # 7 local elements over dp=2 -> chunk 4 (one padded slot), fp32
        assert shapes["w"].shape == (2, 1, 4)
        assert shapes["w"].dtype == jnp.float32

    def test_zero_state_shapes_matches_masters(self):
        # regression: zero_state_shapes was once shadowed by a dead
        # ``= None`` placeholder — pin that it is the real function
        plan = MeshPlan(("data", "model"), (2, 1))
        params = {"w": jax.ShapeDtypeStruct((6, 2), jnp.float32)}
        st = zero_state_shapes(params, {"w": (None, None)}, plan)
        assert isinstance(st, ZeroState)
        assert st.step.shape == () and st.step.dtype == jnp.int32
        want = master_shapes(params, {"w": (None, None)}, plan)
        assert st.mu["w"].shape == want["w"].shape
        assert st.nu["w"].shape == want["w"].shape


class TestShardGatherRoundtrip:
    def test_roundtrip_single_device(self):
        plan = MeshPlan.single_device()
        p = jnp.asarray(np.random.default_rng(0).normal(size=(5, 3)),
                        jnp.float32)
        m = shard_master_local(p, plan)
        assert m.shape == (1, 1, 15) and m.dtype == jnp.float32
        back = gather_master_local(m, (5, 3), jnp.float32, plan)
        assert back.shape == (5, 3)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(p))

    def test_gather_casts_to_compute_dtype(self):
        # the Fig-14 cast op: masters are fp32, the gathered copy is not
        plan = MeshPlan.single_device()
        p = jnp.ones((4, 4), jnp.float32) * 1.5
        out = gather_master_local(shard_master_local(p, plan), (4, 4),
                                  jnp.bfloat16, plan)
        assert out.dtype == jnp.bfloat16

    def test_init_zero_state_local_is_zeroed(self):
        plan = MeshPlan.single_device()
        masters = {"w": shard_master_local(jnp.ones((3, 3)), plan)}
        st = init_zero_state_local(masters, plan)
        assert int(st.step) == 0
        assert not np.any(np.asarray(st.mu["w"]))
        assert not np.any(np.asarray(st.nu["w"]))
        # mu and nu must be independent buffers, not aliases
        assert st.mu["w"] is not st.nu["w"]


class TestModelCombine:
    def test_combine_tree_none_for_model_sharded_else_sum(self):
        plan = MeshPlan(("data", "model"), (1, 2))
        specs = {"wq": P(None, "model"), "norm": P(None, None),
                 "wo": P("model", None)}
        assert model_combine_tree(specs, plan) == {
            "wq": "none", "norm": "sum", "wo": "none"}

    def test_combine_is_identity_when_tp_1(self):
        plan = MeshPlan(("data", "model"), (2, 1))
        grads = {"w": jnp.ones((2, 2))}
        out = combine_model_grads(grads, {"w": "sum"}, plan)
        assert out["w"] is grads["w"]


class TestZeroUpdateAgainstPlainDP:
    """On one device ZeRO is plain AdamW on a flattened view — the update,
    clip norm, and moments must agree with the replicated baseline bitwise.
    """

    def _setup(self):
        rng = np.random.default_rng(7)
        plan = MeshPlan.single_device()
        cfg = AdamWConfig(lr=1e-2, weight_decay=0.1, grad_clip=1.0)
        params = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
                  "b": jnp.asarray(rng.normal(size=(3,)), jnp.float32)}
        grads = {"w": jnp.asarray(rng.normal(size=(4, 3)) * 3, jnp.float32),
                 "b": jnp.asarray(rng.normal(size=(3,)) * 3, jnp.float32)}
        ones = {"w": 1.0, "b": 1.0}
        return plan, cfg, params, grads, ones

    def test_bitwise_match_and_state_step(self):
        plan, cfg, params, grads, ones = self._setup()
        masters = {n: shard_master_local(p, plan) for n, p in params.items()}
        gflat = {n: shard_master_local(g, plan) for n, g in grads.items()}
        zst = init_zero_state_local(masters, plan)
        new_m, zst2, znorm = zero_adamw_update(cfg, masters, gflat, zst,
                                               plan, ones)

        ast = AdamWState(jnp.zeros((), jnp.int32),
                         {n: jnp.zeros_like(p) for n, p in params.items()},
                         {n: jnp.zeros_like(p) for n, p in params.items()})
        new_p, ast2, pnorm = plain_dp_adamw_update(cfg, params, grads, ast,
                                                   plan, ones)

        assert np.asarray(znorm) == np.asarray(pnorm)
        assert int(zst2.step) == int(ast2.step) == 1
        for n, p in params.items():
            got = gather_master_local(new_m[n], p.shape, jnp.float32, plan)
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(new_p[n]), err_msg=n)
            got_mu = gather_master_local(zst2.mu[n], p.shape, jnp.float32,
                                         plan)
            np.testing.assert_array_equal(np.asarray(got_mu),
                                          np.asarray(ast2.mu[n]), err_msg=n)

    def test_clip_actually_clips(self):
        plan, cfg, params, grads, ones = self._setup()
        masters = {n: shard_master_local(p, plan) for n, p in params.items()}
        gflat = {n: shard_master_local(g, plan) for n, g in grads.items()}
        _, _, norm = zero_adamw_update(cfg, masters, gflat,
                                       init_zero_state_local(masters, plan),
                                       plan, ones)
        assert float(norm) > cfg.grad_clip    # the scale path was exercised

    def test_two_steps_advance_moments(self):
        plan, cfg, params, grads, ones = self._setup()
        masters = {n: shard_master_local(p, plan) for n, p in params.items()}
        gflat = {n: shard_master_local(g, plan) for n, g in grads.items()}
        st = init_zero_state_local(masters, plan)
        m1, st1, _ = zero_adamw_update(cfg, masters, gflat, st, plan, ones)
        m2, st2, _ = zero_adamw_update(cfg, m1, gflat, st1, plan, ones)
        assert int(st2.step) == 2
        assert not np.array_equal(np.asarray(m1["w"]), np.asarray(m2["w"]))
        assert not np.array_equal(np.asarray(st1.nu["w"]),
                                  np.asarray(st2.nu["w"]))


class TestFlatStreamKernels:
    """The hoisted global kernels the opt actors run (repro.optim.zero
    ``shard_flat``/``gather_flat``/``init_zero_flat``/``zero_stage_update``):
    flat ``(dp, 1, chunk)`` fp32 layout, zero padding preserved through
    AdamW, and bitwise agreement with the dense reference update."""

    def _tensors(self, seed=11):
        rng = np.random.default_rng(seed)
        params = {"w": jnp.asarray(rng.normal(size=(3, 5)), jnp.float32),
                  "b": jnp.asarray(rng.normal(size=(7,)), jnp.float32)}
        grads = {n: jnp.asarray(rng.normal(size=p.shape) * 2, jnp.float32)
                 for n, p in params.items()}
        return params, grads

    def test_shard_gather_roundtrip_dp2_with_padding(self):
        from repro.optim.zero import gather_flat, shard_flat
        params, _ = self._tensors()
        for n, p in params.items():
            m = shard_flat(p, dp=2)
            nelem = int(np.prod(p.shape))
            chunk = -(-nelem // 2)
            assert m.shape == (2, 1, chunk) and m.dtype == jnp.float32
            # padding slots are exactly zero
            flat = np.asarray(m).reshape(-1)
            assert not np.any(flat[nelem:])
            back = gather_flat(m, shape=p.shape, dtype="float32")
            np.testing.assert_array_equal(np.asarray(back), np.asarray(p),
                                          err_msg=n)

    def test_gather_casts_before_reshape(self):
        # Fig 14: the cast happens on the flat shard (before the gather in
        # the multi-device lowering), so the output is compute-dtype
        from repro.optim.zero import gather_flat, shard_flat
        p = jnp.asarray(np.random.default_rng(0).normal(size=(4, 4)),
                        jnp.float32)
        out = gather_flat(shard_flat(p, dp=2), shape=(4, 4), dtype="bfloat16")
        assert out.dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(p.astype(jnp.bfloat16)))

    def test_zero_stage_update_matches_dense_adamw_bitwise(self):
        from repro.optim.adamw import AdamWState, adamw_math
        from repro.optim.zero import (gather_flat, init_zero_flat,
                                      shard_flat, zero_stage_update)
        params, grads = self._tensors()
        lr, b1, b2, eps, wd = 1e-2, 0.9, 0.999, 1e-8, 0.1

        masters = {n: shard_flat(p, dp=2) for n, p in params.items()}
        st = init_zero_flat(masters)
        new_m, st2 = zero_stage_update(masters, grads, st, lr, dp=2,
                                       beta1=b1, beta2=b2, eps=eps,
                                       weight_decay=wd)

        step = jnp.asarray(1, jnp.int32)
        for n, p in params.items():
            dp_, dmu, dnu = adamw_math(p, grads[n], jnp.zeros_like(p),
                                       jnp.zeros_like(p), step, lr, b1, b2,
                                       eps, wd)
            got = gather_flat(new_m[n], shape=p.shape, dtype="float32")
            np.testing.assert_array_equal(np.asarray(got), np.asarray(dp_),
                                          err_msg=n)
            got_mu = gather_flat(st2.mu[n], shape=p.shape, dtype="float32")
            np.testing.assert_array_equal(np.asarray(got_mu),
                                          np.asarray(dmu), err_msg=n)
        assert int(st2.step) == 1

    def test_padding_stays_zero_through_update(self):
        # zero grads on zero padding -> AdamW moves padding by
        # -lr*wd*0 - lr*0/(sqrt(0)+eps) = 0; the invariant that makes the
        # shard/gather round-trip lossless across steps
        from repro.optim.zero import (init_zero_flat, shard_flat,
                                      zero_stage_update)
        p = jnp.asarray(np.arange(7), jnp.float32)       # chunk pads 7 -> 8
        g = jnp.ones((7,), jnp.float32)
        masters = {"w": shard_flat(p, dp=2)}
        st = init_zero_flat(masters)
        for _ in range(3):
            masters, st = zero_stage_update(masters, {"w": g}, st, 1e-2,
                                            dp=2, beta1=0.9, beta2=0.999,
                                            eps=1e-8, weight_decay=0.1)
        for t in (masters["w"], st.mu["w"], st.nu["w"]):
            assert np.asarray(t).reshape(-1)[7] == 0.0
