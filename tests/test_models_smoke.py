"""Per-architecture smoke tests: reduced config (2 layers, d_model<=256,
<=4 experts) of the same family, one forward/train step + one decode step on
CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCHITECTURES
from repro.models.common import MeshPlan
from repro.models.model_zoo import build_model

ARCH_NAMES = sorted(ARCHITECTURES)
PLAN = MeshPlan.single_device()
B, S = 2, 32
CACHE_LEN = 64


def make_batch(cfg: ModelConfig, rng):
    batch = {}
    if cfg.embed_frontend and not cfg.encoder_decoder:
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32))
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(B, S + 1)).astype(np.int32))
    if cfg.encoder_decoder:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)).astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_grad(arch):
    cfg = ARCHITECTURES[arch].reduced()
    bundle = build_model(cfg, PLAN)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = make_batch(cfg, rng)

    loss, metrics = jax.jit(bundle.loss_fn)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0

    # one SGD step: gradients exist and are finite for every leaf
    def scalar_loss(p):
        return bundle.loss_fn(p, batch)[0]

    grads = jax.jit(jax.grad(scalar_loss))(params)
    leaves = jax.tree.leaves(grads)
    assert leaves, arch
    for g in leaves:
        assert np.all(np.isfinite(np.asarray(g, np.float32))), \
            f"{arch}: non-finite grad"
    # at least some gradient signal somewhere
    total = sum(float(jnp.sum(jnp.abs(g))) for g in leaves)
    assert total > 0, f"{arch}: zero gradients"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_prefill_decode(arch):
    cfg = ARCHITECTURES[arch].reduced()
    bundle = build_model(cfg, PLAN)
    params = bundle.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    batch = make_batch(cfg, rng)

    h_last, caches = jax.jit(
        lambda p, b: bundle.prefill(p, b, CACHE_LEN))(params, batch)
    assert h_last.shape == (B, 1, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(h_last, np.float32)))

    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B,)), jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    logits, new_caches = jax.jit(bundle.decode_step)(params, caches, tok, pos)
    assert logits.shape == (B, cfg.padded_vocab())
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), \
        f"{arch}: non-finite decode logits"

    # a second step at pos+1 must also work (cache threading)
    logits2, _ = jax.jit(bundle.decode_step)(params, new_caches, tok, pos + 1)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "deepseek-v2-lite-16b",
                                  "mamba2-370m", "jamba-v0.1-52b"])
def test_decode_matches_prefill_continuation(arch):
    """Decoding token t from a prefill of t-1 tokens must give (approximately)
    the hidden state a full prefill of t tokens would."""
    cfg = ARCHITECTURES[arch].reduced()
    bundle = build_model(cfg, PLAN)
    params = bundle.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab_size, size=(B, S + 1)).astype(np.int32)

    # full prefill over S+1 tokens
    full, _ = jax.jit(lambda p, b: bundle.prefill(p, b, CACHE_LEN))(
        params, {"tokens": jnp.asarray(toks)})
    # prefill S tokens, decode token S
    _, caches = jax.jit(lambda p, b: bundle.prefill(p, b, CACHE_LEN))(
        params, {"tokens": jnp.asarray(toks[:, :S])})
    logits, _ = jax.jit(bundle.decode_step)(
        params, caches, jnp.asarray(toks[:, S]), jnp.full((B,), S, jnp.int32))

    # compare the decode logits to unembed(full last hidden)
    ref_logits = np.asarray(full[:, 0] @ params["unembed"], np.float32)
    got = np.asarray(logits, np.float32)
    np.testing.assert_allclose(got, ref_logits, rtol=2e-2, atol=2e-2)


def test_param_counts_are_plausible():
    """6·N·D sanity: full-config param counts within 40% of the nameplate."""
    expected = {
        "llama3-8b": 8.0e9, "qwen2.5-3b": 3.1e9, "mamba2-370m": 0.37e9,
        "phi4-mini-3.8b": 3.8e9, "deepseek-v2-lite-16b": 15.7e9,
        "pixtral-12b": 12.0e9, "deepseek-v3-671b": 671e9,
        "qwen3-1.7b": 1.7e9, "jamba-v0.1-52b": 52e9, "whisper-medium": 0.76e9,
    }
    for name, nominal in expected.items():
        n = ARCHITECTURES[name].param_count()
        assert 0.6 * nominal < n < 1.6 * nominal, \
            f"{name}: {n/1e9:.2f}B vs nominal {nominal/1e9:.2f}B"
