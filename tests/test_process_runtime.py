"""Process-backed actor runtime: one OS worker per node id, real transport.

The spec builders here are module-level classes so they pickle under the
``spawn`` start method; each worker invokes the builder locally, so the
actor closures themselves never cross a process boundary — only the
builder's plain-data attributes do.
"""
import pickle

import numpy as np
import pytest

from repro.runtime import (ActorSpec, InferSpecBuilder, ProcessRuntime,
                           WorkerError)


class ChainBuilder:
    """src(node 0) -> mid(node 1) -> sink(node 2): base+v -> x+1 -> x*2.

    ``src`` emits a small float32 vector so the cross-node edges carry
    measurable bytes; its ``on_epoch`` hook accepts a per-epoch base value
    through ``ctx``. ``src`` also stashes a private (unpicklable) value
    under a ``"__"`` key: it must be stripped at the node boundary, never
    pickled onto the wire.
    """

    def __init__(self, n=4):
        self.n = n

    def __call__(self):
        base = [0.0]

        def set_base(v):
            if v is not None:
                base[0] = float(v)

        def src(version):
            return {"x": np.full((8,), base[0] + version, np.float32),
                    "__local_only__": lambda: None}

        def mid(p, version):
            assert "__local_only__" not in p, sorted(p)
            assert isinstance(p["x"], np.ndarray)
            return {"x": p["x"] + 1.0}

        def sink(p, version):
            assert "__local_only__" not in p, sorted(p)
            return p["x"] * 2.0

        specs = [
            ActorSpec("src", src, (), out_regs=2, max_fires=self.n,
                      node=0, thread=0, wants_version=True,
                      on_epoch=set_base),
            ActorSpec("mid", mid, ("src",), out_regs=2, node=1, thread=0,
                      wants_version=True),
            ActorSpec("sink", sink, ("mid",), out_regs=2, node=2, thread=0,
                      wants_version=True),
        ]
        return specs, "sink"


class CrashBuilder:
    """Two nodes; the node-1 actor raises on its third fire."""

    def __call__(self):
        def boom(x, version):
            if version == 2:
                raise RuntimeError("kaboom on version 2")
            return x

        specs = [
            ActorSpec("src", _emit_version, (), out_regs=2, max_fires=6,
                      node=0, thread=0, wants_version=True),
            ActorSpec("bad", boom, ("src",), out_regs=2, node=1, thread=0,
                      wants_version=True),
        ]
        return specs, "bad"


class StuckBuilder:
    """``sink`` needs both ``src`` and ``never``; ``never`` has no fires,
    so ``src`` stalls against its register quota and the epoch never
    completes."""

    def __call__(self):
        specs = [
            ActorSpec("src", _emit_version, (), out_regs=2, max_fires=3,
                      node=0, thread=0, wants_version=True),
            ActorSpec("never", _emit_version, (), out_regs=1, max_fires=0,
                      node=0, thread=1, wants_version=True),
            ActorSpec("sink", lambda a, b: a, ("src", "never"), out_regs=1,
                      node=1, thread=0),
        ]
        return specs, "sink"


def _emit_version(version):
    return np.float32(version)


class TestProcessRuntime:
    def test_cross_node_chain_reuse_fires_and_edges(self):
        """One persistent runtime over 3 worker processes: correct results,
        epoch reuse, per-epoch ctx and fires overrides, per-edge byte
        accounting, and stripping of private ``__`` payload keys (exercised
        inside the worker-side actor fns)."""
        with ProcessRuntime(ChainBuilder(n=4)) as rt:
            outs = rt.run(timeout=60.0)
            expect = [(v + 1.0) * 2.0 for v in range(4)]
            assert [float(o[0]) for o in outs] == expect
            assert all(o.shape == (8,) for o in outs)
            assert rt.last_fired == {"src": 4, "mid": 4, "sink": 4}
            # the two cross-node hops each carried 4 fires x 8 float32
            for edge in (("src", "mid"), ("mid", "sink")):
                assert rt.last_edge_bytes[edge] == 4 * 8 * 4
            # epoch reuse: same runtime, new base via ctx, fewer fires
            outs = rt.run(ctx={"src": 100.0}, fires={"src": 2}, timeout=60.0)
            assert [float(o[0]) for o in outs] == [202.0, 204.0]
            assert rt.last_fired["src"] == 2
            with pytest.raises(ValueError, match="unknown actor"):
                rt.run(ctx={"nope": 1}, fires={"src": 1})

    def test_worker_crash_propagates_with_remote_traceback(self):
        """An exception inside a worker surfaces on the driver as a
        WorkerError naming the node, with the worker-side traceback chained
        so the real failing frame is visible."""
        with ProcessRuntime(CrashBuilder()) as rt:
            with pytest.raises(WorkerError, match="worker for node 1") as ei:
                rt.run(timeout=60.0)
        assert ei.value.node == 1
        assert "kaboom on version 2" in (ei.value.remote_traceback or "")
        assert ei.value.__cause__ is not None

    def test_timeout_names_unfired_actors(self):
        """A wedged epoch times out naming the unfinished bounded actors
        with fired/max counts — the debuggable handle for a hung run."""
        with ProcessRuntime(StuckBuilder()) as rt:
            with pytest.raises(TimeoutError, match=r"src=\d/3"):
                rt.run(timeout=3.0)


class TestProcessRuntimeClose:
    """close() must be idempotent and must never leak worker processes —
    not after clean runs, not after a worker crash, not after a hard kill
    (the elastic-training story depends on a dead session being fully
    reclaimable before the resume session spawns its own workers)."""

    def test_close_is_idempotent(self):
        rt = ProcessRuntime(ChainBuilder(n=2))
        rt.run(timeout=60.0)
        procs = list(rt._procs.values())
        rt.close()
        rt.close()    # second close: no-op, no error
        assert all(not p.is_alive() for p in procs)

    def test_no_leak_after_worker_crash(self):
        rt = ProcessRuntime(CrashBuilder())
        procs = list(rt._procs.values())
        with pytest.raises(WorkerError):
            rt.run(timeout=60.0)
        # the raise path already closed the runtime; nothing may survive
        assert all(not p.is_alive() for p in procs)
        rt.close()    # and closing an already-failed runtime stays safe

    def test_no_leak_after_fault_injected_kill(self):
        from repro.runtime.chaos import FaultPlan, KillWorker

        rt = ProcessRuntime(ChainBuilder(n=4),
                            faults=FaultPlan([KillWorker("mid", fire=2)]))
        procs = list(rt._procs.values())
        with pytest.raises(WorkerError, match="exit code 57"):
            rt.run(timeout=60.0)
        assert all(not p.is_alive() for p in procs)
        rt.close()


class TestProcessRuntimeGuards:
    def test_unpicklable_builder_rejected_up_front(self):
        """A closure builder fails fast on the driver with an actionable
        message, not deep inside a worker bootstrap."""
        with pytest.raises(ValueError, match="picklable spec builder"):
            ProcessRuntime(lambda: ([], None))

    def test_spec_builder_without_recipe_refuses_to_pickle(self):
        """An executor built straight from a lowered program (no recipe)
        cannot be shipped to workers — pickling must say why."""
        b = InferSpecBuilder(["x"], 2, staged=object())
        with pytest.raises(ValueError, match="lowering recipe"):
            pickle.dumps(b)
