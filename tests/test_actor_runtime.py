"""Actor runtime tests: the paper's §4 protocol, Figs 2/6/8 scenarios."""
import numpy as np
import pytest

from repro.runtime import (
    ActorSpec, CommModel, ThreadedRuntime, analyze, make_actor_id,
    parse_actor_id, pipeline_specs, plan_registers, simulate)


def _noop(*a):
    return 0


class TestAddressing:
    def test_roundtrip(self):
        aid = make_actor_id(3, 7, 2, 12345)
        assert parse_actor_id(aid) == (3, 7, 2, 12345)
        assert aid < (1 << 64)

    def test_out_of_range(self):
        with pytest.raises(ValueError):
            make_actor_id(1 << 12, 0, 0, 0)

    def test_roundtrip_at_field_maxima(self):
        """Every field at its widest legal value survives the 64-bit pack
        (node/thread 12 bits, queue 8, index 32) — off-by-one masking in
        either direction would corrupt a neighbouring field here."""
        fields = ((1 << 12) - 1, (1 << 12) - 1, (1 << 8) - 1, (1 << 32) - 1)
        aid = make_actor_id(*fields)
        assert parse_actor_id(aid) == fields
        assert aid == (1 << 64) - 1
        assert parse_actor_id(make_actor_id(0, 0, 0, 0)) == (0, 0, 0, 0)

    @pytest.mark.parametrize("field,bad", [
        ("node", (1 << 12, 0, 0, 0)),
        ("thread", (0, 1 << 12, 0, 0)),
        ("queue", (0, 0, 1 << 8, 0)),
        ("actor", (0, 0, 0, 1 << 32)),
        ("node", (-1, 0, 0, 0)),
        ("actor", (0, 0, 0, -1)),
    ])
    def test_each_field_rejected_past_its_width(self, field, bad):
        """One past the width (and negatives) must fail fast *naming the
        field*, not silently alias into a neighbouring field's bits."""
        with pytest.raises(ValueError, match=field):
            make_actor_id(*bad)

    def test_ids_unique_and_hierarchical(self):
        ids = {make_actor_id(n, t, 0, i)
               for n in range(3) for t in range(3) for i in range(5)}
        assert len(ids) == 45


class TestProtocol:
    def test_chain_runs_all_batches(self):
        specs = [
            ActorSpec("src", _noop, (), out_regs=2, max_fires=10, thread=0),
            ActorSpec("mid", lambda x: x + 1, ("src",), out_regs=2, thread=1),
            ActorSpec("sink", lambda x: x, ("mid",), out_regs=2, thread=2),
        ]
        res = simulate(specs)
        assert not res.deadlocked
        assert res.fires == {"src": 10, "mid": 10, "sink": 10}

    def test_counters_bounded_by_quota(self):
        """Back-pressure: fast producer never exceeds its register quota even
        when the consumer is 10x slower (credit-based flow control, §4.3)."""
        for quota in (1, 2, 4):
            specs = [
                ActorSpec("fast", _noop, (), out_regs=quota, max_fires=50,
                          duration=0.1, thread=0),
                ActorSpec("slow", _noop, ("fast",), out_regs=1, duration=1.0,
                          thread=1),
            ]
            res = simulate(specs)
            assert not res.deadlocked
            assert res.peak_regs["fast"] <= quota
            # with quota q, producer is exactly q batches ahead at steady state
            assert res.fires["fast"] == 50 and res.fires["slow"] == 50

    def test_zero_copy_reference_passing(self):
        """Same payload object flows producer -> consumer (no copy)."""
        big = np.arange(1024)
        seen = []
        specs = [
            ActorSpec("p", lambda: big, (), out_regs=2, max_fires=3),
            ActorSpec("c", lambda x: seen.append(x), ("p",), out_regs=1),
        ]
        res = simulate(specs)
        assert not res.deadlocked
        assert all(x is big for x in seen)

    def test_multi_consumer_refcount(self):
        """A register referenced by 2 consumers recycles only after both ack;
        producer with quota 1 therefore waits for the slower consumer."""
        specs = [
            ActorSpec("p", _noop, (), out_regs=1, max_fires=5, duration=0.1),
            ActorSpec("c_fast", _noop, ("p",), out_regs=1, duration=0.1, thread=1),
            ActorSpec("c_slow", _noop, ("p",), out_regs=1, duration=2.0, thread=2),
        ]
        res = simulate(specs)
        assert not res.deadlocked
        # the slow consumer paces everyone: makespan >= 5 * 2.0
        assert res.makespan >= 10.0
        assert res.fires == {"p": 5, "c_fast": 5, "c_slow": 5}


class TestFigure2:
    """Resource-sharing scenario: two movers feed two ops on one device with
    a memory budget of 3 register units. With explicit register quotas the
    actor runtime completes; no OOM and no deadlock (paper Fig 2)."""

    def test_no_deadlock_under_contention(self):
        specs = [
            ActorSpec("M1", _noop, (), out_regs=1, max_fires=8, thread=0,
                      duration=0.2),
            ActorSpec("M2", _noop, (), out_regs=1, max_fires=8, thread=0,
                      duration=0.2),
            # O1 "needs more memory": quota 1; O2 small: quota 2 — both on
            # the same compute thread 1 (shared device)
            ActorSpec("O1", _noop, ("M1",), out_regs=1, duration=1.0, thread=1),
            ActorSpec("O2", _noop, ("M2",), out_regs=2, duration=0.5, thread=1),
        ]
        res = simulate(specs)
        assert not res.deadlocked
        assert res.fires["O1"] == 8 and res.fires["O2"] == 8
        # total register residency never exceeds the static plan
        assert res.peak_regs["M1"] <= 1 and res.peak_regs["M2"] <= 1
        assert res.peak_regs["O1"] <= 1 and res.peak_regs["O2"] <= 2


class TestFigure6:
    """Register-count pipelining (paper Fig 6): actor1 with 3 out registers,
    actor2/actor3 with 2 — all three actors act concurrently at time2."""

    def test_pipelining_overlap(self):
        specs = [
            ActorSpec("a1", _noop, (), out_regs=3, max_fires=12, duration=1.0,
                      thread=0),
            ActorSpec("a2", _noop, ("a1",), out_regs=2, duration=1.0, thread=1),
            ActorSpec("a3", _noop, ("a2",), out_regs=2, duration=1.0, thread=2),
        ]
        res = simulate(specs, comm=CommModel(same_node=0.0))
        assert not res.deadlocked
        # perfect pipeline: makespan ~ 12 + 2 (fill) not 36 (serial)
        assert res.makespan <= 15.0 + 1e-6
        # all three actors busy simultaneously at some point
        def busy_at(name, t):
            return any(s <= t < e for s, e in res.history[name])
        assert any(busy_at("a1", t) and busy_at("a2", t) and busy_at("a3", t)
                   for t in np.arange(0, res.makespan, 0.5))

    def test_single_register_serializes(self):
        """With quota 1 everywhere the same chain degrades toward serial."""
        def mk(q):
            return [
                ActorSpec("a1", _noop, (), out_regs=q, max_fires=12,
                          duration=1.0, thread=0),
                ActorSpec("a2", _noop, ("a1",), out_regs=q, duration=1.0,
                          thread=1),
                ActorSpec("a3", _noop, ("a2",), out_regs=q, duration=1.0,
                          thread=2),
            ]
        res1 = simulate(mk(1), comm=CommModel(same_node=0.0))
        res2 = simulate(mk(2), comm=CommModel(same_node=0.0))
        assert res2.makespan < res1.makespan
        assert not res1.deadlocked and not res2.deadlocked


class TestThreadedRuntime:
    def test_real_threads_compute(self):
        """Actors on real OS threads compute a correct sum via the protocol."""
        acc = []
        specs = [
            ActorSpec("src", lambda: len(acc), (), out_regs=2, max_fires=20,
                      node=0, thread=0),
            ActorSpec("sq", lambda x: x * x, ("src",), out_regs=2, node=0,
                      thread=1),
            ActorSpec("sink", lambda x: acc.append(x), ("sq",), out_regs=1,
                      node=0, thread=2),
        ]
        rt = ThreadedRuntime(specs, collect_outputs_of="sq")
        outs = rt.run(timeout=30.0)
        assert len(outs) == 20
        assert len(acc) == 20

    def test_worker_exception_propagates(self):
        def boom(x):
            raise RuntimeError("kaboom")
        specs = [
            ActorSpec("src", _noop, (), out_regs=1, max_fires=3, thread=0),
            ActorSpec("bad", boom, ("src",), out_regs=1, thread=1),
        ]
        with pytest.raises(RuntimeError, match="kaboom"):
            ThreadedRuntime(specs).run(timeout=10.0)

    def test_run_is_reusable(self):
        """A runtime is built once and re-run per epoch: actors reset at the
        start of each run (fire counts, registers, instrumentation), so two
        runs yield identical results and counters stay inspectable between
        them — the persistent executors rely on this."""
        seen = []
        specs = [
            ActorSpec("src", lambda version: version, (), out_regs=2,
                      max_fires=3, thread=0, wants_version=True),
            ActorSpec("sink", lambda x: seen.append(x) or x, ("src",),
                      out_regs=1, thread=1),
        ]
        rt = ThreadedRuntime(specs, collect_outputs_of="sink")
        assert rt.run(timeout=30.0) == [0, 1, 2]
        # post-run counters inspectable until the next run resets them
        assert rt.by_name["src"].fired == 3
        assert rt.last_fired == {"src": 3, "sink": 3}
        assert rt.run(timeout=30.0) == [0, 1, 2]
        assert seen == [0, 1, 2, 0, 1, 2]

    def test_run_fires_override_and_ctx(self):
        """Per-epoch `fires` overrides the spec bound (serve rounds vary
        their work count) and `ctx` reaches on_epoch hooks before any
        fire; unknown names are rejected."""
        base = [10]

        def set_base(v):
            if v is not None:
                base[0] = v

        specs = [
            ActorSpec("src", lambda version: base[0] + version, (),
                      out_regs=2, max_fires=0, thread=0, wants_version=True,
                      on_epoch=set_base),
            ActorSpec("sink", lambda x: x, ("src",), out_regs=1, thread=1),
        ]
        rt = ThreadedRuntime(specs, collect_outputs_of="sink")
        assert rt.run(fires={"src": 2}, timeout=30.0) == [10, 11]
        assert rt.run(ctx={"src": 100}, fires={"src": 3},
                      timeout=30.0) == [100, 101, 102]
        with pytest.raises(ValueError, match="unknown actor"):
            rt.run(ctx={"nope": 1}, fires={"src": 1})

    def test_timeout_names_unfired_actors(self):
        """A hung epoch times out with the *unfinished bounded actors and
        their fired/max counts* in the message — the only debuggable handle
        when a distributed run wedges."""
        import threading
        gate = threading.Event()
        specs = [
            ActorSpec("src", lambda: gate.wait(timeout=30.0), (), out_regs=1,
                      max_fires=3, thread=0),
            ActorSpec("sink", lambda x: x, ("src",), out_regs=1, thread=1),
        ]
        rt = ThreadedRuntime(specs)
        try:
            with pytest.raises(TimeoutError, match=r"src=\d/3"):
                rt.run(timeout=0.3)
        finally:
            gate.set()


class TestPipelineSchedules:
    def test_1f1b_memory_vs_gpipe(self):
        """1F1B quota (=stages) matches GPipe throughput at a fraction of the
        activation memory (paper §6.5 / Megatron comparison)."""
        S, M = 4, 16
        gpipe = analyze(S, M, regs=[M] * S)
        onef1b = analyze(S, M, regs=[S] * S)
        assert onef1b.makespan <= gpipe.makespan * 1.05
        assert max(onef1b.peak_activation_regs.values()) <= S
        assert max(gpipe.peak_activation_regs.values()) >= M - 2

    def test_planner_picks_small_quota(self):
        plan = plan_registers(num_stages=4, num_microbatches=16)
        assert max(plan.regs) <= 8  # far below the GPipe-style 16
        assert plan.bubble_fraction < 0.35

    def test_more_registers_never_hurt(self):
        S, M = 3, 12
        spans = [analyze(S, M, regs=[r] * S).makespan for r in (1, 2, 3, 6)]
        assert all(a >= b - 1e-9 for a, b in zip(spans, spans[1:]))

    def test_zero_quota_rejected(self):
        """A zero/negative quota must fail fast naming the stage, not be
        silently clamped to 1 (which hid planner bugs)."""
        with pytest.raises(ValueError, match=r"stage 1 .* got 0"):
            pipeline_specs(3, 8, regs=[2, 0, 1])
        with pytest.raises(ValueError, match=r"stage 0 .* got -1"):
            pipeline_specs(2, 8, regs=[-1, 1])
