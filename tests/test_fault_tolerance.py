"""Kill-and-resume bit-identity (the elastic-training acceptance test).

The contract under test, end to end:

* ``compile(snapshot_dir=...)`` makes every training step emit an async
  per-stage snapshot (``snap{s}`` actors off the hot path), finalized by a
  driver-side MANIFEST — so ``latest_snapshot(dir)`` always equals the
  number of *completed* steps, even when a fault kills the run mid-step.
* ``compile(faults=FaultPlan([KillWorker(actor, fire=k)]))`` kills the
  named actor's worker at its k-th cumulative fire: an exception on the
  threads runtime, a hard ``os._exit`` of the stage's worker process on
  the processes runtime. Both surface as ``WorkerError`` on the driver.
* ``compile(restore=dir)`` resumes from the newest completed snapshot —
  params, Adam moments, and the step counter the lr schedule indexes.

Acceptance: for every (actor, fire-index) of a 3-step AdamW run, kill the
run there, resume from the last completed snapshot, and the combined loss
history AND final params/optimizer state are bitwise identical to an
uninterrupted run (the monolithic reference — itself pinned bit-identical
to the actor pipeline in test_api.py).
"""
import tempfile

import numpy as np
import pytest

from repro import api
from repro.core.graph import LogicalGraph
from repro.core.lowering import OptimizerSpec
from repro.core.placement import Placement
from repro.runtime.base import WorkerError
from repro.runtime.chaos import FaultPlan, KillWorker, WorkerKilled
from repro.runtime.snapshot import (latest_snapshot, list_snapshots,
                                    load_snapshot)

B, W, S, M, STEPS = 8, 8, 2, 2, 3


def _graph():
    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (B, W))
    labels = g.input("labels", (B,), dtype="int32")
    for i in range(S):
        w = g.input(f"w{i}", (W, W))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < S - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")
    return g


def _params_and_data(seed=0):
    rng = np.random.default_rng(seed)
    params = {f"w{i}": (rng.normal(size=(W, W)) * 0.1).astype(np.float32)
              for i in range(S)}
    data = {"x": rng.normal(size=(B, W)).astype(np.float32),
            "labels": rng.integers(0, W, size=(B,)).astype(np.int32)}
    return params, data


def _lr_schedule(s):
    # module-level so the processes runtime can pickle it into workers
    return 1e-3 * 0.9 ** s


def _opt():
    # schedule + clipping: restore must also bring back the step counter
    # (lr schedule index) and the Adam moments for bits to match
    return OptimizerSpec.adamw(lr=_lr_schedule, grad_clip=1.0)


@pytest.fixture(scope="module")
def ref():
    """Uninterrupted STEPS-step reference: losses, final params, opt state."""
    params, data = _params_and_data()
    sess = api.compile(_graph(), mode="train", backend="monolithic",
                       params=dict(params), optimizer=_opt(),
                       num_microbatches=M)
    losses = [float(sess.step(**data).loss) for _ in range(STEPS)]
    return {"params0": params, "data": data, "losses": losses,
            "final_params": sess.params, "opt_state": sess.opt_state}


def _assert_matches_ref(ref, losses, params, opt_state):
    assert losses == ref["losses"]
    for n, v in ref["final_params"].items():
        assert np.array_equal(np.asarray(params[n]), np.asarray(v)), n
    rs = ref["opt_state"]
    assert int(opt_state.step) == int(rs.step)
    for n in rs.mu:
        assert np.array_equal(np.asarray(opt_state.mu[n]),
                              np.asarray(rs.mu[n])), n
        assert np.array_equal(np.asarray(opt_state.nu[n]),
                              np.asarray(rs.nu[n])), n


def _kill_and_resume(ref, runtime, actor, fire):
    params, data = ref["params0"], ref["data"]
    with tempfile.TemporaryDirectory() as d:
        kw = dict(mode="train", backend="actors", stages=S, runtime=runtime,
                  params=dict(params), optimizer=_opt(), num_microbatches=M)
        sess = api.compile(_graph(), snapshot_dir=d,
                           faults=FaultPlan([KillWorker(actor, fire=fire)]),
                           **kw)
        losses, killed = [], False
        try:
            for _ in range(STEPS):
                losses.append(float(sess.step(**data).loss))
        except WorkerError:
            killed = True
        finally:
            sess.close()
        assert killed, f"kill at {actor} fire {fire} never triggered"
        # the core snapshot invariant: completed snapshots == completed steps
        n = latest_snapshot(d) or 0
        assert n == len(losses) < STEPS
        if n:
            res = api.compile(_graph(), restore=d, **kw)
            assert res.step_count == n
        else:
            res = api.compile(_graph(), **kw)    # died before any snapshot
        try:
            losses += [float(res.step(**data).loss)
                       for _ in range(STEPS - n)]
            final_params, opt_state = res.params, res.opt_state
        finally:
            res.close()
        _assert_matches_ref(ref, losses, final_params, opt_state)


# every fire index of the stage actors over a 3-step run: f{s} and b{s}
# each fire M*STEPS times, opt{s}/snap{s} once per step
_THREAD_CASES = (
    [(f"f{s}", k) for s in range(S) for k in range(1, M * STEPS + 1)]
    + [(f"b{s}", k) for s in range(S) for k in range(1, M * STEPS + 1)]
    + [(f"opt{s}", k) for s in range(S) for k in range(1, STEPS + 1)]
    + [("snap0", 2)]
)


class TestKillAndResumeThreads:
    @pytest.mark.parametrize("actor,fire", _THREAD_CASES,
                             ids=[f"{a}-fire{k}" for a, k in _THREAD_CASES])
    def test_bit_identical(self, ref, actor, fire):
        _kill_and_resume(ref, "threads", actor, fire)

    def test_worker_killed_is_a_worker_error(self):
        assert issubclass(WorkerKilled, WorkerError)


class TestKillAndResumeProcesses:
    """Same contract when the kill is a real ``os._exit`` of a worker
    process — the driver sees the death via exit code, not an exception."""

    @pytest.mark.parametrize("actor,fire",
                             [("f0", 3), ("b1", 4), ("opt1", 2)],
                             ids=["f0-fire3", "b1-fire4", "opt1-fire2"])
    def test_bit_identical(self, ref, actor, fire):
        _kill_and_resume(ref, "processes", actor, fire)


class TestSnapshotRestoreSurface:
    def test_snapshot_every(self, ref):
        params, data = ref["params0"], ref["data"]
        with tempfile.TemporaryDirectory() as d:
            with api.compile(_graph(), mode="train", stages=S,
                             params=dict(params), optimizer=_opt(),
                             num_microbatches=M, snapshot_dir=d,
                             snapshot_every=2) as sess:
                for _ in range(STEPS):
                    sess.step(**data)
            assert list_snapshots(d) == [2]

    def test_restore_onto_monolithic_backend(self, ref):
        """Partition-agnostic restore: a snapshot from a 2-stage actor run
        resumes the whole-graph monolithic reference bit-identically."""
        params, data = ref["params0"], ref["data"]
        with tempfile.TemporaryDirectory() as d:
            with api.compile(_graph(), mode="train", stages=S,
                             params=dict(params), optimizer=_opt(),
                             num_microbatches=M, snapshot_dir=d) as sess:
                losses = [float(sess.step(**data).loss)]
            mono = api.compile(_graph(), mode="train", backend="monolithic",
                               params=dict(params), optimizer=_opt(),
                               num_microbatches=M, restore=d)
            assert mono.step_count == 1
            losses += [float(mono.step(**data).loss)
                       for _ in range(STEPS - 1)]
            _assert_matches_ref(ref, losses, mono.params, mono.opt_state)

    def test_load_snapshot_roundtrip(self, ref):
        params, data = ref["params0"], ref["data"]
        with tempfile.TemporaryDirectory() as d:
            with api.compile(_graph(), mode="train", stages=S,
                             params=dict(params), optimizer=_opt(),
                             num_microbatches=M, snapshot_dir=d) as sess:
                for _ in range(STEPS):
                    sess.step(**data)
                want_params, want_opt = sess.params, sess.opt_state
            got_params, got_opt, step, meta = load_snapshot(d)
            assert step == STEPS
            assert meta["num_stages"] == S and meta["stateful"]
            for n, v in want_params.items():
                assert np.array_equal(np.asarray(got_params[n]),
                                      np.asarray(v)), n
            assert int(got_opt.step) == int(want_opt.step)

    def test_restore_empty_dir_raises(self, ref):
        params, _ = ref["params0"], ref["data"]
        with tempfile.TemporaryDirectory() as d:
            with pytest.raises(FileNotFoundError, match="no completed"):
                api.compile(_graph(), mode="train", stages=S,
                            params=dict(params), optimizer=_opt(),
                            num_microbatches=M, restore=d)

    def test_train_only_options_rejected(self):
        g = _graph()
        with pytest.raises(ValueError, match="mode='train'"):
            api.compile(g, mode="infer", snapshot_dir="/tmp/x")
        with pytest.raises(ValueError, match="mode='train'"):
            api.compile(g, mode="infer", faults=FaultPlan([]))
        with pytest.raises(ValueError, match="mode='train'"):
            api.compile(g, mode="infer", snapshot_every=2)

    def test_actors_only_options_rejected(self, ref):
        params = ref["params0"]
        for kw in ({"snapshot_dir": "/tmp/x"}, {"faults": FaultPlan([])}):
            with pytest.raises(ValueError, match="backend='actors'"):
                api.compile(_graph(), mode="train", backend="monolithic",
                            params=dict(params), **kw)

    def test_snapshot_every_without_dir_rejected(self, ref):
        with pytest.raises(ValueError, match="snapshot_every"):
            api.compile(_graph(), mode="train", params=dict(ref["params0"]),
                        snapshot_every=2)


# ---------------------------------------------------------------------------
# Mixed-precision ZeRO rows: the sharded snapshot is partition-agnostic too.
# ---------------------------------------------------------------------------

S4 = 4


def _zero_graph():
    """A 4-layer variant so the snapshot under test is written by a 4-stage
    pipeline and restored onto a different cut."""
    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (B, W))
    labels = g.input("labels", (B,), dtype="int32")
    for i in range(S4):
        w = g.input(f"w{i}", (W, W))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < S4 - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")
    return g


def _zero_params_and_data(seed=3):
    rng = np.random.default_rng(seed)
    params = {f"w{i}": (rng.normal(size=(W, W)) * 0.1).astype(np.float32)
              for i in range(S4)}
    data = {"x": rng.normal(size=(B, W)).astype(np.float32),
            "labels": rng.integers(0, W, size=(B,)).astype(np.int32)}
    return params, data


def _zero_kw(params, **extra):
    kw = dict(mode="train", params=dict(params), optimizer=_opt(),
              num_microbatches=M, zero=True, precision="bf16",
              loss_scale=1024.0)
    kw.update(extra)
    return kw


class TestZeroKillAndResume:
    """zero=True precision='bf16': kill mid-step, resume from the *sharded*
    snapshot — onto the same cut, onto a different cut, and onto the
    monolithic backend — bitwise against an uninterrupted reference."""

    @pytest.fixture(scope="class")
    def zref(self):
        params, data = _zero_params_and_data()
        sess = api.compile(_zero_graph(), backend="monolithic",
                           **_zero_kw(params))
        losses = [float(sess.step(**data).loss) for _ in range(STEPS)]
        return {"params0": params, "data": data, "losses": losses,
                "final_params": sess.params, "opt_state": sess.opt_state}

    def _run_killed(self, zref, d, actor, fire, runtime="threads"):
        params, data = zref["params0"], zref["data"]
        sess = api.compile(_zero_graph(), snapshot_dir=d,
                           faults=FaultPlan([KillWorker(actor, fire=fire)]),
                           backend="actors", stages=S4, runtime=runtime,
                           **_zero_kw(params))
        losses, killed = [], False
        try:
            for _ in range(STEPS):
                losses.append(float(sess.step(**data).loss))
        except WorkerError:
            killed = True
        finally:
            sess.close()
        assert killed, f"kill at {actor} fire {fire} never triggered"
        n = latest_snapshot(d) or 0
        assert n == len(losses) < STEPS
        return losses, n

    @pytest.mark.parametrize("actor,fire,runtime",
                             [("opt2", 2, "threads"), ("b3", 3, "threads"),
                              ("f1", 3, "processes")],
                             ids=["opt2-fire2", "b3-fire3", "f1-fire3-proc"])
    def test_resume_same_partition(self, zref, actor, fire, runtime):
        params, data = zref["params0"], zref["data"]
        with tempfile.TemporaryDirectory() as d:
            losses, n = self._run_killed(zref, d, actor, fire, runtime)
            with api.compile(_zero_graph(), restore=d, backend="actors",
                             stages=S4, runtime=runtime,
                             **_zero_kw(params)) as res:
                assert res.step_count == n
                losses += [float(res.step(**data).loss)
                           for _ in range(STEPS - n)]
                final_params, opt_state = res.params, res.opt_state
        _assert_matches_ref(zref, losses, final_params, opt_state)

    def test_resume_onto_two_stages(self, zref):
        """4-stage sharded snapshot -> 2-stage pipeline: the flat shards are
        host-gathered to full tensors on load and re-sharded by the new
        cut, so the continued run is bitwise identical."""
        params, data = zref["params0"], zref["data"]
        with tempfile.TemporaryDirectory() as d:
            losses, n = self._run_killed(zref, d, "opt1", 2)
            with api.compile(_zero_graph(), restore=d, backend="actors",
                             stages=2, **_zero_kw(params)) as res:
                assert res.step_count == n
                losses += [float(res.step(**data).loss)
                           for _ in range(STEPS - n)]
                final_params, opt_state = res.params, res.opt_state
        _assert_matches_ref(zref, losses, final_params, opt_state)

    def test_resume_onto_monolithic(self, zref):
        params, data = zref["params0"], zref["data"]
        with tempfile.TemporaryDirectory() as d:
            losses, n = self._run_killed(zref, d, "f2", 4)
            res = api.compile(_zero_graph(), restore=d, backend="monolithic",
                              **_zero_kw(params))
            assert res.step_count == n
            losses += [float(res.step(**data).loss)
                       for _ in range(STEPS - n)]
        _assert_matches_ref(zref, losses, res.params, res.opt_state)

    def test_sharded_snapshot_loads_full_tensors(self, zref):
        """load_snapshot never surfaces shards: params and moments come
        back at the logical shapes regardless of the zero layout."""
        params, data = zref["params0"], zref["data"]
        with tempfile.TemporaryDirectory() as d:
            with api.compile(_zero_graph(), backend="actors", stages=S4,
                             snapshot_dir=d, **_zero_kw(params)) as sess:
                sess.step(**data)
            got_params, got_opt, step, meta = load_snapshot(d)
            assert step == 1 and meta["zero"] is True
            for n, v in params.items():
                assert got_params[n].shape == v.shape
                assert got_params[n].dtype == np.float32
                assert got_opt.mu[n].shape == v.shape
