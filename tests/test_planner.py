"""Planner tests — SBP signature selection minimizing Table-2 cost.

Pure logic (no devices): we check the *plan*, not the numerics (numerics are
covered by tests/dist suites).
"""
import pytest

from repro.core.graph import LogicalGraph
from repro.core.placement import Placement
from repro.core.planner import plan


def mk_placement(data=2, model=4):
    return Placement(("data", "model"), (data, model))


def test_data_parallel_preferred_for_small_weights():
    """A small-weight matmul chain: planner should choose pure data
    parallelism (weights B, activations S(0)) — zero boxing cost."""
    g = LogicalGraph(mk_placement())
    x = g.input("x", (1024, 32), sbp="S(0),S(0)")
    w = g.input("w", (32, 32))          # free weight: planner chooses
    y = g.matmul(x, w)
    p = plan(g)
    assert p.total_cost == 0
    assert repr(p.tensor_sbp["w"]) == "(B, B)"


def test_megatron_mlp_one_boxing():
    """Pinned megatron weights: col-parallel then row-parallel. The only comm
    should be the final P -> materialized boxing; no all-gather between."""
    g = LogicalGraph(mk_placement())
    x = g.input("x", (256, 512), sbp="S(0),B")
    w1 = g.input("w1", (512, 2048), sbp="B,S(1)")
    w2 = g.input("w2", (2048, 512), sbp="B,S(0)")
    h = g.matmul(x, w1, name="mm1")
    a = g.unary(h, "relu", name="relu")
    y = g.matmul(a, w2, name="mm2")
    p = plan(g)
    assert repr(p.tensor_sbp["mm1.out"]) == "(S(0), S(1))"
    assert repr(p.tensor_sbp["relu.out"]) == "(S(0), S(1))"
    # exactly one boxing edge and it is the final partial materialization
    boxed_tensors = [b[0] for b in p.boxings]
    assert boxed_tensors in ([], ["mm2.out"]) or all(
        t == "mm2.out" for t in boxed_tensors)
    assert not p.tensor_sbp["mm2.out"].has_partial


def test_deferred_partial_reduction():
    """§3.3: U(S1) x V(S0) -> P; x W(B) keeps P. The planner must NOT insert
    an all-reduce between the two matmuls (P x B -> P rule is cheaper)."""
    pl = Placement(("model",), (4,))
    g = LogicalGraph(pl)
    u = g.input("u", (64, 128), sbp="S(1)")
    v = g.input("v", (128, 256), sbp="S(0)")
    w = g.input("w", (256, 32), sbp="B")
    uv = g.matmul(u, v, name="uv")
    uvw = g.matmul(uv, w, name="uvw")
    p = plan(g)
    assert repr(p.tensor_sbp["uv.out"]) == "(P(sum))"
    # boxing only at the very end (uvw.out materialization), never on uv.out
    for tname, *_ in p.boxings:
        assert tname != "uv.out", f"planner reduced early: {p.describe()}"


def test_pinned_output_respected():
    g = LogicalGraph(mk_placement())
    x = g.input("x", (64, 64), sbp="S(0),B")
    w = g.input("w", (64, 64), sbp="B,B")
    y = g.matmul(x, w)
    y.pin("B,B")
    p = plan(g)
    assert repr(p.tensor_sbp[y.name]) == "(B, B)"


def test_infeasible_raises():
    """A pinned output no matmul rule can ever produce: P(max)."""
    g = LogicalGraph(mk_placement())
    x = g.input("x", (64, 64), sbp="S(0),B")
    w = g.input("w", (64, 64), sbp="B,B")
    y = g.matmul(x, w)
    y.pin("P(max),B")   # matmul only ever emits P(sum)
    with pytest.raises(ValueError):
        plan(g)

    with pytest.raises(ValueError):
        # pin validation: split axis beyond tensor rank fails immediately
        g2 = LogicalGraph(mk_placement())
        g2.input("x", (64, 64), sbp="S(5),B")


def test_plan_describe_mentions_boxing():
    g = LogicalGraph(mk_placement())
    x = g.input("x", (64, 64), sbp="S(0),B")
    w1 = g.input("w1", (64, 64), sbp="B,S(1)")
    w2 = g.input("w2", (64, 64), sbp="B,S(1)")
    y1 = g.matmul(x, w1, name="m1")           # (S0, S1)
    y2 = g.matmul(y1, w2, name="m2")          # needs boxing: S(1) x S(1) invalid
    p = plan(g)
    desc = p.describe()
    assert "SBP plan" in desc
    assert p.total_cost > 0  # resharding is unavoidable here
