"""Substrate tests: checkpointing, data pipeline, dry-run parser math."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        from repro.train.checkpoint import load_checkpoint, save_checkpoint

        tree = {"a": jnp.arange(12.0).reshape(3, 4),
                "nested": {"b": jnp.ones((5,), jnp.int32)},
                "lst": [jnp.zeros((2, 2))]}
        save_checkpoint(str(tmp_path / "ck"), tree, step=7,
                        meta={"arch": "x"})
        restored, step = load_checkpoint(str(tmp_path / "ck"), tree)
        assert step == 7
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_shape_mismatch_raises(self, tmp_path):
        from repro.train.checkpoint import load_checkpoint, save_checkpoint

        save_checkpoint(str(tmp_path / "ck"), {"a": jnp.ones((2, 2))})
        with pytest.raises(ValueError):
            load_checkpoint(str(tmp_path / "ck"), {"a": jnp.ones((3, 3))})

    def test_missing_leaf_raises(self, tmp_path):
        from repro.train.checkpoint import load_checkpoint, save_checkpoint

        save_checkpoint(str(tmp_path / "ck"), {"a": jnp.ones((2,))})
        with pytest.raises(KeyError):
            load_checkpoint(str(tmp_path / "ck"),
                            {"a": jnp.ones((2,)), "b": jnp.ones((2,))})


class TestDataPipeline:
    def test_actor_pipeline_delivers_all_batches_in_order_shape(self):
        from repro.data.pipeline import ActorDataPipeline, SyntheticLM

        src = SyntheticLM(vocab_size=128, batch=2, seq_len=16, seed=1)
        batches = list(ActorDataPipeline(src, num_batches=7, buffers=2))
        assert len(batches) == 7
        for b in batches:
            assert b.shape == (2, 17) and b.dtype == np.int32
            assert (b >= 0).all() and (b < 128).all()

    def test_backpressure_bounds_buffering(self):
        """A slow consumer must not let the loader run unboundedly ahead."""
        import time

        from repro.data.pipeline import ActorDataPipeline

        produced = []

        def src(i):
            produced.append(i)
            return np.zeros((1, 4), np.int32)

        pipe = ActorDataPipeline(src, num_batches=20, buffers=2)
        it = iter(pipe)
        next(it)
        time.sleep(0.3)     # consumer stalls
        # loader quota 2 + preprocess 2 + stage 1 + queue 2 bounds run-ahead
        assert len(produced) <= 8, produced
        for _ in range(19):
            next(it)
        assert len(produced) == 20

    def test_reiteration_rebuilds_actor_chain(self):
        """Actors are single-use state machines; a second epoch must get a
        fresh chain (and keep delivering), not hang on spent actors."""
        from repro.data.pipeline import ActorDataPipeline

        seen = []

        def src(i):
            seen.append(i)
            return np.full((1, 4), i, np.int32)

        pipe = ActorDataPipeline(src, num_batches=3, buffers=2)
        first = list(pipe)
        second = list(pipe)
        assert len(first) == len(second) == 3
        # the source index restarts per epoch
        assert seen == [0, 1, 2, 0, 1, 2]


class TestDryrunParser:
    def test_wire_bytes_factors(self):
        from repro.launch.dryrun import wire_bytes

        c = {"kind": "all_reduce", "operand_bytes": 1000, "group_size": 4}
        assert wire_bytes(c) == 2 * 3 / 4 * 1000
        c["kind"] = "reduce_scatter"
        assert wire_bytes(c) == 3 / 4 * 1000
        c["kind"] = "all_gather"
        assert wire_bytes(c) == 3 * 1000
        c["kind"] = "all_to_all"
        assert wire_bytes(c) == 3 / 4 * 1000
        c["group_size"] = 1
        assert wire_bytes(c) == 0.0

    def test_parser_while_and_calls(self):
        from repro.launch.dryrun import _HloTextParser

        text = """
func.func public @main(%arg0: tensor<8x8xf32>) {
  %c = stablehlo.constant dense<5> : tensor<i32>
  %w:2 = stablehlo.while(%iterArg = %arg0, %iterArg_0 = %c)
  cond {
    %c_1 = stablehlo.constant dense<5> : tensor<i32>
    %p = stablehlo.compare  LT, %iterArg_0, %c_1,  SIGNED : (tensor<i32>, tensor<i32>) -> tensor<i1>
    stablehlo.return %p : tensor<i1>
  } do {
    %d = stablehlo.dot_general %iterArg, %iterArg, contracting_dims = [1] x [0] : (tensor<8x8xf32>, tensor<8x8xf32>) -> tensor<8x8xf32>
    %cc = func.call @inner(%d) : (tensor<8x8xf32>) -> tensor<8x8xf32>
    stablehlo.return %cc, %iterArg_0 : tensor<8x8xf32>, tensor<i32>
  }
  return
}
func.func private @inner(%a: tensor<8x8xf32>) -> tensor<8x8xf32> {
  %g = "stablehlo.all_gather"(%a) <{all_gather_dim = 0 : i64, replica_groups = dense<"0x00"> : tensor<2x4xi64>, use_global_device_ids}> : (tensor<8x8xf32>) -> tensor<32x8xf32>
  return %g : tensor<8x8xf32>
}
"""
        p = _HloTextParser(text)
        # the dot inside the while body: 2*8*8*8 flops x 5 trips
        assert p.dot_flops == 2 * 8 * 8 * 8 * 5
        # the all_gather inside @inner, called from the while: trip 5
        assert len(p.collectives) == 1
        c = p.collectives[0]
        assert c["kind"] == "all_gather" and c["group_size"] == 4
        assert c["trip"] == 5
        assert c["operand_bytes"] == 8 * 8 * 4


class TestConfigsRegistry:
    def test_all_archs_present_with_shapes(self):
        from repro.configs.base import INPUT_SHAPES
        from repro.configs.registry import ARCHITECTURES, supports_shape

        assert len(ARCHITECTURES) == 10
        assert len(INPUT_SHAPES) == 4
        skips = [(a, s) for a in ARCHITECTURES for s in INPUT_SHAPES.values()
                 if not supports_shape(ARCHITECTURES[a], s)]
        # exactly the documented whisper x long_500k skip
        assert skips == [("whisper-medium", INPUT_SHAPES["long_500k"])]

    def test_reduced_configs_are_small(self):
        from repro.configs.registry import ARCHITECTURES

        for cfg in ARCHITECTURES.values():
            r = cfg.reduced()
            assert r.num_layers == 2
            assert r.d_model <= 512
            assert (r.num_experts or 0) <= 4
