"""Hypothesis property tests for the actor protocol invariants."""
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see requirements-dev.txt)")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.runtime import ActorSpec, CommModel, simulate


def _noop(*a):
    return 0


@st.composite
def layered_dag(draw):
    """Random layered actor DAG: every layer consumes some of the previous."""
    n_layers = draw(st.integers(2, 4))
    widths = [draw(st.integers(1, 3)) for _ in range(n_layers)]
    batches = draw(st.integers(1, 12))
    specs = []
    names_prev = []
    tid = 0
    for li, w in enumerate(widths):
        names = []
        for i in range(w):
            name = f"a{li}_{i}"
            if li == 0:
                inputs = ()
            else:
                k = draw(st.integers(1, len(names_prev)))
                inputs = tuple(draw(st.permutations(names_prev))[:k])
            specs.append(ActorSpec(
                name, _noop, inputs,
                out_regs=draw(st.integers(1, 3)),
                duration=draw(st.sampled_from([0.1, 0.5, 1.0])),
                thread=tid % 8,
                max_fires=batches if li == 0 else None))
            names.append(name)
            tid += 1
        names_prev = names
    return specs, batches


@settings(max_examples=40, deadline=None)
@given(layered_dag())
def test_dag_always_completes_without_deadlock(sd):
    """Any layered DAG with quotas >= 1 completes all batches: the protocol
    is deadlock-free for acyclic graphs (credit-based flow control)."""
    specs, batches = sd
    res = simulate(specs)
    assert not res.deadlocked
    for s in specs:
        assert res.fires[s.name] == batches


@settings(max_examples=40, deadline=None)
@given(layered_dag())
def test_register_quota_never_exceeded(sd):
    """No actor ever holds more live out-registers than its static quota —
    the compile-time memory plan is a true upper bound at runtime."""
    specs, batches = sd
    res = simulate(specs)
    for s in specs:
        assert res.peak_regs[s.name] <= s.out_regs


@settings(max_examples=20, deadline=None)
@given(layered_dag(), st.floats(0.0, 0.01))
def test_makespan_monotone_in_comm_latency(sd, lat):
    """More communication latency can only slow the schedule down."""
    specs, _ = sd
    fast = simulate(specs, comm=CommModel(same_node=0.0))
    slow = simulate(specs, comm=CommModel(same_node=lat))
    assert slow.makespan >= fast.makespan - 1e-9
