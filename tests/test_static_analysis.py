"""Static plan verifier tests (repro.analysis).

Oracle soundness in both directions: every plan the real compile paths
produce must PASS, and hand-built bad plans — a quota-starved cycle, an
illegal split signature, a partial value leaking through a sink — must be
rejected at compile time with the offending cycle/edge named, before any
actor fires.
"""
import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro import analysis, api
from repro.analysis import membound
from repro.analysis.__main__ import main as analysis_cli
from repro.analysis.deadlock import (check_deadlock, min_feasible_regs,
                                     min_feasible_stage_regs)
from repro.analysis.sbp_check import check_sbp
from repro.analysis.skeleton import (infer_spec_skeleton, serve_spec_skeleton,
                                     train_spec_skeleton)
from repro.analysis.trace import TraceRecorder, check_trace
from repro.core.graph import LogicalGraph, partition_stages
from repro.core.lowering import OptimizerSpec
from repro.core.placement import Placement
from repro.core.planner import plan as plan_sbp
from repro.core.sbp import NdSbp
from repro.runtime.actor import ActorSpec
from repro.runtime.chaos import DelayEdge, DuplicateReq, FaultPlan
from repro.runtime.pipeline import _validate_regs

B, W, S, M = 8, 8, 2, 2


def _noop(*args):
    return 0


def _train_graph():
    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (B, W))
    labels = g.input("labels", (B,), dtype="int32")
    for i in range(S):
        w = g.input(f"w{i}", (W, W))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < S - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")
    return g


def _train_params(rng=None):
    rng = rng or np.random.default_rng(0)
    return {f"w{i}": (rng.normal(size=(W, W)) * 0.1).astype(np.float32)
            for i in range(S)}


def _train_data(rng=None):
    rng = rng or np.random.default_rng(1)
    return {"x": rng.normal(size=(B, W)).astype(np.float32),
            "labels": rng.integers(0, W, size=(B,)).astype(np.int32)}


def _starved_cycle_specs(a_regs=1):
    """The canonical quota-starved cycle: C needs a second token from A, but
    A's sole register is parked waiting for X's ack, and X cannot fire its
    second time until C releases A's register — a three-way wait cycle fixed
    by giving A a second register."""
    return [
        ActorSpec("A", fn=_noop, inputs=(), out_regs=a_regs, max_fires=2),
        ActorSpec("X", fn=_noop, inputs=("A",), out_regs=1, max_fires=2,
                  emit_every=2),
        ActorSpec("C", fn=_noop, inputs=("A", "X"), out_regs=1, max_fires=1),
    ]


class TestDeadlockPass:
    def test_1f1b_train_skeleton_is_live(self):
        for stages, mb in [(2, 4), (4, 8), (3, 3)]:
            specs = train_spec_skeleton(stages, mb, clip=True, dynamic=True,
                                        stateful=True, snapshot=True)
            result = check_deadlock(specs)
            assert result.ok, (stages, mb, result)
            assert all(result.fired[n] == result.required[n]
                       for n in result.fired)

    def test_serial_quotas_are_live(self):
        specs = train_spec_skeleton(4, 8, [1, 1, 1, 1])
        assert check_deadlock(specs).ok

    def test_infer_and_serve_skeletons_are_live(self):
        assert check_deadlock(infer_spec_skeleton(3, 5)).ok
        assert check_deadlock(serve_spec_skeleton(2, round_items=4)).ok

    def test_quota_starved_cycle_is_rejected_with_cycle_named(self):
        result = check_deadlock(_starved_cycle_specs())
        assert not result.ok
        assert set(result.cycle) == {"A", "X", "C"}
        (violation,) = analysis.deadlock_violations(result)
        assert violation.pass_name == "deadlock"
        assert "quota-starved cycle" in violation.message
        assert " -> ".join(result.cycle + (result.cycle[0],)) \
            == violation.subject

    def test_min_feasible_regs_fixes_the_cycle(self):
        feasible = min_feasible_regs(_starved_cycle_specs())
        assert feasible == {"A": 2, "X": 1}
        fixed = _starved_cycle_specs(a_regs=feasible["A"])
        assert check_deadlock(fixed).ok

    def test_pure_starvation_has_no_cycle(self):
        specs = [
            ActorSpec("A", fn=_noop, inputs=(), out_regs=2, max_fires=1),
            ActorSpec("C", fn=_noop, inputs=("A",), out_regs=1, max_fires=3),
        ]
        result = check_deadlock(specs)
        assert not result.ok and result.cycle == ()
        (violation,) = analysis.deadlock_violations(result)
        assert "starvation" in violation.message
        assert min_feasible_regs(specs) is None  # no quota fixes a rate gap

    def test_unbounded_source_needs_fires(self):
        specs = [ActorSpec("src", fn=_noop, inputs=(), out_regs=1),
                 ActorSpec("sink", fn=_noop, inputs=("src",), out_regs=1,
                           max_fires=2)]
        with pytest.raises(ValueError, match="unbounded source"):
            check_deadlock(specs)
        assert check_deadlock(specs, fires={"src": 2}).ok

    def test_unknown_producer_is_rejected(self):
        specs = [ActorSpec("sink", fn=_noop, inputs=("ghost",), out_regs=1,
                           max_fires=1)]
        with pytest.raises(ValueError, match="unknown producer"):
            check_deadlock(specs)

    def test_min_feasible_stage_regs(self):
        regs = min_feasible_stage_regs(4, 8)
        assert len(regs) == 4 and all(r >= 1 for r in regs)
        specs = train_spec_skeleton(4, 8, regs)
        assert check_deadlock(specs).ok


class TestSbpPass:
    def test_real_plans_pass(self):
        g = _train_graph()
        plan = plan_sbp(g)
        violations, checked = check_sbp(g, plan, partition_stages(g, S))
        assert violations == [] and checked > 0

    def test_split_indivisibility_names_the_tensor(self):
        placement = Placement(("d",), (2,), device_kind="cpu")
        g = LogicalGraph(placement)
        x = g.input("x", (3, 8))
        w = g.input("w", (8, 8))
        g.matmul(x, w, name="y")
        plan = plan_sbp(g)
        bad = dataclasses.replace(
            plan, tensor_sbp={**plan.tensor_sbp, "x": NdSbp.parse("S(0)")})
        violations, _ = check_sbp(g, bad)
        assert any(v.subject == "x" and "illegal for shape" in v.message
                   for v in violations)

    def test_partial_leaking_through_sink_is_named(self):
        g = _train_graph()
        plan = plan_sbp(g)
        sink = g.sinks()[0].name
        bad = dataclasses.replace(
            plan,
            tensor_sbp={**plan.tensor_sbp, sink: NdSbp.parse("P")},
            boxings=[b for b in plan.boxings if b[1] != "__epilogue__"])
        violations, _ = check_sbp(g, bad)
        assert any(v.subject == sink and "leaks through a graph sink"
                   in v.message for v in violations)

    def test_partial_crossing_stage_boundary_is_named(self):
        g = _train_graph()
        plan = plan_sbp(g)
        part = partition_stages(g, S)
        # relu0.out is the stage-0 -> stage-1 boundary tensor
        bad = dataclasses.replace(
            plan, tensor_sbp={**plan.tensor_sbp,
                              "relu0.out": NdSbp.parse("P")})
        violations, _ = check_sbp(g, bad, part)
        assert any("crosses the stage" in v.message for v in violations)
        # with the lowering's materialized boundary signatures the same plan
        # is fine: no partial actually crosses
        materialized = {"relu0.out": NdSbp.parse("B")}
        violations, _ = check_sbp(g, bad, part, boundary_sbp=materialized)
        assert not any("crosses the stage" in v.message for v in violations)


class TestCompileCheck:
    def test_every_mode_backend_passes_by_default(self):
        params, data = _train_params(), _train_data()
        for backend in ("actors", "monolithic"):
            sess = api.compile(_train_graph(), mode="train", backend=backend,
                               stages=S, params=dict(params),
                               num_microbatches=M)
            try:
                assert sess.static_report.verdict == "PASS"
                assert "static analysis: PASS" in sess.describe()
                assert "static peak bytes" in sess.describe()
            finally:
                sess.close()

    def test_bad_plan_is_rejected_before_any_fire(self):
        g = _train_graph()
        plan = plan_sbp(g)
        sink = g.sinks()[0].name
        bad = dataclasses.replace(
            plan,
            tensor_sbp={**plan.tensor_sbp, sink: NdSbp.parse("P")},
            boxings=[b for b in plan.boxings if b[1] != "__epilogue__"])
        with pytest.raises(analysis.AnalysisError,
                           match="leaks through a graph sink"):
            api.compile(g, mode="train", stages=S,
                        params=dict(_train_params()), num_microbatches=M,
                        plan=bad)

    def test_check_off_skips(self):
        sess = api.compile(_train_graph(), mode="train", backend="monolithic",
                           params=dict(_train_params()), num_microbatches=M,
                           check="off")
        assert sess.static_report.verdict == "SKIPPED"
        assert "static analysis: skipped" in sess.describe()

    def test_unknown_check_value_is_rejected(self):
        with pytest.raises(ValueError, match="unknown check"):
            api.compile(_train_graph(), mode="train", backend="monolithic",
                        params=dict(_train_params()), check="sometimes")

    def test_run_session_checks_is_rerunnable(self):
        sess = api.compile(_train_graph(), mode="train", stages=S,
                           params=dict(_train_params()), num_microbatches=M)
        try:
            report = analysis.run_session_checks(sess)
            assert report.verdict == "PASS"
            assert report.checked_channels > 0
            assert all(v > 0 for v in report.peak_bytes_per_device.values())
        finally:
            sess.close()


class TestSkeletonParity:
    """The dummy-fn skeletons must mirror the real executor topologies field
    by field, or the CLI/min-regs search analyzes a different network than
    the one that runs."""

    @staticmethod
    def _key(s):
        return (s.name, tuple(s.inputs), s.out_regs, s.max_fires,
                s.emit_every, s.node, s.thread)

    def test_infer_topology_matches(self):
        g = _train_graph()
        sess = api.compile(g, mode="infer", backend="actors", stages=S,
                           num_microbatches=4, microbatch_inputs=["x"])
        try:
            real, _ = sess._engine._make_builder()()
            skel = infer_spec_skeleton(S, 4, sess.regs)
            assert sorted(map(self._key, real)) \
                == sorted(map(self._key, skel))
        finally:
            sess.close()

    def test_train_topology_matches(self):
        opt = OptimizerSpec.adamw(lr=1e-3, grad_clip=1.0)
        sess = api.compile(_train_graph(), mode="train", stages=S,
                           params=dict(_train_params()), optimizer=opt,
                           num_microbatches=M)
        try:
            real, _ = sess._engine._make_builder()()
            skel = train_spec_skeleton(S, M, sess.regs, clip=True,
                                       stateful=True)
            assert sorted(map(self._key, real)) \
                == sorted(map(self._key, skel))
        finally:
            sess.close()


class TestQuotaValidation:
    def test_zero_quota_error_reports_feasible_vector(self):
        with pytest.raises(ValueError) as err:
            _validate_regs([2, 0, 1], 3, 4)
        assert "minimal feasible quotas" in str(err.value)
        assert "stage 1" in str(err.value)

    def test_compile_rejects_zero_quota_with_feasible_vector(self):
        with pytest.raises(ValueError, match="minimal feasible quotas"):
            api.compile(_train_graph(), mode="train", stages=S,
                        params=dict(_train_params()), num_microbatches=M,
                        regs=[1, 0])


class TestMemoryBound:
    def test_train_bound_covers_measured_peak(self):
        sess = api.compile(_train_graph(), mode="train", stages=S,
                           params=dict(_train_params()), num_microbatches=M)
        try:
            sess.step(**_train_data())
            bound = sum(sess.static_report.peak_bytes_per_device.values())
            measured = sess._engine.peak_inflight_activations
            assert bound >= measured > 0
        finally:
            sess.close()

    def test_optimizer_state_streams_are_counted(self):
        g = _train_graph()
        params = _train_params()
        plain = api.compile(g, mode="train", backend="monolithic",
                            params=dict(params), check="off")
        opt = OptimizerSpec.adamw(lr=1e-3)
        sess = api.compile(_train_graph(), mode="train", stages=S,
                           params=dict(params), optimizer=opt,
                           num_microbatches=M)
        sgd = api.compile(_train_graph(), mode="train", stages=S,
                          params=dict(params), num_microbatches=M)
        try:
            adamw_bytes = sum(
                sess.static_report.peak_bytes_per_device.values())
            sgd_bytes = sum(sgd.static_report.peak_bytes_per_device.values())
            # AdamW adds the m/v moment streams on top of the same pipeline
            assert adamw_bytes > sgd_bytes
        finally:
            plain.close()
            sess.close()
            sgd.close()


class TestTraceSanitizer:
    def test_clean_run_has_canonical_trace(self):
        rec = TraceRecorder()
        sess = api.compile(_train_graph(), mode="train", stages=S,
                           params=dict(_train_params()), num_microbatches=M)
        try:
            sess.executor.trace = rec
            data = _train_data()
            sess.step(**data)
            sess.step(**data)
            specs, _ = sess._engine._make_builder()()
            violations, stats = check_trace(rec, specs)
            assert violations == []
            assert stats.deliveries > 0 and stats.duplicates_dropped == 0
        finally:
            sess.close()

    def test_chaos_faults_are_absorbed_and_certified(self):
        plan = FaultPlan((DuplicateReq("f0", "f1", version=0),
                          DelayEdge("f1", "b1", seconds=0.02, version=1)))
        rec = TraceRecorder()
        sess = api.compile(_train_graph(), mode="train", stages=S,
                           params=dict(_train_params()), num_microbatches=M,
                           faults=plan)
        try:
            sess.executor.trace = rec
            sess.step(**_train_data())
            specs, _ = sess._engine._make_builder()()
            violations, stats = check_trace(rec, specs)
            assert violations == []
            assert stats.duplicates_dropped == 1
            assert stats.faults == 2
        finally:
            sess.close()

    def test_corrupted_trace_is_flagged(self):
        specs = [ActorSpec("p", fn=_noop, inputs=(), out_regs=2, max_fires=2),
                 ActorSpec("c", fn=_noop, inputs=("p",), out_regs=1,
                           max_fires=2)]
        rec = TraceRecorder()
        rec.record_delivery("c", "p", 1, (1,), 1)  # released out of order
        rec.record_delivery("c", "p", 0, (0,), 1)
        violations, _ = check_trace(rec, specs)
        assert any("canonical stride-1 order" in v.message
                   for v in violations)

    def test_trace_requires_threads_runtime(self):
        from repro.runtime.base import make_runtime
        with pytest.raises(ValueError, match="requires runtime='threads'"):
            make_runtime("processes", lambda: ([], None),
                         trace=TraceRecorder())


class TestCLI:
    def test_cli_passes_on_zoo_config(self, capsys):
        rc = analysis_cli(["qwen3-1.7b", "--stages", "2", "--regs", "1f1b",
                           "--microbatches", "4"])
        out = capsys.readouterr().out
        assert rc == 0
        assert "static analysis: PASS" in out
        assert "analyzer wall time" in out

    def test_cli_infer_mode_and_explicit_regs(self, capsys):
        rc = analysis_cli(["qwen3-1.7b", "--stages", "2", "--regs", "1,1",
                           "--mode", "infer"])
        assert rc == 0

    def test_cli_rejects_wrong_quota_count(self, capsys):
        rc = analysis_cli(["qwen3-1.7b", "--stages", "2", "--regs", "1,2,3"])
        assert rc == 2


class TestStageBoundaryBound:
    def test_plan_level_bound_without_lowering(self):
        g = _train_graph()
        plan = plan_sbp(g)
        part = partition_stages(g, S)
        bound = membound.stage_boundary_bound(g, plan, part, [2, 1], M)
        assert set(bound) == {"stage0", "stage1"}
        assert all(v >= 0 for v in bound.values())
