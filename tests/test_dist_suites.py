"""Run the multi-device numerics suites in subprocesses.

The main pytest process keeps the default single CPU device (per the repo
policy: only launch/dryrun.py forces a placeholder device count). Anything
needing >1 device runs here as a grouped subprocess suite with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import os
import pathlib
import subprocess
import sys

import pytest

HERE = pathlib.Path(__file__).parent
SRC = str(HERE.parent / "src")

SUITES = sorted(p.name for p in (HERE / "dist").glob("suite_*.py"))


@pytest.mark.parametrize("suite", SUITES)
def test_dist_suite(suite):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)  # the suite sets its own device count
    proc = subprocess.run(
        [sys.executable, str(HERE / "dist" / suite)],
        env=env, capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        raise AssertionError(
            f"suite {suite} failed\n--- stdout ---\n{proc.stdout[-4000:]}"
            f"\n--- stderr ---\n{proc.stderr[-4000:]}")
    assert f"ALL-OK" in proc.stdout
