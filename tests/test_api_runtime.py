"""The ``runtime=`` compile option: process-backed sessions must be
bit-identical to the threaded and monolithic paths for all three modes.

These tests spawn real worker processes (one per node id) through the
public API only — ``api.compile(..., runtime="processes")`` — and compare
with :func:`repro.api.assert_sessions_match`, which checks losses, grads,
params and optimizer state bitwise. Each pairing gets a *fresh* monolithic
reference session: ``assert_sessions_match(steps=N)`` advances both sides.
"""
import numpy as np
import pytest

from repro import api
from repro.configs.registry import get_config
from repro.core.graph import LogicalGraph
from repro.core.lowering import OptimizerSpec
from repro.core.placement import Placement

B, W, S, M = 16, 32, 4, 4


def _graph(with_loss=True, depth=S):
    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (B, W))
    if with_loss:
        labels = g.input("labels", (B,), dtype="int32")
    for i in range(depth):
        w = g.input(f"w{i}", (W, W))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < depth - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    if with_loss:
        g.softmax_xent(h, labels, name="loss")
    return g


def _params_and_data(g, seed=0):
    rng = np.random.default_rng(seed)
    params, data = {}, {}
    for t in g.inputs:
        if t.name.startswith("w"):
            params[t.name] = (rng.normal(size=t.shape) * 0.1).astype(
                np.float32)
        elif t.dtype == "int32":
            data[t.name] = rng.integers(0, W, size=t.shape).astype(np.int32)
        else:
            data[t.name] = rng.normal(size=t.shape).astype(np.float32)
    return params, data


class TestRuntimeOption:
    def test_unknown_runtime_rejected(self):
        with pytest.raises(ValueError, match="runtime"):
            api.compile(_graph(False), mode="infer", stages=2,
                        num_microbatches=M, microbatch_inputs=["x"],
                        runtime="fibers")

    def test_runtime_requires_actor_backend(self):
        with pytest.raises(ValueError, match="backend='actors'"):
            api.compile(_graph(False), mode="infer", backend="monolithic",
                        num_microbatches=M, microbatch_inputs=["x"],
                        runtime="threads")


class TestProcessSessions:
    def test_infer_three_way_and_reuse(self):
        """threads == processes == monolithic, bitwise; the process session
        is then re-fed new inputs (persistent workers, fresh epoch)."""
        gi = _graph(with_loss=False)
        params, data = _params_and_data(gi)
        inputs = {**params, **data}
        kw = dict(mode="infer", stages=S, num_microbatches=M,
                  microbatch_inputs=["x"])
        st = api.compile(gi, runtime="threads", **kw)
        sp = api.compile(gi, runtime="processes", **kw)
        sm = api.compile(gi, backend="monolithic", num_microbatches=M,
                         microbatch_inputs=["x"])
        try:
            api.assert_sessions_match(st, sm, inputs)
            api.assert_sessions_match(sp, sm, inputs)
            # runtime reuse across epochs with new inputs
            api.assert_sessions_match(
                sp, sm, dict(inputs, x=inputs["x"] + 1.0))
            assert "runtime=processes" in sp.describe()
            assert "runtime=threads" in st.describe()
            assert any(v > 0 for v in sp.executor.last_edge_bytes.values())
        finally:
            sp.close()
            st.close()

    def test_train_three_way_adamw(self):
        """3 training steps, AdamW + global-norm clipping: losses, grads,
        params and optimizer state all bitwise-equal across runtimes."""
        gt = _graph()
        params, data = _params_and_data(gt)
        opt = OptimizerSpec.adamw(lr=1e-2, grad_clip=1.0)
        kw = dict(mode="train", stages=S, num_microbatches=M, optimizer=opt)
        tt = api.compile(_graph(), runtime="threads",
                         params=dict(params), **kw)
        tp = api.compile(_graph(), runtime="processes",
                         params=dict(params), **kw)
        def mono():
            return api.compile(_graph(), backend="monolithic",
                               params=dict(params), optimizer=opt,
                               mode="train", num_microbatches=M)
        try:
            api.assert_sessions_match(tt, mono(), data, steps=3)
            api.assert_sessions_match(tp, mono(), data, steps=3)
        finally:
            tp.close()
            tt.close()

    def test_serve_token_streams_match(self):
        cfg = get_config("qwen2.5-3b").reduced()
        serve_kw = dict(mode="serve", num_groups=2, group_size=2,
                        max_prompt_len=8, max_new_tokens=4)
        vm = api.compile(cfg, backend="monolithic", **serve_kw)
        vp = api.compile(cfg, runtime="processes", stages=2, **serve_kw)
        reqs = [(np.array([3, 1, 4, 1], np.int32), 4),
                (np.array([2, 7], np.int32), 3),
                (np.array([5], np.int32), 4)]
        try:
            om = vm.generate(reqs)
            op = vp.generate(reqs)
            assert len(om) == len(op) == len(reqs)
            for i, (a, b) in enumerate(zip(om, op)):
                assert np.array_equal(a, b), (i, a, b)
            assert "runtime=processes" in vp.describe()
        finally:
            vp.close()
