"""Unit tests for SBP types, deduction rules and the Table-2 cost model.

Pure logic — no jax devices required.
"""

import pytest

from repro.core import ops as ops_mod
from repro.core.boxing import nd_transition_cost, transition_cost
from repro.core.placement import Placement
from repro.core.sbp import B, Broadcast, P, Partial, Sbp, Split, ndsbp


class TestSbpTypes:
    def test_parse_components(self):
        assert Sbp.parse("S(0)") == Split(0)
        assert Sbp.parse("S(3)") == Split(3)
        assert Sbp.parse("B") == Broadcast()
        assert Sbp.parse("P") == Partial("sum")
        assert Sbp.parse("P(max)") == Partial("max")

    def test_parse_nd(self):
        nd = ndsbp("S(0), B")
        assert nd.components == (Split(0), Broadcast())
        nd = ndsbp("(S(0), S(1), P(sum))")
        assert nd.components == (Split(0), Split(1), Partial("sum"))

    def test_invalid(self):
        with pytest.raises(ValueError):
            Sbp.parse("Q(1)")
        with pytest.raises(ValueError):
            Partial("mean")
        with pytest.raises(ValueError):
            Split(-1)

    def test_local_shape(self):
        nd = ndsbp("S(0), S(1)")
        assert nd.local_shape((8, 16), (2, 4)) == (4, 4)
        nd = ndsbp("S(0), S(0)")          # two axes split the same dim
        assert nd.local_shape((8, 16), (2, 4)) == (1, 16)
        nd = ndsbp("B, P")
        assert nd.local_shape((8, 16), (2, 4)) == (8, 16)

    def test_validate_rejects_uneven(self):
        with pytest.raises(ValueError):
            ndsbp("S(0), B").validate_for_shape((7, 3), (2, 4))
        with pytest.raises(ValueError):
            ndsbp("S(2), B").validate_for_shape((8, 8), (2, 4))

    def test_num_replicas(self):
        assert ndsbp("B, B").num_replicas((2, 4)) == 8
        assert ndsbp("S(0), B").num_replicas((2, 4)) == 4
        assert ndsbp("S(0), S(1)").num_replicas((2, 4)) == 1


class TestTable2Cost:
    """Table 2 of the paper, entry by entry (same-device column)."""

    T = 1024.0
    p = 4

    def c(self, a, b, disjoint=False, p2=None):
        return transition_cost(Sbp.parse(a), Sbp.parse(b), self.T, self.p,
                               p2=p2, disjoint=disjoint)

    def test_same_set(self):
        assert self.c("S(0)", "S(0)").volume == 0
        r = self.c("S(0)", "S(1)")
        assert r.volume == (self.p - 1) / self.p * self.T
        assert r.primitive == "all_to_all"
        r = self.c("S(0)", "B")
        assert r.volume == (self.p - 1) * self.T and r.primitive == "all_gather"
        assert self.c("S(0)", "P").volume == 0
        assert self.c("B", "S(1)").volume == 0
        assert self.c("B", "B").volume == 0
        assert self.c("B", "P").volume == 0
        r = self.c("P", "S(0)")
        assert r.volume == (self.p - 1) * self.T and r.primitive == "reduce_scatter"
        r = self.c("P", "B")
        assert r.volume == 2 * (self.p - 1) * self.T and r.primitive == "all_reduce"
        assert self.c("P", "P").volume == 0

    def test_disjoint_set(self):
        p2 = 8
        assert self.c("S(0)", "S(0)", True, p2).volume == self.T
        assert self.c("S(0)", "S(1)", True, p2).volume == self.T
        assert self.c("S(0)", "B", True, p2).volume == p2 * self.T
        assert self.c("S(0)", "P", True, p2).volume == self.T
        assert self.c("B", "S(0)", True, p2).volume == self.T
        assert self.c("B", "B", True, p2).volume == p2 * self.T
        assert self.c("B", "P", True, p2).volume == self.T
        assert self.c("P", "S(0)", True, p2).volume == self.p * self.T
        assert self.c("P", "B", True, p2).volume == (self.p + p2 - 1) * self.T
        assert self.c("P", "P", True, p2).volume == self.p * self.T

    def test_nd_cost_identity_free(self):
        assert nd_transition_cost(ndsbp("S(0),B"), ndsbp("S(0),B"), self.T,
                                  (2, 4)) == 0

    def test_nd_cost_single_axis(self):
        # only the model axis changes: S->B all_gather over groups of 4,
        # tensor already split in half on data axis -> per-group T/2
        got = nd_transition_cost(ndsbp("S(0),S(1)"), ndsbp("S(0),B"),
                                 self.T, (2, 4))
        assert got == (4 - 1) * self.T / 2


class TestDeduction:
    def test_matmul_table1(self):
        """Table 1, all six rows, via the op registry."""
        spec = ops_mod.OpSpec(ops_mod.get("matmul"))
        rows = {(repr(r.ins[0]), repr(r.ins[1])): repr(r.out)
                for r in spec.rules()}
        assert rows[("S(0)", "B")] == "S(0)"
        assert rows[("B", "S(1)")] == "S(1)"
        assert rows[("S(1)", "S(0)")] == "P(sum)"
        assert rows[("P(sum)", "B")] == "P(sum)"
        assert rows[("B", "P(sum)")] == "P(sum)"
        assert rows[("B", "B")] == "B"

    def test_matmul_table3_2d(self):
        """Table 3: 2-D signatures arise as per-axis products of Table 1."""
        spec = ops_mod.OpSpec(ops_mod.get("matmul"))
        sigs = {(repr(i[0]), repr(i[1])): repr(o)
                for i, o, _ in spec.nd_signatures(2)}
        assert sigs[("(S(0), B)", "(B, S(1))")] == "(S(0), S(1))"
        assert sigs[("(S(0), S(1))", "(B, S(0))")] == "(S(0), P(sum))"

    def test_bias_add_excludes_partial(self):
        spec = ops_mod.OpSpec(ops_mod.get("bias_add"))
        for r in spec.rules():
            assert not r.ins[0].is_partial, "P+B bias would double-apply bias"

    def test_partial_through_linear_only(self):
        lin = ops_mod.OpSpec(ops_mod.get("ew_unary"), {"ndim": 2, "linear": True})
        non = ops_mod.OpSpec(ops_mod.get("ew_unary"), {"ndim": 2, "linear": False})
        assert any(r.ins[0].is_partial for r in lin.rules())
        assert not any(r.ins[0].is_partial for r in non.rules())


class TestPlacement:
    def test_partition_spec(self):
        from jax.sharding import PartitionSpec

        pl = Placement(("data", "model"), (2, 4))
        assert pl.partition_spec(ndsbp("S(0),B")) == PartitionSpec("data")
        assert pl.partition_spec(ndsbp("S(1),S(0)")) == PartitionSpec(
            "model", "data")
        assert pl.partition_spec(ndsbp("S(0),S(0)")) == PartitionSpec(
            ("data", "model"))
        assert pl.partition_spec(ndsbp("B,B")) == PartitionSpec()
        with pytest.raises(ValueError):
            pl.partition_spec(ndsbp("P,B"))
