"""Multi-device suite: 1F1B *training* with stage meshes on disjoint devices.

The forward-only suite (suite_actor_pipeline.py) covers inference pipelines;
this one runs the full fwd/bwd/opt training pipeline with each stage lowered
onto its own device group (the paper's MPMD placement):

* part 1 — data-parallel stages: 4 stages x 2 disjoint devices each (8
  total), SGD, checked against the monolithic step on a single 2-device
  mesh. Cotangents cross stage-mesh boundaries via the explicit
  cot_shardings transfers.
* part 2 — stateful AdamW with global-norm clipping: the acc actors' P
  squared-norm partials live on *disjoint* meshes and the norm actor's
  host-side P→B combine must still produce one global clip scale; optimizer
  state persists across steps on each stage's devices.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[2] / "src"))

import numpy as np

import jax

from repro.core.graph import LogicalGraph, partition_stages
from repro.core.lowering import OptimizerSpec, lower_train_stages
from repro.core.placement import Placement
from repro.core.planner import plan
from repro.runtime import TrainPipelineExecutor
from repro.train.steps import make_graph_train_step

STAGES, MICROBATCHES, BATCH, WIDTH = 4, 4, 16, 32


def _graph(placement):
    g = LogicalGraph(placement)
    h = g.input("x", (BATCH, WIDTH), sbp="S(0)")
    labels = g.input("labels", (BATCH,), dtype="int32", sbp="S(0)")
    for i in range(STAGES):
        w = g.input(f"w{i}", (WIDTH, WIDTH))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < STAGES - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")
    return g


def _setup(optimizer=None):
    placement = Placement(("data",), (2,), device_kind="cpu")
    g = _graph(placement)
    p = plan(g)
    part = partition_stages(g, num_stages=STAGES)
    devs = jax.devices()
    assert len(devs) >= 2 * STAGES
    stage_meshes = [placement.to_mesh(devices=devs[2 * s:2 * s + 2])
                    for s in range(STAGES)]
    tstaged = lower_train_stages(g, p, part,
                                 [f"w{i}" for i in range(STAGES)],
                                 stage_meshes=stage_meshes,
                                 optimizer=optimizer)
    rng = np.random.default_rng(5)
    params = {f"w{i}": (rng.normal(size=(WIDTH, WIDTH)) * 0.5
                        ).astype(np.float32) for i in range(STAGES)}
    data = {"x": rng.normal(size=(BATCH, WIDTH)).astype(np.float32),
            "labels": rng.integers(0, WIDTH, (BATCH,)).astype(np.int32)}
    mono = make_graph_train_step(g, placement.to_mesh(devices=devs[:2]),
                                 list(params), ["x", "labels"],
                                 MICROBATCHES, optimizer=optimizer)
    return tstaged, params, data, mono


def sgd_disjoint_meshes():
    tstaged, params, data, mono = _setup()
    pipe = TrainPipelineExecutor(tstaged, dict(params), ["x", "labels"],
                                 MICROBATCHES)
    mono_params = dict(params)
    for step in range(3):
        ml, mg, mono_params = mono.step(mono_params, data)
        pl, pg, pipe_params = pipe.step(data)
        assert np.allclose(float(pl), float(ml), rtol=1e-5), step
        for n in params:
            assert np.allclose(np.asarray(pg[n]), np.asarray(mg[n]),
                               rtol=1e-4, atol=1e-5), (step, n)
            assert np.allclose(np.asarray(pipe_params[n]),
                               np.asarray(mono_params[n]),
                               rtol=1e-4, atol=1e-5), (step, n)
    quota = [max(1, STAGES - s) for s in range(STAGES)]
    assert pipe.peak_inflight_activations <= max(quota)


def adamw_clip_disjoint_meshes():
    opt = OptimizerSpec.adamw(lr=lambda s: 1e-3 * (0.5 ** s), grad_clip=0.5)
    tstaged, params, data, mono = _setup(optimizer=opt)
    pipe = TrainPipelineExecutor(tstaged, dict(params), ["x", "labels"],
                                 MICROBATCHES)
    mono_params = dict(params)
    for step in range(3):
        ml, mg, mono_params = mono.step(mono_params, data)
        pl, pg, pipe_params = pipe.step(data)
        assert np.allclose(float(pl), float(ml), rtol=1e-5), step
        # clipping engaged, norm agreed across disjoint meshes
        assert float(pipe.last_grad_norm) > opt.grad_clip
        assert np.allclose(float(pipe.last_grad_norm),
                           float(mono.last_grad_norm), rtol=1e-5)
        for n in params:
            assert np.allclose(np.asarray(pg[n]), np.asarray(mg[n]),
                               rtol=1e-4, atol=1e-6), (step, n)
            assert np.allclose(np.asarray(pipe_params[n]),
                               np.asarray(mono_params[n]),
                               rtol=1e-4, atol=1e-6), (step, n)
        assert int(pipe.opt_state.step) == step + 1
        assert len(pipe.last_history["norm"]) == 1
    ps, ms = pipe.opt_state, mono.opt_state
    for n in params:
        assert np.allclose(np.asarray(ps.mu[n]), np.asarray(ms.mu[n]),
                           rtol=1e-4, atol=1e-7), n
        assert np.allclose(np.asarray(ps.nu[n]), np.asarray(ms.nu[n]),
                           rtol=1e-4, atol=1e-9), n


if __name__ == "__main__":
    sgd_disjoint_meshes()
    adamw_clip_disjoint_meshes()
    print("ALL-OK")
