"""Multi-device suite: stage-partitioned actor execution on a (2,2) mesh.

Runs a planner-sharded MLP (data x model parallel inside every stage) both
monolithically and as an actor-driven pipeline of independently lowered
stages, and checks the results agree. Boundary tensors planned as
partial-value are materialized by the stage-exit boxing — this is the path a
single-device test cannot reach.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[2] / "src"))

import numpy as np

from repro.core.graph import LogicalGraph, partition_stages
from repro.core.lowering import lower_plan, lower_stages
from repro.core.placement import Placement
from repro.core.planner import plan
from repro.runtime import ActorPipelineExecutor


def main():
    placement = Placement(("data", "model"), (2, 2), device_kind="cpu")
    g = LogicalGraph(placement)
    x = g.input("x", (32, 64), sbp="S(0),B")
    w0 = g.input("w0", (64, 128))
    w1 = g.input("w1", (128, 64))
    w2 = g.input("w2", (64, 64))
    h = g.matmul(x, w0, name="mm0")
    h = g.unary(h, "relu", name="relu0")
    h = g.matmul(h, w1, name="mm1")
    h = g.unary(h, "relu", name="relu1")
    h = g.matmul(h, w2, name="mm2")
    p = plan(g)
    mesh = placement.to_mesh()
    part = partition_stages(g, num_stages=2)
    print(part.describe(g))

    mono = lower_plan(g, p, mesh)
    staged = lower_stages(g, p, part, mesh=mesh)

    rng = np.random.default_rng(7)
    inputs = {t.name: rng.normal(size=t.shape).astype(np.float32)
              for t in g.inputs}
    args = [inputs[t.name] for t in g.inputs]

    ref = [np.asarray(v) for v in mono(*args)]
    seq = [np.asarray(v) for v in staged(*args)]
    assert all(np.allclose(r, s, rtol=1e-5, atol=1e-5)
               for r, s in zip(ref, seq)), "staged != monolithic"

    ex = ActorPipelineExecutor(staged, ["x"], num_microbatches=4)
    got = ex.run(inputs)
    # actor run microbatches the batch axis; compare against per-microbatch
    # monolithic execution (bitwise) and the full batch (allclose)
    chunks = np.split(inputs["x"], 4, axis=0)
    per_mb = np.concatenate(
        [np.asarray(mono(c, *args[1:])[0]) for c in chunks], axis=0)
    assert np.array_equal(got[0], per_mb), "actor pipeline != per-microbatch"
    assert np.allclose(got[0], ref[0], rtol=1e-4, atol=1e-4)


def partial_boundary():
    """A stage boundary tensor stored as partial-value: the stage-exit boxing
    materializes it (P -> B psum). The monolithic program instead defers the
    reduction through the next matmul (§3.3), so results agree only to fp32
    reduction-order tolerance."""
    placement = Placement(("model",), (4,), device_kind="cpu")
    g = LogicalGraph(placement)
    x = g.input("x", (16, 64), sbp="B")
    w0 = g.input("w0", (64, 64), sbp="S(0)")  # contraction split -> P output
    w1 = g.input("w1", (64, 32))
    with g.stage(0):
        h = g.matmul(x, w0, name="mm0")
    h.pin("P")
    with g.stage(1):
        g.matmul(h, w1, name="mm1")
    p = plan(g)
    assert p.tensor_sbp["mm0.out"].has_partial
    mesh = placement.to_mesh()
    part = partition_stages(g)
    mono = lower_plan(g, p, mesh)
    staged = lower_stages(g, p, part, mesh=mesh)
    assert not staged.boundary_sbp["mm0.out"].has_partial

    rng = np.random.default_rng(3)
    inputs = {t.name: rng.normal(size=t.shape).astype(np.float32)
              for t in g.inputs}
    args = [inputs[t.name] for t in g.inputs]
    ref = np.asarray(mono(*args)[0])
    seq = np.asarray(staged(*args)[0])
    npref = (inputs["x"] @ inputs["w0"]) @ inputs["w1"]
    assert np.allclose(seq, npref, rtol=1e-4, atol=1e-4)
    assert np.allclose(seq, ref, rtol=1e-3, atol=1e-3)
    ex = ActorPipelineExecutor(staged, ["x"], num_microbatches=2)
    got = ex.run(inputs)
    assert np.allclose(got[0], npref, rtol=1e-4, atol=1e-4)


if __name__ == "__main__":
    main()
    partial_boundary()
    print("ALL-OK")
