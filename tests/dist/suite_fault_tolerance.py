"""Multi-device suite: elastic kill-and-resume across stage partitions.

The elastic-training claim of the snapshot format: a snapshot is the flat
logical state (params + merged Adam moments + step counter), not a record
of the partition that wrote it. So a 4-stage run on 8 devices (2 per stage,
the paper's MPMD placement) that is killed by fault injection mid-training
must resume — from its own per-stage snapshot files — onto a *2-stage*
partition over different device groups, and finish the trajectory the
uninterrupted reference follows.

Kill mechanics are the threads runtime here (the processes runtime is
covered by tests/test_fault_tolerance.py; worker processes cannot share
the forced 8-device host platform of this suite cleanly).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[2] / "src"))

import numpy as np

import jax

from repro import api
from repro.core.graph import LogicalGraph
from repro.core.lowering import OptimizerSpec
from repro.core.placement import Placement
from repro.runtime import (FaultPlan, KillWorker, WorkerError,
                           latest_snapshot)

STAGES, MICROBATCHES, BATCH, WIDTH, STEPS = 4, 4, 16, 32, 3


def _graph(placement):
    g = LogicalGraph(placement)
    h = g.input("x", (BATCH, WIDTH), sbp="S(0)")
    labels = g.input("labels", (BATCH,), dtype="int32", sbp="S(0)")
    for i in range(STAGES):
        w = g.input(f"w{i}", (WIDTH, WIDTH))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < STAGES - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")
    return g


def _opt():
    return OptimizerSpec.adamw(lr=lambda s: 1e-3 * (0.5 ** s),
                               grad_clip=0.5)


def elastic_kill_and_resume():
    placement = Placement(("data",), (2,), device_kind="cpu")
    devs = jax.devices()
    assert len(devs) >= 8
    rng = np.random.default_rng(5)
    params = {f"w{i}": (rng.normal(size=(WIDTH, WIDTH)) * 0.5
                        ).astype(np.float32) for i in range(STAGES)}
    data = {"x": rng.normal(size=(BATCH, WIDTH)).astype(np.float32),
            "labels": rng.integers(0, WIDTH, (BATCH,)).astype(np.int32)}

    ref = api.compile(_graph(placement), mode="train", backend="monolithic",
                      params=dict(params), optimizer=_opt(),
                      num_microbatches=MICROBATCHES,
                      mesh=placement.to_mesh(devices=devs[:2]))
    ref_losses = [float(ref.step(**data).loss) for _ in range(STEPS)]

    with tempfile.TemporaryDirectory() as d:
        # 4 stages x 2 disjoint devices each, async snapshots every step,
        # f2's worker killed during step 2 (fire MICROBATCHES + 1)
        meshes4 = [placement.to_mesh(devices=devs[2 * s:2 * s + 2])
                   for s in range(STAGES)]
        sess = api.compile(
            _graph(placement), mode="train", stages=STAGES,
            params=dict(params), optimizer=_opt(),
            num_microbatches=MICROBATCHES, stage_meshes=meshes4,
            snapshot_dir=d,
            faults=FaultPlan([KillWorker("f2", fire=MICROBATCHES + 1)]))
        losses = []
        try:
            for _ in range(STEPS):
                losses.append(float(sess.step(**data).loss))
            raise AssertionError("kill never triggered")
        except WorkerError:
            pass
        finally:
            sess.close()
        n = latest_snapshot(d)
        assert n == len(losses) == 1, (n, losses)

        # resume the SAME trajectory on a different partition: 2 stages
        # over different 4-device groups
        meshes2 = [placement.to_mesh(devices=devs[0:4:2]),
                   placement.to_mesh(devices=devs[4:8:2])]
        res = api.compile(
            _graph(placement), mode="train", stages=2,
            params=dict(params), optimizer=_opt(),
            num_microbatches=MICROBATCHES, stage_meshes=meshes2,
            restore=d)
        assert res.step_count == n
        assert int(res.opt_state.step) == n
        losses += [float(res.step(**data).loss) for _ in range(STEPS - n)]
        final_params, opt_state = res.params, res.opt_state
        res.close()

    for got, want in zip(losses, ref_losses):
        assert np.allclose(got, want, rtol=1e-5), (losses, ref_losses)
    rs = ref.opt_state
    assert int(opt_state.step) == int(rs.step) == STEPS
    for nme in params:
        assert np.allclose(np.asarray(final_params[nme]),
                           np.asarray(ref.params[nme]),
                           rtol=1e-4, atol=1e-6), nme
        assert np.allclose(np.asarray(opt_state.mu[nme]),
                           np.asarray(rs.mu[nme]),
                           rtol=1e-4, atol=1e-7), nme


def zero_mixed_precision_kill_and_resume():
    """zero=True precision='bf16' at a real DP=2: the opt actors hold flat
    ``(2, 1, chunk)`` fp32 master/moment shards; a 4-stage run killed
    mid-step resumes from the sharded snapshot onto a 2-stage cut and
    finishes the uninterrupted monolithic trajectory."""
    placement = Placement(("data",), (2,), device_kind="cpu")
    devs = jax.devices()
    assert len(devs) >= 8
    rng = np.random.default_rng(9)
    params = {f"w{i}": (rng.normal(size=(WIDTH, WIDTH)) * 0.5
                        ).astype(np.float32) for i in range(STAGES)}
    data = {"x": rng.normal(size=(BATCH, WIDTH)).astype(np.float32),
            "labels": rng.integers(0, WIDTH, (BATCH,)).astype(np.int32)}
    kw = dict(mode="train", params=dict(params), optimizer=_opt(),
              num_microbatches=MICROBATCHES, zero=True, precision="bf16",
              loss_scale=2.0 ** 10)

    ref = api.compile(_graph(placement), backend="monolithic",
                      mesh=placement.to_mesh(devices=devs[:2]), **kw)
    ref_losses = [float(ref.step(**data).loss) for _ in range(STEPS)]
    assert ref.optimizer.zero_dp == 2     # the data axis folded into ZeRO

    with tempfile.TemporaryDirectory() as d:
        meshes4 = [placement.to_mesh(devices=devs[2 * s:2 * s + 2])
                   for s in range(STAGES)]
        sess = api.compile(
            _graph(placement), stages=STAGES, stage_meshes=meshes4,
            snapshot_dir=d,
            faults=FaultPlan([KillWorker("opt2", fire=2)]), **kw)
        losses = []
        try:
            for _ in range(STEPS):
                losses.append(float(sess.step(**data).loss))
            raise AssertionError("kill never triggered")
        except WorkerError:
            pass
        finally:
            sess.close()
        n = latest_snapshot(d)
        assert n == len(losses) == 1, (n, losses)

        meshes2 = [placement.to_mesh(devices=devs[0:4:2]),
                   placement.to_mesh(devices=devs[4:8:2])]
        res = api.compile(_graph(placement), stages=2,
                          stage_meshes=meshes2, restore=d, **kw)
        assert res.step_count == n
        assert int(res.opt_state.step) == n
        losses += [float(res.step(**data).loss) for _ in range(STEPS - n)]
        final_params, opt_state = res.params, res.opt_state
        res.close()

    for got, want in zip(losses, ref_losses):
        assert np.allclose(got, want, rtol=1e-5), (losses, ref_losses)
    rs = ref.opt_state
    assert int(opt_state.step) == int(rs.step) == STEPS
    for nme in params:
        # masters and moments surface fp32 at logical shapes
        assert np.asarray(final_params[nme]).dtype == np.float32
        assert np.allclose(np.asarray(final_params[nme]),
                           np.asarray(ref.params[nme]),
                           rtol=1e-4, atol=1e-6), nme
        assert np.allclose(np.asarray(opt_state.mu[nme]),
                           np.asarray(rs.mu[nme]),
                           rtol=1e-4, atol=1e-7), nme


if __name__ == "__main__":
    elastic_kill_and_resume()
    zero_mixed_precision_kill_and_resume()
    print("ALL-OK")
